"""Checkpoint manager: atomicity, crash recovery, deterministic resume."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_smoke_config
from repro.data.pipeline import make_batch_fn
from repro.models import model as M
from repro.train import optimizer as O
from repro.train.train_loop import make_train_step


def _tree_equal(a, b):
    return all(bool(jnp.array_equal(x, y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_roundtrip_bitwise(tmp_path):
    cfg = get_smoke_config("smollm-360m")
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    opt = O.OptimizerConfig()
    opt_state = O.init_opt_state(params, opt)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(10, params, opt_state)
    like = {"params": params, "opt_state": opt_state}
    restored, step = mgr.restore(like)
    assert step == 10
    assert _tree_equal(restored["params"], params)
    assert _tree_equal(restored["opt_state"], opt_state)


def test_bf16_leaves_roundtrip(tmp_path):
    tree = {"w": jnp.arange(16, dtype=jnp.bfloat16) * 0.1}
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    restored, _ = mgr.restore({"params": tree, "opt_state": None})
    assert restored["params"]["w"].dtype == jnp.bfloat16
    assert _tree_equal(restored["params"], tree)


def test_torn_write_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.ones(4)}
    mgr.save(1, tree)
    # simulate a crash mid-save of step 2: tmp dir exists, no manifest move
    os.makedirs(tmp_path / "step_00000002.tmp")
    (tmp_path / "step_00000002.tmp" / "junk").write_text("partial")
    # and a LATEST pointing at a checkpoint that never completed
    (tmp_path / "LATEST").write_text("2")
    mgr2 = CheckpointManager(str(tmp_path))
    assert mgr2.latest_step() == 1     # falls back to newest valid
    restored, step = mgr2.restore({"params": tree, "opt_state": None})
    assert step == 1


def test_gc_keeps_recent(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.ones(2)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    names = sorted(p for p in os.listdir(tmp_path) if p.startswith("step_"))
    assert names == ["step_00000003", "step_00000004"]


def test_restart_resumes_identically(tmp_path):
    """Kill-and-restart produces bitwise the same params as an uninterrupted
    run: the fault-tolerance contract (checkpoint + step-indexed data)."""
    cfg = get_smoke_config("smollm-360m")
    opt = O.OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=8)
    batch_fn = make_batch_fn(cfg, seq_len=32, global_batch=4)
    step_fn = jax.jit(make_train_step(cfg, opt))

    def fresh():
        p = M.init_model(jax.random.PRNGKey(0), cfg)
        return p, O.init_opt_state(p, opt)

    # uninterrupted: 6 steps
    p_a, s_a = fresh()
    for i in range(6):
        p_a, s_a, _ = step_fn(p_a, s_a, batch_fn(i))

    # interrupted: 3 steps, checkpoint, "crash", restore, 3 more
    p_b, s_b = fresh()
    for i in range(3):
        p_b, s_b, _ = step_fn(p_b, s_b, batch_fn(i))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, p_b, s_b)
    del p_b, s_b
    like = {"params": fresh()[0], "opt_state": fresh()[1]}
    restored, start = mgr.restore(like)
    p_c, s_c = restored["params"], restored["opt_state"]
    for i in range(start, 6):
        p_c, s_c, _ = step_fn(p_c, s_c, batch_fn(i))

    for x, y in zip(jax.tree.leaves(p_a), jax.tree.leaves(p_c)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_elastic_restore_respec(tmp_path):
    """A checkpoint restores under a different sharding spec (elastic
    rescale): here single-device respec, the mesh path is exercised in
    test_sharding.py's subprocess."""
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, tree)
    shard = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    restored, _ = mgr.restore({"params": tree, "opt_state": None},
                              shardings={"params": {"w": shard},
                                         "opt_state": None})
    assert _tree_equal(restored["params"], tree)
