"""The unified SPU operator API: registry dispatch, capability negotiation,
traffic descriptors as the single byte-count source, and the deprecation
shims over the pre-registry entry points."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops as OPS
from repro.core import attention_cache as AC
from repro.core import formats as F
from repro.ops.base import SpuDeprecationWarning


# ---------------------------------------------------------------------------
# registry / capability negotiation
# ---------------------------------------------------------------------------

def test_registry_covers_all_kinds_and_formats():
    quads = OPS.registered()
    kinds = {k for k, _, _, _ in quads}
    assert kinds == set(OPS.OP_KINDS)
    assert {lo for _, _, _, lo in quads} == set(OPS.LAYOUTS)
    # jnp covers every storage format for every kind, in both layouts
    for kind in OPS.OP_KINDS:
        for fmt in ("mx8", "int8", "fp8_e4m3", "fp8_e5m2", "fp32", "bf16",
                    "fp16"):
            for layout in OPS.LAYOUTS:
                assert OPS.supports(kind, fmt, "jnp", layout), \
                    (kind, fmt, layout)
    # the fused pallas kernels exist exactly for MX8 compute ops
    assert OPS.supports("state_update", "mx8", "pallas")
    assert OPS.supports("attn_decode", "mx8", "pallas")
    assert OPS.supports("mla_decode", "mx8", "pallas")
    assert not OPS.supports("state_update", "fp16", "pallas")
    # ... and their paged twins, plus the in-place paged kv_append (dense
    # kv_append stays jnp-only: it is an XLA scatter, not an SPU compute op)
    assert OPS.supports("attn_decode", "mx8", "pallas", "paged")
    assert OPS.supports("mla_decode", "mx8", "pallas", "paged")
    assert OPS.supports("state_update", "mx8", "pallas", "paged")
    assert OPS.supports("kv_append", "mx8", "pallas", "paged")
    assert not OPS.supports("kv_append", "mx8", "pallas", "dense")


def test_resolve_backend_negotiation():
    # auto prefers pallas where registered, else jnp
    assert OPS.resolve_backend("state_update", "mx8") == "pallas"
    assert OPS.resolve_backend("state_update", "int8") == "jnp"
    # explicit capable request is honored
    assert OPS.resolve_backend("state_update", "mx8", "jnp") == "jnp"
    # incapable request: non-strict falls back (historical heuristic) ...
    assert OPS.resolve_backend("state_update", "fp16", "pallas") == "jnp"
    # ... strict errors, and the error names the registered capability set
    with pytest.raises(ValueError, match="not registered"):
        OPS.resolve_backend("state_update", "fp16", "pallas", strict=True)
    with pytest.raises(ValueError, match="no backend registered"):
        OPS.resolve_backend("state_update", "fp4_imaginary")


def test_get_op_unknown_triple_lists_registry():
    with pytest.raises(KeyError, match="registered ops"):
        OPS.get_op("attn_decode", "pallas", "fp32")


def test_serve_backend_flag_errors_clearly():
    """--backend pallas with a non-mx8 format must fail up front."""
    from repro.launch.serve import main
    with pytest.raises(SystemExit, match="not registered"):
        main(["--arch", "mamba2-2.7b", "--smoke-size", "--requests", "1",
              "--state-format", "fp16", "--backend", "pallas"])


# ---------------------------------------------------------------------------
# traffic descriptors
# ---------------------------------------------------------------------------

def test_state_update_traffic_matches_format_bits():
    B, H, dk, dv = 4, 8, 128, 64
    for fmt, bpv in (("fp16", 2.0), ("int8", 1.0625), ("mx8", 1.0)):
        cfg = OPS.StateQuantConfig(fmt=fmt, rounding="nearest", backend="jnp")
        t = OPS.traffic(OPS.plan_state_update_dims(B, H, dk, dv, cfg))
        assert t.state_read == pytest.approx(B * H * dk * dv * bpv)
        assert t.state_write == pytest.approx(t.state_read)
        assert t.total > t.state_total > 0


def test_attn_decode_traffic_scales_with_cache():
    cfg = OPS.StateQuantConfig(fmt="mx8", rounding="nearest", backend="jnp")
    dims = dict(B=2, T=256, KVH=4, dk=64, dv=64, n=1, H=8)
    t1 = OPS.traffic(OPS.plan_attn_decode_dims("attn_decode", dims, cfg))
    dims2 = dict(dims, T=512)
    t2 = OPS.traffic(OPS.plan_attn_decode_dims("attn_decode", dims2, cfg))
    assert t2.state_read == pytest.approx(2 * t1.state_read)
    assert t1.state_read == pytest.approx(2 * 256 * 4 * (64 + 64) * 1.0)


def test_pimsim_bytes_sourced_from_op_traffic():
    """The timing model's workload bytes ARE the registered op's traffic."""
    from repro.core import pimsim as PS
    w = PS.StateWorkload(8, 4, 2, 64, 32, "mx8")
    t = OPS.traffic(w.plan)
    assert w.state_bytes == pytest.approx(w.n_layers * t.state_read)


def test_roofline_bytes_sourced_from_op_traffic():
    import dataclasses
    from repro.analysis import roofline as RL
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("zamba2-2.7b")

    @dataclasses.dataclass
    class SC:
        global_batch: int = 4
        seq_len: int = 256

    sc = SC()
    by_kind = OPS.decode_traffic_by_kind(cfg, sc.global_batch, sc.seq_len)
    kv, state = RL._cache_state_bytes(cfg, sc)
    assert state == pytest.approx(by_kind["state_update"].state_read)
    assert kv == pytest.approx(by_kind["attn_decode"].state_read)


def test_decode_op_plans_cover_model_families():
    from repro.configs import get_smoke_config
    kinds = {e.kind for e in
             OPS.decode_op_plans(get_smoke_config("zamba2-2.7b"), 2, 128)}
    assert kinds == {"state_update", "attn_decode", "kv_append"}
    kinds = {e.kind for e in
             OPS.decode_op_plans(get_smoke_config("deepseek-v2-236b"), 2, 128)}
    assert kinds == {"mla_decode", "kv_append"}


# ---------------------------------------------------------------------------
# deprecation shims (external scripts keep working, bit-identically)
# ---------------------------------------------------------------------------

def _su_inputs(B=2, H=2, dk=32, dv=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    S0 = jax.random.normal(ks[0], (B, H, dv, dk))
    d = jax.nn.sigmoid(jax.random.normal(ks[1], (B, H, dk)))
    k = jax.random.normal(ks[2], (B, H, dk))
    v = jax.random.normal(ks[3], (B, H, dv))
    q = jax.random.normal(ks[4], (B, H, dk))
    return F.mx8_quantize(S0), d, k, v, q


def test_kernels_ops_state_update_shim():
    from repro.kernels import ops as KOPS
    qS, d, k, v, q = _su_inputs()
    cfg = OPS.StateQuantConfig(fmt="mx8", rounding="stochastic",
                               backend="pallas")
    Sn, y = OPS.state_update_step(qS, d, k, v, q, cfg, seed=3)
    with pytest.warns(SpuDeprecationWarning):
        Sn2, y2 = KOPS.state_update(qS, d, k, v, q, 3)
    for f in ("mantissa", "exponent", "micro"):
        assert jnp.array_equal(Sn.payload[f], Sn2.payload[f]), f
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y2))


def test_core_state_update_step_shim():
    from repro.core import state_update as SU
    qS, d, k, v, q = _su_inputs(seed=1)
    cfg = SU.StateQuantConfig(fmt="mx8", rounding="stochastic", backend="jnp")
    Sn, y = OPS.state_update_step(qS, d, k, v, q, cfg, seed=7)
    with pytest.warns(SpuDeprecationWarning):
        Sn2, y2 = SU.state_update_step(qS, d, k, v, q, cfg, seed=7)
    for f in ("mantissa", "exponent", "micro"):
        assert jnp.array_equal(Sn.payload[f], Sn2.payload[f]), f
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y2))


def test_kernels_ops_attention_decode_shim():
    from repro.kernels import ops as KOPS
    B, H, KVH, dh, T = 2, 4, 2, 32, 128
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, H, dh))
    K = jax.random.normal(ks[1], (B, T, KVH, dh))
    V = jax.random.normal(ks[2], (B, T, KVH, dh))
    qK, qV = F.mx8_quantize(K), F.mx8_quantize(V)
    lengths = jnp.array([100, 64], jnp.int32)
    cache = AC.KVCache(qK, qV, lengths, "mx8")
    cfg = OPS.StateQuantConfig(fmt="mx8", rounding="nearest", backend="pallas")
    y = OPS.attn_decode(cache, q, cfg)
    with pytest.warns(SpuDeprecationWarning):
        y2 = KOPS.attention_decode(q, qK, qV, lengths)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y2))


def test_shim_modules_import_without_warning():
    with warnings.catch_warnings():
        warnings.simplefilter("error", SpuDeprecationWarning)
        import importlib
        import repro.core.state_update
        import repro.kernels.ops
        importlib.reload(repro.kernels.ops)
        importlib.reload(repro.core.state_update)
        # config-object re-exports stay silent too
        repro.core.state_update.StateQuantConfig(fmt="fp32")


# ---------------------------------------------------------------------------
# unified entry point: GQA + MLA decode through one op step
# ---------------------------------------------------------------------------

def test_attention_decode_step_unifies_gqa_and_mla():
    cfg = OPS.StateQuantConfig(fmt="mx8", rounding="stochastic",
                               backend="pallas")
    B, KVH, dh, T = 2, 2, 32, 128
    cache = AC.init_kv_cache(B, T, KVH, dh, cfg)
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    kv = jax.random.normal(ks[0], (B, 1, KVH, dh))
    q = jax.random.normal(ks[1], (B, 4, dh))
    out, cache = OPS.attention_decode_step(cache, kv, kv, q, cfg, seed=0)
    assert out.shape == (B, 4, dh)
    assert int(cache.lengths[0]) == 1
    # MLA: latent-only cache; v_width routes to the mla_decode op
    mla_cache = AC.init_kv_cache(B, T, 1, 96, cfg, mla_v_width=64)
    ckv = jax.random.normal(ks[2], (B, 1, 1, 96))
    qm = jax.random.normal(ks[1], (B, 4, 96))
    out_m, mla_cache = OPS.attention_decode_step(mla_cache, ckv, None, qm,
                                                 cfg, scale=0.1, seed=0)
    assert out_m.shape == (B, 4, 64)
    assert OPS.attn_kind_of(mla_cache) == "mla_decode"


# ---------------------------------------------------------------------------
# registry contract checker (repro.analysis.lint pass 3)
# ---------------------------------------------------------------------------

def test_registry_satisfies_lint_contracts():
    """Every registered quadruple passes the RC3xx contract checker: protocol
    overrides, sane non-negative traffic, page-granular paged state streams,
    a jnp twin per pallas op, and decode_op_plans coverage of every config.
    An op registered with an inconsistent traffic descriptor fails tier-1
    here, not just the lint CLI."""
    from repro.analysis.lint.contracts import lint_registry_contracts
    findings = lint_registry_contracts()
    assert findings == [], "\n".join(f.render() for f in findings)
