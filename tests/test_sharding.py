"""Distribution correctness on a small host-device mesh (subprocess: these
tests need 8 CPU devices, while the rest of the suite must see 1)."""
import subprocess
import sys
import textwrap

from conftest import subprocess_env

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.mesh import make_local_parallel
from repro.dist import sharding as SH
from repro.configs import get_smoke_config
from repro.models import model as M
from repro.train import optimizer as O
from repro.train.train_loop import make_train_step
from repro.data.pipeline import make_batch_fn
"""


def _run(body: str) -> str:
    code = _PRELUDE + textwrap.dedent(body)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=900, env=subprocess_env())
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def test_sharded_train_matches_single_device():
    out = _run("""
    cfg = get_smoke_config('llama3.2-1b')
    par = make_local_parallel(data=2, model=4)
    opt = O.OptimizerConfig(lr=1e-3)
    batch_fn = make_batch_fn(cfg, seq_len=32, global_batch=4)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    opt_state = O.init_opt_state(params, opt)

    # single-device reference
    step1 = jax.jit(make_train_step(cfg, opt))
    p1, s1, m1 = step1(params, opt_state, batch_fn(0))

    # sharded
    p_shard = SH.param_shardings(params, cfg, par)
    o_shard = SH.opt_state_shardings(opt_state, p_shard, par)
    b = batch_fn(0)
    b_shard = SH.batch_shardings(b, par)
    params_s = jax.device_put(params, p_shard)
    opt_s = jax.device_put(opt_state, o_shard)
    b_s = jax.device_put(b, b_shard)
    with par.mesh:
        step2 = jax.jit(make_train_step(cfg, opt, par=par),
                        in_shardings=(p_shard, o_shard, b_shard))
        p2, s2, m2 = step2(params_s, opt_s, b_s)
    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - np.asarray(c, dtype=np.float32))))
            for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    print('LOSS', float(m1['loss']), float(m2['loss']), 'MAXDIFF', d)
    assert abs(float(m1['loss']) - float(m2['loss'])) < 1e-4
    assert d < 5e-3
    print('OK')
    """)
    assert "OK" in out


def test_moe_ep_matches_local():
    out = _run("""
    import functools
    from repro.models import layers as L
    cfg = get_smoke_config('dbrx-132b')
    par = make_local_parallel(data=2, model=4)
    key = jax.random.PRNGKey(0)
    p = L.init_moe(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
    y_local = L.apply_moe(p, x, cfg, None)
    with par.mesh:
        y_ep = jax.jit(lambda p, x: L.apply_moe(p, x, cfg, par))(p, x)
    err = float(jnp.max(jnp.abs(y_local - y_ep)))
    # capacity is per-shard under EP so token-drop patterns can differ
    # slightly; the overwhelming majority of tokens must agree exactly
    frac = float(jnp.mean(jnp.abs(y_local - y_ep) < 1e-4))
    print('ERR', err, 'AGREE', frac)
    assert frac > 0.95
    print('OK')
    """)
    assert "OK" in out


def test_decode_sharded_matches_single_device():
    out = _run("""
    from repro.models.config import SHAPES, ShapeConfig
    cfg = get_smoke_config('zamba2-2.7b')
    par = make_local_parallel(data=2, model=4)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    B, S = 4, 16
    batch = {'tokens': jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size),
             'targets': jnp.zeros((B, S), jnp.int32)}
    logits, caches = M.prefill(params, cfg, batch)
    lengths = jnp.full((B,), S, jnp.int32)
    caches = M.set_cache_lengths(caches, lengths)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    l1, _ = M.decode_step(params, cfg, tok, caches, lengths, seed=5)
    with par.mesh:
        l2, _ = jax.jit(lambda p, t, c, ln: M.decode_step(p, cfg, t, c, ln, seed=5))(
            params, tok, caches, lengths)
    print('DIFF', float(jnp.max(jnp.abs(l1 - l2))))
    assert float(jnp.max(jnp.abs(l1 - l2))) < 1e-2
    print('OK')
    """)
    assert "OK" in out


def test_gradient_compression_error_feedback():
    out = _run("""
    from repro.dist.compression import (compressed_allreduce_mean,
                                        init_error_feedback, compressed_bytes)
    mesh = jax.make_mesh((8,), ('pod',),
                         axis_types=(jax.sharding.AxisType.Auto,))
    grads = {'w': jax.random.normal(jax.random.PRNGKey(0), (8, 64, 32))}
    ef = init_error_feedback(jax.tree.map(lambda g: g[0], grads))

    def per_pod(g, e):
        return compressed_allreduce_mean(g, e, 'pod')

    f = jax.shard_map(per_pod, mesh=mesh,
                      in_specs=(P('pod'), P()), out_specs=(P(), P()),
                      check_vma=False)
    # NB: out ef differs per pod in general; with identical init it's fine
    red, ef2 = f({'w': grads['w']}, ef)
    exact = grads['w'].mean(0)
    err1 = float(jnp.max(jnp.abs(red['w'] - exact)))
    # one-step quantization error is bounded by the int8 step size
    step = float(jnp.abs(grads['w']).max()) / 127
    print('ERR', err1, 'STEP', step)
    assert err1 < 4 * step
    # error feedback: accumulated residual is carried, not lost
    assert float(jnp.max(jnp.abs(ef2['w']))) > 0
    assert compressed_bytes(ef) < ef['w'].size * 2  # beats bf16 on the wire
    print('OK')
    """)
    assert "OK" in out


def test_checkpoint_elastic_mesh_reshard(tmp_path):
    out = _run(f"""
    from repro.checkpoint.manager import CheckpointManager
    cfg = get_smoke_config('smollm-360m')
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    par_a = make_local_parallel(data=2, model=4)
    shard_a = SH.param_shardings(params, cfg, par_a)
    params_a = jax.device_put(params, shard_a)
    mgr = CheckpointManager({str(tmp_path)!r})
    mgr.save(7, params_a)
    # restore onto a DIFFERENT mesh shape (elastic rescale 2x4 -> 4x2)
    par_b = make_local_parallel(data=4, model=2)
    shard_b = SH.param_shardings(params, cfg, par_b)
    restored, step = mgr.restore({{'params': params, 'opt_state': None}},
                                 shardings={{'params': shard_b,
                                            'opt_state': None}})
    ok = all(bool(jnp.array_equal(x, y)) for x, y in
             zip(jax.tree.leaves(params), jax.tree.leaves(restored['params'])))
    print('STEP', step, 'EQ', ok)
    assert ok and step == 7
    print('OK')
    """)
    assert "OK" in out
