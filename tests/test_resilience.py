"""Resilience layer: deterministic fault injection (repro.serving.faults)
and graceful degradation (repro.serving.resilience) -- every injected
fault maps to a documented recovery, non-faulted requests stay bit-exact,
and the engine always drains to terminal statuses.

The whole module runs under the tier-1 shadow-ledger sanitizer
(``REPRO_SANITIZE=1`` via conftest): any injected fault that leaks pages,
host pins, or staged prefetches raises ``SanitizerError`` immediately.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.state_update import StateQuantConfig
from repro.models import model as M
from repro.serving.api import Engine, ServeConfig
from repro.serving.engine import TERMINAL_STATUSES
from repro.serving.faults import SITES, FaultPlan, FaultSpecError
from repro.serving.resilience import (BlobCorruption, LADDER, StepWatchdog,
                                      corrupt_blob, crc_blob,
                                      retry_transient, verify_blob)
from repro.serving.sampler import SamplingConfig
from repro.serving.scheduler import SchedulerConfig
from repro.analysis.lint.runtime import SanitizerError


# ---------------------------------------------------------------------------
# FaultPlan: spec grammar + deterministic triggers
# ---------------------------------------------------------------------------

def test_fault_spec_parses_every_site():
    plan = FaultPlan(";".join(SITES))
    assert set(plan.rules) == set(SITES)
    assert plan.total_injected == 0


@pytest.mark.parametrize("bad", [
    "", "   ", "frobnicate:nth=1", "alloc:nth", "alloc:nth=",
    "alloc:wat=3", "nan:p=1.5", "alloc:nth=1;alloc:nth=2",
])
def test_fault_spec_rejects_malformed(bad):
    with pytest.raises(FaultSpecError):
        FaultPlan(bad)


def test_nth_trigger_fires_exactly_once():
    plan = FaultPlan("alloc:nth=3")
    fired = [plan.should_fire("alloc") for _ in range(6)]
    assert fired == [False, False, True, False, False, False]
    assert plan.injected["alloc"] == 1


def test_step_trigger_tracks_engine_step():
    plan = FaultPlan("alloc:step=2")
    plan.set_step(1)
    assert not plan.should_fire("alloc")
    plan.set_step(2)
    assert plan.should_fire("alloc")
    assert not plan.should_fire("alloc")      # one-shot by default


def test_rid_trigger_and_cap():
    plan = FaultPlan("nan:rid=3,n=2")
    assert not plan.should_fire("nan", rid=1)
    assert plan.should_fire("nan", rid=3)
    assert plan.should_fire("nan", rid=3)
    assert not plan.should_fire("nan", rid=3)  # n=2 cap reached
    assert plan.injected["nan"] == 2


def test_unlisted_site_never_fires():
    plan = FaultPlan("alloc:nth=1")
    assert not plan.should_fire("host_pin")
    assert plan.should_fire("alloc")


def test_probabilistic_trigger_is_seed_deterministic():
    a = FaultPlan("alloc:p=0.5", seed=7)
    b = FaultPlan("alloc:p=0.5", seed=7)
    c = FaultPlan("alloc:p=0.5", seed=8)
    seq_a = [a.should_fire("alloc") for _ in range(64)]
    seq_b = [b.should_fire("alloc") for _ in range(64)]
    seq_c = [c.should_fire("alloc") for _ in range(64)]
    assert seq_a == seq_b                      # same seed, same schedule
    assert seq_a != seq_c                      # different seed diverges
    assert 0 < sum(seq_a) < 64


def test_plan_from_env_and_maybe_precedence():
    assert FaultPlan.from_env(env={}) is None
    plan = FaultPlan.from_env(env={"REPRO_FAULTS": "nan:rid=1"}, seed=3)
    assert plan is not None and plan.seed == 3
    assert FaultPlan.maybe(None, use_env=False) is None
    explicit = FaultPlan.maybe("alloc:nth=1", seed=2)
    assert explicit is not None and "alloc" in explicit.rules
    assert plan.param("slow_step", "ms", default=9.0) == 9.0
    assert FaultPlan("slow_step:ms=250").param("slow_step", "ms") == 250.0


# ---------------------------------------------------------------------------
# resilience primitives: checksums, bounded retry, watchdog
# ---------------------------------------------------------------------------

def test_blob_crc_roundtrip_detects_single_byte_flip():
    blob = [np.arange(12, dtype=np.float32).reshape(3, 4),
            np.zeros(5, np.int32)]
    crc = crc_blob(blob)
    verify_blob(blob, crc, "spill blob")           # clean: no raise
    verify_blob(blob, None, "legacy blob")         # unchecked: no raise
    corrupt_blob(blob)
    with pytest.raises(BlobCorruption) as ei:
        verify_blob(blob, crc, "spill blob", rid=7)
    assert ei.value.rid == 7 and "spill blob" in str(ei.value)


def test_crc_is_shape_sensitive():
    a = [np.arange(12, dtype=np.float32).reshape(3, 4)]
    b = [np.arange(12, dtype=np.float32).reshape(4, 3)]
    assert crc_blob(a) != crc_blob(b)


def test_corrupt_blob_handles_readonly_views():
    arr = np.arange(8, dtype=np.float32)
    arr.setflags(write=False)
    blob = [arr]
    crc = crc_blob(blob)
    corrupt_blob(blob)                      # must not raise on readonly
    assert crc_blob(blob) != crc


def test_retry_transient_bounded():
    calls = []

    def flaky():
        calls.append(1)
        return len(calls) >= 3

    retries = []
    assert retry_transient(flaky, attempts=4,
                           on_retry=retries.append) is True
    assert len(calls) == 3 and retries == [1, 2]

    assert retry_transient(lambda: False, attempts=3) is False

    def boom():
        raise RuntimeError("real fault")
    with pytest.raises(RuntimeError):       # exceptions are not transient
        retry_transient(boom)


def test_watchdog_flags_only_over_budget():
    wd = StepWatchdog(None)
    assert not wd.enabled and not wd.observe(0, 1e9)
    wd = StepWatchdog(0.1)
    assert not wd.observe(0, 0.05)
    assert wd.observe(1, 0.25) and wd.trips == 1
    assert wd.slowest_s == 0.25
    assert tuple(LADDER) == ("drop_prefix", "demote_store", "preempt",
                             "shed")


# ---------------------------------------------------------------------------
# engine-level fault -> recovery (small real model, greedy = bit-exact)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def llama():
    cfg = get_smoke_config("llama3.2-1b").with_(
        state_quant=StateQuantConfig(fmt="fp32", rounding="nearest",
                                     backend="jnp"))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


_GREEDY = SamplingConfig(temperature=0.0)


def _batch_engine(llama, fault_plan=None, **kw):
    cfg, params = llama
    return Engine(params, cfg, ServeConfig(
        backend="paged", batch=2, n_pages=17, n_slabs=5, sampling=_GREEDY,
        fault_plan=fault_plan, **kw))


def _run_batch(llama, fault_plan=None, **kw):
    cfg, _ = llama
    rng = np.random.default_rng(0)
    eng = _batch_engine(llama, fault_plan=fault_plan, **kw)
    hs = [eng.submit(rng.integers(0, cfg.vocab_size, n).astype(np.int32),
                     max_new_tokens=5) for n in (10, 14, 18)]
    eng.run()
    return eng, hs


@pytest.fixture(scope="module")
def baseline(llama):
    """Fault-free reference outputs for the 3-request batch workload."""
    eng, hs = _run_batch(llama)
    assert [h.status for h in hs] == ["done"] * 3
    return [h.output for h in hs]


def test_disabled_faults_cost_nothing(llama, baseline):
    eng, hs = _run_batch(llama)
    assert eng.engine.faults is None          # no plan installed
    assert not eng.engine._nan_guard          # no per-step finite scan
    assert not eng.engine.watchdog.enabled    # no wall-clock checks
    assert [h.output for h in hs] == baseline


@pytest.mark.slow
def test_nan_quarantines_only_the_poisoned_request(llama, baseline):
    eng, hs = _run_batch(llama, fault_plan="nan:rid=1")
    assert hs[1].status == "failed"
    assert "non-finite" in hs[1].request.detail
    # the other rows of the same decode batch are untouched, bit for bit
    assert hs[0].status == "done" and hs[0].output == baseline[0]
    assert hs[2].status == "done" and hs[2].output == baseline[2]
    assert eng.engine.faults.injected["nan"] == 1
    m = eng.obs.metrics
    assert m.value("quarantines_total") == 1
    assert eng.stats()["requests_failed"] == 1


@pytest.mark.slow
def test_transient_alloc_is_retried_transparently(llama, baseline):
    eng, hs = _run_batch(llama, fault_plan="alloc:nth=1")
    assert [h.status for h in hs] == ["done"] * 3
    assert [h.output for h in hs] == baseline
    m = eng.obs.metrics
    assert m.value("fault_retries_total", site="alloc") >= 1
    assert m.value("faults_recovered_total", site="alloc") >= 1


@pytest.mark.slow
def test_slow_step_trips_watchdog_without_dropping_work(llama, baseline):
    eng, hs = _run_batch(llama, fault_plan="slow_step:step=1,ms=80",
                         step_budget_s=0.05)
    assert eng.engine.watchdog.trips >= 1
    assert [h.output for h in hs] == baseline
    assert eng.obs.metrics.value("watchdog_trips_total") >= 1


def _preempt_engine(llama, fault_plan=None):
    cfg, params = llama
    return Engine(params, cfg, ServeConfig(
        backend="paged", batch=1, n_pages=9, n_slabs=5, sampling=_GREEDY,
        scheduler=SchedulerConfig(policy="priority"),
        fault_plan=fault_plan))


def _run_preempted(llama, fault_plan):
    """Long request B preempted by urgent A: exercises spill -> host pin
    -> (staged prefetch ->) resume with the given plan."""
    cfg, _ = llama
    rng = np.random.default_rng(2)
    prompt_b = rng.integers(0, cfg.vocab_size, 140).astype(np.int32)
    prompt_a = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    eng = _preempt_engine(llama, fault_plan)
    hb = eng.submit(prompt_b, max_new_tokens=8, priority=5)
    while hb.status == "queued" and eng.step():
        pass
    ha = eng.submit(prompt_a, max_new_tokens=6, priority=0)
    eng.engine._preempt(hb.rid)
    eng.run()
    return eng, ha, hb


@pytest.fixture(scope="module")
def preempt_ref(llama):
    """B's outputs served alone, never preempted, never faulted."""
    cfg, _ = llama
    rng = np.random.default_rng(2)
    prompt_b = rng.integers(0, cfg.vocab_size, 140).astype(np.int32)
    eng = _preempt_engine(llama)
    return eng.submit(prompt_b, max_new_tokens=8, priority=5
                      ).result().output


@pytest.mark.slow
def test_corrupt_spill_blob_recovers_by_reprefill(llama, preempt_ref):
    eng, ha, hb = _run_preempted(llama, "blob_corrupt:nth=1")
    assert ha.status == "done" and hb.status == "done"
    assert hb.output == preempt_ref          # re-prefill is bit-exact
    m = eng.obs.metrics
    assert m.value("blob_corruptions_total") == 1
    assert m.value("faults_recovered_total", site="blob_corrupt") == 1
    assert eng.engine.pool.host.pinned_bytes == 0


@pytest.mark.slow
def test_transient_host_pin_never_drops_live_state(llama, preempt_ref):
    eng, ha, hb = _run_preempted(llama, "host_pin:nth=1")
    assert ha.status == "done" and hb.status == "done"
    assert hb.output == preempt_ref
    assert eng.engine.faults.injected["host_pin"] == 1
    assert eng.engine.pool.host.pinned_bytes == 0


@pytest.mark.slow
def test_failed_prefetch_commit_falls_back_to_sync_resume(llama,
                                                          preempt_ref):
    eng, ha, hb = _run_preempted(llama, "prefetch_commit:nth=1")
    assert ha.status == "done" and hb.status == "done"
    assert hb.output == preempt_ref
    m = eng.obs.metrics
    assert m.value("faults_recovered_total", site="prefetch_commit") == 1
    assert eng.engine.pool.host.pinned_bytes == 0


# ---------------------------------------------------------------------------
# satellite 1: abort with an in-flight prefetch leaks nothing
# ---------------------------------------------------------------------------

def test_abort_with_inflight_prefetch_unpins_and_teardown_is_clean():
    cfg = get_smoke_config("mamba2-2.7b").with_(
        state_quant=StateQuantConfig(fmt="fp32", rounding="nearest",
                                     backend="jnp"))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    eng = Engine(params, cfg, ServeConfig(
        backend="paged", batch=1, n_pages=9, n_slabs=5, sampling=_GREEDY,
        scheduler=SchedulerConfig(policy="priority")))
    hb = eng.submit(rng.integers(0, cfg.vocab_size, 20).astype(np.int32),
                    max_new_tokens=8, priority=5)
    while hb.status == "queued" and eng.step():
        pass
    ha = eng.submit(rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=4, priority=0)
    eng.engine._preempt(hb.rid)
    eng.step()                               # stages B's prefetch
    pool = eng.engine.pool
    assert pool._staged, "prefetch was not staged"
    # the leak the shadow ledger would flag: staged prefetch at teardown
    with pytest.raises(SanitizerError, match="^PL255"):
        pool.sanitizer_check_leaks("mid-flight check")
    hb.abort()                               # must cancel the prefetch too
    assert hb.status == "aborted"
    assert not pool._staged
    ha.result()
    assert ha.status == "done"
    assert pool.host.pinned_bytes == 0
    pool.sanitizer_check_leaks("post-abort")  # drained: no PL255


# ---------------------------------------------------------------------------
# satellite 2 + admission control: overload never wedges the engine
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_never_admittable_head_is_rejected_not_spun(llama):
    cfg, params = llama
    eng = Engine(params, cfg, ServeConfig(
        backend="paged", batch=1, n_pages=9, n_slabs=5, sampling=_GREEDY))
    rng = np.random.default_rng(4)
    # a retained request holds every usable page past completion (896
    # prompt tokens + generated tail = the full pool), so the next
    # request's admission can never succeed -- not even with the pool idle
    big = eng.submit(rng.integers(0, cfg.vocab_size, 896).astype(np.int32),
                     max_new_tokens=2, retain=True)
    big.result()
    assert big.status == "done"
    assert eng.engine.pool.free_pages == 0
    h = eng.submit(rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
                   max_new_tokens=4)
    eng.run()                                # must terminate, not spin
    assert h.status == "rejected"
    assert "page budget" in h.request.detail
    assert eng.stats()["requests_rejected"] == 1


@pytest.mark.slow
def test_max_queued_sheds_at_the_door(llama, baseline):
    eng, hs = _run_batch(llama, max_queued=1)
    assert hs[0].status == "done" and hs[0].output == baseline[0]
    assert [h.status for h in hs[1:]] == ["rejected"] * 2
    assert all("max_queued" in h.request.detail for h in hs[1:])
    assert eng.stats()["requests_rejected"] == 2


@pytest.mark.slow
def test_request_timeout_expires_stale_queue_entries(llama):
    cfg, params = llama
    eng = Engine(params, cfg, ServeConfig(
        backend="paged", batch=1, n_pages=17, n_slabs=5, sampling=_GREEDY,
        request_timeout_s=50.0))
    rng = np.random.default_rng(5)
    ha = eng.submit(rng.integers(0, cfg.vocab_size, 10).astype(np.int32),
                    max_new_tokens=5)
    while ha.status == "queued" and eng.step():
        pass
    hb = eng.submit(rng.integers(0, cfg.vocab_size, 14).astype(np.int32),
                    max_new_tokens=5)
    hb.request.t_submit -= 100.0    # simulate 100 s already spent queued
    eng.run()
    # batch=1: A keeps the slot, B ages past the deadline while waiting
    assert ha.status == "done"
    assert hb.status == "rejected"
    assert "request_timeout_s" in hb.request.detail
    assert eng.obs.metrics.value("request_timeouts_total") >= 1


def test_slots_backend_rejects_resilience_options():
    for kw in ({"fault_plan": "nan:rid=0"}, {"nan_guard": True},
               {"max_queued": 4}, {"request_timeout_s": 1.0},
               {"step_budget_s": 0.5}):
        with pytest.raises(ValueError, match="paged"):
            ServeConfig(backend="slots", **kw)


# ---------------------------------------------------------------------------
# seeded chaos: random plans under open-loop traffic always drain
# ---------------------------------------------------------------------------

def _random_plan(rng) -> str:
    clauses = []
    if rng.random() < 0.7:
        clauses.append(f"alloc:p={rng.uniform(0.05, 0.4):.2f}")
    if rng.random() < 0.5:
        clauses.append(f"nan:p={rng.uniform(0.02, 0.15):.2f}")
    if rng.random() < 0.5:
        clauses.append(f"slow_step:p={rng.uniform(0.1, 0.5):.2f},ms=1")
    if rng.random() < 0.5:
        clauses.append("host_pin:p=0.5")
    if rng.random() < 0.5:
        clauses.append("blob_corrupt:p=0.5")
    if rng.random() < 0.5:
        clauses.append("prefetch_commit:p=0.5")
    return ";".join(clauses) or "alloc:p=0.25"


@pytest.mark.slow
@pytest.mark.parametrize("seed", [11, 23])
def test_chaos_every_request_terminal_and_engine_drains(llama, seed):
    """Property test, hand-seeded (hypothesis is not in the image): a
    random fault plan under open-loop traffic leaves every request in a
    terminal status, the engine fully drained, and the shadow-ledger
    sanitizer (enabled module-wide) silent."""
    cfg, _ = llama
    rng = np.random.default_rng(seed)
    plan = _random_plan(rng)
    eng = _batch_engine(llama, fault_plan=plan)
    hs = []
    for _ in range(6):
        n = int(rng.integers(6, 24))
        hs.append(eng.submit(
            rng.integers(0, cfg.vocab_size, n).astype(np.int32),
            max_new_tokens=int(rng.integers(2, 6))))
        eng.step()                           # open loop: arrivals mid-run
    eng.run()
    statuses = [h.status for h in hs]
    assert all(s in TERMINAL_STATUSES for s in statuses), \
        f"non-terminal under plan {plan!r} (seed {seed}): {statuses}"
    assert not eng.engine.has_work()
    assert eng.engine.pool.host.pinned_bytes == 0
    done = [h for h in hs if h.status == "done"]
    assert done, f"plan {plan!r} starved every request"
