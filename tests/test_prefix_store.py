"""Radix prefix store: tree invariants (deterministic + hypothesis
property tests when available) and end-to-end bit-exactness of warm and
cold cross-request prefix hits for an attention arch (llama) and a hybrid
arch (zamba2)."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.state_update import StateQuantConfig
from repro.models import model as M
from repro.serving.api import Engine, ServeConfig
from repro.serving.memory import PAGE_TOKENS
from repro.serving.memory.prefix_store import PrefixStore
from repro.serving.sampler import SamplingConfig

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # container has no hypothesis; CI installs it
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# pure-tree invariants (no jax, no model)
# ---------------------------------------------------------------------------

def _tok(page_vals, page_tokens=4):
    """Token list whose i-th page chunk is page_vals[i] repeated."""
    out = []
    for v in page_vals:
        out.extend([v] * page_tokens)
    return out


def test_chunks_drops_partial_tail():
    s = PrefixStore(8, page_tokens=4)
    assert s.chunks([1, 2, 3]) == []
    assert s.chunks([1, 2, 3, 4, 5]) == [(1, 2, 3, 4)]
    assert s.chunks([1, 2, 3, 4, 5, 6, 7, 8], max_pages=1) == [(1, 2, 3, 4)]


def test_extend_then_match_longest_prefix():
    s = PrefixStore(8, page_tokens=4)
    path, created = s.extend(s.chunks(_tok([1, 2, 3])))
    assert len(path) == len(created) == 3
    assert [n.depth for n in path] == [1, 2, 3]
    # full match
    assert s.match(s.chunks(_tok([1, 2, 3]))) == path
    # longest-prefix: diverges at page 2
    assert s.match(s.chunks(_tok([1, 2, 9]))) == path[:2]
    assert s.match(s.chunks(_tok([9, 2, 3]))) == []
    # re-extend creates nothing new, shares the path
    path2, created2 = s.extend(s.chunks(_tok([1, 2, 3, 4])))
    assert path2[:3] == path and len(created2) == 1


def test_eviction_leaf_only_lru_order():
    s = PrefixStore(8, page_tokens=4)
    s.extend(s.chunks(_tok([1, 2, 3])))
    cands = s.evict_candidates()
    assert [n.depth for n in cands] == [3]      # only the leaf
    s.remove(cands[0])
    assert s.n_pages == 2
    # now depth-2 is the leaf
    assert [n.depth for n in s.evict_candidates()] == [2]


def test_locked_nodes_never_evicted():
    s = PrefixStore(2, page_tokens=4)
    path, _ = s.extend(s.chunks(_tok([1, 2])))
    locked = {path[-1].node_id}
    cands = s.evict_candidates(locked=lambda n: n.node_id in locked)
    assert cands == []                          # leaf locked, parent interior
    assert s.over_capacity() == 0
    s.extend(s.chunks(_tok([1, 9])))            # now over capacity
    assert s.over_capacity() == 1
    cands = s.evict_candidates(locked=lambda n: n.node_id in locked)
    assert [n.chunk for n in cands] == [(9, 9, 9, 9)]


def test_lru_touch_on_match():
    s = PrefixStore(8, page_tokens=4)
    pa, _ = s.extend(s.chunks(_tok([1, 2])))
    pb, _ = s.extend(s.chunks(_tok([3, 4])))
    s.match(s.chunks(_tok([1, 2])))             # touch path A
    order = s.evict_candidates()
    assert order[0] is pb[-1]                   # B's leaf is now LRU


if HAVE_HYPOTHESIS:
    _page_vals = st.lists(st.integers(0, 3), min_size=1, max_size=5)

    @settings(max_examples=60, deadline=None)
    @given(st.lists(_page_vals, min_size=1, max_size=12))
    def test_prop_match_is_longest_stored_prefix(seqs):
        s = PrefixStore(capacity_pages=1000, page_tokens=4)
        inserted = set()
        for vals in seqs:
            s.extend(s.chunks(_tok(vals)))
            for i in range(1, len(vals) + 1):
                inserted.add(tuple(vals[:i]))
        for vals in seqs:
            probe = vals + [7]                  # diverge past the stored path
            path = s.match(s.chunks(_tok(probe)))
            depths = [tuple(probe[:i]) in inserted
                      for i in range(1, len(probe) + 1)]
            expect = 0
            for hit in depths:
                if not hit:
                    break
                expect += 1
            assert len(path) == expect
            assert [n.depth for n in path] == list(range(1, expect + 1))

    @settings(max_examples=60, deadline=None)
    @given(st.lists(_page_vals, min_size=1, max_size=12),
           st.integers(1, 6))
    def test_prop_eviction_respects_capacity_locks_and_leaves(seqs, cap):
        s = PrefixStore(capacity_pages=cap, page_tokens=4)
        for vals in seqs:
            s.extend(s.chunks(_tok(vals)))
        locked_ids = {n.node_id for n in s.nodes()[::3]}   # every 3rd locked
        locked = lambda n: n.node_id in locked_ids
        while s.over_capacity() > 0:
            cands = s.evict_candidates(locked=locked)
            if not cands:
                break
            s.remove(cands[0])
        # capacity met unless locks forbid it; locked nodes all survived
        live = {n.node_id for n in s.nodes()}
        assert locked_ids <= live
        if s.over_capacity() > 0:
            assert all(locked(n) for n in s.evict_candidates())
        # parent-chain integrity: every node's parent is live and its chunk
        # still resolves through the tree
        for n in s.nodes():
            if n.parent is not None:
                assert n.parent.node_id in live
                assert n.parent.children[n.chunk] is n
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_prop_match_is_longest_stored_prefix():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_prop_eviction_respects_capacity_locks_and_leaves():
        pass


# ---------------------------------------------------------------------------
# end-to-end bit-exactness: warm + cold store hits vs full re-prefill
# ---------------------------------------------------------------------------

def _greedy_pair(arch):
    cfg = get_smoke_config(arch).with_(
        state_quant=StateQuantConfig(fmt="fp32", rounding="nearest",
                                     backend="jnp"))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    return params, cfg


def _serve(params, cfg, prompts, prefix_cache, max_new=5):
    eng = Engine(params, cfg, ServeConfig(
        backend="paged", batch=2, n_pages=17, n_slabs=5,
        sampling=SamplingConfig(temperature=0.0),
        prefix_cache=prefix_cache, prefix_store_pages=8))
    hs = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    eng.run()
    return eng, hs


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["llama3.2-1b", "zamba2-2.7b"])
def test_store_hit_bit_exact_warm_and_cold(arch):
    params, cfg = _greedy_pair(arch)
    rng = np.random.default_rng(0)
    sysp = rng.integers(0, cfg.vocab_size, PAGE_TOKENS).astype(np.int32)
    prompts = [np.concatenate(
        [sysp, rng.integers(0, cfg.vocab_size, 10).astype(np.int32)])
        for _ in range(2)]

    eng_b, refs = _serve(params, cfg, prompts, prefix_cache=False)
    eng_s, hits = _serve(params, cfg, prompts, prefix_cache=True)
    st = eng_s.stats()
    assert [h.output for h in hits] == [r.output for r in refs]
    assert st["prefix_hits"] == 1          # request 1 adopted request 0's page
    assert st["shared_page_hits"] >= 1
    assert st["prefill_tokens"] < eng_b.stats()["prefill_tokens"]

    # cold: demote the stored page(s) to host, hit must promote + stay exact
    pool = eng_s.engine.pool
    assert pool.demote_all() >= 1
    cold_prompt = np.concatenate(
        [sysp, rng.integers(0, cfg.vocab_size, 10).astype(np.int32)])
    ref = eng_b.submit(cold_prompt, max_new_tokens=5)
    eng_b.run()
    hit = eng_s.submit(cold_prompt, max_new_tokens=5)
    eng_s.run()
    st2 = eng_s.stats()
    assert hit.output == ref.output
    assert st2["prefix_hits"] == 2
    if pool.page_nbytes > 0:
        assert st2["promote_bytes"] > 0


@pytest.mark.slow
def test_store_hit_prefill_token_accounting():
    params, cfg = _greedy_pair("llama3.2-1b")
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 150).astype(np.int32)
    eng, (h0, h1) = _serve(params, cfg, [prompt, prompt.copy()], True)
    st = eng.stats()
    assert h0.output == h1.output
    # request 0 ingests all 150; request 1 only the 22-token un-cached tail
    assert st["prefill_tokens"] == 150 + (150 - PAGE_TOKENS)
    assert st["prefix_hit_tokens"] == PAGE_TOKENS
