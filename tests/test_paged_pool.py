"""Paged state/KV pool: allocation invariants, preemption round-trip,
time-axis recapacity regression, scheduler-driven serving, sampling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import attention_cache as AC
from repro.core import formats as F
from repro.core import pimsim
from repro.core.state_update import StateQuantConfig
from repro.models import model as M
from repro.serving.engine import (EngineConfig, PagedEngineConfig,
                                  PagedServingEngine, Request, ServingEngine)
from repro.serving.memory import (PAGE_TOKENS, BankAwarePlacement,
                                  BankTopology, PagedStatePool, pages_for)
from repro.serving.sampler import SamplingConfig, sample
from repro.serving.scheduler import Scheduler, SchedulerConfig


@pytest.fixture(scope="module")
def tiny():
    cfg = get_smoke_config("llama3.2-1b")
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    return params, cfg


@pytest.fixture(scope="module")
def tiny_fp32():
    cfg = get_smoke_config("llama3.2-1b").with_(
        state_quant=StateQuantConfig(fmt="fp32", rounding="nearest",
                                     backend="jnp"))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    return params, cfg


# ---------------------------------------------------------------------------
# allocator invariants
# ---------------------------------------------------------------------------

def test_placement_alloc_free_invariants():
    topo = BankTopology(pseudo_channels=4, bank_pairs=4)
    pl = BankAwarePlacement(33, topo)
    assert pl.n_free == 32                       # page 0 reserved
    a = pl.alloc(8)
    assert a is not None and len(set(a)) == 8 and 0 not in a
    # bank-aware: 8 pages over 16 coords -> no coordinate holds two
    assert pl.live_map().max() == 1
    b = pl.alloc(24)
    assert pl.n_free == 0
    assert pl.alloc(1) is None                   # exhausted, state unchanged
    assert pl.n_free == 0
    pl.free(a)
    assert pl.n_free == 8
    c = pl.alloc(8)
    assert set(c) == set(a)                      # ids conserved, no leaks
    pl.free(b)
    pl.free(c)
    assert pl.n_free == 32
    assert pl.live_map().sum() == 0


def test_pool_register_grow_release(tiny):
    params, cfg = tiny
    pool = PagedStatePool(cfg, n_pages=9, n_slabs=5)
    assert pool.usable_pages == 8
    assert pool.register(1, 2) and pool.register(2, 3)
    assert pool.free_pages == 3
    assert pool.grow(1, 3)
    assert pool.free_pages == 0
    assert not pool.grow(2, 1)                   # full, copy-free failure
    # fragmentation: rid1 holds 5 pages / 300 tokens, rid2 3 pages / 384
    frag = pool.fragmentation({1: 300, 2: 384})
    assert frag == pytest.approx(1.0 - 684 / (8 * PAGE_TOKENS))
    assert pool.occupancy() == 1.0
    pool.release(1)
    assert pool.free_pages == 5 and pool.free_slabs == 3
    pool.release(2)
    assert pool.free_pages == 8 and pool.free_slabs == 4


def test_pimsim_scores_real_page_map():
    sys_cfg = pimsim.SystemConfig()
    uniform = np.full((4, 4), 10.0)
    hot = np.zeros((4, 4))
    hot[0, 0] = 160.0                            # same traffic, one bank pair
    r_u = pimsim.placement_step_latency(uniform, sys_cfg)
    r_h = pimsim.placement_step_latency(hot, sys_cfg)
    assert r_u["conflict_factor"] == pytest.approx(1.0)
    assert r_h["conflict_factor"] > 3.0
    assert r_h["t_real_s"] > r_u["t_real_s"]


# ---------------------------------------------------------------------------
# time-axis recapacity regression (what _recapacity used to guess)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["mx8", "fp16"])
def test_recapacity_stacked_batch_divisible_by_128(fmt):
    """B=128 stacked caches: the retired heuristic picked the first axis
    divisible by 128 -- the *batch* axis on (G, B, T, ...) leaves -- and
    would have resized batch instead of time.  Pin the explicit behavior."""
    sq = StateQuantConfig(fmt=fmt, rounding="nearest", backend="jnp")
    cache = AC.init_kv_cache(128, 256, 1, 16, sq)
    k = jax.random.normal(jax.random.PRNGKey(0), (128, 256, 1, 16))
    cache = AC.KVCache(
        F.quantize(k, "mx8") if fmt == "mx8" else k.astype(jnp.float16),
        cache.v, jnp.full((128,), 256, jnp.int32), cache.fmt,
        cache.v_width, cache.time_axis)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (2,) + x.shape), cache)
    assert stacked.stack_offset == 1             # lengths (2, 128)

    grown = AC.recapacity(stacked, 384)
    leaf = (grown.k.payload["mantissa"] if fmt == "mx8" else grown.k)
    assert leaf.shape[:3] == (2, 128, 384)       # time grew, batch intact
    if fmt == "mx8":
        assert grown.k.shape == (128, 384, 1, 16)  # logical aux follows
        np.testing.assert_array_equal(
            grown.k.payload["mantissa"][:, :, :256],
            stacked.k.payload["mantissa"])

    trimmed = AC.recapacity(stacked, 128)
    leaf_t = (trimmed.k.payload["mantissa"] if fmt == "mx8" else trimmed.k)
    assert leaf_t.shape[:3] == (2, 128, 128)
    src = (stacked.k.payload["mantissa"] if fmt == "mx8" else stacked.k)
    np.testing.assert_array_equal(np.asarray(leaf_t),
                                  np.asarray(src[:, :, :128]))


def test_kvcache_max_len_uses_time_axis():
    sq = StateQuantConfig(fmt="mx8", rounding="nearest", backend="jnp")
    cache = AC.init_kv_cache(2, 256, 1, 16, sq)
    assert cache.time_axis == 1
    assert cache.max_len == 256


# ---------------------------------------------------------------------------
# preemption round-trip: evict -> resume -> bit-identical logits
# ---------------------------------------------------------------------------

def test_preemption_roundtrip_bit_identical_logits(tiny):
    params, cfg = tiny                           # mx8 + stochastic rounding
    pool = PagedStatePool(cfg, n_pages=9, n_slabs=5)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    pr = jnp.asarray(prompt)[None]
    logits, row = jax.jit(lambda p, b: M.prefill(p, cfg, b))(
        params, {"tokens": pr, "targets": pr})
    assert pool.register(7, pages_for(len(prompt)))
    pool.insert_prefill(7, row)
    tok = int(jnp.argmax(logits[0]))
    lengths = np.array([12, 0], np.int32)
    for step in (1, 2):                          # warm the caches a little
        lg = pool.decode(params, [7, None],
                         np.array([tok, 0], np.int32), lengths, seed=step)
        tok = int(jnp.argmax(lg[0]))
        lengths[0] += 1

    # host-copy snapshot: the decode step *donates* the pools (in-place
    # page/slab updates), so device-side references would be deleted
    snapshot = [np.asarray(x) for x in pool.pools]
    pages_before = list(pool.page_table[7])
    lg_a = np.asarray(pool.decode(params, [7, None],
                                  np.array([tok, 0], np.int32),
                                  lengths, seed=42))
    pool.pools = [jnp.asarray(x) for x in snapshot]  # rewind the step

    sp = pool.spill(7, int(lengths[0]))          # evict to host
    assert 7 not in pool.page_table
    assert pool.resume(7, sp)                    # re-pin (fresh placement)
    lg_b = np.asarray(pool.decode(params, [7, None],
                                  np.array([tok, 0], np.int32),
                                  lengths, seed=42))
    np.testing.assert_array_equal(lg_a[0], lg_b[0])
    # placement may differ; identity must not depend on physical page ids
    assert len(pool.page_table[7]) == len(pages_before)


# ---------------------------------------------------------------------------
# end-to-end serving through the paged engine
# ---------------------------------------------------------------------------

def _reference_outputs(params, cfg, prompts, n_new):
    eng = ServingEngine(params, cfg, EngineConfig(slots=2,
                                                  cache_capacity=384))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=n_new))
    return {r.rid: r.output for r in eng.run()}


def test_paged_engine_mixed_workload_matches_greedy(tiny_fp32):
    """Short + long prompts (chunked prefill for the long one) through a
    small pool; every request's greedy tokens must match the fixed-slot
    engine."""
    params, cfg = tiny_fp32
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (10, 150, 9, 40)]
    refs = _reference_outputs(params, cfg, prompts, 5)

    eng = PagedServingEngine(params, cfg, PagedEngineConfig(
        max_decode_batch=3, n_pages=7, n_slabs=7, prefill_chunk=128))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=5))
    done = eng.run()
    assert len(done) == len(prompts)
    for r in done:
        assert not r.truncated
        assert r.output == refs[r.rid], (r.rid, r.output, refs[r.rid])
    stats = eng.stats()
    assert stats["tokens"] == 5 * len(prompts)
    assert 0.0 <= stats["occupancy"] <= 1.0
    assert 0.0 <= stats["fragmentation"] < 1.0
    assert "p99_ttft_s" in stats and "p50_tok_latency_s" in stats


def test_paged_engine_prefill_buckets(tiny_fp32):
    """Opt-in prefill bucketing (the JH103 lint-finding fix): snapping the
    full-sequence prefill length down to a fixed bucket set must not change
    greedy outputs -- the prompt tail streams through the bit-exact decode
    pending path -- while collapsing one-prefill-compile-per-prompt-length
    to one per bucket."""
    params, cfg = tiny_fp32
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (9, 11, 13, 42, 44, 46)]
    refs = _reference_outputs(params, cfg, prompts, 4)

    eng = PagedServingEngine(params, cfg, PagedEngineConfig(
        max_decode_batch=3, n_pages=9, n_slabs=7, prefill_chunk=128,
        prefill_buckets=(8, 32, 128)))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    done = eng.run()
    assert len(done) == len(prompts)
    for r in done:
        assert r.output == refs[r.rid], (r.rid, r.output, refs[r.rid])
    # six distinct prompt lengths, but only two buckets actually prefill
    # (9-13 -> 8, 42-46 -> 32): the compile count follows the bucket set
    assert eng.obs.recompiles.counts().get("engine.prefill", 0) <= 2


def test_paged_engine_growth_preemption_e2e(tiny_fp32):
    """Pool too small for both requests' full contexts: one must be evicted
    when the other's block table grows, then resume and still produce the
    exact greedy continuation."""
    params, cfg = tiny_fp32
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, 120).astype(np.int32)
               for _ in range(2)]
    refs = _reference_outputs(params, cfg, prompts, 12)

    eng = PagedServingEngine(params, cfg, PagedEngineConfig(
        max_decode_batch=2, n_pages=4, n_slabs=5, prefill_chunk=128))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=12))
    done = eng.run()
    assert len(done) == 2
    assert eng.preemptions >= 1                  # growth forced an eviction
    for r in done:
        assert not r.truncated
        assert r.output == refs[r.rid], (r.rid, r.output, refs[r.rid])


def test_paged_pool_doubles_inflight_in_same_bytes(tiny_fp32):
    """Acceptance: within the byte budget of a slots=4 x cap=256 fixed pool,
    the paged pool keeps 2x as many short requests in flight."""
    params, cfg = tiny_fp32
    slots, cap = 4, 256
    probe = PagedStatePool(cfg, n_pages=2, n_slabs=2)
    budget = slots * ((cap // PAGE_TOKENS) * probe.page_nbytes
                      + probe.slab_nbytes)

    eng = PagedServingEngine(params, cfg, PagedEngineConfig(
        max_decode_batch=2 * slots, byte_budget=budget, n_pages=None,
        n_slabs=2 * slots + 1, prefill_chunk=128))
    assert eng.pool.bytes_total() <= budget
    rng = np.random.default_rng(4)
    for i in range(2 * slots):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, 8 + i
                                               ).astype(np.int32),
                           max_new_tokens=4))
    eng._admit()
    assert len(eng.active) == 2 * slots          # all resident at once
    done = eng.run()
    assert len(done) == 2 * slots
    assert all(len(r.output) == 4 for r in done)


def test_paged_engine_priority_scheduling(tiny_fp32):
    """Lower priority value finishes first when capacity forces queueing."""
    params, cfg = tiny_fp32
    eng = PagedServingEngine(params, cfg, PagedEngineConfig(
        max_decode_batch=1, n_pages=3, n_slabs=3,
        scheduler=SchedulerConfig(policy="priority")))
    rng = np.random.default_rng(5)
    for i, prio in enumerate((5, 0, 3)):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, 8
                                               ).astype(np.int32),
                           max_new_tokens=3, priority=prio))
    done = eng.run()
    assert [r.rid for r in done] == [1, 2, 0]    # by priority, not arrival


# ---------------------------------------------------------------------------
# scheduler unit behavior
# ---------------------------------------------------------------------------

def test_scheduler_policies():
    def mk(rid, prio=0, deadline=None, t=0.0):
        r = Request(rid=rid, prompt=np.zeros(1, np.int32), priority=prio,
                    deadline=deadline)
        r.t_submit = t
        return r

    s = Scheduler(SchedulerConfig(policy="priority"))
    a, b, c = mk(0, prio=2, t=0.0), mk(1, prio=0, t=1.0), mk(2, prio=2, t=2.0)
    for r in (a, b, c):
        s.push(r)
    assert s.pop() is b and s.pop() is a and s.pop() is c

    s = Scheduler(SchedulerConfig(policy="deadline"))
    d, e = mk(0, deadline=9.0, t=0.0), mk(1, deadline=1.0, t=1.0)
    s.push(d)
    s.push(e)
    assert s.pop() is e                          # EDF
    assert s.choose_victim([d, e]) is d          # latest deadline evicted
    assert s.should_preempt(e, d)
    assert not s.should_preempt(d, e)

    s = Scheduler(SchedulerConfig(policy="fcfs"))
    s.push(mk(0, t=1.0))
    assert not s.should_preempt(mk(1, t=2.0), s.peek())


# ---------------------------------------------------------------------------
# sampler: top-p
# ---------------------------------------------------------------------------

def test_top_p_restricts_to_nucleus():
    logits = jnp.log(jnp.array([[0.6, 0.3, 0.08, 0.02]]))
    key = jax.random.PRNGKey(0)
    cfg = SamplingConfig(temperature=1.0, top_p=0.5)
    toks = [int(sample(logits, cfg, jax.random.fold_in(key, i))[0])
            for i in range(32)]
    assert set(toks) == {0}                      # only the top token survives
    cfg = SamplingConfig(temperature=1.0, top_p=0.85)
    toks = [int(sample(logits, cfg, jax.random.fold_in(key, i))[0])
            for i in range(64)]
    assert set(toks) <= {0, 1} and 1 in toks
    # top_p=1.0 leaves the distribution untouched
    cfg = SamplingConfig(temperature=1.0, top_p=1.0)
    toks = {int(sample(logits, cfg, jax.random.fold_in(key, i))[0])
            for i in range(200)}
    assert {0, 1, 2} <= toks
