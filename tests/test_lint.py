"""repro.analysis.lint: jit-hazard rules, ledger protocol rules, registry
contracts, and the runtime shadow-ledger sanitizer.

Every JH/PL/RC code gets a positive (fires) and a negative (stays quiet)
case; the runtime half injects a deliberate double-unref and a teardown
leak into a real pool and requires the sanitizer to catch both.
"""
import os
import textwrap

import pytest

from repro.analysis.lint import (RULES, SanitizerError, ShadowLedger,
                                 baseline_diff, run_lint)
from repro.analysis.lint.findings import (Finding, counts_by_code,
                                          suppressed_codes)
from repro.analysis.lint.jit_hazards import lint_jit_hazards
from repro.analysis.lint.ledger import lint_ledger_protocol

_SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def _codes(findings):
    return {f.code for f in findings}


def _lint_snippet(tmp_path, source, pass_fn):
    p = tmp_path / "snippet.py"
    p.write_text(textwrap.dedent(source))
    return pass_fn([str(p)])


# ---------------------------------------------------------------------------
# pass 1: jit hazards -- positive and negative per rule
# ---------------------------------------------------------------------------

JH_CASES = {
    "JH101": (
        """
        import jax
        import numpy as np
        def decode_step(fn, xs):
            out = []
            for x in xs:
                out.append(np.asarray(x))
            return out
        step = jax.jit(decode_step)
        """,
        """
        import jax
        import numpy as np
        def decode_step(fn, xs):
            ys = fn(xs)
            ys_np = np.asarray(ys)
            return list(ys_np)
        step = jax.jit(decode_step)
        """,
    ),
    "JH102": (
        """
        import jax
        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
        """,
        """
        import jax
        @jax.jit
        def f(x):
            if x.shape[0] > 0:
                return x
            return -x
        """,
    ),
    "JH103": (
        """
        import jax
        import numpy as np
        step = jax.jit(lambda t: t)
        def build_table(rids, table):
            npg = max(len(table[r]) for r in rids)
            return np.zeros((len(rids), npg), np.int32)
        """,
        """
        import jax
        import numpy as np
        step = jax.jit(lambda t: t)
        def build_table(n_rows, n_pages):
            return np.zeros((n_rows, n_pages), np.int32)
        """,
    ),
    "JH104": (
        """
        import jax
        def decode_impl(params, pools, tokens):
            return pools
        step = jax.jit(decode_impl)
        """,
        """
        import jax
        def decode_impl(params, pools, tokens):
            return pools
        step = jax.jit(decode_impl, donate_argnums=(1,))
        """,
    ),
    "JH105": (
        """
        import jax
        @jax.jit
        def g(tree):
            return {k: tree for k in set(("a", "b"))}
        """,
        """
        import jax
        @jax.jit
        def g(tree, names):
            return {k: tree for k in sorted(names)}
        """,
    ),
    "JH106": (
        """
        import jax
        class Eng:
            def __init__(self):
                self.scale = 1.0
            def rescale(self):
                self.scale = 2.0
            def step_fn(self, x):
                return x * self.scale
            def build(self):
                return jax.jit(self.step_fn)
        """,
        """
        import jax
        class Eng:
            def __init__(self):
                self.scale = 1.0
            def step_fn(self, x):
                return x * self.scale
            def build(self):
                return jax.jit(self.step_fn)
        """,
    ),
}


@pytest.mark.parametrize("code", sorted(JH_CASES))
def test_jit_hazard_rule(code, tmp_path):
    pos, neg = JH_CASES[code]
    hits = _lint_snippet(tmp_path / "pos", pos, lint_jit_hazards)
    assert code in _codes(hits), f"{code} should fire on the positive case"
    (tmp_path / "pos" / "snippet.py").unlink()
    quiet = _lint_snippet(tmp_path / "neg", neg, lint_jit_hazards)
    assert code not in _codes(quiet), \
        f"{code} must stay quiet on the negative case: {quiet}"


def _mkdirs(tmp_path):
    for d in ("pos", "neg"):
        (tmp_path / d).mkdir(exist_ok=True)


@pytest.fixture(autouse=True)
def _fixture_dirs(tmp_path):
    _mkdirs(tmp_path)


# ---------------------------------------------------------------------------
# pass 2 (static): ledger protocol -- positive and negative per rule
# ---------------------------------------------------------------------------

PL_CASES = {
    "PL201": (
        """
        def claim(placement, table, rid, n):
            pages = placement.alloc(n)
            table[rid] = pages
            placement.unref(pages)
        """,
        """
        def claim(placement, table, rid, n):
            pages = placement.alloc(n)
            if pages is None:
                return False
            table[rid] = pages
            placement.unref(pages)
            return True
        """,
    ),
    "PL202": (
        """
        def claim(placement, n):
            pages = placement.alloc(n)
            if pages is None:
                return None
            return pages
        """,
        """
        def claim(placement, n):
            pages = placement.alloc(n)
            if pages is None:
                return None
            return pages
        def drop(placement, pages):
            placement.unref(pages)
        """,
    ),
    "PL203": (
        """
        class Pool:
            def release(self, rid):
                pages = self.page_table.pop(rid)
                return len(pages)
        """,
        """
        class Pool:
            def release(self, rid):
                pages = self.page_table.pop(rid)
                self.placement.unref(pages)
                return len(pages)
        """,
    ),
    "PL204": (
        """
        def drop(placement, pages):
            placement.free(pages)
        """,
        """
        def drop(placement, pages):
            placement.unref(pages)
        """,
    ),
    "PL205": (
        """
        class Tiered:
            def spill(self, rid, length):
                blob = self.extract(rid)
                self.host.cache_add(len(blob))
                return blob
        """,
        """
        class Tiered:
            def spill_with_retry(self, rid, length):
                blob = self.extract(rid)
                self.host.pin(rid, len(blob))
                return blob
        """,
    ),
    "PL206": (
        """
        class Engine:
            def admit(self, req, pages):
                self.pool.register(req.rid, pages)
                return True
        """,
        """
        class Engine:
            def admit(self, req, pages):
                ok = retry_transient(
                    lambda: self.pool.register(req.rid, pages))
                if not ok:
                    self.degrade(req)
                return bool(ok)
        """,
    ),
}


@pytest.mark.parametrize("code", sorted(PL_CASES))
def test_ledger_rule(code, tmp_path):
    pos, neg = PL_CASES[code]
    hits = _lint_snippet(tmp_path / "pos", pos, lint_ledger_protocol)
    assert code in _codes(hits), f"{code} should fire on the positive case"
    quiet = _lint_snippet(tmp_path / "neg", neg, lint_ledger_protocol)
    assert code not in _codes(quiet), \
        f"{code} must stay quiet on the negative case: {quiet}"


# ---------------------------------------------------------------------------
# suppression + baseline ratchet
# ---------------------------------------------------------------------------

def test_suppression_comment(tmp_path):
    src = """
    def drop(placement, pages):
        placement.free(pages)  # lint: disable=PL204
    """
    assert _lint_snippet(tmp_path / "pos", src, lint_ledger_protocol) == []


def test_suppression_preceding_line():
    lines = ["x = 1\n", "# lint: disable=JH101, PL204\n", "y = 2\n"]
    assert suppressed_codes(lines, 3) == {"JH101", "PL204"}
    assert suppressed_codes(lines, 1) == set()


def test_baseline_ratchet():
    f = [Finding("JH101", "m", "a.py", 1), Finding("JH101", "m", "a.py", 9),
         Finding("PL204", "m", "b.py", 2)]
    assert counts_by_code(f) == {"JH101": 2, "PL204": 1}
    over, room = baseline_diff(f, {"JH101": 2, "PL204": 2, "RC301": 1})
    assert over == {}                       # nothing above baseline
    assert room == {"PL204": 1, "RC301": 1}
    over, _ = baseline_diff(f, {"JH101": 1})
    assert over == {"JH101": 1, "PL204": 1}


def test_every_rule_documented():
    for code in RULES:
        title, hint = RULES[code]
        assert title and hint
    covered = set(JH_CASES) | set(PL_CASES) | \
        {"PL250", "PL251", "PL252", "PL253", "PL254", "PL255"} | \
        {"RC301", "RC302", "RC303", "RC304", "RC305"}
    assert covered == set(RULES), "every rule needs a test case"


def test_repo_is_lint_clean():
    """The committed tree carries no unsuppressed static findings -- the
    same gate CI's lint job enforces via the (empty) baseline."""
    findings = run_lint([_SRC], include_contracts=False)
    assert findings == [], "\n".join(f.render() for f in findings)


# ---------------------------------------------------------------------------
# runtime shadow ledger (PL25x)
# ---------------------------------------------------------------------------

def _raises_code(code):
    return pytest.raises(SanitizerError, match=f"^{code}")


def test_shadow_unit_transitions():
    led = ShadowLedger(n_pages=8)
    led.on_alloc([1, 2])
    with _raises_code("PL253"):
        led.on_alloc([2])                    # double-alloc
    led.on_ref([1])
    with _raises_code("PL250"):
        led.on_ref([5])                      # ref on free page
    with _raises_code("PL252"):
        led.on_unref([1], freed=[1])         # freed with a live sharer
    led2 = ShadowLedger()
    led2.on_alloc([3])
    led2.on_unref([3], freed=[3])
    with _raises_code("PL251"):
        led2.on_unref([3], freed=[3])        # double-free
    led3 = ShadowLedger()
    led3.on_alloc([4, 5])
    with _raises_code("PL254"):
        led3.check_live([4, 9])              # use-after-evict
    with _raises_code("PL255"):
        led3.assert_no_leaks(expected_live=[4])   # 5 is an orphan
    led3.assert_no_leaks(expected_live=[4, 5])


@pytest.fixture(scope="module")
def sanitized_pool():
    os.environ["REPRO_SANITIZE"] = "1"       # conftest default, made explicit
    from repro.configs import get_smoke_config
    from repro.serving.memory import PagedStatePool
    cfg = get_smoke_config("llama3.2-1b")
    return PagedStatePool(cfg, n_pages=9, n_slabs=5)


def test_pool_double_unref_caught(sanitized_pool):
    pool = sanitized_pool
    assert pool.placement._shadow is not None, "sanitizer must be attached"
    assert pool.register(70, 2)
    pages = list(pool.page_table[70])
    pool.release(70)                         # legitimate release
    with _raises_code("PL251"):
        pool.placement.unref(pages)          # injected double-unref


def test_pool_use_after_evict_caught(sanitized_pool):
    pool = sanitized_pool
    assert pool.register(71, 2)
    stale = list(pool.page_table[71])
    pool.placement.unref(stale)              # pages freed under the table
    with _raises_code("PL254"):
        pool.block_table([71])
    # repair the pool for subsequent tests: drop the dangling entry
    pool.page_table.pop(71)
    pool._free_slabs.append(pool.slab_of.pop(71))


def test_pool_teardown_leak_caught(sanitized_pool):
    pool = sanitized_pool
    assert pool.register(72, 2)
    pool.page_table.pop(72)                  # injected leak: pages orphaned
    with _raises_code("PL255"):
        pool.sanitizer_check_leaks()
    # repair: re-own and release cleanly, then the check passes
    leaked = pool.placement._shadow.live_pages()
    pool.placement.unref(leaked)
    pool._free_slabs.append(pool.slab_of.pop(72))
    pool.sanitizer_check_leaks()


def test_clean_lifecycle_passes_sanitizer(sanitized_pool):
    pool = sanitized_pool
    assert pool.register(73, 3)
    assert pool.grow(73, 1)
    pool.release(73)
    pool.sanitizer_check_leaks()


# ---------------------------------------------------------------------------
# pass 3: registry contracts (RC3xx)
# ---------------------------------------------------------------------------

def _broken_registry(monkeypatch, *ops):
    from repro.ops import registry
    patched = dict(registry._REGISTRY)
    for op in ops:
        for fmt in op.formats:
            patched[(op.kind, op.backend, fmt, op.layout)] = op
    monkeypatch.setattr(registry, "_REGISTRY", patched)


def test_contracts_clean_on_real_registry():
    from repro.analysis.lint.contracts import lint_registry_contracts
    findings = lint_registry_contracts()
    assert findings == [], "\n".join(f.render() for f in findings)


def test_contract_missing_impl_and_twin(monkeypatch):
    from repro.analysis.lint.contracts import lint_registry_contracts
    from repro.ops.base import SpuOp

    class _Hollow(SpuOp):
        kind = "state_update"
        backend = "pallas"
        formats = ("_lint_fake",)
        layout = "dense"

    _broken_registry(monkeypatch, _Hollow())
    codes = _codes(lint_registry_contracts())
    assert "RC301" in codes                  # no execute/traffic override
    assert "RC304" in codes                  # pallas without a jnp twin


def test_contract_invalid_traffic(monkeypatch):
    from repro.analysis.lint.contracts import lint_registry_contracts
    from repro.ops.base import SpuOp, TrafficBytes

    class _Negative(SpuOp):
        kind = "state_update"
        backend = "jnp"
        formats = ("_lint_fake",)
        layout = "dense"

        def execute(self, state, inputs, plan):
            return state, None

        def traffic(self, plan):
            return TrafficBytes(state_read=-1.0)

    _broken_registry(monkeypatch, _Negative())
    codes = _codes(lint_registry_contracts())
    assert "RC302" in codes


def test_contract_page_alignment(monkeypatch):
    from repro.analysis.lint.contracts import lint_registry_contracts
    from repro.ops.base import SpuOp, TrafficBytes

    class _Unaligned(SpuOp):
        kind = "attn_decode"
        backend = "jnp"
        formats = ("_lint_fake",)
        layout = "paged"

        def execute(self, state, inputs, plan):
            return state, None

        def traffic(self, plan):
            # token-granular state reads: illegal for a paged op
            return TrafficBytes(state_read=float(plan.dim("T")))

    _broken_registry(monkeypatch, _Unaligned())
    codes = _codes(lint_registry_contracts())
    assert "RC303" in codes


def test_contract_config_coverage(monkeypatch):
    from repro import configs
    from repro.analysis.lint.contracts import lint_registry_contracts
    monkeypatch.setattr(configs, "ALL_ARCHS",
                        list(configs.ALL_ARCHS) + ["_lint_bogus_arch"])
    findings = [f for f in lint_registry_contracts() if f.code == "RC305"]
    assert findings and "_lint_bogus_arch" in findings[0].message
