"""Unit + property tests for the low-precision formats (paper §3.2/§5.3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# optional in the execution environment; CI installs it (see ci.yml).
# importorskip keeps the module COLLECTABLE either way -- a module-level
# ImportError would abort the whole suite's collection, not just this file.
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import formats as F

FMTS = ["mx8", "int8", "fp8_e4m3", "fp8_e5m2", "fp16"]


@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("shape", [(4, 32), (2, 3, 128), (1, 256)])
def test_roundtrip_shapes(fmt, shape):
    x = jax.random.normal(jax.random.PRNGKey(0), shape)
    qt = F.quantize(x, fmt)
    xd = F.dequantize(qt)
    assert xd.shape == shape
    assert jnp.all(jnp.isfinite(xd))


def test_error_ordering_matches_paper():
    """Fig. 6 accuracy axis: int8 < mx8 < e4m3 < e5m2 in RMS error for
    well-scaled data (mantissa width ordering)."""
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 256))
    rms = {f: float(jnp.sqrt(jnp.mean((F.dequantize(F.quantize(x, f)) - x) ** 2)))
           for f in ["int8", "mx8", "fp8_e4m3", "fp8_e5m2"]}
    assert rms["int8"] < rms["mx8"] < rms["fp8_e4m3"] < rms["fp8_e5m2"]


def test_mx8_idempotent():
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 128))
    xd = F.dequantize(F.mx8_quantize(x))
    xd2 = F.dequantize(F.mx8_quantize(xd))
    assert jnp.array_equal(xd, xd2)


def test_mx8_zero_group():
    x = jnp.zeros((2, 32))
    qt = F.mx8_quantize(x)
    assert float(jnp.abs(F.dequantize(qt)).sum()) == 0.0


def test_mx8_storage_budget():
    """MX8 must average exactly 8 bits/value: 7 payload + 8/16 exp + 1/2 µe."""
    assert F.FORMAT_BITS["mx8"] == 8.0
    qt = F.mx8_quantize(jnp.ones((4, 64)))
    n = 4 * 64
    logical_bits = (qt.payload["mantissa"].size * 7
                    + qt.payload["exponent"].size * 8
                    + qt.payload["micro"].size * 8)
    assert logical_bits == n * 8


def test_sr_unbiased():
    """Stochastic rounding preserves values in expectation (the property that
    defeats swamping, paper §3.2)."""
    val = 0.031415  # not representable in 6-bit mantissa
    x = jnp.full((4096, 16), val)
    bits = F.sr_bits(x.shape, seed=7)
    got = float(F.dequantize(F.mx8_quantize(x, "stochastic", bits)).mean())
    # nearest rounding collapses to the representable neighbor; SR's sample
    # mean must beat RNE's systematic bias by a wide margin
    rne = float(F.dequantize(F.mx8_quantize(x, "nearest")).mean())
    assert abs(got - val) < abs(rne - val) / 5
    assert abs(rne - val) > 1e-4


def test_counter_hash_deterministic_and_uniform():
    b1 = F.sr_bits((1000,), seed=3)
    b2 = F.sr_bits((1000,), seed=3)
    assert jnp.array_equal(b1, b2)
    b3 = F.sr_bits((1000,), seed=4)
    assert not jnp.array_equal(b1, b3)
    u = np.asarray(b1, dtype=np.float64) / 2**32
    assert 0.4 < u.mean() < 0.6
    assert abs(np.mean(u < 0.25) - 0.25) < 0.05


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(min_value=-1e4, max_value=1e4,
                          allow_nan=False, allow_infinity=False),
                min_size=16, max_size=16))
def test_mx8_error_bound_property(vals):
    """|x - q(x)| <= 2^-6 * group_max + tiny, for every element."""
    x = jnp.asarray(vals, jnp.float32)[None, :]
    xd = F.dequantize(F.mx8_quantize(x))
    gmax = float(jnp.max(jnp.abs(x)))
    err = float(jnp.max(jnp.abs(xd - x)))
    assert err <= gmax * 2.0 ** -5 + 1e-6


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_fp8_sr_stays_in_range(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed % 1000), (4, 32)) * 100
    bits = F.sr_bits(x.shape, seed=seed)
    for fmt in ("fp8_e4m3", "fp8_e5m2"):
        xd = F.dequantize(F.quantize(x, fmt, "stochastic", bits))
        assert jnp.all(jnp.isfinite(xd))
        assert float(jnp.max(jnp.abs(xd))) <= F._FP8_MAX[fmt]


def test_strict_mx_arith_close_to_fused():
    """The hardware MX-adder path (strict) vs our fused f32 path differ by
    at most one extra rounding step (DESIGN.md §2)."""
    key = jax.random.PRNGKey(5)
    a = jax.random.normal(key, (8, 64))
    b = jax.random.normal(jax.random.PRNGKey(6), (8, 64))
    strict = F.strict_mx_add(a, b)
    fused = F.dequantize(F.mx8_quantize(a + b))
    denom = jnp.maximum(jnp.abs(a + b), 1e-3)
    assert float(jnp.median(jnp.abs(strict - fused) / denom)) < 0.05
