"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, bitwise state.

The backend-parity sweep at the bottom iterates the SPU op REGISTRY rather
than a hardcoded kernel list: for every (op kind, format) with more than one
registered backend, all backends must produce bit-identical packed state and
matching outputs.  Registering a new backend automatically enrolls it."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops as OPS
from repro.core import attention_cache as AC
from repro.core import formats as F
from repro.kernels import ref
from repro.kernels.mx_attention import mx_attention_decode
from repro.kernels.mx_quant import mx_quantize
from repro.kernels.mx_state_update import mx_state_update


def _su_inputs(B, H, dk, dv, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    S0 = jax.random.normal(ks[0], (B, H, dv, dk), dtype)
    d = jax.nn.sigmoid(jax.random.normal(ks[1], (B, H, dk), dtype))
    k = jax.random.normal(ks[2], (B, H, dk), dtype)
    v = jax.random.normal(ks[3], (B, H, dv), dtype)
    q = jax.random.normal(ks[4], (B, H, dk), dtype)
    return F.mx8_quantize(S0), d, k, v, q


@pytest.mark.parametrize("B,H,dk,dv", [
    (1, 1, 16, 16),        # minimum tile
    (2, 3, 128, 64),       # mamba2-like (N=128, P=64)
    (1, 2, 64, 128),       # zamba-like
    (2, 1, 256, 512),      # retnet-like
    (1, 1, 128, 1040),     # mlstm-like augmented dv
])
@pytest.mark.parametrize("rounding", ["nearest", "stochastic"])
def test_state_update_kernel_bitwise(B, H, dk, dv, rounding):
    qS, d, k, v, q = _su_inputs(B, H, dk, dv)
    qr, yr = ref.quantized_state_update_stored_ref(
        qS, d, k, v, q, rounding=rounding, seed=11)
    qk, yk = mx_state_update(qS, d, k, v, q, seed=11, rounding=rounding)
    for f in ("mantissa", "exponent", "micro"):
        assert jnp.array_equal(qr.payload[f], qk.payload[f]), f
    np.testing.assert_allclose(yr, yk, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("in_dtype", [jnp.float32, jnp.bfloat16])
def test_state_update_kernel_dtypes(in_dtype):
    qS, d, k, v, q = _su_inputs(2, 2, 128, 64, dtype=in_dtype)
    qk, yk = mx_state_update(qS, d, k, v, q, seed=0)
    assert yk.dtype == jnp.float32
    assert jnp.all(jnp.isfinite(yk))


def test_state_update_scalar_decay_broadcast():
    qS, d, k, v, q = _su_inputs(2, 2, 128, 64)
    d_scalar = d[..., :1]
    q1, y1 = mx_state_update(qS, d_scalar, k, v, q, seed=3)
    d_full = jnp.broadcast_to(d_scalar, d.shape)
    q2, y2 = mx_state_update(qS, d_full, k, v, q, seed=3)
    assert jnp.array_equal(q1.payload["mantissa"], q2.payload["mantissa"])
    np.testing.assert_allclose(y1, y2, rtol=1e-6)


def test_state_update_multi_step_matches_ref():
    """Several chained steps stay bitwise equal (SR counters line up)."""
    qS, d, k, v, q = _su_inputs(1, 2, 64, 32)
    qR = qS
    for step in range(5):
        qS, _ = mx_state_update(qS, d, k, v, q, seed=step)
        qR, _ = ref.quantized_state_update_stored_ref(
            qR, d, k, v, q, rounding="stochastic", seed=step)
    assert jnp.array_equal(qS.payload["mantissa"], qR.payload["mantissa"])


@pytest.mark.parametrize("B,H,KVH,dh,T,t_blk", [
    (1, 4, 4, 64, 128, 128),     # MHA
    (2, 8, 2, 128, 256, 64),     # GQA G=4
    (1, 15, 5, 64, 256, 128),    # smollm heads (G=3)
])
def test_attention_kernel_vs_ref(B, H, KVH, dh, T, t_blk):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, dh))
    K = jax.random.normal(ks[1], (B, T, KVH, dh))
    V = jax.random.normal(ks[2], (B, T, KVH, dh))
    lengths = jnp.arange(1, B + 1) * (T // (B + 1)) + 1
    qK, qV = F.mx8_quantize(K), F.mx8_quantize(V)
    y_ref = ref.mx_attention_decode_ref(q, qK, qV, lengths)
    y_k = mx_attention_decode(q, qK, qV, lengths, t_block=t_blk)
    np.testing.assert_allclose(y_ref, y_k, rtol=2e-4, atol=2e-5)


def test_attention_kernel_mla_mode():
    B, H, dkc, vw, T = 2, 16, 192, 128, 256
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    q = jax.random.normal(ks[0], (B, H, dkc))
    C = jax.random.normal(ks[1], (B, T, 1, dkc))
    qC = F.mx8_quantize(C)
    lengths = jnp.array([200, 64], jnp.int32)
    y = mx_attention_decode(q, qC, None, lengths, v_width=vw)
    kf = F.dequantize(qC)
    y_ref = ref.attention_decode_ref(q, kf, kf[..., :vw], lengths,
                                     scale=dkc ** -0.5)
    np.testing.assert_allclose(y_ref, y, rtol=2e-4, atol=2e-5)


def test_attention_kernel_respects_lengths():
    """Entries beyond `lengths` must not contribute."""
    B, H, KVH, dh, T = 1, 2, 2, 64, 256
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, H, dh))
    K = jax.random.normal(ks[1], (B, T, KVH, dh))
    V = jax.random.normal(ks[2], (B, T, KVH, dh))
    L = 100
    y1 = mx_attention_decode(q, F.mx8_quantize(K), F.mx8_quantize(V),
                             jnp.array([L]))
    K2 = K.at[:, L:].set(99.0)
    V2 = V.at[:, L:].set(-99.0)
    y2 = mx_attention_decode(q, F.mx8_quantize(K2), F.mx8_quantize(V2),
                             jnp.array([L]))
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("rounding", ["nearest", "stochastic"])
@pytest.mark.parametrize("shape", [(16, 64), (300, 128), (5, 7, 32)])
def test_quant_kernel_bitwise(rounding, shape):
    x = jax.random.normal(jax.random.PRNGKey(3), shape)
    qk = mx_quantize(x, seed=9, rounding=rounding, row_block=64)
    qr = ref.mx_quantize_ref(x, rounding=rounding, seed=9)
    for f in ("mantissa", "exponent", "micro"):
        assert jnp.array_equal(qk.payload[f], qr.payload[f]), f


# ---------------------------------------------------------------------------
# registry-driven backend parity: every (op kind, format) with >1 backend
# ---------------------------------------------------------------------------

def _multi_backend_cases():
    """Dense-layout (kind, fmt) pairs with more than one registered backend.

    The paged-layout backends get the same treatment in
    ``tests/test_paged_decode.py``, which also pins them bit-identical to
    the dense-gather path end to end.
    """
    cases = {}
    for kind, backend, fmt, layout in OPS.registered():
        if layout == "dense":
            cases.setdefault((kind, fmt), set()).add(backend)
    return sorted((k, f, tuple(sorted(bs)))
                  for (k, f), bs in cases.items() if len(bs) > 1)


PARITY_CASES = _multi_backend_cases()


def _assert_state_identical(a, b, ctx):
    if isinstance(a, F.QuantizedTensor):
        for f in a.payload:
            assert jnp.array_equal(a.payload[f], b.payload[f]), (ctx, f)
    else:
        assert jnp.array_equal(a, b), ctx


@pytest.mark.parametrize("kind,fmt,backends", PARITY_CASES,
                         ids=[f"{k}-{f}" for k, f, _ in PARITY_CASES])
@pytest.mark.parametrize("rounding", ["nearest", "stochastic"])
def test_registry_backend_parity(kind, fmt, backends, rounding):
    """All registered backends of a (kind, fmt) agree: bit-identical packed
    state, matching outputs."""
    B, H, KVH, dk, dv, T = 2, 4, 2, 64, 32, 128
    results = []
    for backend in backends:
        cfg = OPS.StateQuantConfig(fmt=fmt, rounding=rounding,
                                   backend=backend)
        assert OPS.resolve_backend(kind, fmt, backend, strict=True) == backend
        if kind == "state_update":
            S0 = OPS.init_state(B, H, dk, dv, cfg)
            ks = jax.random.split(jax.random.PRNGKey(0), 4)
            d = jax.nn.sigmoid(jax.random.normal(ks[0], (B, H, dk)))
            k = jax.random.normal(ks[1], (B, H, dk))
            v = jax.random.normal(ks[2], (B, H, dv))
            q = jax.random.normal(ks[3], (B, H, dk))
            Sn, y = OPS.state_update_step(S0, d, k, v, q, cfg, seed=11)
            results.append((backend, Sn, y))
        elif kind in ("attn_decode", "mla_decode"):
            ks = jax.random.split(jax.random.PRNGKey(1), 3)
            if kind == "mla_decode":
                cache = AC.init_kv_cache(B, T, 1, dk + dv, cfg,
                                         mla_v_width=dk)
                kv, vv = jax.random.normal(ks[0], (B, 1, 1, dk + dv)), None
                q = jax.random.normal(ks[1], (B, H, dk + dv))
            else:
                cache = AC.init_kv_cache(B, T, KVH, dk, cfg)
                kv = jax.random.normal(ks[0], (B, 1, KVH, dk))
                vv = jax.random.normal(ks[2], (B, 1, KVH, dk))
                q = jax.random.normal(ks[1], (B, H, dk))
            for step in range(3):
                cache = AC.append(cache, kv, vv, cfg, seed=step)
            y = OPS.attn_decode(cache, q, cfg)
            results.append((backend, cache.k, y))
        else:
            pytest.skip(f"{kind}: single-backend kinds are not parity cases")
    (b0, S_ref, y_ref), rest = results[0], results[1:]
    for backend, Sn, y in rest:
        _assert_state_identical(S_ref, Sn, (kind, fmt, b0, backend))
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y),
                                   rtol=2e-4, atol=2e-5,
                                   err_msg=f"{kind}/{fmt}: {b0} vs {backend}")
