"""End-to-end behaviour + hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# optional in the execution environment; CI installs it (see ci.yml)
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import attention_cache as AC
from repro.core import formats as F
from repro import ops


# ---------------------------------------------------------------------------
# Eq. 2 algebraic invariants (hypothesis)
# ---------------------------------------------------------------------------

dims = st.sampled_from([(16, 16), (32, 16), (16, 48)])


@settings(max_examples=20, deadline=None)
@given(dims, st.integers(0, 2**16))
def test_state_update_zero_decay_resets(dkdv, seed):
    """d=0 forgets the old state entirely: S' = k vᵀ exactly."""
    dk, dv = dkdv
    ks = jax.random.split(jax.random.PRNGKey(seed % 997), 4)
    S0 = jax.random.normal(ks[0], (1, 1, dv, dk))
    k = jax.random.normal(ks[1], (1, 1, dk))
    v = jax.random.normal(ks[2], (1, 1, dv))
    q = jax.random.normal(ks[3], (1, 1, dk))
    Sn, y = ops.state_update_float(S0, jnp.zeros((1, 1, 1)), k, v, q,
                                   dtype=jnp.float32)
    expect = v[0, 0][:, None] * k[0, 0][None, :]
    np.testing.assert_allclose(Sn[0, 0], expect, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(y[0, 0], expect @ q[0, 0], rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(dims, st.floats(0.1, 0.99))
def test_state_update_linearity_in_v(dkdv, decay):
    """Eq.2 is linear in v: doubling v doubles the rank-1 increment."""
    dk, dv = dkdv
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    k = jax.random.normal(ks[1], (1, 1, dk))
    v = jax.random.normal(ks[2], (1, 1, dv))
    q = jax.random.normal(ks[3], (1, 1, dk))
    Z = jnp.zeros((1, 1, dv, dk))
    d = jnp.full((1, 1, 1), decay)
    S1, _ = ops.state_update_float(Z, d, k, v, q, dtype=jnp.float32)
    S2, _ = ops.state_update_float(Z, d, k, 2 * v, q, dtype=jnp.float32)
    np.testing.assert_allclose(2 * S1, S2, rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**16))
def test_quantized_update_bounded_drift(seed):
    """One MX8 step's deviation from the f32 step is bounded by the format's
    relative error on the state magnitude."""
    dk = dv = 32
    ks = jax.random.split(jax.random.PRNGKey(seed % 991), 4)
    S0 = jax.random.normal(ks[0], (1, 1, dv, dk))
    d = jax.nn.sigmoid(jax.random.normal(ks[1], (1, 1, dk)))
    k = jax.random.normal(ks[2], (1, 1, dk))
    v = jax.random.normal(ks[3], (1, 1, dv))
    q = jnp.ones((1, 1, dk))
    cfg = ops.StateQuantConfig()
    qS = F.mx8_quantize(S0)
    qn, yq = ops.state_update_step(qS, d, k, v, q, cfg, seed=seed)
    Sf, yf = ops.state_update_float(F.dequantize(qS), d, k, v, q,
                                    dtype=jnp.float32)
    rel = float(jnp.linalg.norm(F.dequantize(qn) - Sf)
                / jnp.linalg.norm(Sf))
    assert rel < 0.03, rel


# ---------------------------------------------------------------------------
# KV cache invariants
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(1, 6))
def test_cache_append_then_attend_prefix_invariance(n_tok):
    """Tokens appended after position L never change attention at length L."""
    cfg = ops.StateQuantConfig()
    B, KVH, dh, T = 1, 2, 32, 128
    cache = AC.init_kv_cache(B, T, KVH, dh, cfg)
    ks = jax.random.split(jax.random.PRNGKey(n_tok), 3)
    for i in range(n_tok):
        kv = jax.random.normal(jax.random.fold_in(ks[0], i), (B, 1, KVH, dh))
        cache = AC.append(cache, kv, kv, cfg, seed=i)
    q = jax.random.normal(ks[2], (B, 4, dh))
    frozen = AC.KVCache(cache.k, cache.v, jnp.full((B,), n_tok), cfg.fmt)
    y1 = AC.attend(frozen, q, cfg)
    extra = jax.random.normal(ks[1], (B, 1, KVH, dh)) * 50
    cache2 = AC.append(cache, extra, extra, cfg, seed=99)
    frozen2 = AC.KVCache(cache2.k, cache2.v, jnp.full((B,), n_tok), cfg.fmt)
    y2 = AC.attend(frozen2, q, cfg)
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-6)


def test_cache_append_roundtrip_values():
    cfg = ops.StateQuantConfig()
    B, KVH, dh, T = 2, 1, 16, 128
    cache = AC.init_kv_cache(B, T, KVH, dh, cfg)
    k0 = jnp.ones((B, 1, KVH, dh)) * 0.5
    cache = AC.append(cache, k0, k0, cfg)
    kd = F.dequantize(cache.k)
    np.testing.assert_allclose(kd[:, 0], 0.5, rtol=0.02)
    assert float(jnp.abs(kd[:, 1:]).max()) == 0.0
    assert list(np.asarray(cache.lengths)) == [1, 1]


# ---------------------------------------------------------------------------
# end-to-end: quantized serving degrades gracefully
# ---------------------------------------------------------------------------

def test_e2e_quantized_vs_float_generation():
    """Greedy generations from MX8 and fp32 caches start identically on a
    random tiny model (logits gaps >> quantization noise)."""
    from repro.configs import get_smoke_config
    from repro.models import model as M
    toks = {}
    for fmt in ("fp32", "mx8"):
        cfg = get_smoke_config("mamba2-2.7b").with_(
            state_quant=ops.StateQuantConfig(fmt=fmt, rounding="stochastic",
                                            backend="jnp"))
        params = M.init_model(jax.random.PRNGKey(7), cfg)
        prompt = jax.random.randint(jax.random.PRNGKey(8), (1, 16), 0,
                                    cfg.vocab_size)
        batch = {"tokens": prompt, "targets": prompt}
        logits, caches = M.prefill(params, cfg, batch)
        lengths = jnp.full((1,), 16, jnp.int32)
        caches = M.set_cache_lengths(caches, lengths)
        seq = [int(jnp.argmax(logits[0]))]
        for i in range(4):
            logits, caches = M.decode_step(
                params, cfg, jnp.asarray([seq[-1]], jnp.int32), caches,
                lengths + i, seed=i)
            seq.append(int(jnp.argmax(logits[0])))
        toks[fmt] = seq
    assert toks["fp32"] == toks["mx8"], toks
