"""Optimizer, data pipeline, pimsim, and loss-goes-down integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import pimsim as PS
from repro.data.pipeline import DataConfig, SyntheticLM, make_batch_fn
from repro.models import model as M
from repro.train import optimizer as O
from repro.train.train_loop import LoopConfig, make_train_step, train_loop


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def _numpy_adamw(p, g, m, v, step, opt):
    lr = float(O.schedule(opt, jnp.asarray(step)))
    m = opt.b1 * m + (1 - opt.b1) * g
    v = opt.b2 * v + (1 - opt.b2) * g * g
    mh = m / (1 - opt.b1 ** step)
    vh = v / (1 - opt.b2 ** step)
    return p - lr * (mh / (np.sqrt(vh) + opt.eps) + opt.weight_decay * p), m, v


def test_adamw_matches_numpy_reference():
    opt = O.OptimizerConfig(lr=1e-2, warmup_steps=0, clip_norm=1e9,
                            weight_decay=0.1)
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
    g = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]])}
    state = O.init_opt_state(p, opt)
    pn, mn, vn = np.asarray(p["w"]), np.zeros((2, 2)), np.zeros((2, 2))
    for step in range(1, 4):
        p, state, _ = O.adamw_update(p, g, state, opt)
        pn, mn, vn = _numpy_adamw(pn, np.asarray(g["w"]), mn, vn, step, opt)
        np.testing.assert_allclose(np.asarray(p["w"]), pn, rtol=1e-5)


def test_grad_clipping():
    opt = O.OptimizerConfig(lr=1e-2, clip_norm=0.1, warmup_steps=0,
                            weight_decay=0.0)
    p = {"w": jnp.zeros(3)}
    g = {"w": jnp.asarray([100.0, 0.0, 0.0])}
    state = O.init_opt_state(p, opt)
    _, state, metrics = O.adamw_update(p, g, state, opt)
    assert float(metrics["grad_norm"]) == pytest.approx(100.0)
    # clipped gradient enters the moments
    assert float(state["m"]["w"][0]) == pytest.approx(0.1 * 0.1, rel=1e-4)


def test_schedule_shape():
    opt = O.OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    lrs = [float(O.schedule(opt, jnp.asarray(s))) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1)


def test_grad_accum_equivalence():
    cfg = get_smoke_config("smollm-360m")
    opt = O.OptimizerConfig(lr=1e-3, warmup_steps=0)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    st = O.init_opt_state(params, opt)
    batch_fn = make_batch_fn(cfg, seq_len=32, global_batch=4)
    b = batch_fn(0)
    p1, _, m1 = make_train_step(cfg, opt)(params, st, b)
    p2, _, m2 = make_train_step(cfg, opt, grad_accum=2)(params, st, b)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
    d = max(float(jnp.max(jnp.abs(a - c)))
            for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 1e-5


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_step_indexed():
    cfg = DataConfig(seq_len=64, global_batch=4, vocab_size=100)
    lm = SyntheticLM(cfg)
    a = lm.batch(3)
    b = lm.batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = lm.batch(4)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_host_sharding():
    cfg = DataConfig(seq_len=16, global_batch=8, vocab_size=50)
    lm = SyntheticLM(cfg)
    h0 = lm.batch(0, host=0, n_hosts=2)
    h1 = lm.batch(0, host=1, n_hosts=2)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_data_has_learnable_structure():
    cfg = DataConfig(seq_len=128, global_batch=2, vocab_size=100)
    lm = SyntheticLM(cfg)
    b = lm.batch(0)
    P, half = cfg.copy_period, cfg.copy_period // 2
    toks = np.concatenate([b["tokens"], b["targets"][:, -1:]], 1)
    assert np.array_equal(toks[:, half:P], toks[:, 0:half])


# ---------------------------------------------------------------------------
# training integration: loss decreases on structured data
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_loss_decreases():
    cfg = get_smoke_config("smollm-360m")
    opt = O.OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=40)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    opt_state = O.init_opt_state(params, opt)
    batch_fn = make_batch_fn(cfg, seq_len=64, global_batch=8)
    step_fn = jax.jit(make_train_step(cfg, opt))
    params, opt_state, hist = train_loop(
        step_fn, params, opt_state, batch_fn,
        LoopConfig(total_steps=30, log_every=1000, checkpoint_every=1000),
        log=lambda *_: None)
    assert np.mean(hist[-5:]) < np.mean(hist[:5]) - 0.2, hist


# ---------------------------------------------------------------------------
# pimsim: the paper's architecture ratios
# ---------------------------------------------------------------------------

def test_pimsim_fig5_ratios():
    sys_cfg = PS.SystemConfig()
    spec = PS.PAPER_MODELS["retnet-2.7b"]
    w = PS.StateWorkload(128, spec.n_layers, spec.n_heads, spec.dk, spec.dv,
                         "fp16")
    t_gpu = PS.gpu_state_update_latency(w, sys_cfg)
    tm = t_gpu / PS.pim_state_update_latency(w, sys_cfg, "time_multiplexed")
    pl = t_gpu / PS.pim_state_update_latency(w, sys_cfg, "pipelined")
    assert 2.3 < tm < 3.3, f"time-mux {tm} (paper: 2.8x)"
    assert 3.6 < pl < 5.0, f"pipelined {pl} (paper: 4.3x)"


def test_pimsim_fig12_throughput_ordering():
    sys_cfg = PS.SystemConfig()
    for name in ("retnet-2.7b", "mamba2-2.7b", "zamba2-7b"):
        spec = PS.PAPER_MODELS[name]
        th = {s: PS.generation_throughput(spec, 128, 2048, sys_cfg, s)
              for s in ("gpu", "gpu_q", "gpu_pim", "pimba")}
        assert th["gpu"] < th["gpu_q"] <= th["gpu_pim"] < th["pimba"], (name, th)
        assert th["pimba"] / th["gpu"] <= 4.5   # paper: up to 4.1x
        assert th["pimba"] / th["gpu_pim"] <= 2.4  # paper: up to 2.1x


def test_pimsim_batch_scaling():
    """State-update fraction grows with batch (paper Fig. 3 trend)."""
    sys_cfg = PS.SystemConfig()
    spec = PS.PAPER_MODELS["retnet-2.7b"]
    fracs = []
    for b in (32, 128):
        lat = PS.generation_step_latency(spec, b, 2048, sys_cfg, "gpu")
        fracs.append(lat["state"] / lat["total"])
    assert fracs[1] > fracs[0]
    assert fracs[1] > 0.5           # paper: 73.8% at batch 128
