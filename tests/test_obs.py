"""Observability subsystem: registry semantics, span lifecycle ordering,
Chrome-trace export validity, recompile watcher, engine integration."""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.state_update import StateQuantConfig
from repro.models import model as M
from repro.obs import (Observability, MetricsRegistry, TraceBuffer,
                       LifecycleTracker, RecompileWatcher, PHASES,
                       validate_chrome_trace, trace_features)
from repro.obs.metrics import Histogram
from repro.serving.api import Engine, ServeConfig


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_gauge_semantics():
    m = MetricsRegistry()
    m.counter("reqs").inc()
    m.counter("reqs").inc(2.5)
    assert m.value("reqs") == 3.5
    with pytest.raises(ValueError):
        m.counter("reqs").inc(-1)
    g = m.gauge("active")
    g.set(4)
    g.dec()
    assert m.value("active") == 3.0
    # untouched metrics read 0.0, never KeyError (schema stability)
    assert m.value("never_written") == 0.0
    assert m.value("reqs", ) == 3.5


def test_labels_partition_families():
    m = MetricsRegistry()
    m.counter("requests_total", status="done").inc(3)
    m.counter("requests_total", status="aborted").inc()
    assert m.value("requests_total", status="done") == 3.0
    assert m.value("requests_total", status="aborted") == 1.0
    assert m.value("requests_total", status="truncated") == 0.0
    # kind / label-set mismatches are bugs, not silent new families
    with pytest.raises(ValueError):
        m.gauge("requests_total", status="done")
    with pytest.raises(ValueError):
        m.counter("requests_total", other="x")


def test_histogram_exact_then_bounded():
    h = Histogram(cap=8)
    xs = [3.0, 1.0, 2.0, 5.0, 4.0]
    for x in xs:
        h.observe(x)
    assert h.count == 5 and h.sum == 15.0 and h.mean == 3.0
    # below the cap the percentile is exact np.percentile of everything
    assert h.percentile(50) == float(np.percentile(xs, 50))
    assert h.percentile(99) == float(np.percentile(xs, 99))
    for x in range(100):
        h.observe(float(x))
    assert h.count == 105            # count/sum stay exact
    assert len(h.samples) < 8        # reservoir stays bounded
    s = h.summary()
    assert set(s) == {"count", "sum", "mean", "p50", "p90", "p99", "max"}


def test_empty_histogram_reads_zero():
    m = MetricsRegistry()
    h = m.histogram("step_s", compile="false")
    assert h.percentile(99) == 0.0 and h.mean == 0.0
    assert m.family_samples("step_s") == []
    assert m.family_count("nope") == 0.0


def test_prometheus_text_renders_all_kinds():
    m = MetricsRegistry()
    m.counter("toks").inc(7)
    m.gauge("live", pool="a").set(2)
    m.histogram("lat_s").observe(0.5)
    text = m.prometheus_text()
    assert "# TYPE toks counter" in text
    assert "toks 7" in text
    assert 'live{pool="a"} 2' in text
    assert "# TYPE lat_s summary" in text
    assert 'lat_s{quantile="0.99"} 0.5' in text
    assert "lat_s_count 1" in text


# ---------------------------------------------------------------------------
# trace buffer
# ---------------------------------------------------------------------------

def test_trace_ring_keeps_metadata_and_counts_drops():
    tr = TraceBuffer(capacity=4)
    for i in range(10):
        tr.instant(f"e{i}", cat="x")
    assert tr.dropped == 6
    evs = tr.events()
    # thread_name metadata survives ring eviction
    assert any(e["ph"] == "M" for e in evs)
    obj = tr.to_chrome()
    assert validate_chrome_trace(obj) == []
    assert obj["otherData"]["dropped_events"] == 6


def test_trace_export_chrome_and_jsonl(tmp_path):
    tr = TraceBuffer()
    tr.complete("step", cat="step", ts=tr.now_us(), dur=100.0, batch=2)
    tr.counter("bank_traffic", {"pch00_bursts": 3.0})
    tr.async_span("decode", 7, "request", 0.0, 50.0, rid=7)
    p_json, p_jsonl = tmp_path / "t.json", tmp_path / "t.jsonl"
    tr.save(str(p_json))
    tr.save(str(p_jsonl))
    obj = json.loads(p_json.read_text())
    assert validate_chrome_trace(obj) == []
    feats = trace_features(obj)
    assert {"steps", "spans", "bank"} <= feats
    lines = [json.loads(L) for L in p_jsonl.read_text().splitlines()]
    assert len(lines) == len(tr.events())


def test_schema_catches_invalid_traces():
    assert validate_chrome_trace([]) == ["top level must be an object"]
    assert validate_chrome_trace({}) == ["missing traceEvents list"]
    bad = {"traceEvents": [
        {"ph": "X", "name": "no_dur", "pid": 1, "tid": 0, "ts": 0.0},
        {"ph": "b", "name": "open", "cat": "request", "id": "1",
         "pid": 1, "tid": 0, "ts": 0.0},            # never closed
        {"ph": "?", "name": "junk", "pid": 1, "tid": 0, "ts": 0.0},
    ]}
    errs = validate_chrome_trace(bad)
    assert any("dur" in e for e in errs)
    assert any("dangling" in e for e in errs)
    assert any("unknown phase" in e for e in errs)


# ---------------------------------------------------------------------------
# lifecycle spans
# ---------------------------------------------------------------------------

def test_span_chain_complete_and_derived_metrics():
    tr = TraceBuffer()
    m = MetricsRegistry()
    lc = LifecycleTracker(tr, m)
    lc.enqueued(1, t=10.0)
    lc.phase(1, "prefill", t=12.0)
    lc.phase(1, "decode", t=13.0)
    lc.phase(1, "spilled", t=14.0)
    lc.phase(1, "decode", t=16.5)
    lc.first_token(1, t=13.5)
    lc.finish(1, "done", n_tokens=5, t=20.0)
    rec = lc.record(1)
    assert rec.complete_chain()
    assert rec.phase_sequence() == ["queued", "prefill", "decode",
                                    "spilled", "decode"]
    assert rec.queue_delay_s == 2.0
    assert rec.ttft_s == 3.5
    assert rec.preemption_cost_s == 2.5
    assert rec.tpot_s == pytest.approx((20.0 - 13.5) / 4)
    # duplicate phase transition is a no-op, not a new span
    lc2 = LifecycleTracker()
    lc2.enqueued(2, t=0.0)
    lc2.phase(2, "decode", t=1.0)
    lc2.phase(2, "decode", t=2.0)
    assert len(lc2.record(2).spans) == 2


def test_interrupt_closes_span_without_terminal_status():
    lc = LifecycleTracker(TraceBuffer(), MetricsRegistry())
    lc.enqueued(3, t=0.0)
    lc.phase(3, "decode", t=1.0)
    lc.interrupt(3, t=2.0)
    rec = lc.record(3)
    assert not rec.terminal and rec.interrupted
    assert rec.spans[-1].closed and rec.spans[-1].interrupted
    assert lc.open_spans() == []
    # work resumes: a fresh span opens, and finishing completes the chain
    lc.phase(3, "decode", t=3.0)
    lc.finish(3, "done", n_tokens=2, t=4.0)
    assert lc.record(3).complete_chain()


def test_phases_vocabulary_enforced():
    lc = LifecycleTracker()
    lc.enqueued(1)
    with pytest.raises(AssertionError):
        lc.phase(1, "warp_drive")
    assert set(PHASES) == {"queued", "prefill", "decode", "spilled"}


# ---------------------------------------------------------------------------
# recompile watcher
# ---------------------------------------------------------------------------

def test_recompile_watcher_detects_shape_change():
    obs = Observability()
    fn = obs.wrap_jit(jax.jit(lambda x: x * 2), "f")
    fn(np.ones((4,), np.float32))
    assert fn.n_compiles == 1
    assert obs.recompiles.n_events == 1
    assert obs.recompiles.events[0].is_warmup
    fn(np.ones((4,), np.float32))          # cache hit: no new event
    assert obs.recompiles.n_events == 1
    fn(np.ones((8,), np.float32))          # fresh abstract shape
    assert fn.n_compiles == 2
    ev = obs.recompiles.events[-1]
    assert not ev.is_warmup
    assert any("(4,)" in c and "(8,)" in c for c in ev.changed)
    assert obs.recompiles.n_recompiles == 1
    assert obs.recompiles.counts() == {"f": 2}
    # the trace carries the signature (the CI --require recompile_signature)
    obj = obs.tracer.to_chrome()
    assert "recompile_signature" in trace_features(obj)
    # metrics mirror
    assert obs.metrics.value("recompiles_total", fn="f") == 2.0


def test_watched_function_is_transparent():
    obs = Observability()
    jitted = jax.jit(lambda x: x + 1)
    fn = obs.wrap_jit(jitted, "g")
    out = fn(jnp_ones := np.ones((2,), np.float32))
    np.testing.assert_allclose(np.asarray(out), jnp_ones + 1)
    # attribute passthrough keeps the retrace-pin idiom working
    assert fn._cache_size() == 1


# ---------------------------------------------------------------------------
# engine integration (both backends)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_fp32():
    cfg = get_smoke_config("llama3.2-1b").with_(
        state_quant=StateQuantConfig(fmt="fp32", rounding="nearest",
                                     backend="jnp"))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    return params, cfg


def _mk(params, cfg, backend):
    return Engine(params, cfg, ServeConfig(backend=backend, batch=2,
                                           cache_capacity=128, n_pages=9,
                                           n_slabs=5))


@pytest.mark.parametrize("backend", ["slots", "paged"])
def test_engine_trace_valid_and_chains_complete(tiny_fp32, backend):
    params, cfg = tiny_fp32
    eng = _mk(params, cfg, backend)
    rng = np.random.default_rng(0)
    hs = [eng.submit(rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                     max_new_tokens=3) for _ in range(3)]
    eng.run()
    obj = eng.obs.tracer.to_chrome()
    assert validate_chrome_trace(obj) == []
    feats = trace_features(obj)
    assert {"steps", "spans", "recompile"} <= feats
    if backend == "paged":
        assert "bank" in feats
    # every terminal request has a complete queued->terminal chain
    recs = eng.obs.lifecycle.terminal_records()
    assert len(recs) == 3
    for r in recs:
        assert r.complete_chain()
        assert r.phase_sequence()[0] == "queued"
    assert eng.obs.lifecycle.open_spans() == []
    # per-request record is reachable through the facade
    rec = eng.lifecycle(hs[0])
    assert rec is not None and rec.ttft_s > 0


def test_stats_is_registry_view(tiny_fp32):
    params, cfg = tiny_fp32
    eng = _mk(params, cfg, "slots")
    rng = np.random.default_rng(1)
    eng.submit(rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
               max_new_tokens=4)
    eng.run()
    st = eng.stats()
    m = eng.obs.metrics
    assert st["tokens"] == m.value("tokens_total")
    assert st["requests_done"] == m.value("requests_total", status="done")
    assert st["prefill_tokens"] == m.value("prefill_tokens_total")
    assert st["compile_steps"] + \
        m.histogram("step_s", compile="false").count \
        == m.family_count("step_s")
    assert st["recompiles"] >= 1.0
    # compile-tagged steps are excluded from the nocompile percentile
    assert st["p99_step_nocompile_s"] <= st["p99_step_s"]


def test_run_max_steps_interrupts_spans(tiny_fp32):
    """The run(max_steps) bugfix: surfaced still-active requests get their
    open span closed with an explicit interrupted marker -- the exported
    trace has no dangling async spans."""
    params, cfg = tiny_fp32
    eng = _mk(params, cfg, "paged")
    rng = np.random.default_rng(2)
    hs = [eng.submit(rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                     max_new_tokens=64) for _ in range(2)]
    out = eng.run(max_steps=2)
    live = [r for r in out if r.status not in ("done", "aborted",
                                               "truncated")]
    assert live, "workload must still be active at max_steps"
    assert eng.obs.lifecycle.open_spans() == []
    for r in live:
        rec = eng.obs.lifecycle.record(r.rid)
        assert rec.interrupted and rec.spans[-1].interrupted
    assert validate_chrome_trace(eng.obs.tracer.to_chrome()) == []
    # resuming reopens a span in the interrupted phase; chains complete
    eng.run()
    for h in hs:
        rec = eng.obs.lifecycle.record(h.rid)
        assert rec.complete_chain()
    for r in live:
        seq = eng.obs.lifecycle.record(r.rid).phase_sequence()
        assert seq.count("decode") >= 2


def test_prometheus_endpoint_smoke(tiny_fp32):
    params, cfg = tiny_fp32
    eng = _mk(params, cfg, "paged")
    rng = np.random.default_rng(3)
    eng.submit(rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
               max_new_tokens=2)
    eng.run()
    text = eng.prometheus_text()
    assert "# TYPE requests_total counter" in text
    assert "# TYPE step_s summary" in text
    assert "pages_alloc_total" in text
