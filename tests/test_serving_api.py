"""Request-lifecycle serving API: ServeConfig backend selection, streaming
handles, open-loop step(), abort at every lifecycle point, stats schema."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.state_update import StateQuantConfig
from repro.models import model as M
from repro.serving.api import Engine, RequestHandle, ServeConfig
from repro.serving.engine import PagedEngineConfig, EngineConfig


@pytest.fixture(scope="module")
def tiny_fp32():
    cfg = get_smoke_config("llama3.2-1b").with_(
        state_quant=StateQuantConfig(fmt="fp32", rounding="nearest",
                                     backend="jnp"))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    return params, cfg


def _mk(params, cfg, backend, **kw):
    base = dict(batch=2, cache_capacity=128, n_pages=9, n_slabs=5)
    base.update(kw)
    return Engine(params, cfg, ServeConfig(backend=backend, **base))


# ---------------------------------------------------------------------------
# config + construction
# ---------------------------------------------------------------------------

def test_serve_config_selects_backend(tiny_fp32):
    params, cfg = tiny_fp32
    assert isinstance(ServeConfig(backend="slots").engine_config(),
                      EngineConfig)
    pcfg = ServeConfig(backend="paged", batch=3).engine_config()
    assert isinstance(pcfg, PagedEngineConfig)
    assert pcfg.max_decode_batch == 3 and pcfg.n_slabs == 7  # 2B+1 default
    with pytest.raises(ValueError):
        ServeConfig(backend="gpu")
    for backend in ("slots", "paged"):
        assert _mk(params, cfg, backend).backend == backend


def test_slots_backend_rejects_fork_and_retain(tiny_fp32):
    params, cfg = tiny_fp32
    eng = _mk(params, cfg, "slots")
    with pytest.raises(ValueError, match="paged"):
        eng.submit(np.arange(4, dtype=np.int32), retain=True)
    with pytest.raises(ValueError, match="paged"):
        eng.session()


# ---------------------------------------------------------------------------
# streaming: tokens surface per step, handle iteration drives the loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["slots", "paged"])
def test_streaming_order_matches_final_output(tiny_fp32, backend):
    params, cfg = tiny_fp32
    eng = _mk(params, cfg, backend)
    rng = np.random.default_rng(0)
    hs = [eng.submit(rng.integers(0, cfg.vocab_size, 8 + 3 * i
                                  ).astype(np.int32), max_new_tokens=5)
          for i in range(3)]
    streamed = {h.rid: [] for h in hs}
    arrivals = 0
    while eng.step():
        for h in hs:
            got = h.new_tokens()
            streamed[h.rid].extend(got)
            arrivals += bool(got)
    for h in hs:
        streamed[h.rid].extend(h.new_tokens())
        assert h.status == "done"
        assert streamed[h.rid] == h.output, (h.rid, streamed[h.rid], h.output)
        assert len(h.output) == 5
    assert arrivals > 3          # tokens arrived incrementally, not at drain


def test_handle_iteration_drives_engine(tiny_fp32):
    params, cfg = tiny_fp32
    eng = _mk(params, cfg, "paged")
    rng = np.random.default_rng(1)
    h1 = eng.submit(rng.integers(0, cfg.vocab_size, 10).astype(np.int32),
                    max_new_tokens=6)
    h2 = eng.submit(rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
                    max_new_tokens=4)
    toks = list(h1)              # continuous batching: h2 progresses too
    assert toks == h1.output and len(toks) == 6
    assert h1.status == "done"
    h2.result()
    assert h2.status == "done" and len(h2.output) == 4


# ---------------------------------------------------------------------------
# abort: queued, mid-decode, spilled
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["slots", "paged"])
def test_abort_mid_decode_frees_capacity(tiny_fp32, backend):
    params, cfg = tiny_fp32
    eng = _mk(params, cfg, backend)
    rng = np.random.default_rng(2)
    ha = eng.submit(rng.integers(0, cfg.vocab_size, 10).astype(np.int32),
                    max_new_tokens=12)
    hb = eng.submit(rng.integers(0, cfg.vocab_size, 11).astype(np.int32),
                    max_new_tokens=12)
    for _ in range(3):
        eng.step()
    assert ha.status == "running" and len(ha.output) >= 2
    seen = len(ha.output)
    assert ha.abort()
    assert ha.status == "aborted"
    assert not ha.abort()        # terminal: second abort is a no-op
    hb.result()
    assert hb.status == "done" and len(hb.output) == 12
    # freed capacity is immediately reusable
    hc = eng.submit(rng.integers(0, cfg.vocab_size, 9).astype(np.int32),
                    max_new_tokens=3)
    hc.result()
    assert hc.status == "done" and len(hc.output) == 3
    # the aborted handle kept its streamed tokens, and no more arrived
    assert len(ha.output) == seen
    if backend == "paged":
        pool = eng.engine.pool
        assert pool.free_pages == pool.usable_pages
        assert len(pool.page_table) == 0
    st = eng.stats()
    assert st["requests_aborted"] == 1 and st["requests_done"] == 2


@pytest.mark.parametrize("backend", ["slots", "paged"])
def test_abort_queued_request_never_runs(tiny_fp32, backend):
    params, cfg = tiny_fp32
    eng = _mk(params, cfg, backend, batch=1)
    rng = np.random.default_rng(3)
    h1 = eng.submit(rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=4)
    h2 = eng.submit(rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=4)
    assert h2.status == "queued"
    assert h2.abort() and h2.status == "aborted"
    done = eng.run()
    assert h1.status == "done"
    assert h2.output == []
    assert {r.rid for r in done} == {h1.rid, h2.rid}
    if backend == "paged":
        assert len(eng.engine.sched) == 0


def test_abort_spilled_request_drops_pages(tiny_fp32):
    """Preempt a victim into host spill, then abort it: the blob and its
    page references must be dropped, the survivor must finish normally."""
    params, cfg = tiny_fp32
    eng = _mk(params, cfg, "paged", batch=2, n_pages=4, n_slabs=5)
    rng = np.random.default_rng(4)
    hs = [eng.submit(rng.integers(0, cfg.vocab_size, 120).astype(np.int32),
                     max_new_tokens=12) for _ in range(2)]
    while not eng.engine.spilled and eng.step():
        pass
    assert eng.engine.spilled, "pool too large: no preemption happened"
    victim_rid = next(iter(eng.engine.spilled))
    victim = next(h for h in hs if h.rid == victim_rid)
    survivor = next(h for h in hs if h.rid != victim_rid)
    assert victim.abort()
    assert victim.status == "aborted"
    assert victim_rid not in eng.engine.spilled
    assert len(eng.engine.sched) == 0     # heap entry tombstoned + pruned
    survivor.result()
    assert survivor.status == "done" and len(survivor.output) == 12
    pool = eng.engine.pool
    assert pool.free_pages == pool.usable_pages
    assert pool.free_slabs == pool.n_slabs - 1


# ---------------------------------------------------------------------------
# run(max_steps) + stats schema (the {"tokens": 0} bugfix)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["slots", "paged"])
def test_run_step_cap_surfaces_active_requests(tiny_fp32, backend):
    params, cfg = tiny_fp32
    eng = _mk(params, cfg, backend, batch=1)
    rng = np.random.default_rng(5)
    h1 = eng.submit(rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=10)
    h2 = eng.submit(rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=10)
    out = eng.run(max_steps=2)
    statuses = {r.rid: r.status for r in out}
    assert statuses[h1.rid] == "running"     # surfaced, not dropped
    assert statuses[h2.rid] == "queued"
    st = eng.stats()
    assert st["active_requests"] == 1 and st["queued_requests"] == 1
    # drain completes normally afterwards
    done = eng.run()
    assert all(r.status == "done" for r in done)


def test_slots_capacity_clip_is_truncated_not_done(tiny_fp32):
    """A request stopped by slot capacity (not max_new/eos) was clipped:
    it must end `truncated`, matching the paged pool's contract."""
    params, cfg = tiny_fp32
    eng = _mk(params, cfg, "slots", batch=1, cache_capacity=128)
    rng = np.random.default_rng(6)
    h = eng.submit(rng.integers(0, cfg.vocab_size, 120).astype(np.int32),
                   max_new_tokens=50)
    h.result()
    assert h.status == "truncated"
    assert h.request.truncated
    assert 0 < len(h.output) < 50
    assert eng.stats()["requests_truncated"] == 1


_SCHEMA = ("tokens", "wall_s", "tokens_per_s", "prefill_tokens",
           "requests_done", "requests_aborted", "requests_truncated",
           "active_requests", "queued_requests",
           "mean_ttft_s", "p50_ttft_s", "p99_ttft_s",
           "p50_step_s", "p99_step_s",
           "p50_tok_latency_s", "p99_tok_latency_s")


@pytest.mark.parametrize("backend", ["slots", "paged"])
def test_stats_full_schema_before_any_finish(tiny_fp32, backend):
    params, cfg = tiny_fp32
    eng = _mk(params, cfg, backend)
    st = eng.stats()
    for key in _SCHEMA:
        assert key in st, key
        assert st[key] == 0.0, (key, st[key])
    if backend == "paged":
        for key in ("preemptions", "occupancy", "fragmentation",
                    "gather_bytes", "pages_allocated", "shared_page_hits",
                    "shared_page_savings"):
            assert key in st and st[key] == 0.0, key
