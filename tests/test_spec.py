"""Speculative decoding: greedy exactness, state rollback, draft sources,
acceptance accounting, and mid-speculation teardown.

The load-bearing guarantee is *greedy exactness*: with speculation on, the
emitted token stream is bit-identical to non-speculative paged decoding --
drafts only decide how many of the model's own tokens one fused verify pass
may confirm.  The parity matrix below pins that across attention (llama),
SSM (mamba2) and hybrid shared-attention (zamba2) architectures, on both
the pallas/mx8 and jnp/fp32 paths, for both draft sources.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.state_update import StateQuantConfig
from repro.models import model as M
from repro.serving.api import Engine, ServeConfig
from repro.serving.engine import (PagedEngineConfig, PagedServingEngine,
                                  Request)
from repro.serving.memory import PAGE_TOKENS, PagedStatePool, pages_for
from repro.serving.sampler import SamplingConfig
from repro.serving.spec import KController, ModelDraft, NGramDraft

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # container has no hypothesis; CI installs it
    HAVE_HYPOTHESIS = False


_CACHE = {}


def _build(arch, fmt="fp32", backend="jnp"):
    key = (arch, fmt, backend)
    if key not in _CACHE:
        cfg = get_smoke_config(arch).with_(
            state_quant=StateQuantConfig(fmt=fmt, rounding="nearest",
                                         backend=backend))
        _CACHE[key] = (M.init_model(jax.random.PRNGKey(0), cfg), cfg)
    return _CACHE[key]


def _serve(params, cfg, prompts, spec, max_new=5, spec_k=3, **kw):
    eng = PagedServingEngine(params, cfg, PagedEngineConfig(
        max_decode_batch=2, n_pages=17, n_slabs=5, prefill_chunk=128,
        spec=spec, spec_k=spec_k, **kw))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=max_new))
    done = eng.run()
    return eng, {r.rid: list(r.output) for r in done}


def _prompts(cfg, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in (12, 9)]


# ---------------------------------------------------------------------------
# greedy exactness: the parity matrix
# ---------------------------------------------------------------------------

PARITY_MATRIX = [
    ("llama3.2-1b", "fp32", "jnp"),
    ("llama3.2-1b", "mx8", "pallas"),
    ("mamba2-2.7b", "fp32", "jnp"),
    ("mamba2-2.7b", "mx8", "pallas"),
    ("zamba2-2.7b", "fp32", "jnp"),
    ("zamba2-2.7b", "mx8", "pallas"),
]


@pytest.mark.parametrize("arch,fmt,backend", PARITY_MATRIX)
def test_spec_ngram_greedy_bit_identical(arch, fmt, backend):
    params, cfg = _build(arch, fmt, backend)
    prompts = _prompts(cfg)
    _, ref = _serve(params, cfg, prompts, spec=None)
    eng, out = _serve(params, cfg, prompts, spec="ngram")
    assert out == ref, (arch, fmt, backend)
    st = eng.stats()
    assert st["accepted_tokens"] <= st["proposed_tokens"]


# the model-draft source drives the identical verify/rollback machinery, so
# one pallas config suffices on top of the per-family jnp coverage
MODEL_DRAFT_MATRIX = [
    ("llama3.2-1b", "fp32", "jnp"),
    ("llama3.2-1b", "mx8", "pallas"),
    ("mamba2-2.7b", "fp32", "jnp"),
    ("zamba2-2.7b", "fp32", "jnp"),
]


@pytest.mark.parametrize("arch,fmt,backend", MODEL_DRAFT_MATRIX)
def test_spec_model_draft_greedy_bit_identical(arch, fmt, backend):
    params, cfg = _build(arch, fmt, backend)
    prompts = _prompts(cfg)
    _, ref = _serve(params, cfg, prompts, spec=None, max_new=4)
    eng, out = _serve(params, cfg, prompts, spec="model:llama3.2-1b",
                      max_new=4)
    assert out == ref, (arch, fmt, backend)
    # same arch + same params seed drafts for itself on the jnp path, but
    # exactness must hold whatever the draft proposes -- no acceptance gate


# ---------------------------------------------------------------------------
# pool-level verify parity + bit-exact rollback of rejected positions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,length", [("mamba2-2.7b", 127),
                                         ("zamba2-2.7b", 128)])
def test_spec_verify_positions_and_rollback_bit_exact(arch, length, n=3):
    """decode_spec position i's logits == the i-th sequential decode step,
    and commit_spec restores the state slab of *exactly* the selected
    position: all-accept equals n sequential steps, sel=0 equals one."""
    params, cfg = _build(arch)
    pool = PagedStatePool(cfg, n_pages=10, n_slabs=5)
    rng = np.random.default_rng(length)
    prompt = rng.integers(0, cfg.vocab_size, length).astype(np.int32)
    pr = jnp.asarray(prompt)[None]
    logits, row = jax.jit(lambda p, b: M.prefill(p, cfg, b))(
        params, {"tokens": pr, "targets": pr})
    assert pool.register(1, pages_for(length))
    pool.insert_prefill(1, row)
    tok = int(jnp.argmax(logits[0]))

    # copies, not views: the pools are donated into later jitted steps, so
    # a zero-copy np.asarray view would read reused buffers
    snapshot = [np.array(x) for x in pool.pools]
    pages0 = list(pool.page_table[1])

    def slab_rows(pools):
        s = pool.slab_of[1]
        return [np.array(p[s]) for p, spec
                in zip(pools, pool.paging.specs) if spec.kind == "slab"]

    def rewind():
        grown = [p for p in pool.page_table[1] if p not in pages0]
        if grown:
            pool.placement.free(grown)
        pool.page_table[1] = list(pages0)
        pool.pools = [jnp.asarray(x) for x in snapshot]

    # sequential reference: n steps, seeds 1..n
    seq_logits, toks = [], [tok]
    L = np.array([length, 0], np.int32)
    for step in range(n):
        while L[0] // PAGE_TOKENS + 1 > len(pool.page_table[1]):
            assert pool.grow(1, 1)
        lg = pool.decode(params, [1, None],
                         np.array([toks[-1], 0], np.int32), L, seed=step + 1)
        seq_logits.append(np.array(lg))
        toks.append(int(jnp.argmax(lg[0])))
        L[0] += 1
    seq_slabs = slab_rows(pool.pools)

    # one verify pass over the same n tokens at seed 1 (per-position seeds
    # seed + i match the sequential steps' 1..n)
    rewind()
    while pages_for(length + n) > len(pool.page_table[1]):
        assert pool.grow(1, 1)
    tokens = np.array([toks[:n], [0] * n], np.int32)
    lengths = np.array([length, 0], np.int32)
    lg, snaps = pool.decode_spec(params, [1, None], tokens, lengths, seed=1,
                                 min_pages=pages_for(length + n))
    lg = np.array(lg)
    for i in range(n):
        np.testing.assert_array_equal(lg[:1, i], seq_logits[i][:1],
                                      err_msg=f"position {i}")

    # all-accept: slab rows == n sequential steps
    pool.commit_spec([1, None], snaps, np.array([n - 1, 0], np.int32))
    for a, b in zip(slab_rows(pool.pools), seq_slabs):
        np.testing.assert_array_equal(a, b)

    # rollback to position 0: slab rows == exactly one sequential step
    rewind()
    while pages_for(length + n) > len(pool.page_table[1]):
        assert pool.grow(1, 1)
    _, snaps2 = pool.decode_spec(params, [1, None], tokens, lengths, seed=1,
                                 min_pages=pages_for(length + n))
    pool.commit_spec([1, None], snaps2, np.array([0, 0], np.int32))
    rolled = slab_rows(pool.pools)
    rewind()
    while length // PAGE_TOKENS + 1 > len(pool.page_table[1]):
        assert pool.grow(1, 1)
    pool.decode(params, [1, None], np.array([toks[0], 0], np.int32),
                np.array([length, 0], np.int32), seed=1)
    for a, b in zip(rolled, slab_rows(pool.pools)):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# acceptance accounting + stream ordering
# ---------------------------------------------------------------------------

def test_spec_acceptance_accounting_and_stream_order():
    """Per-run invariants of the acceptance counters, and the stream is
    append-only: tokens surface through the handle in emit order and an
    earlier read is always a prefix of a later one (sampled mode included --
    only greedy promises *which* tokens, every mode promises the order)."""
    params, cfg = _build("llama3.2-1b")
    rng = np.random.default_rng(7)
    base = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    prompt = np.concatenate([base, base, base]).astype(np.int32)
    for temp in (0.0, 0.8):
        eng = Engine(params, cfg, ServeConfig(
            backend="paged", batch=2, n_pages=17, n_slabs=5,
            sampling=SamplingConfig(temperature=temp, top_p=0.9),
            spec="ngram", spec_k=3))
        h = eng.submit(prompt, max_new_tokens=16)
        seen = []
        while eng.step():
            out = h.output
            assert out[:len(seen)] == seen, "token stream reordered"
            seen = out
        assert h.status == "done" and len(h.output) == 16
        st = eng.stats()
        assert 0 <= st["accepted_tokens"] <= st["proposed_tokens"]
        assert 0.0 <= st["acceptance_rate"] <= 1.0
        if st["proposed_tokens"]:
            assert st["accepted_tokens_per_step"] >= 1.0


def test_spec_stats_schema_stable_when_off():
    params, cfg = _build("llama3.2-1b")
    eng = PagedServingEngine(params, cfg, PagedEngineConfig(
        max_decode_batch=2, n_pages=9, n_slabs=5, prefill_chunk=128))
    eng.submit(Request(rid=0, prompt=_prompts(cfg)[1], max_new_tokens=2))
    eng.run()
    st = eng.stats()
    for key in ("proposed_tokens", "accepted_tokens", "acceptance_rate",
                "accepted_tokens_per_step"):
        assert st[key] == 0.0, key


# ---------------------------------------------------------------------------
# draft sources and the k-controller (host-side, model-free)
# ---------------------------------------------------------------------------

def test_ngram_draft_proposes_the_repeating_continuation():
    d = NGramDraft()
    d.admit(0, [])
    ctx = [1, 2, 3, 9, 1, 2, 3]
    assert d.propose(0, ctx, 2) == [9, 1]      # after the 3-gram [1, 2, 3]
    assert d.propose(0, [5, 6, 7], 3) == []    # nothing repeats
    d.release(0)
    assert d.propose(0, ctx, 2) == []          # released rids never propose


def test_kcontroller_decays_and_recovers():
    k = KController(k_max=4, window=4)
    assert k.k_for(0) == 4                     # optimistic start
    for _ in range(4):
        k.observe(0, 4, 0)
    assert k.k_for(0) == 1                     # full rejection decays to 1
    for _ in range(4):
        k.observe(0, 4, 4)
    assert k.k_for(0) == 4                     # full acceptance climbs back
    k.observe(0, 0, 0)                         # no drafts = no evidence
    assert k.k_for(0) == 4
    k.forget(0)
    assert k.k_for(0) == 4


def test_model_draft_catchup_and_rollback_counter():
    params, cfg = _build("llama3.2-1b")
    d = ModelDraft(cfg, params, max_requests=2, max_len=512)
    prompt = list(map(int, _prompts(cfg)[0]))
    assert d.admit(1, prompt)
    out1 = d.propose(1, prompt, 3)
    assert len(out1) == 3 and d.consumed[1] == len(prompt)
    # rejected drafts are behind the counter: the next call re-proposes from
    # the verified context and the first draft is reproducible
    out2 = d.propose(1, prompt, 3)
    assert out2 == out1
    # accepted tokens arrive as context; the draft catches up, then drafts
    out3 = d.propose(1, prompt + out1[:2], 2)
    assert len(out3) == 2
    d.release(1)
    assert 1 not in d.consumed
    d.sanitizer_check_leaks()                  # pages freed with the rid


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.integers(0, 7), min_size=1, max_size=40),
           st.integers(1, 5))
    def test_prop_ngram_proposal_is_a_witnessed_continuation(ctx, k):
        """Whatever propose returns actually follows an earlier occurrence
        of the context's trailing gram, and never exceeds k tokens."""
        d = NGramDraft()
        d.admit(0, [])
        out = d.propose(0, ctx, k)
        assert 0 <= len(out) <= k
        if out:
            n = len(ctx)
            witnessed = False
            for g in range(min(d.max_gram, n - 1), 0, -1):
                tail = ctx[n - g:]
                for start in range(n - g - 1, -1, -1):
                    if (ctx[start:start + g] == tail
                            and ctx[start + g:start + g + len(out)] == out):
                        witnessed = True
            assert witnessed

    @settings(max_examples=60, deadline=None)
    @given(st.integers(1, 6), st.integers(1, 8),
           st.lists(st.tuples(st.integers(0, 6), st.integers(0, 6)),
                    max_size=30))
    def test_prop_kcontroller_bounds(k_max, window, history):
        """k_for stays in [1, k_max] under any observation history, and
        observations never record accepted > proposed evidence backwards."""
        k = KController(k_max=k_max, window=window)
        for proposed, accepted in history:
            k.observe(0, proposed, min(accepted, proposed))
            assert 1 <= k.k_for(0) <= k_max
        assert 1 <= k.k_for(0) <= k_max
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_prop_ngram_proposal_is_a_witnessed_continuation():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_prop_kcontroller_bounds():
        pass


# ---------------------------------------------------------------------------
# mid-speculation teardown: abort, preempt, chaos alloc
# ---------------------------------------------------------------------------

def test_spec_abort_mid_speculation_unwinds_cleanly():
    """Aborting a request mid-speculation frees its target pages AND its
    draft-model state: drafted-but-unverified tokens die with the draft
    (they were never in the output), and the drained engine passes the
    shadow-ledger teardown for both pools."""
    params, cfg = _build("llama3.2-1b")
    prompts = _prompts(cfg)
    eng = PagedServingEngine(params, cfg, PagedEngineConfig(
        max_decode_batch=2, n_pages=40, n_slabs=5, prefill_chunk=128,
        spec="model:llama3.2-1b", spec_k=3))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=12))
    while not (len(eng.active) == 2
               and all(len(a.req.output) >= 2
                       for a in eng.active.values())):
        assert eng.step()
    assert 0 in eng.draft.consumed             # mid-speculation, draft live
    assert eng.abort(0)
    assert 0 not in eng.draft.consumed         # draft state went with it
    eng.run()
    done = {r.rid: r for r in eng.done}
    assert done[0].status == "aborted"
    assert done[1].status == "done"
    # parity for the survivor: same tokens as a clean non-spec run
    _, ref = _serve(params, cfg, prompts, spec=None, max_new=12)
    assert list(done[1].output) == ref[1]
    eng.draft.sanitizer_check_leaks()


def test_spec_preempt_mid_speculation_stays_bit_exact():
    """Preempting a speculating request spills, resumes, and still emits
    the exact greedy stream; the draft source is suspended and lazily
    re-admitted after resume."""
    params, cfg = _build("llama3.2-1b")
    prompts = _prompts(cfg)
    _, ref = _serve(params, cfg, prompts, spec=None, max_new=8)
    eng = PagedServingEngine(params, cfg, PagedEngineConfig(
        max_decode_batch=2, n_pages=17, n_slabs=5, prefill_chunk=128,
        spec="ngram", spec_k=3))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=8))
    while not any(len(a.req.output) >= 2 for a in eng.active.values()):
        assert eng.step()
    rid = next(r for r, a in eng.active.items() if len(a.req.output) >= 2)
    eng._preempt(rid)
    done = {r.rid: list(r.output) for r in eng.run()}
    assert done == ref
    assert eng.preemptions >= 1


def test_spec_chaos_alloc_inside_verify_step():
    """A transient alloc fault during speculative headroom growth recovers
    (retry or preemption) without leaking pages or corrupting the stream."""
    params, cfg = _build("llama3.2-1b")
    prompts = _prompts(cfg)
    _, ref = _serve(params, cfg, prompts, spec=None, max_new=6)
    eng, out = _serve(params, cfg, prompts, spec="ngram", max_new=6,
                      fault_plan="alloc:nth=1")
    assert out == ref
    assert eng.obs.metrics.value("faults_recovered_total", site="alloc") >= 1
