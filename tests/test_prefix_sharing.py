"""Copy-on-write prefix sharing: fork bit-exactness vs unshared re-prefill,
refcount lifecycle, CoW tail isolation, spill/resume of shared pages,
multi-turn sessions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.paged import PAGE_TOKENS, pages_for
from repro.core.state_update import StateQuantConfig
from repro.models import model as M
from repro.serving.api import Engine, ServeConfig
from repro.serving.memory import PagedStatePool
from repro.serving.scheduler import SchedulerConfig


@pytest.fixture(scope="module")
def tiny_fp32():
    cfg = get_smoke_config("llama3.2-1b").with_(
        state_quant=StateQuantConfig(fmt="fp32", rounding="nearest",
                                     backend="jnp"))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    return params, cfg


@pytest.fixture(scope="module")
def hybrid_fp32():
    cfg = get_smoke_config("zamba2-2.7b").with_(
        state_quant=StateQuantConfig(fmt="fp32", rounding="nearest",
                                     backend="jnp"))
    params = M.init_model(jax.random.PRNGKey(3), cfg)
    return params, cfg


def _paged(params, cfg, **kw):
    base = dict(batch=3, n_pages=9, n_slabs=7)
    base.update(kw)
    return Engine(params, cfg, ServeConfig(backend="paged", **base))


def _full_context(parent, child):
    """The token sequence a forked child's decode is conditioned on."""
    return np.concatenate([
        np.asarray(parent.request.prompt, np.int32),
        np.asarray(parent.output, np.int32),
        np.asarray(child.request.prompt, np.int32)])


# ---------------------------------------------------------------------------
# pool-level: fork shares physical pages, decode rows agree bitwise
# ---------------------------------------------------------------------------

def test_pool_fork_shares_pages_and_logits_bit_identical(tiny_fp32):
    params, cfg = tiny_fp32
    pool = PagedStatePool(cfg, n_pages=9, n_slabs=5)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 140).astype(np.int32)
    pr = jnp.asarray(prompt)[None]
    logits, row = jax.jit(lambda p, b: M.prefill(p, cfg, b))(
        params, {"tokens": pr, "targets": pr})
    assert pool.register(1, pages_for(len(prompt)))
    pool.insert_prefill(1, row)
    before = pool.pages_allocated
    assert pool.fork(1, 2, len(prompt))
    # CoW cost: one private tail page, prefix shared by reference
    assert pool.pages_allocated == before + 1
    assert pool.page_table[2][0] == pool.page_table[1][0]       # shared
    assert pool.page_table[2][1] != pool.page_table[1][1]       # copied tail
    assert pool.shared_page_savings == 1
    tok = int(jnp.argmax(logits[0]))
    lg = pool.decode(params, [1, 2, None],
                     np.array([tok, tok, 0], np.int32),
                     np.array([140, 140, 0], np.int32), seed=7)
    np.testing.assert_array_equal(np.asarray(lg[0]), np.asarray(lg[1]))
    pool.release(1)
    assert pool.shared_page_savings == 0     # child now sole owner
    pool.release(2)
    assert pool.free_pages == pool.usable_pages


def test_pool_fork_at_page_boundary_copies_nothing(tiny_fp32):
    params, cfg = tiny_fp32
    pool = PagedStatePool(cfg, n_pages=9, n_slabs=5)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, PAGE_TOKENS).astype(np.int32)
    pr = jnp.asarray(prompt)[None]
    _, row = jax.jit(lambda p, b: M.prefill(p, cfg, b))(
        params, {"tokens": pr, "targets": pr})
    assert pool.register(1, 1)
    pool.insert_prefill(1, row)
    before = pool.pages_allocated
    assert pool.fork(1, 2, PAGE_TOKENS)
    assert pool.pages_allocated == before    # zero new pages
    assert pool.page_table[2] == pool.page_table[1]
    assert pool.shared_page_savings == 1


# ---------------------------------------------------------------------------
# engine-level: forked continuations == unshared re-prefill, exactly
# ---------------------------------------------------------------------------

def test_fork_matches_unshared_reprefill(tiny_fp32):
    params, cfg = tiny_fp32
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 140).astype(np.int32)
    turn = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)

    eng = _paged(params, cfg)
    parent = eng.submit(prompt, max_new_tokens=2, retain=True)
    parent.result()
    assert parent.status == "done"
    child = eng.fork(parent, turn, max_new_tokens=5)
    child.result()
    # no re-prefill happened: only the parent's prompt plus the child's
    # streamed continuation tokens were ever ingested
    st = eng.stats()
    assert st["prefill_tokens"] == len(prompt) + len(turn) + 1
    assert st["shared_page_hits"] == 1

    # the acceptance reference is the unshared *dense* re-prefill path:
    # the fixed-slot engine prefills the full context into contiguous
    # caches -- no pages, no sharing, no chunking
    ref_eng = Engine(params, cfg, ServeConfig(backend="slots", batch=2,
                                              cache_capacity=256))
    ref = ref_eng.submit(_full_context(parent, child), max_new_tokens=5)
    ref.result()
    assert child.output == ref.output, (child.output, ref.output)
    # sharing saved prefill work vs the unshared run
    rst = ref_eng.stats()
    assert st["prefill_tokens"] - len(prompt) < rst["prefill_tokens"]


def test_parallel_forks_share_prefix_and_agree(tiny_fp32):
    """N sampled continuations of one prompt: all children share the full
    prefix pages; with greedy sampling they must agree token-for-token."""
    params, cfg = tiny_fp32
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 140).astype(np.int32)
    eng = _paged(params, cfg)
    parent = eng.submit(prompt, max_new_tokens=1, retain=True)
    parent.result()
    kids = [eng.fork(parent, max_new_tokens=4) for _ in range(2)]
    eng.run()
    assert kids[0].output == kids[1].output
    assert all(k.status == "done" for k in kids)
    st = eng.stats()
    # 2 pages (parent) + 1 tail copy per child; prefix page never re-alloced
    assert st["pages_allocated"] == 2 + 2
    assert st["shared_page_hits"] == 2
    # vs 2 independent submissions: 2 * 2 pages just for the prompts
    assert st["pages_allocated"] < 2 * pages_for(len(prompt) + 5) + 2


def test_fork_tail_copy_isolates_parent(tiny_fp32):
    """A child's appends go to its private tail copy: forking the same
    parent again after the first child ran must see pristine state."""
    params, cfg = tiny_fp32
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, 135).astype(np.int32)
    eng = _paged(params, cfg)
    parent = eng.submit(prompt, max_new_tokens=1, retain=True)
    parent.result()
    first = eng.fork(parent, max_new_tokens=5)
    first.result()
    second = eng.fork(parent, max_new_tokens=5)
    second.result()
    assert first.output == second.output, (first.output, second.output)


def test_refcounts_drop_to_zero_after_all_owners(tiny_fp32):
    params, cfg = tiny_fp32
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, 140).astype(np.int32)
    eng = _paged(params, cfg)
    parent = eng.submit(prompt, max_new_tokens=1, retain=True)
    parent.result()
    kids = [eng.fork(parent, max_new_tokens=3) for _ in range(2)]
    # drive until both children hold their shared references
    while any(k.status == "queued" for k in kids):
        eng.step()
    pool = eng.engine.pool
    prefix_page = pool.page_table[parent.rid][0]
    assert pool.placement.refcount(prefix_page) == 3
    assert pool.shared_page_savings == 2
    eng.run()
    assert pool.placement.refcount(prefix_page) == 1   # parent only
    eng.release(parent)
    assert pool.placement.refcount(prefix_page) == 0
    assert pool.shared_page_savings == 0
    assert pool.free_pages == pool.usable_pages
    assert pool.free_slabs == pool.n_slabs - 1


def test_spill_resume_with_shared_pages_bit_exact(tiny_fp32):
    """Preempt a fork holding shared prefix pages: the shared page must not
    leave the device (the co-owners keep it), resume must continue
    bit-exactly, and the final tokens must equal the unshared reference."""
    params, cfg = tiny_fp32
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab_size, 140).astype(np.int32)
    # pool sized so the urgent late arrival forces preempting a fork:
    # parent 2 pages + 2 fork tails + 1 for the short prompt = 5 > 4 usable
    eng = _paged(params, cfg, batch=3, n_pages=5, n_slabs=7,
                 scheduler=SchedulerConfig(policy="priority"))
    parent = eng.submit(prompt, max_new_tokens=1, retain=True)
    parent.result()
    kids = [eng.fork(parent, max_new_tokens=10, priority=2)
            for _ in range(2)]
    while any(k.status == "queued" for k in kids):
        eng.step()
    urgent = eng.submit(rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                        max_new_tokens=2, priority=0)
    while not eng.engine.spilled and eng.step():
        pass
    assert eng.engine.spilled, "urgent arrival did not preempt a fork"
    sp = next(iter(eng.engine.spilled.values()))[0]
    assert sp.shared, "spilled fork held no shared pages"
    assert sp.pages_needed < sp.n_pages     # shared pages stayed resident
    eng.run()
    assert eng.engine.preemptions >= 1
    assert urgent.status == "done"
    assert all(k.status == "done" and len(k.output) == 10 for k in kids)
    assert kids[0].output == kids[1].output  # resumed == never-preempted

    ref_eng = _paged(params, cfg)
    ref = ref_eng.submit(_full_context(parent, kids[0]), max_new_tokens=10)
    ref.result()
    assert kids[0].output == ref.output, (kids[0].output, ref.output)


def test_fork_hybrid_model_copies_recurrent_state(hybrid_fp32):
    """Hybrid arch (attention pages + SSM slabs): the fork's slab copy must
    hand the child the exact recurrent state at the parent's length."""
    params, cfg = hybrid_fp32
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, 140).astype(np.int32)
    turn = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
    eng = _paged(params, cfg, batch=2)
    parent = eng.submit(prompt, max_new_tokens=1, retain=True)
    parent.result()
    child = eng.fork(parent, turn, max_new_tokens=4)
    child.result()
    ref_eng = _paged(params, cfg, batch=2)
    ref = ref_eng.submit(_full_context(parent, child), max_new_tokens=4)
    ref.result()
    assert child.output == ref.output, (child.output, ref.output)


# ---------------------------------------------------------------------------
# sessions
# ---------------------------------------------------------------------------

def test_session_multi_turn_matches_full_reprefill(tiny_fp32):
    params, cfg = tiny_fp32
    rng = np.random.default_rng(8)
    turns = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
             for n in (30, 6, 5)]
    eng = _paged(params, cfg)
    chat = eng.session()
    handles = []
    context = []
    for t in turns:
        h = chat.send(t, max_new_tokens=3)
        h.result()
        assert h.status == "done"
        handles.append(h)
        context.extend(map(int, t))
        # the reply to the conversation so far must equal a from-scratch
        # re-prefill of the whole history
        ref_eng = _paged(params, cfg)
        ref = ref_eng.submit(np.asarray(context, np.int32), max_new_tokens=3)
        ref.result()
        assert h.output == ref.output, (h.output, ref.output)
        context.extend(h.output)
    # only the newest turn stays retained; closing frees everything
    pool = eng.engine.pool
    assert len(eng.engine.retained) == 1
    chat.close()
    assert pool.free_pages == pool.usable_pages
    # the whole 3-turn chat never re-ingested history
    total_sent = sum(len(t) for t in turns)
    st = eng.stats()
    assert st["prefill_tokens"] <= total_sent + 2 * 1  # + fed parent tokens
