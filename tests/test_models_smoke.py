"""Per-architecture smoke tests: reduced configs, one train/forward step on
CPU, asserting output shapes and no NaNs (spec requirement), plus the
prefill->decode consistency check on the unquantized path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, get_config, get_smoke_config
from repro.core.state_update import StateQuantConfig
from repro.models import model as M


def _batch(cfg, B=2, S=32, seed=0):
    key = jax.random.PRNGKey(seed)
    if cfg.family == "vlm":
        return {
            "patches": jax.random.normal(key, (B, cfg.prefix_len,
                                               cfg.frontend_dim)),
            "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
            "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        }
    if cfg.family == "audio":
        return {
            "frames": jax.random.normal(key, (B, S, cfg.frontend_dim)),
            "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        }
    return {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: M.train_loss(p, cfg, batch))(params)
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    assert 0.0 < float(loss) < 20.0
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    if cfg.encoder_only:
        pytest.skip("encoder-only: no decode step")
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    logits, caches = M.prefill(params, cfg, batch)
    assert logits.shape == (B, cfg.vocab_size)
    lengths = jnp.full((B,), S + (cfg.prefix_len if cfg.family == "vlm" else 0),
                       jnp.int32)
    caches = M.set_cache_lengths(caches, lengths)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for step in range(3):
        logits, caches = M.decode_step(params, cfg, tok, caches,
                                       lengths + step, seed=step)
        assert jnp.all(jnp.isfinite(logits)), f"{arch}: decode NaN"
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-2.7b", "zamba2-2.7b",
                                  "deepseek-v2-236b", "xlstm-1.3b"])
def test_prefill_decode_consistency_unquantized(arch):
    """With an fp32 cache, decoding position S from the prefill caches must
    match the full-forward logits at position S (teacher forcing)."""
    cfg = get_smoke_config(arch).with_(
        state_quant=StateQuantConfig(fmt="fp32", rounding="nearest",
                                     backend="jnp"))
    if cfg.moe is not None:
        # capacity-based MoE drops tokens under load; prefill (S tokens) and
        # decode (1 token) then see different drop patterns, which is the
        # expected inference semantics -- neutralize it for this check
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = M.init_model(jax.random.PRNGKey(1), cfg)
    B, S = 1, 33
    batch = _batch(cfg, B, S)
    # full forward over S tokens: logits at position S-1 predict token S
    logits_full, _ = M.prefill(params, cfg, batch)
    # prefill S-1 tokens, decode token S-1
    batch_head = {k: (v[:, :S - 1] if v.ndim >= 2 and v.shape[1] == S else v)
                  for k, v in batch.items()}
    _, caches = M.prefill(params, cfg, batch_head)
    lengths = jnp.full((B,), S - 1 + (cfg.prefix_len if cfg.family == "vlm" else 0),
                       jnp.int32)
    caches = M.set_cache_lengths(caches, lengths)
    logits_dec, _ = M.decode_step(params, cfg, batch["tokens"][:, S - 1],
                                  caches, lengths, seed=0)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "zamba2-2.7b"])
def test_quantized_decode_close_to_unquantized(arch):
    """MX8 caches perturb decode logits only mildly (Table 2 at smoke scale)."""
    outs = {}
    for fmt in ("fp32", "mx8"):
        cfg = get_smoke_config(arch).with_(
            state_quant=StateQuantConfig(fmt=fmt, rounding="stochastic",
                                         backend="jnp"))
        params = M.init_model(jax.random.PRNGKey(2), cfg)
        batch = _batch(cfg, 1, 32, seed=3)
        logits, caches = M.prefill(params, cfg, batch)
        lengths = jnp.full((1,), 32, jnp.int32)
        caches = M.set_cache_lengths(caches, lengths)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        logits2, _ = M.decode_step(params, cfg, tok, caches, lengths, seed=0)
        outs[fmt] = np.asarray(logits2)
    cos = (outs["fp32"] * outs["mx8"]).sum() / (
        np.linalg.norm(outs["fp32"]) * np.linalg.norm(outs["mx8"]))
    assert cos > 0.99, cos


def test_full_configs_instantiate_abstractly():
    """Every FULL config builds its parameter tree abstractly (no memory)."""
    from repro.launch import specs as SP
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch).with_(param_dtype="bfloat16")
        shapes = SP.params_struct(cfg)
        n = sum(np.prod(l.shape) for l in jax.tree.leaves(shapes))
        assert n > 1e8, f"{arch}: suspiciously small ({n:.2e} params)"


def test_param_counts_match_public_sizes():
    """Full configs land near their nameplate parameter counts."""
    import repro.launch.specs as SP
    expected = {
        "yi-9b": (8.0e9, 10.5e9),
        "llama3.2-1b": (1.0e9, 1.6e9),
        "yi-34b": (32e9, 36e9),
        "smollm-360m": (0.3e9, 0.45e9),
        # the assigned config line (48L, d=2048, 4H, proj-factor-2 mLSTM)
        # lands at ~1.9B with the standard parameterization
        "xlstm-1.3b": (1.2e9, 2.2e9),
        "deepseek-v2-236b": (220e9, 250e9),
        "dbrx-132b": (125e9, 140e9),
        "zamba2-2.7b": (2.2e9, 3.2e9),
        "paligemma-3b": (2.3e9, 3.5e9),
        "hubert-xlarge": (0.8e9, 1.3e9),
    }
    for arch, (lo, hi) in expected.items():
        cfg = get_config(arch)
        shapes = SP.params_struct(cfg)
        n = float(sum(np.prod(l.shape) for l in jax.tree.leaves(shapes)))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params not in [{lo/1e9}, {hi/1e9}]"
