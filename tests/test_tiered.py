"""Tiered memory hierarchy: scheduler lookahead, host-tier budget
accounting, and the async spill-resume prefetch path -- bit-exact resume
with the device copy overlapping decode (verified via trace spans)."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.state_update import StateQuantConfig
from repro.models import model as M
from repro.serving.api import Engine, ServeConfig
from repro.serving.engine import Request
from repro.serving.memory.tiered import HostTier
from repro.serving.sampler import SamplingConfig
from repro.serving.scheduler import Scheduler, SchedulerConfig


# ---------------------------------------------------------------------------
# scheduler lookahead
# ---------------------------------------------------------------------------

def _req(rid, priority=0, t=0.0):
    r = Request(rid=rid, prompt=np.zeros(4, np.int32), priority=priority)
    r.t_submit = t
    return r


def test_lookahead_dispatch_order_without_popping():
    s = Scheduler(SchedulerConfig(policy="priority"))
    for rid, pri in ((0, 5), (1, 0), (2, 3)):
        s.push(_req(rid, pri, t=rid))
    assert [r.rid for r in s.lookahead(2)] == [1, 2]
    assert [r.rid for r in s.lookahead(10)] == [1, 2, 0]
    assert len(s) == 3                       # nothing popped
    s.remove(1)
    assert [r.rid for r in s.lookahead(2)] == [2, 0]   # tombstone skipped


def test_lookahead_respects_resume_boost():
    s = Scheduler(SchedulerConfig(policy="priority"))
    s.push(_req(0, priority=1, t=0.0))
    s.push(_req(1, priority=1, t=1.0), resumed=True)   # boost beats t_submit
    assert [r.rid for r in s.lookahead(2)] == [1, 0]


# ---------------------------------------------------------------------------
# host tier ledger
# ---------------------------------------------------------------------------

def test_host_tier_pins_overshoot_cache_respects_budget():
    h = HostTier(byte_budget=100)
    h.pin(1, 80.0)
    assert h.room_for(20) and not h.room_for(21)
    h.pin(2, 50.0)                 # pins may overshoot: live state survives
    assert h.bytes_used == 130.0 and not h.room_for(1)
    assert h.unpin(1) == 80.0 and h.unpin(1) == 0.0
    h.cache_add(40.0)
    assert h.bytes_used == 90.0
    h.cache_drop(60.0)             # clamped at zero
    assert h.cached_bytes == 0.0
    assert HostTier(None).room_for(1e18)     # unmetered


def test_store_demote_falls_back_to_evict_when_budget_full():
    cfg = get_smoke_config("llama3.2-1b").with_(
        state_quant=StateQuantConfig(fmt="fp32", rounding="nearest",
                                     backend="jnp"))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 140).astype(np.int32)
    eng = Engine(params, cfg, ServeConfig(
        backend="paged", batch=2, n_pages=17, n_slabs=5,
        sampling=SamplingConfig(temperature=0.0),
        prefix_cache=True, prefix_store_pages=4, host_tier_bytes=0))
    eng.submit(prompt, max_new_tokens=3)
    eng.run()
    pool = eng.engine.pool
    assert pool.store.n_pages >= 1
    before = pool.store.n_pages
    # budget 0: demote has no host room -> leaf nodes evict instead
    assert pool.demote_all() == 0
    assert pool.store.n_pages < before
    assert pool.host.cached_bytes == 0.0      # eviction drained the ledger


# ---------------------------------------------------------------------------
# preempt -> host demotion -> async prefetch resume, overlapping decode
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_prefetch_resume_bit_exact_and_overlaps_decode():
    cfg = get_smoke_config("llama3.2-1b").with_(
        state_quant=StateQuantConfig(fmt="fp32", rounding="nearest",
                                     backend="jnp"))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    greedy = SamplingConfig(temperature=0.0)
    rng = np.random.default_rng(2)
    prompt_b = rng.integers(0, cfg.vocab_size, 140).astype(np.int32)
    prompt_a = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)

    def mk():
        return Engine(params, cfg, ServeConfig(
            backend="paged", batch=1, n_pages=9, n_slabs=5, sampling=greedy,
            scheduler=SchedulerConfig(policy="priority")))

    # reference: B served alone, never preempted
    ref = mk()
    ref_out = ref.submit(prompt_b, max_new_tokens=8, priority=5
                         ).result().output

    eng = mk()
    hb = eng.submit(prompt_b, max_new_tokens=8, priority=5)
    while hb.status == "queued" and eng.step():
        pass
    assert hb.status == "running"
    # an urgent short request arrives; evict B to the host tier while A runs
    ha = eng.submit(prompt_a, max_new_tokens=6, priority=0)
    eng.engine._preempt(hb.rid)
    eng.run()
    st = eng.stats()

    # bit-exact through spill -> host pin -> staged prefetch -> commit
    assert ha.status == "done" and hb.status == "done"
    assert hb.output == ref_out
    assert st["preemptions"] >= 1
    assert st["prefetch_commits"] >= 1      # resume went through the stage
    assert st["tier_hits"] >= 1
    assert st["demote_bytes"] > 0 and st["promote_bytes"] > 0
    assert eng.engine.pool.host.pinned_bytes == 0    # ledger drained

    # the staged copy must overlap decode: at least one decode_step X event
    # falls entirely inside a prefetch b/e span
    evs = eng.obs.tracer.events()
    begins = [e for e in evs
              if e.get("cat") == "prefetch" and e["ph"] == "b"]
    ends = {e["id"]: e["ts"] for e in evs
            if e.get("cat") == "prefetch" and e["ph"] == "e"}
    steps = [e for e in evs if e.get("cat") == "step" and e["ph"] == "X"]
    assert begins, "no prefetch span in the trace"
    assert any(s["ts"] >= b["ts"] and s["ts"] + s["dur"] <= ends[b["id"]]
               for b in begins for s in steps), \
        "no decode step ran inside a prefetch span"


def test_prefetch_cancel_returns_staging_pages():
    cfg = get_smoke_config("mamba2-2.7b").with_(
        state_quant=StateQuantConfig(fmt="fp32", rounding="nearest",
                                     backend="jnp"))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    eng = Engine(params, cfg, ServeConfig(
        backend="paged", batch=1, n_pages=9, n_slabs=5,
        sampling=SamplingConfig(temperature=0.0),
        scheduler=SchedulerConfig(policy="priority")))
    hb = eng.submit(rng.integers(0, cfg.vocab_size, 20).astype(np.int32),
                    max_new_tokens=8, priority=5)
    while hb.status == "queued" and eng.step():
        pass
    ha = eng.submit(rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=4, priority=0)
    eng.engine._preempt(hb.rid)
    pool = eng.engine.pool
    # stage the prefetch by stepping once with A active, then abort B
    eng.step()
    hb.abort()
    assert hb.status == "aborted"
    assert not pool.prefetch_ready(hb.rid)
    ha.result()
    assert ha.status == "done"
    assert pool.host.pinned_bytes == 0
