"""Mesh-free unit tests for repro.dist.compression.

The subprocess test in test_sharding.py exercises the compressed
all-reduce on a real 8-device 'pod' axis; these tests pin down the
numerics -- round-trip error bound, error-feedback carry, wire size --
on a single device where failures are cheap to bisect.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.compression import (INT8_MAX, compressed_allreduce_mean,
                                    compressed_bytes, dequantize_int8,
                                    init_error_feedback, quantize_int8)


@pytest.mark.parametrize("shape", [(64,), (32, 48), (4, 8, 16)])
def test_quantize_roundtrip_error_bound(rng, shape):
    x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    q, scale = quantize_int8(x)
    assert q.dtype == jnp.int8
    err = jnp.max(jnp.abs(dequantize_int8(q, scale) - x))
    # round-to-nearest against a max-abs grid: half a step, plus float slop
    step = float(jnp.max(jnp.abs(x))) / INT8_MAX
    assert float(err) <= 0.5 * step * (1 + 1e-5)


def test_quantize_zero_tensor_is_exact():
    q, scale = quantize_int8(jnp.zeros((16, 16)))
    np.testing.assert_array_equal(np.asarray(q), 0)
    assert np.isfinite(float(scale))


def test_error_feedback_carries_residual_across_steps(rng):
    """The residual rounded away at step t must be re-applied at t+1:
    averaged over many steps of a CONSTANT gradient, the compressed
    stream converges on the true gradient far beyond one-shot precision."""
    g = {"w": jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))}
    ef = init_error_feedback(g)
    steps = 64
    acc = jnp.zeros_like(g["w"])
    for _ in range(steps):
        red, ef = compressed_allreduce_mean(g, ef, axis_name=None)
        acc = acc + red["w"]
    mean_err = float(jnp.max(jnp.abs(acc / steps - g["w"])))
    one_shot = float(jnp.max(jnp.abs(
        compressed_allreduce_mean(g, init_error_feedback(g), None)[0]["w"]
        - g["w"])))
    step = float(jnp.max(jnp.abs(g["w"]))) / INT8_MAX
    assert one_shot <= 0.5 * step * (1 + 1e-5)
    # with EF the time-average beats the one-shot quantization floor
    assert mean_err < max(one_shot / 4, 1e-6)


def test_error_feedback_residual_is_bounded(rng):
    """EF must not let the carried residual blow up over many steps."""
    g = {"w": jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32))}
    ef = init_error_feedback(g)
    for _ in range(200):
        _, ef = compressed_allreduce_mean(g, ef, axis_name=None)
    step = float(jnp.max(jnp.abs(g["w"]))) / INT8_MAX
    # residual stays within one quantization step of zero
    assert float(jnp.max(jnp.abs(ef["w"]))) <= 2 * step


def test_compressed_bytes_beats_bf16_wire():
    tree = {"a": jnp.zeros((128, 64)), "b": jnp.zeros((1000,))}
    n_vals = 128 * 64 + 1000
    wire = compressed_bytes(tree)
    assert wire < n_vals * 2            # bf16 baseline
    assert wire >= n_vals               # 1 byte/value + scales


def test_treedef_and_shapes_preserved(rng):
    g = {"a": jnp.asarray(rng.normal(size=(4, 4)).astype(np.float32)),
         "nest": {"b": jnp.asarray(rng.normal(size=(8,)).astype(np.float32))}}
    ef = init_error_feedback(g)
    red, ef2 = compressed_allreduce_mean(g, ef, axis_name=None)
    assert jax.tree_util.tree_structure(red) == jax.tree_util.tree_structure(g)
    assert jax.tree_util.tree_structure(ef2) == jax.tree_util.tree_structure(g)
    for x, y in zip(jax.tree.leaves(red), jax.tree.leaves(g)):
        assert x.shape == y.shape
