"""The paper's central claims at op level: prefill/decode equivalence of the
generalized state update across model families, and the swamping study."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import formats as F
from repro import ops as OPS
from repro.kernels import ref
from repro.models.ssm import chunked_la_scalar, chunked_la_vector


def _seq_reference(q, k, v, log_d):
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    St = jnp.zeros((B, H, dk, dv))
    ys = []
    for t in range(S):
        d = jnp.exp(log_d[..., t]) if log_d.ndim == 3 else jnp.exp(log_d[..., t, :])
        d_ = d[..., None, None] if log_d.ndim == 3 else d[..., :, None]
        St = d_ * St + k[:, :, t, :, None] * v[:, :, t, None, :]
        ys.append(jnp.einsum("bhkv,bhk->bhv", St, q[:, :, t]))
    return jnp.stack(ys, 2), St


@pytest.mark.parametrize("chunk", [8, 16, 64])
@pytest.mark.parametrize("dk,dv", [(32, 16), (16, 48)])
def test_chunked_scalar_engine(chunk, dk, dv):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    B, H, S = 2, 2, 64
    q = jax.random.normal(ks[0], (B, H, S, dk))
    k = jax.random.normal(ks[1], (B, H, S, dk))
    v = jax.random.normal(ks[2], (B, H, S, dv))
    log_a = -jax.nn.softplus(jax.random.normal(ks[3], (B, H, S)))
    y1, S1 = chunked_la_scalar(q, k, v, log_a, chunk)
    y2, S2 = _seq_reference(q, k, v, log_a)
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(S1, S2, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [8, 32])
def test_chunked_vector_engine(chunk):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    B, H, S, dk, dv = 2, 2, 64, 16, 24
    q = jax.random.normal(ks[0], (B, H, S, dk))
    k = jax.random.normal(ks[1], (B, H, S, dk))
    v = jax.random.normal(ks[2], (B, H, S, dv))
    log_f = jnp.maximum(-jax.nn.softplus(jax.random.normal(ks[3], (B, H, S, dk))),
                        -1.0)
    y1, S1 = chunked_la_vector(q, k, v, log_f, chunk)
    y2, S2 = _seq_reference(q, k, v, log_f)
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(S1, S2, rtol=2e-4, atol=2e-4)


def test_quantized_stream_tracks_float_stream():
    """Decode-time Eq.2 with an MX8 state stays close to the fp32 stream
    over many steps (the accuracy claim of Table 2 at op granularity)."""
    B, H, dk, dv, T = 1, 2, 64, 32, 200
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    d = jax.nn.sigmoid(jax.random.normal(ks[0], (B, H, dk)) + 2.0)
    cfg = OPS.StateQuantConfig(fmt="mx8", rounding="stochastic")
    qS = OPS.init_state(B, H, dk, dv, cfg)
    Sf = jnp.zeros((B, H, dv, dk))
    errs = []
    for t in range(T):
        kk = jax.random.normal(jax.random.PRNGKey(3 * t + 1), (B, H, dk))
        vv = jax.random.normal(jax.random.PRNGKey(3 * t + 2), (B, H, dv))
        qq = jax.random.normal(jax.random.PRNGKey(3 * t + 3), (B, H, dk))
        qS, yq = OPS.state_update_step(qS, d, kk, vv, qq, cfg, seed=t)
        Sf, yf = OPS.state_update_float(Sf, d, kk, vv, qq, dtype=jnp.float32)
        errs.append(float(jnp.linalg.norm(yq - yf) / jnp.linalg.norm(yf)))
    # error stays bounded -- no swamping divergence
    assert np.mean(errs[-20:]) < 0.15, np.mean(errs[-20:])


from repro.analysis.formats_study import run_swamping_study


def test_swamping_ordering_across_formats():
    errs = run_swamping_study(T=300)
    # narrow-mantissa fp8 under RNE diverges; wider formats track fp32
    assert errs[("mx8", "stochastic")] < errs[("fp8_e5m2", "nearest")] / 2
    assert errs[("int8", "stochastic")] < errs[("fp8_e5m2", "nearest")] / 3
    assert errs[("fp8_e4m3", "nearest")] < errs[("fp8_e5m2", "nearest")]
    assert errs[("fp16", "nearest")] < 0.01
    # stochastic rounding rescues the block/narrow formats
    # (paper Fig. 4: e5m2 62 -> 12.2 ppl with SR)
    assert errs[("mx8", "stochastic")] < errs[("mx8", "nearest")]
    assert errs[("fp8_e5m2", "stochastic")] < errs[("fp8_e5m2", "nearest")] / 2
    assert errs[("fp8_e4m3", "stochastic")] < errs[("fp8_e4m3", "nearest")] / 2


def test_decode_matches_prefill_state_handoff():
    """Chunked prefill's final state continued by Eq.2 decode equals running
    the sequential recurrence end-to-end (the prefill->generation handoff)."""
    B, H, S, dk, dv = 1, 2, 32, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    q = jax.random.normal(ks[0], (B, H, S + 1, dk))
    k = jax.random.normal(ks[1], (B, H, S + 1, dk))
    v = jax.random.normal(ks[2], (B, H, S + 1, dv))
    log_a = -jax.nn.softplus(jax.random.normal(ks[3], (B, H, S + 1)))
    # prefill on the first S tokens
    _, S_pre = chunked_la_scalar(q[:, :, :S], k[:, :, :S], v[:, :, :S],
                                 log_a[..., :S], chunk=8)
    # decode step S+1 on the float path (stored layout = transposed)
    Sn, y_dec = OPS.state_update_float(
        jnp.swapaxes(S_pre, -1, -2), jnp.exp(log_a[..., S])[..., None],
        k[:, :, S], v[:, :, S], q[:, :, S], dtype=jnp.float32)
    y_all, _ = _seq_reference(q, k, v, log_a)
    np.testing.assert_allclose(y_dec, y_all[:, :, S], rtol=1e-3, atol=1e-4)
