"""Block-table-native paged decode: bit-exact parity with the dense-gather
path, (kind x backend x format x layout) registry lookups, page-granular
traffic, and the steady-state loop's freedom from gather/scatter."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops as OPS
from repro.configs import get_smoke_config
from repro.core.state_update import StateQuantConfig
from repro.models import model as M
from repro.serving.engine import (EngineConfig, PagedEngineConfig,
                                  PagedServingEngine, Request, ServingEngine)
from repro.serving.memory import PAGE_TOKENS, PagedStatePool, pages_for


def _build(arch, fmt, backend, rounding):
    cfg = get_smoke_config(arch).with_(
        state_quant=StateQuantConfig(fmt=fmt, rounding=rounding,
                                     backend=backend))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    return params, cfg


def _prefill_pool(params, cfg, prompt_len, n_pages=8, n_slabs=5):
    pool = PagedStatePool(cfg, n_pages=n_pages, n_slabs=n_slabs)
    rng = np.random.default_rng(prompt_len)
    prompt = rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
    pr = jnp.asarray(prompt)[None]
    logits, row = jax.jit(lambda p, b: M.prefill(p, cfg, b))(
        params, {"tokens": pr, "targets": pr})
    assert pool.register(1, pages_for(prompt_len))
    pool.insert_prefill(1, row)
    return pool, int(jnp.argmax(logits[0]))


def _decode_steps(pool, params, tok, length, n_steps):
    """Greedy decode steps over a two-row batch (row 1 idle), growing the
    block table over page boundaries like the engine's headroom check."""
    outs = []
    L = np.array([length, 0], np.int32)
    t = tok
    for step in range(n_steps):
        while L[0] // PAGE_TOKENS + 1 > len(pool.page_table[1]):
            assert pool.grow(1, 1)
        lg = pool.decode(params, [1, None], np.array([t, 0], np.int32),
                         L, seed=step + 1)
        outs.append(np.asarray(lg))
        t = int(jnp.argmax(lg[0]))
        L[0] += 1
    return outs


# two archs (one attention, one SSM) x both backends x lengths straddling
# a page boundary: 127 (tail slot of page 1), 128 (page-exact), 129 (page 2);
# plus the novel pallas kernel branches -- MLA's latent-only cache (dummy V
# refs) and zamba2's shared-attention group re-binding -- on the boundary pair
PARITY_MATRIX = [
    (arch, fmt, backend, L)
    for arch in ("llama3.2-1b", "mamba2-2.7b")
    for fmt, backend in (("mx8", "pallas"), ("mx8", "jnp"),
                         ("fp32", "jnp"))
    for L in (127, 128, 129)
] + [
    (arch, "mx8", "pallas", L)
    for arch in ("deepseek-v2-236b", "zamba2-2.7b")
    for L in (127, 129)
]


@pytest.mark.parametrize(
    "arch,fmt,backend,length", PARITY_MATRIX,
    ids=[f"{a}-{f}-{b}-L{L}" for a, f, b, L in PARITY_MATRIX])
def test_paged_decode_bit_identical_to_dense_gather(arch, fmt, backend,
                                                    length):
    """Steady-state paged decode must produce bit-identical logits to the
    dense-gather reference path, across the page boundary."""
    rounding = "stochastic" if fmt == "mx8" else "nearest"
    params, cfg = _build(arch, fmt, backend, rounding)
    pool, tok = _prefill_pool(params, cfg, length)
    snapshot = [np.asarray(x) for x in pool.pools]
    pages0 = list(pool.page_table[1])

    pool.decode_mode = "gather"
    ref = _decode_steps(pool, params, tok, length, n_steps=2)

    pool.pools = [jnp.asarray(x) for x in snapshot]
    grown = [p for p in pool.page_table[1] if p not in pages0]
    if grown:
        pool.placement.free(grown)
    pool.page_table[1] = list(pages0)
    pool.decode_mode = "paged"
    got = _decode_steps(pool, params, tok, length, n_steps=2)

    for step, (a, b) in enumerate(zip(ref, got)):
        np.testing.assert_array_equal(
            a, b, err_msg=f"{arch}/{fmt}/{backend}/L={length} step {step}")


# ---------------------------------------------------------------------------
# registry: the layout axis
# ---------------------------------------------------------------------------

def test_registry_lookup_errors_list_quadruples():
    """(kind x backend x format x layout) lookup failures name the
    registered quadruples, layout included."""
    with pytest.raises(KeyError) as ei:
        OPS.get_op("attn_decode", "pallas", "fp32", "paged")
    msg = str(ei.value)
    assert "layout 'paged'" in msg
    assert "attn_decode[pallas:mx8:paged]" in msg
    assert "attn_decode[jnp:fp32:dense]" in msg

    with pytest.raises(ValueError, match="layout 'paged'"):
        OPS.resolve_backend("attn_decode", "fp32", "pallas",
                            layout="paged", strict=True)
    # negotiation is per-layout: fp32 paged falls back to the jnp paged op
    assert OPS.resolve_backend("attn_decode", "fp32", "pallas",
                               layout="paged") == "jnp"
    with pytest.raises(ValueError, match="unknown op layout"):
        class Bad(OPS.SpuOp):
            kind = "attn_decode"
            backend = "jnp"
            formats = ("fp32",)
            layout = "ragged"
        OPS.register(Bad)


def test_paged_plans_carry_layout():
    cfg = get_smoke_config("llama3.2-1b")
    dense = OPS.decode_op_plans(cfg, 2, 200)
    paged = OPS.decode_op_plans(cfg, 2, 200, layout="paged")
    assert {e.plan.layout for e in dense} == {"dense"}
    assert {e.plan.layout for e in paged} == {"paged"}


def test_paged_attention_traffic_is_page_granular():
    """A 129-token context streams two whole pages under the paged ops;
    the append writes one row regardless of context length."""
    quant = OPS.StateQuantConfig(fmt="mx8", rounding="nearest", backend="jnp")
    dims = dict(B=2, T=129, KVH=2, dk=64, dv=64, n=1, H=4)
    paged = OPS.traffic(OPS.plan_attn_decode_dims(
        "attn_decode", dims, quant, layout="paged"))
    dense = OPS.traffic(OPS.plan_attn_decode_dims("attn_decode", dims, quant))
    bits = OPS.fmt_bits("mx8")
    row_vals = 2 * (64 + 64)
    assert paged.state_read == pytest.approx(2 * 2 * PAGE_TOKENS * row_vals
                                             * bits / 8.0)
    assert dense.state_read == pytest.approx(2 * 129 * row_vals * bits / 8.0)
    ap = OPS.traffic(OPS.plan("kv_append", dims, quant, "jnp",
                              layout="paged"))
    ad = OPS.traffic(OPS.plan("kv_append", dims, quant, "jnp"))
    assert ap.state_write == pytest.approx(ad.state_write)  # one row each
    dims_big = dict(dims, T=4 * PAGE_TOKENS)
    ap_big = OPS.traffic(OPS.plan("kv_append", dims_big, quant, "jnp",
                                  layout="paged"))
    assert ap_big.state_write == pytest.approx(ap.state_write)


# ---------------------------------------------------------------------------
# engine-level: donation, retraces, residual gather accounting
# ---------------------------------------------------------------------------

def test_slotted_engine_donation_no_retrace():
    """donate_argnames on the slotted engine's decode jit must not retrace:
    one compiled executable serves every step."""
    cfg = get_smoke_config("llama3.2-1b").with_(
        state_quant=StateQuantConfig(fmt="fp32", rounding="nearest",
                                     backend="jnp"))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, EngineConfig(slots=2,
                                                  cache_capacity=128))
    rng = np.random.default_rng(0)
    for i in range(3):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, 8
                                               ).astype(np.int32),
                           max_new_tokens=4))
    done = eng.run()
    assert len(done) == 3 and all(len(r.output) == 4 for r in done)
    assert eng._decode._cache_size() == 1, "decode retraced"


def test_paged_engine_decode_no_retrace():
    """The paged engine's retrace pin, mirroring the slotted one: a
    constant-shape workload (equal-length prompts in one page bucket, the
    full decode batch, same shape as benchmarks/paged_smoke.py) must be
    served by exactly one compiled decode executable.  The recompile
    watcher must agree with the jit cache, and tag only warmup compiles."""
    cfg = get_smoke_config("llama3.2-1b").with_(
        state_quant=StateQuantConfig(fmt="fp32", rounding="nearest",
                                     backend="jnp"))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    eng = PagedServingEngine(params, cfg, PagedEngineConfig(
        max_decode_batch=4, n_pages=9, n_slabs=9, prefill_chunk=128))
    rng = np.random.default_rng(0)
    for i in range(4):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, 12
                                               ).astype(np.int32),
                           max_new_tokens=4))
    done = eng.run()
    assert len(done) == 4 and all(len(r.output) == 4 for r in done)
    assert eng.pool._decode.n_compiles == 1, "paged decode retraced"
    assert eng.obs.recompiles.counts().get("pool.decode", 0) == 1
    assert eng.obs.recompiles.n_recompiles == 0, \
        [e.changed for e in eng.obs.recompiles.events if not e.is_warmup]
    # the step series separates the one compile step from steady state
    stats = eng.stats()
    assert stats["compile_steps"] >= 1.0
    assert stats["recompiles"] == float(len(eng.obs.recompiles.events))
    assert sum(eng.step_compiled) == int(stats["compile_steps"])


def test_paged_engine_gather_bytes_only_at_the_edges():
    """Steady-state decode moves zero gather/scatter bytes: the ledger grows
    only at prefill insertion (and spill/resume), never per decode step."""
    cfg = get_smoke_config("llama3.2-1b").with_(
        state_quant=StateQuantConfig(fmt="fp32", rounding="nearest",
                                     backend="jnp"))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    eng = PagedServingEngine(params, cfg, PagedEngineConfig(
        max_decode_batch=2, n_pages=7, n_slabs=5))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (9, 17)]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
    eng._admit()
    after_prefill = eng.pool.gather_bytes
    expected = sum(eng.pool.request_nbytes(pages_for(len(p)))
                   for p in prompts)
    assert after_prefill == pytest.approx(expected)
    done = eng.run()
    assert len(done) == 2 and eng.preemptions == 0
    assert eng.pool.gather_bytes == pytest.approx(after_prefill), \
        "decode steps moved gather/scatter bytes"
    stats = eng.stats()
    assert stats["gather_bytes"] == pytest.approx(after_prefill)
    assert any(k.startswith("op_traffic_bytes/") for k in stats)


def test_paged_engine_spill_resume_accounts_gather_bytes(tmp_path):
    """Preemption still rides gather/scatter -- and is accounted as such."""
    cfg = get_smoke_config("llama3.2-1b").with_(
        state_quant=StateQuantConfig(fmt="fp32", rounding="nearest",
                                     backend="jnp"))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    eng = PagedServingEngine(params, cfg, PagedEngineConfig(
        max_decode_batch=2, n_pages=4, n_slabs=5, prefill_chunk=128))
    rng = np.random.default_rng(3)
    for i in range(2):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, 120
                                               ).astype(np.int32),
                           max_new_tokens=12))
    done = eng.run()
    assert len(done) == 2 and eng.preemptions >= 1
    # every preemption costs one spill + one resume on top of the prefills
    min_expected = (2 + 2 * eng.preemptions) * eng.pool.request_nbytes(1)
    assert eng.pool.gather_bytes >= min_expected
