import os

# Smoke tests and benches must see the single real CPU device; only the
# dry-run module requests 512 placeholder devices (and only in its own
# process).  Tests that need a small multi-device mesh spawn subprocesses
# (see test_sharding.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Tier-1 runs with the shadow-ledger sanitizer on: every refcount transition
# in the paged/tiered pools is mirrored and double-free / use-after-evict /
# teardown-leak raise immediately (repro.analysis.lint.runtime).  Opt out of
# an individual run with REPRO_SANITIZE=0.
os.environ.setdefault("REPRO_SANITIZE", "1")

import numpy as np
import pytest

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")


def subprocess_env(**extra):
    """Env for test subprocesses: absolute src prepended to the INHERITED
    PYTHONPATH (never clobbered -- pytest may run from outside the repo)."""
    env = dict(os.environ)
    inherited = env.get("PYTHONPATH")
    env["PYTHONPATH"] = _SRC + (os.pathsep + inherited if inherited else "")
    env.update(extra)
    return env


@pytest.fixture
def rng():
    return np.random.default_rng(0)
