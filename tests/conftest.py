import os

# Smoke tests and benches must see the single real CPU device; only the
# dry-run module requests 512 placeholder devices (and only in its own
# process).  Tests that need a small multi-device mesh spawn subprocesses
# (see test_sharding.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
