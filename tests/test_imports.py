"""Every module under src/repro must be importable.

A dead import -- like the seed tree's ``repro.dist``, which every sharded
launcher entry point depended on while the package did not exist -- must
fail tier-1 loudly instead of hiding behind launcher ``main()``s and
module-level ``importorskip``s.

Runs in a subprocess: ``repro.launch.dryrun`` mutates ``XLA_FLAGS`` at
import time (it requests 512 placeholder devices), which must never leak
into the pytest process where the rest of the suite relies on seeing the
single real CPU device.
"""
import subprocess
import sys

from conftest import subprocess_env

# every package under src/repro must contribute at least this many modules;
# a collection collapse (deleted package, import-crashed subtree) trips it
_MODULE_FLOOR = 55

_WALK = """
import importlib, pathlib, sys

import jax
jax.devices()  # lock the backend to the real device(s) BEFORE any module
               # (repro.launch.dryrun) can request 512 placeholder devices

import repro
# filesystem walk, not pkgutil: several subpackages are namespace packages
# (no __init__.py) and pkgutil silently skips subtrees it cannot resolve --
# exactly the failure mode this test exists to catch
root = pathlib.Path(list(repro.__path__)[0])
names = {"repro"}
for p in sorted(root.rglob("*.py")):
    parts = ("repro",) + p.relative_to(root).with_suffix("").parts
    if parts[-1] == "__init__":
        parts = parts[:-1]
    names.add(".".join(parts))
failed = []
for name in sorted(names):
    try:
        importlib.import_module(name)
    except Exception as e:  # noqa: BLE001 -- report every broken module
        failed.append(f"{name}: {type(e).__name__}: {e}")
print(f"IMPORTED {len(names)}")
if failed:
    print("\\n".join(failed))
    sys.exit(1)
"""


def test_every_repro_module_imports():
    env = subprocess_env()
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run([sys.executable, "-c", _WALK], capture_output=True,
                          text=True, timeout=600, env=env)
    assert proc.returncode == 0, (
        f"broken modules:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    n = int(proc.stdout.split("IMPORTED")[1].split()[0])
    assert n >= _MODULE_FLOOR, (
        f"only {n} modules under repro (floor {_MODULE_FLOOR}) -- "
        f"a package vanished from the walk")
