"""Serving engine integration: continuous batching correctness + throughput
accounting on a tiny model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving.engine import EngineConfig, Request, ServingEngine


@pytest.fixture(scope="module")
def tiny():
    cfg = get_smoke_config("llama3.2-1b")
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    return params, cfg


def _greedy_reference(params, cfg, prompt, n_new):
    """Single-request greedy decode, no engine."""
    B, S = 1, len(prompt)
    batch = {"tokens": jnp.asarray(prompt, jnp.int32)[None],
             "targets": jnp.asarray(prompt, jnp.int32)[None]}
    logits, caches = M.prefill(params, cfg, batch)
    lengths = jnp.full((1,), S, jnp.int32)
    caches = M.set_cache_lengths(caches, lengths)
    out = [int(jnp.argmax(logits[0]))]
    tok = jnp.asarray([out[-1]], jnp.int32)
    for step in range(n_new - 1):
        logits, caches = M.decode_step(params, cfg, tok, caches,
                                       lengths + step, seed=step + 1)
        out.append(int(jnp.argmax(logits[0])))
        tok = jnp.asarray([out[-1]], jnp.int32)
    return out


def test_engine_single_request(tiny):
    params, cfg = tiny
    eng = ServingEngine(params, cfg, EngineConfig(slots=2, cache_capacity=128))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=6))
    done = eng.run()
    assert len(done) == 1
    assert len(done[0].output) == 6
    assert all(0 <= t < cfg.vocab_size for t in done[0].output)


def test_engine_batched_requests_complete(tiny):
    params, cfg = tiny
    eng = ServingEngine(params, cfg, EngineConfig(slots=3, cache_capacity=128))
    rng = np.random.default_rng(1)
    n_req = 7   # > slots: exercises admission + slot reuse
    for i in range(n_req):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size,
                                               8 + i).astype(np.int32),
                           max_new_tokens=4 + (i % 3)))
    done = eng.run()
    assert len(done) == n_req
    for r in done:
        assert len(r.output) == 4 + (r.rid % 3)
    stats = eng.stats()
    assert stats["tokens"] == sum(4 + (i % 3) for i in range(n_req))
    assert stats["tokens_per_s"] > 0


def test_engine_matches_unbatched_greedy(tiny):
    """Continuous batching must not change any request's greedy tokens.

    Note: the decode seed differs between engine steps and the reference
    loop, so run the quant-free config where SR seeds cannot matter."""
    from repro.core.state_update import StateQuantConfig
    cfg = get_smoke_config("llama3.2-1b").with_(
        state_quant=StateQuantConfig(fmt="fp32", rounding="nearest",
                                     backend="jnp"))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (10, 13, 9)]
    refs = [_greedy_reference(params, cfg, p, 5) for p in prompts]

    eng = ServingEngine(params, cfg, EngineConfig(slots=3, cache_capacity=128))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=5))
    done = sorted(eng.run(), key=lambda r: r.rid)
    for r, ref_toks in zip(done, refs):
        assert r.output == ref_toks, (r.rid, r.output, ref_toks)


def test_engine_hybrid_model():
    cfg = get_smoke_config("zamba2-2.7b")
    params = M.init_model(jax.random.PRNGKey(3), cfg)
    eng = ServingEngine(params, cfg, EngineConfig(slots=2, cache_capacity=128))
    rng = np.random.default_rng(3)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 6
                                                      ).astype(np.int32),
                           max_new_tokens=3))
    done = eng.run()
    assert len(done) == 3
