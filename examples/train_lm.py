"""End-to-end training driver: a ~100M-param llama-family model for a few
hundred steps on structured synthetic data, with fault-tolerant checkpointing.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--resume]

Loss falls well below the unigram entropy as the model learns the copy
structure in the data (induction heads).  Kill it mid-run and start again
with --resume: it continues bitwise from the last checkpoint.
"""
import argparse
import os

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import make_batch_fn
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.train import optimizer as O
from repro.train.train_loop import LoopConfig, make_train_step, train_loop

# ~100M params: a shrunk llama3-family config
CONFIG_100M = ModelConfig(
    name="llama-100m", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
    d_ff=2048, vocab_size=16384,
    pattern=("attn",), ffn_kind="swiglu", rope_theta=10_000.0,
    tie_embeddings=True,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = CONFIG_100M
    opt = O.OptimizerConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    opt_state = O.init_opt_state(params, opt)
    n = sum(np.prod(p.shape) for p in jax.tree.leaves(params))
    print(f"model: {cfg.name} ({n/1e6:.1f}M params)")

    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    start = 0
    if args.resume and mgr.latest_step() is not None:
        restored, start = mgr.restore({"params": params,
                                       "opt_state": opt_state})
        params, opt_state = restored["params"], restored["opt_state"]
        print(f"resumed from step {start}")

    batch_fn = make_batch_fn(cfg, args.seq_len, args.batch)
    step_fn = jax.jit(make_train_step(cfg, opt))
    params, opt_state, hist = train_loop(
        step_fn, params, opt_state, batch_fn,
        LoopConfig(total_steps=args.steps, log_every=10, checkpoint_every=50),
        checkpoint_mgr=mgr, start_step=start)
    print(f"final loss {hist[-1]:.4f} (start {hist[0]:.4f}); "
          f"uniform entropy would be {np.log(cfg.vocab_size):.2f}")


if __name__ == "__main__":
    main()
