"""Format ablation (paper Figs. 4/6): sweep state formats x rounding on a
real tiny SU-LLM and on the controlled accumulation study.

Run:  PYTHONPATH=src python examples/format_ablation.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.formats_study import run_swamping_study
from repro.configs import get_smoke_config
from repro.core.state_update import StateQuantConfig
from repro.models import model as M


def model_level(arch="mamba2-2.7b", n_steps=24):
    """Decode-logit divergence from the fp32 path, per format."""
    base = get_smoke_config(arch)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0,
                                base.vocab_size)
    ref_logits = None
    print(f"\n== model-level ({arch}, {n_steps} decode steps) ==")
    for fmt, rnd in [("fp32", "nearest"), ("mx8", "stochastic"),
                     ("mx8", "nearest"), ("int8", "stochastic"),
                     ("fp8_e5m2", "nearest"), ("fp8_e5m2", "stochastic")]:
        cfg = base.with_(state_quant=StateQuantConfig(fmt=fmt, rounding=rnd,
                                                      backend="jnp"))
        params = M.init_model(jax.random.PRNGKey(7), cfg)
        batch = {"tokens": prompt, "targets": prompt}
        logits, caches = M.prefill(params, cfg, batch)
        lengths = jnp.full((1,), 16, jnp.int32)
        caches = M.set_cache_lengths(caches, lengths)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(n_steps):
            logits, caches = M.decode_step(params, cfg, tok, caches,
                                           lengths + i, seed=i)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        if ref_logits is None:
            ref_logits = logits
            print(f"{fmt:10s} {rnd:10s}  (reference)")
        else:
            err = float(jnp.linalg.norm(logits - ref_logits)
                        / jnp.linalg.norm(ref_logits))
            print(f"{fmt:10s} {rnd:10s}  logit_rel_err={err:.4f}")


def op_level():
    print("== op-level accumulation study (paper Fig. 4 mechanism) ==")
    errs = run_swamping_study(T=300)
    for (fmt, rnd), e in sorted(errs.items(), key=lambda kv: kv[1]):
        print(f"{fmt:10s} {rnd:10s}  state_rel_err={e:.4f}")


if __name__ == "__main__":
    op_level()
    model_level()
