"""Streaming multi-turn chat on the request-lifecycle serving facade.

One `Engine` (paged, bank-aware pool), three concurrent "users":

  * user A chats for --turns turns through a `Session` -- every turn after
    the first *forks* the previous one copy-on-write, so the conversation
    history is never re-prefilled;
  * user B streams a long one-shot generation token by token;
  * user C submits a request and aborts it mid-decode.

All three share the same continuous decode batch; tokens surface from
`Engine.step()` as they are sampled.

Run:  PYTHONPATH=src python examples/serve_batched.py [--arch mamba2-2.7b]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.state_update import StateQuantConfig
from repro.models import model as M
from repro.serving.api import Engine, ServeConfig
from repro.serving.sampler import SamplingConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-2.7b",
                    help="any arch with a decode path (smoke-size weights)")
    ap.add_argument("--turns", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--state-format", default="mx8",
                    choices=["mx8", "int8", "fp16", "fp32"])
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).with_(
        state_quant=StateQuantConfig(fmt=args.state_format,
                                     rounding="stochastic",
                                     backend="pallas" if args.state_format ==
                                     "mx8" else "jnp"))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, ServeConfig(
        backend="paged", batch=4, n_pages=17, n_slabs=9,
        sampling=SamplingConfig(temperature=0.8, top_k=40, top_p=0.95)))
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()

    # --- user B: a long streaming generation riding in the same batch
    b = eng.submit(rng.integers(0, cfg.vocab_size, 24).astype(np.int32),
                   max_new_tokens=4 * args.max_new)
    # --- user C: submitted, then cancelled mid-decode
    c = eng.submit(rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
                   max_new_tokens=4 * args.max_new)

    # --- user A: multi-turn chat over copy-on-write prefix sharing
    chat = eng.session()
    for turn in range(args.turns):
        prompt = rng.integers(0, cfg.vocab_size, 8 + 4 * turn
                              ).astype(np.int32)
        h = chat.send(prompt, max_new_tokens=args.max_new)
        print(f"[A turn {turn}] user sent {len(prompt)} tokens")
        for tok in h:                       # streams; B and C decode too
            print(f"[A turn {turn}] {tok}", end=" ", flush=True)
            if turn == 1 and c.status == "running" and len(c.output) > 4:
                c.abort()
                print(f"\n[C] aborted mid-decode after "
                      f"{len(c.output)} tokens", end="")
        print()
        got_b = b.new_tokens()
        if got_b:
            print(f"[B] streamed {len(got_b)} tokens meanwhile "
                  f"(status={b.status})")
    chat.close()
    b.result()                              # drain whatever B has left

    stats = eng.stats()
    wall = time.perf_counter() - t0
    print(f"\narch={cfg.name} state={args.state_format} "
          f"{stats['tokens']:.0f} tokens in {wall:.2f}s "
          f"-> {stats['tokens_per_s']:.1f} tok/s")
    print(f"sessions skipped re-prefill: {stats['prefill_tokens']:.0f} "
          f"tokens ingested for the whole chat, "
          f"{stats['shared_page_hits']:.0f} shared-page hits, "
          f"{stats['requests_aborted']:.0f} aborted, "
          f"{stats['requests_done']:.0f} done")


if __name__ == "__main__":
    main()
