"""Batched serving driver: the Pimba system loop on a small SU-LLM.

Continuous batching over MX8-quantized recurrent states -- requests arrive,
prefill on the chunked "GPU path", decode through the fused state-update
kernel, slots recycle as requests finish.

Run:  PYTHONPATH=src python examples/serve_batched.py [--arch mamba2-2.7b]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.state_update import StateQuantConfig
from repro.models import model as M
from repro.serving.engine import EngineConfig, Request, ServingEngine
from repro.serving.sampler import SamplingConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-2.7b",
                    help="any arch with a decode path (smoke-size weights)")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--state-format", default="mx8",
                    choices=["mx8", "int8", "fp16", "fp32"])
    ap.add_argument("--paged", action="store_true",
                    help="serve from the paged, bank-aware state/KV pool")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).with_(
        state_quant=StateQuantConfig(fmt=args.state_format,
                                     rounding="stochastic",
                                     backend="pallas" if args.state_format ==
                                     "mx8" else "jnp"))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    sampling = SamplingConfig(temperature=0.8, top_k=40, top_p=0.95)
    if args.paged:
        from repro.serving.engine import PagedEngineConfig, PagedServingEngine
        eng = PagedServingEngine(params, cfg, PagedEngineConfig(
            max_decode_batch=args.slots, n_pages=2 * args.slots + 1,
            n_slabs=2 * args.slots + 1, sampling=sampling))
    else:
        eng = ServingEngine(params, cfg,
                            EngineConfig(slots=args.slots, cache_capacity=128,
                                         sampling=sampling))
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size,
                                               8 + i % 16).astype(np.int32),
                           max_new_tokens=args.max_new))
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    stats = eng.stats()
    print(f"arch={cfg.name} state={args.state_format} slots={args.slots}")
    print(f"served {len(done)} requests, {stats['tokens']} tokens "
          f"in {wall:.2f}s -> {stats['tokens_per_s']:.1f} tok/s "
          f"(mean TTFT {stats['mean_ttft_s']*1e3:.0f} ms)")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.output}")


if __name__ == "__main__":
    main()
