"""Quickstart: the paper's technique in five minutes.

1. build a small hybrid model (Zamba2-style: Mamba-2 + shared attention),
2. prefill a prompt (compute-intensive chunked form -- the "GPU phase"),
3. decode tokens through the MX8-quantized state / KV cache via the fused
   state-update kernel (the "PIM phase"),
4. compare against the fp16-state baseline: same tokens, half the bytes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.state_update import StateQuantConfig
from repro.models import model as M


def generate(cfg, params, prompt, n_new=12):
    batch = {"tokens": prompt, "targets": prompt}
    logits, caches = M.prefill(params, cfg, batch)
    lengths = jnp.full((prompt.shape[0],), prompt.shape[1], jnp.int32)
    caches = M.set_cache_lengths(caches, lengths)
    toks = [int(jnp.argmax(logits[0]))]
    state_bytes = sum(
        l.nbytes for l in jax.tree.leaves(caches)) / 1e6
    for i in range(n_new - 1):
        logits, caches = M.decode_step(
            params, cfg, jnp.asarray([toks[-1]], jnp.int32), caches,
            lengths + i, seed=i)
        toks.append(int(jnp.argmax(logits[0])))
    return toks, state_bytes


def main():
    key = jax.random.PRNGKey(0)
    base = get_smoke_config("zamba2-2.7b")
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 24), 0,
                                base.vocab_size)

    results = {}
    for label, fmt, backend in [("fp16 state (GPU baseline)", "fp16", "jnp"),
                                ("MX8 state (Pimba)", "mx8", "pallas")]:
        cfg = base.with_(state_quant=StateQuantConfig(
            fmt=fmt, rounding="stochastic", backend=backend))
        params = M.init_model(key, cfg)   # same weights both runs
        toks, mb = generate(cfg, params, prompt)
        results[label] = (toks, mb)
        print(f"{label:28s} cache+state={mb:7.2f} MB  tokens={toks}")

    t_fp16, t_mx8 = results["fp16 state (GPU baseline)"][0], \
        results["MX8 state (Pimba)"][0]
    agree = sum(a == b for a, b in zip(t_fp16, t_mx8)) / len(t_fp16)
    ratio = results["fp16 state (GPU baseline)"][1] / results["MX8 state (Pimba)"][1]
    print(f"\ntoken agreement: {agree:.0%}   memory ratio fp16/mx8: {ratio:.2f}x")
    print("(the paper's claim in miniature: ~2x smaller decode state, "
          "matching outputs)")


if __name__ == "__main__":
    main()
