"""Chaos smoke (CI tier 2): a fixed-seed fault plan under a real workload.

Runs the same two workloads clean and faulted and enforces the resilience
layer's whole contract in one shot:

  * every request reaches a terminal status (``done`` / ``failed`` /
    ``rejected`` / ``truncated``) -- injected faults never wedge or kill
    the engine;
  * non-faulted requests decode **bit-identically** to the clean run
    (greedy sampling), including a corrupted spill blob recovered by
    re-prefill;
  * zero cost when disabled: with ``fault_plan=None`` the resilience layer
    installs nothing (no plan, no NaN guard, no watchdog) and two clean
    runs take the identical number of engine steps;
  * the decode step stays inside the pinned recompile budget in both
    modes (the fault hooks must not retrace anything);
  * run under ``REPRO_SANITIZE=1`` the shadow ledger raises on any leak a
    fault path forgot to clean up (CI sets it; the run works either way).

Reproduce any CI chaos run locally from its seed::

    PYTHONPATH=src REPRO_SANITIZE=1 python benchmarks/chaos_smoke.py \
        --seed 0 --trace chaos_trace.json
    PYTHONPATH=src python -m repro.obs.schema chaos_trace.json \
        --require steps,resilience
"""
from __future__ import annotations

import argparse
import os
import sys

#: the fixed plan: one transient alloc failure (retried), one poisoned
#: request (quarantined), one slow step (watchdog), and -- in the
#: preemption workload -- one corrupted spill blob (re-prefilled)
BATCH_PLAN = "alloc:nth=1;nan:rid=2;slow_step:step=4,ms=10"
PREEMPT_PLAN = "blob_corrupt:nth=1"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0,
                    help="fault-plan + workload seed (printed by CI; rerun "
                         "with the same value to reproduce a failure)")
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--trace", default="",
                    help="write the faulted batch run's Chrome trace here "
                         "(validate with repro.obs.schema --require "
                         "resilience)")
    ap.add_argument("--max-decode-recompiles", type=int, default=1,
                    help="fail if the paged decode step compiled more than "
                         "this many times across every run (fault hooks "
                         "must not retrace)")
    args = ap.parse_args(argv)

    import jax
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.core.state_update import StateQuantConfig
    from repro.models import model as M
    from repro.serving.api import Engine, ServeConfig
    from repro.serving.engine import TERMINAL_STATUSES
    from repro.serving.sampler import SamplingConfig
    from repro.serving.scheduler import SchedulerConfig

    if os.environ.get("REPRO_SANITIZE", "").strip() in ("", "0", "false"):
        print("note: REPRO_SANITIZE is off; CI runs this smoke with the "
              "shadow-ledger sanitizer enabled")

    cfg = get_smoke_config(args.arch).with_(
        state_quant=StateQuantConfig(fmt="fp32", rounding="nearest",
                                     backend="jnp"))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    greedy = SamplingConfig(temperature=0.0)
    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (10, 14, 18, 22)]
    failures = []

    def check(cond, msg):
        if not cond:
            failures.append(msg)
            print(f"FAIL: {msg}", file=sys.stderr)

    # ---- workload 1: decode batch, clean vs faulted ---------------------
    def run_batch(fault_plan=None):
        eng = Engine(params, cfg, ServeConfig(
            backend="paged", batch=2, n_pages=17, n_slabs=5,
            sampling=greedy, seed=args.seed, fault_plan=fault_plan,
            step_budget_s=5e-3 if fault_plan else None))
        hs = [eng.submit(p, max_new_tokens=5) for p in prompts]
        eng.run()
        return eng, hs

    eng_clean, hs_clean = run_batch()
    base = [h.output for h in hs_clean]
    clean_steps = eng_clean.engine.step_count
    check(all(h.status == "done" for h in hs_clean),
          "clean batch run did not finish every request")
    check(eng_clean.engine.faults is None
          and not eng_clean.engine._nan_guard
          and not eng_clean.engine.watchdog.enabled,
          "fault_plan=None must install no plan, NaN guard, or watchdog")

    # zero cost when disabled: an identical clean run takes the identical
    # number of engine steps (no hidden retries, no extra syncs)
    eng_clean2, hs_clean2 = run_batch()
    check(eng_clean2.engine.step_count == clean_steps,
          f"clean step count drifted: {clean_steps} vs "
          f"{eng_clean2.engine.step_count}")
    check([h.output for h in hs_clean2] == base,
          "clean rerun is not bit-identical")

    eng_f, hs_f = run_batch(BATCH_PLAN)
    statuses = [h.status for h in hs_f]
    check(all(s in TERMINAL_STATUSES for s in statuses),
          f"non-terminal statuses under faults: {statuses}")
    check(hs_f[2].status == "failed",
          f"poisoned rid 2 should be quarantined, got {hs_f[2].status}")
    for i in (0, 1, 3):
        check(hs_f[i].status == "done" and hs_f[i].output == base[i],
              f"non-faulted rid {i} diverged from the clean run")
    plan = eng_f.engine.faults
    m_f = eng_f.obs.metrics
    check(plan.total_injected >= 3,
          f"expected >=3 injected faults, got {plan.injected}")
    check(m_f.value("faults_recovered_total", site="alloc") >= 1,
          "transient alloc was not recovered")
    check(eng_f.engine.watchdog.trips >= 1,
          "slow step did not trip the watchdog")
    if args.trace:
        eng_f.obs.tracer.save(args.trace)
        print(f"trace -> {args.trace}")

    from repro.obs import recompile as RC
    batch_decode_compiles = RC.site_compile_counts().get("pool.decode", 0)

    # ---- workload 2: preempt + corrupted spill blob ---------------------
    def run_preempt(fault_plan=None):
        long_p = rng_p.integers(0, cfg.vocab_size, 140).astype(np.int32)
        short_p = rng_p.integers(0, cfg.vocab_size, 8).astype(np.int32)
        eng = Engine(params, cfg, ServeConfig(
            backend="paged", batch=1, n_pages=9, n_slabs=5, sampling=greedy,
            scheduler=SchedulerConfig(policy="priority"), seed=args.seed,
            fault_plan=fault_plan))
        hb = eng.submit(long_p, max_new_tokens=8, priority=5)
        while hb.status == "queued" and eng.step():
            pass
        ha = eng.submit(short_p, max_new_tokens=6, priority=0)
        eng.engine._preempt(hb.rid)
        eng.run()
        return eng, ha, hb

    rng_p = np.random.default_rng(args.seed + 1)
    _, _, hb_ref = run_preempt()
    rng_p = np.random.default_rng(args.seed + 1)
    eng_p, ha_p, hb_p = run_preempt(PREEMPT_PLAN)
    check(ha_p.status == "done" and hb_p.status == "done",
          f"preempt workload under {PREEMPT_PLAN!r}: "
          f"{ha_p.status}/{hb_p.status}")
    check(hb_p.output == hb_ref.request.output,
          "re-prefill after blob corruption is not bit-exact")
    check(eng_p.obs.metrics.value("blob_corruptions_total") >= 1,
          "injected blob corruption went undetected")
    check(eng_p.engine.pool.host.pinned_bytes == 0,
          "host pin ledger not drained after recovery")

    # ---- recompile budget, per decode batch shape -----------------------
    # the two workloads legitimately compile one decode each (batch=2 and
    # batch=1); the budget binds *within* each, clean and faulted alike
    preempt_decode_compiles = (RC.site_compile_counts().get("pool.decode", 0)
                               - batch_decode_compiles)
    for what, n in (("batch workload", batch_decode_compiles),
                    ("preempt workload", preempt_decode_compiles)):
        check(n <= args.max_decode_recompiles,
              f"{what}: decode compiled {n}x "
              f"(budget {args.max_decode_recompiles}): a fault hook "
              f"retraced")
    decode_compiles = batch_decode_compiles + preempt_decode_compiles

    injected = dict(plan.injected)
    recovered = int(m_f.value("faults_recovered_total", site="alloc")
                    + eng_p.obs.metrics.value("faults_recovered_total",
                                              site="blob_corrupt"))
    n_failed = sum(1 for s in statuses if s == "failed")
    goodput_clean = sum(1 for h in hs_clean if h.status == "done"
                        ) / len(hs_clean)
    goodput_faulted = sum(1 for s in statuses if s == "done") / len(statuses)
    print(f"chaos seed={args.seed} plan={BATCH_PLAN!r}+{PREEMPT_PLAN!r}")
    print(f"  injected={injected} recovered={recovered} failed={n_failed}")
    print(f"  goodput clean={goodput_clean:.2f} "
          f"faulted={goodput_faulted:.2f}")
    print(f"  clean steps={clean_steps} (stable across reruns), "
          f"decode compiles={decode_compiles}")
    if failures:
        print(f"{len(failures)} chaos check(s) failed "
              f"(reproduce: --seed {args.seed})", file=sys.stderr)
        return 1
    print("OK: batch survived every injected fault; "
          "non-faulted requests bit-identical")
    return 0


if __name__ == "__main__":
    sys.exit(main())
