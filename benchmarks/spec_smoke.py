"""Speculation smoke (CI tier 2): the speculative decode contract in one run.

Decodes the n-gram draft's best-case workload (a repetitive prompt) with and
without speculation and enforces:

  * **greedy exactness** -- every request's speculative output is
    bit-identical to plain paged decoding;
  * **it actually speculates** -- ``accepted_tokens_per_step > 1.0`` on the
    self-draft workload (a floor of 1.0 means no draft ever survived);
  * **bounded compile set** -- the verify step compiles at most
    ``--max-decode-recompiles`` times: drafts ride a fixed ``spec_k + 1``
    position window and the k-controller must never change a traced shape;
  * **clean unwind** -- a chaos ``alloc`` fault during a verify step and a
    mid-speculation abort leave no page/slab leaks in the target pool (run
    under ``REPRO_SANITIZE=1``; CI sets it) and unwind drafted-but-unverified
    tokens with the request.

Reproduce a CI run locally::

    PYTHONPATH=src REPRO_SANITIZE=1 python benchmarks/spec_smoke.py --seed 0
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0,
                    help="workload seed (rerun with the same value to "
                         "reproduce a failure)")
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--spec-k", type=int, default=3)
    ap.add_argument("--max-decode-recompiles", type=int, default=1,
                    help="fail if the speculative verify step compiled more "
                         "than this many times (the k-controller and draft "
                         "lengths must never change a traced shape)")
    args = ap.parse_args(argv)

    import jax
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.core.state_update import StateQuantConfig
    from repro.models import model as M
    from repro.serving.api import Engine, ServeConfig
    from repro.serving.sampler import SamplingConfig

    if os.environ.get("REPRO_SANITIZE", "").strip() in ("", "0", "false"):
        print("note: REPRO_SANITIZE is off; CI runs this smoke with the "
              "shadow-ledger sanitizer enabled")

    cfg = get_smoke_config(args.arch).with_(
        state_quant=StateQuantConfig(fmt="fp32", rounding="nearest",
                                     backend="jnp"))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    greedy = SamplingConfig(temperature=0.0)
    rng = np.random.default_rng(args.seed)
    base = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    prompts = [np.concatenate([base, base, base]).astype(np.int32),
               rng.integers(0, cfg.vocab_size, 11).astype(np.int32)]
    failures = []

    def check(cond, msg):
        if not cond:
            failures.append(msg)
            print(f"FAIL: {msg}", file=sys.stderr)

    def run(spec, fault_plan=None, max_new=24):
        eng = Engine(params, cfg, ServeConfig(
            backend="paged", batch=2, n_pages=17, n_slabs=5,
            sampling=greedy, seed=args.seed, spec=spec, spec_k=args.spec_k,
            fault_plan=fault_plan))
        hs = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
        eng.run()
        return eng, hs

    # ---- greedy exactness + acceptance ----------------------------------
    eng_p, hs_p = run(None)
    eng_s, hs_s = run("ngram")
    for i, (hp, hsp) in enumerate(zip(hs_p, hs_s)):
        check(hsp.status == "done", f"request {i} ended {hsp.status}")
        check(hsp.output == hp.output,
              f"request {i}: speculative greedy output diverged from "
              f"plain decode")
    st = eng_s.stats()
    check(st["accepted_tokens_per_step"] > 1.0,
          f"accepted_tokens_per_step={st['accepted_tokens_per_step']:.2f} "
          f"<= 1.0: the self-draft never got a draft accepted on its "
          f"best-case workload")
    check(eng_p.stats()["proposed_tokens"] == 0.0,
          "plain run reported speculation activity")

    # fewer verify steps than emitted tokens is the whole point
    plain_steps = eng_p.engine.step_count
    spec_steps = eng_s.engine.step_count
    check(spec_steps < plain_steps,
          f"speculation took {spec_steps} steps vs {plain_steps} plain")

    from repro.obs import recompile as RC
    spec_compiles = RC.site_compile_counts().get("pool.decode_spec", 0)
    check(spec_compiles <= args.max_decode_recompiles,
          f"verify step compiled {spec_compiles}x (budget "
          f"{args.max_decode_recompiles}): drafting changed a traced shape")

    # ---- chaos: alloc fault inside a verify step + mid-spec abort -------
    # the transient alloc failure fires during speculative headroom growth;
    # recovery (retry or preempt) must leave the page ledger clean, which
    # the sanitizer asserts when the engine drains
    eng_c, hs_c = run("ngram", fault_plan="alloc:nth=1")
    for i, h in enumerate(hs_c):
        check(h.status == "done" and h.output == hs_p[i].output,
              f"request {i} under alloc fault: {h.status} / diverged")

    eng_a = Engine(params, cfg, ServeConfig(
        backend="paged", batch=2, n_pages=17, n_slabs=5, sampling=greedy,
        seed=args.seed, spec="ngram", spec_k=args.spec_k))
    ha = eng_a.submit(prompts[0], max_new_tokens=24)
    hb = eng_a.submit(prompts[1], max_new_tokens=24)
    # drive into mid-generation (speculation active), then abort one row
    while (len(ha.output) < 4 or len(hb.output) < 4) and eng_a.step():
        pass
    check(ha.abort(), "mid-speculation abort did not take")
    eng_a.run()
    check(hb.status == "done" and hb.output == hs_p[1].output,
          "surviving request diverged after a mid-speculation abort")
    check(ha.status == "aborted", f"aborted request ended {ha.status}")
    # drained engine: the sanitizer (REPRO_SANITIZE=1) has already asserted
    # no page/slab leaked from the aborted speculation on teardown

    print(f"spec seed={args.seed} arch={args.arch} spec_k={args.spec_k}")
    print(f"  acc_per_step={st['accepted_tokens_per_step']:.2f} "
          f"rate={st['acceptance_rate']:.2f} "
          f"proposed={st['proposed_tokens']:.0f} "
          f"accepted={st['accepted_tokens']:.0f}")
    print(f"  steps: spec={spec_steps} plain={plain_steps}, "
          f"verify compiles={spec_compiles}")
    if failures:
        print(f"{len(failures)} speculation check(s) failed "
              f"(reproduce: --seed {args.seed})", file=sys.stderr)
        return 1
    print("OK: greedy bit-identical, >1 token/step, compile budget held, "
          "clean unwind under faults")
    return 0


if __name__ == "__main__":
    sys.exit(main())
