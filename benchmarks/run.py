"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``us_per_call`` is measured
wall time on this CPU where the benchmark executes real compute, or 0 for
purely analytical tables; ``derived`` is the figure-level quantity being
reproduced (a ratio, error, or tokens/s).

  fig3_latency_breakdown    state-update share of generation latency vs batch
  fig4_swamping             format x rounding accuracy study
  fig5a_pim_designs         time-mux / pipelined / interleaved PIM throughput
  fig6_area_accuracy        area (paper RTL numbers) x accuracy Pareto
  fig12_generation          end-to-end throughput: gpu / gpu+q / gpu+pim / pimba
  fig13_latency_reduction   per-op latency reduction vs baselines
  fig15_latency_memory      latency + cache memory vs output length
  kernel_state_update       fused kernel vs unfused jnp on CPU (interpret)
  kernel_attention          decode attention kernel vs ref
  serving_throughput        engine tokens/s vs batch (tiny model, real compute)
  serving_open_loop         Poisson arrivals driving Engine.step(): goodput
  serving_shared_prefix     CoW fork vs N independent submissions: prefill
                            tokens + allocated pages saved
  serving_spec              speculative decoding: self-drafted greedy serving,
                            acceptance counters + pimsim verify-step speedup
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

ROWS: List[Tuple[str, float, str]] = []

# one artifact shared by the serving benches; each contributor rewrites the
# file so a partial run still leaves a valid BENCH_serving.json
SERVING_ARTIFACT: dict = {}


def _dump_serving_artifact():
    import json
    with open("BENCH_serving.json", "w") as f:
        json.dump(SERVING_ARTIFACT, f, indent=2, default=float)


def emit(name: str, us_per_call: float, derived: str):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def _timeit(fn: Callable, n: int = 5) -> float:
    fn()  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


# ---------------------------------------------------------------------------

def fig3_latency_breakdown():
    from repro.core import pimsim as PS
    sys_cfg = PS.SystemConfig()
    for name in ("retnet-2.7b", "gla-2.7b", "hgrn2-2.7b", "mamba2-2.7b",
                 "zamba2-7b"):
        spec = PS.PAPER_MODELS[name]
        for batch in (32, 128):
            lat = PS.generation_step_latency(spec, batch, 2048, sys_cfg, "gpu")
            frac = (lat["state"] + lat["attn"]) / lat["total"]
            emit(f"fig3/{name}/b{batch}", 0.0,
                 f"state+attn_frac={frac:.3f}")


def fig4_swamping():
    from repro.analysis.formats_study import run_swamping_study
    t0 = time.perf_counter()
    errs = run_swamping_study(T=300)
    dt = (time.perf_counter() - t0) * 1e6 / len(errs)
    for (fmt, rnd), e in sorted(errs.items(), key=lambda kv: kv[1]):
        emit(f"fig4/{fmt}/{rnd}", dt, f"state_rel_err={e:.4f}")


def fig5a_pim_designs():
    from repro.core import pimsim as PS
    sys_cfg = PS.SystemConfig()
    spec = PS.PAPER_MODELS["retnet-2.7b"]
    w16 = PS.StateWorkload(128, spec.n_layers, spec.n_heads, spec.dk,
                           spec.dv, "fp16")
    w8 = PS.StateWorkload(128, spec.n_layers, spec.n_heads, spec.dk,
                          spec.dv, "mx8")
    t_gpu = PS.gpu_state_update_latency(w16, sys_cfg)
    for design, w, paper in (("time_multiplexed", w16, 2.8),
                             ("pipelined", w16, 4.3),
                             ("pimba_mx8", w8, None)):
        t = PS.pim_state_update_latency(w, sys_cfg,
                                        design.replace("_mx8", ""))
        tag = f"x_vs_gpu={t_gpu/t:.2f}" + (f"(paper={paper})" if paper else "")
        emit(f"fig5a/{design}", 0.0, tag)


def fig6_area_accuracy():
    """Area numbers are the paper's RTL results (Table 3 / Fig 6, not
    re-synthesizable here); accuracy is our measured study."""
    from repro.analysis.formats_study import run_swamping_study
    area_mm2 = {"fp16": 0.081, "int8": 0.072, "mx8": 0.053,
                "fp8_e4m3": 0.048, "fp8_e5m2": 0.046}
    errs = run_swamping_study(T=200)
    for fmt in ("fp16", "int8", "mx8", "fp8_e4m3", "fp8_e5m2"):
        rnd = "stochastic" if fmt not in ("fp16",) else "nearest"
        e = errs[(fmt, rnd)]
        emit(f"fig6/{fmt}+{'sr' if rnd == 'stochastic' else 'rne'}", 0.0,
             f"area_mm2={area_mm2[fmt]};state_rel_err={e:.4f}")


def fig12_generation():
    from repro.core import pimsim as PS
    sys_cfg = PS.SystemConfig()
    gains_gpu, gains_pim = [], []
    for name, spec in PS.PAPER_MODELS.items():
        th = {s: PS.generation_throughput(spec, 128, 2048, sys_cfg, s)
              for s in ("gpu", "gpu_q", "gpu_pim", "pimba")}
        gains_gpu.append(th["pimba"] / th["gpu"])
        gains_pim.append(th["pimba"] / th["gpu_pim"])
        emit(f"fig12/{name}", 0.0,
             f"pimba_vs_gpu={th['pimba']/th['gpu']:.2f};"
             f"pimba_vs_gpupim={th['pimba']/th['gpu_pim']:.2f};"
             f"gpuq_vs_gpu={th['gpu_q']/th['gpu']:.2f}")
    emit("fig12/geomean", 0.0,
         f"vs_gpu={np.exp(np.mean(np.log(gains_gpu))):.2f}(paper~2.0);"
         f"vs_gpupim={np.exp(np.mean(np.log(gains_pim))):.2f}(paper~1.4)")


def fig13_latency_reduction():
    from repro.core import pimsim as PS
    sys_cfg = PS.SystemConfig()
    for name in ("retnet-2.7b", "hgrn2-2.7b", "zamba2-7b", "opt-6.7b"):
        spec = PS.PAPER_MODELS[name]
        for batch in (32, 128):
            l_gpu = PS.generation_step_latency(spec, batch, 2048, sys_cfg, "gpu")
            l_pb = PS.generation_step_latency(spec, batch, 2048, sys_cfg, "pimba")
            su = (l_gpu["state"] / l_pb["state"]) if l_pb["state"] else 0.0
            at = (l_gpu["attn"] / l_pb["attn"]) if l_pb["attn"] else 0.0
            emit(f"fig13/{name}/b{batch}", 0.0,
                 f"e2e={l_gpu['total']/l_pb['total']:.2f};state={su:.1f};"
                 f"attn={at:.1f}")


def fig15_latency_memory():
    from repro import ops as OPS
    from repro.core import pimsim as PS
    sys_cfg = PS.SystemConfig()
    spec = PS.PAPER_MODELS["zamba2-7b"]
    mx8 = OPS.StateQuantConfig(fmt="mx8", rounding="stochastic", backend="jnp")
    for out_len in (256, 1024, 4096):
        seq = 1024 + out_len
        lat = PS.generation_step_latency(spec, 128, seq, sys_cfg, "pimba")
        # memory: weights + resident state + mx8 KV, all sized by the ops'
        # own traffic descriptors (one read pass == the resident footprint)
        state = PS.StateWorkload(128, spec.n_layers, spec.n_heads, spec.dk,
                                 spec.dv, "mx8").state_bytes
        kv_plan = OPS.plan_attn_decode_dims(
            "attn_decode", dict(B=128, T=seq, KVH=spec.attn_kv_heads,
                                dk=spec.attn_head_dim, dv=spec.attn_head_dim,
                                n=1), mx8)
        mem = (spec.n_params * 2 + state
               + OPS.traffic(kv_plan).state_read * spec.attn_layers)
        emit(f"fig15/outlen{out_len}", 0.0,
             f"step_ms={lat['total']*1e3:.2f};mem_gb={mem/1e9:.1f}")


# ---------------------------------------------------------------------------

def kernel_state_update():
    from repro import ops as OPS
    from repro.core import formats as F
    B, H, dk, dv = 8, 8, 128, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    S0 = jax.random.normal(ks[0], (B, H, dv, dk))
    d = jax.nn.sigmoid(jax.random.normal(ks[1], (B, H, dk)))
    k = jax.random.normal(ks[2], (B, H, dk))
    v = jax.random.normal(ks[3], (B, H, dv))
    q = jax.random.normal(ks[4], (B, H, dk))
    qS = F.mx8_quantize(S0)
    for backend in ("pallas", "jnp"):
        cfg = OPS.StateQuantConfig(fmt="mx8", rounding="stochastic",
                                   backend=backend)
        # the op's own traffic descriptor is the bandwidth denominator
        tr = OPS.traffic(OPS.plan_state_update_dims(B, H, dk, dv, cfg))
        fn = jax.jit(lambda s, cfg=cfg: OPS.state_update_step(
            qS, d, k, v, q, cfg, seed=s))
        us = _timeit(lambda: jax.block_until_ready(fn(jnp.int32(1))), n=3)
        emit(f"kernel/state_update/{backend}", us,
             f"GBps_logical={tr.state_total/us*1e6/1e9:.3f};"
             f"ai_flops_per_byte={6*dk*dv/(2*dk*dv):.1f}")
    # fp16 baseline (the paper's GPU configuration)
    Sf = S0.astype(jnp.bfloat16)
    fn = jax.jit(lambda s: OPS.state_update_float(Sf, d, k, v, q))
    us = _timeit(lambda: jax.block_until_ready(fn(0)), n=3)
    emit("kernel/state_update/fp16_baseline", us,
         f"GBps_logical={B*H*dk*dv*2*2/us*1e6/1e9:.3f}")


def kernel_attention():
    from repro import ops as OPS
    from repro.core import attention_cache as AC
    from repro.core import formats as F
    B, H, KVH, dh, T = 4, 8, 2, 128, 1024
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, dh))
    K = jax.random.normal(ks[1], (B, T, KVH, dh))
    V = jax.random.normal(ks[2], (B, T, KVH, dh))
    qK, qV = F.mx8_quantize(K), F.mx8_quantize(V)
    lengths = jnp.full((B,), T, jnp.int32)
    for backend in ("pallas", "jnp"):
        cfg = OPS.StateQuantConfig(fmt="mx8", rounding="nearest",
                                   backend=backend)
        cache = AC.KVCache(qK, qV, lengths, "mx8")
        tr = OPS.traffic(OPS.plan_attn_decode_dims(
            "attn_decode", dict(B=B, T=T, KVH=KVH, dk=dh, dv=dh, n=1, H=H),
            cfg))
        fn = jax.jit(lambda: OPS.attn_decode(cache, q, cfg))
        us = _timeit(lambda: jax.block_until_ready(fn()), n=3)
        emit(f"kernel/attention_decode/{backend}", us,
             f"GBps_logical={tr.state_read/us*1e6/1e9:.3f}")


def serving_throughput():
    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serving.engine import (EngineConfig, PagedEngineConfig,
                                      PagedServingEngine, Request,
                                      ServingEngine)
    cfg = get_smoke_config("mamba2-2.7b")
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    artifact = SERVING_ARTIFACT
    # one mixed prompt set shared by the slots4 and paged rows, so the
    # paged_vs_slots ratio compares pools, not workloads (prefill compiles
    # per distinct prompt length and would otherwise skew the wall clock)
    mixed = [rng.integers(0, cfg.vocab_size,
                          8 + i % 8 if i % 2 else 40 + i).astype(np.int32)
             for i in range(8)]
    for slots in (1, 4):
        eng = ServingEngine(params, cfg,
                            EngineConfig(slots=slots, cache_capacity=128))
        for i in range(slots * 2):
            prompt = (mixed[i] if slots == 4
                      else rng.integers(0, cfg.vocab_size, 8
                                        ).astype(np.int32))
            eng.submit(Request(rid=i, prompt=prompt, max_new_tokens=8))
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        toks = sum(len(r.output) for r in done)
        stats = eng.stats()
        # the registry view: histogram summaries (ttft/step/tok-latency
        # percentiles, step_s split by compile tag) + which jitted fns
        # compiled how often -- the p99_step_s vs p99_step_nocompile_s gap
        # is compile stalls, not steady-state decode
        stats["histograms"] = eng.obs.metrics.summaries()
        stats["recompile_counts"] = eng.obs.recompiles.counts()
        artifact[f"slots{slots}"] = stats
        emit(f"serving/slots{slots}", dt / max(toks, 1) * 1e6,
             f"tokens_per_s={toks/dt:.2f};requests={len(done)};"
             f"p99_ttft_ms={stats.get('p99_ttft_s', 0)*1e3:.1f};"
             f"p99_step_nocompile_ms="
             f"{stats['p99_step_nocompile_s']*1e3:.1f};"
             f"recompiles={stats['recompiles']:.0f}")
    # paged pool: same decode batch and the same mixed prompts; decode runs
    # the block-table-native ops (no per-step gather/scatter)
    eng = PagedServingEngine(params, cfg, PagedEngineConfig(
        max_decode_batch=4, n_pages=9, n_slabs=9, prefill_chunk=128))
    for i, prompt in enumerate(mixed):
        eng.submit(Request(rid=i, prompt=prompt, max_new_tokens=8))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    stats = eng.stats()
    stats["bank_report"] = eng.bank_report()
    stats["histograms"] = eng.obs.metrics.summaries()
    stats["recompile_counts"] = eng.obs.recompiles.counts()
    artifact["paged"] = stats
    # the headline of the block-table-native rewire: paged tokens/s vs the
    # fixed-slot pool on the identical workload (was ~0.28x with the
    # gather/scatter decode path), plus the residual gather ledger
    ratio = (stats["tokens_per_s"]
             / max(artifact["slots4"]["tokens_per_s"], 1e-9))
    artifact["paged_vs_slots"] = ratio
    emit("serving/paged", dt / max(toks, 1) * 1e6,
         f"tokens_per_s={toks/dt:.2f};requests={len(done)};"
         f"paged_vs_slots={ratio:.2f};"
         f"gather_bytes={stats['gather_bytes']:.0f};"
         f"occupancy={stats['occupancy']:.2f};"
         f"fragmentation={stats['fragmentation']:.2f};"
         f"p99_ttft_ms={stats.get('p99_ttft_s', 0)*1e3:.1f};"
         f"p99_step_nocompile_ms="
         f"{stats['p99_step_nocompile_s']*1e3:.1f};"
         f"recompiles={stats['recompiles']:.0f}")
    # --- jit-hazard fix (lint rule JH103): prefill length bucketing -----
    # "before" is the unbucketed paged row above -- one prefill compile per
    # distinct prompt length (8 in this mix).  "after" snaps the prefill to
    # a fixed bucket set and streams the tail through the decode batch, so
    # the prefill jit sees one shape per *bucket*.
    eng_b = PagedServingEngine(params, cfg, PagedEngineConfig(
        max_decode_batch=4, n_pages=9, n_slabs=9, prefill_chunk=128,
        prefill_buckets=(8, 16, 32, 64, 128)))
    for i, prompt in enumerate(mixed):
        eng_b.submit(Request(rid=100 + i, prompt=prompt, max_new_tokens=8))
    t0 = time.perf_counter()
    done_b = eng_b.run()
    dt_b = time.perf_counter() - t0
    toks_b = sum(len(r.output) for r in done_b)
    stats_b = eng_b.stats()
    stats_b["recompile_counts"] = eng_b.obs.recompiles.counts()
    artifact["paged_bucketed"] = stats_b
    artifact["jit_hazard_fix"] = {
        "rule": "JH103 dynamic-shape-feeds-jit (prefill length churn)",
        "fix": "PagedEngineConfig.prefill_buckets=(8, 16, 32, 64, 128)",
        "before": {k: stats[k] for k in
                   ("recompiles", "recompile_counts",
                    "p99_step_nocompile_s", "tokens_per_s")},
        "after": {k: stats_b[k] for k in
                  ("recompiles", "recompile_counts",
                   "p99_step_nocompile_s", "tokens_per_s")},
    }
    emit("serving/paged_bucketed", dt_b / max(toks_b, 1) * 1e6,
         f"tokens_per_s={toks_b/dt_b:.2f};requests={len(done_b)};"
         f"p99_ttft_ms={stats_b.get('p99_ttft_s', 0)*1e3:.1f};"
         f"p99_step_nocompile_ms="
         f"{stats_b['p99_step_nocompile_s']*1e3:.1f};"
         f"recompiles={stats_b['recompiles']:.0f}")
    _dump_serving_artifact()


def serving_open_loop():
    """Open-loop load generation: Poisson arrivals at a configurable rate
    drive `Engine.step()` (no drain-to-empty batching artifacts).  Emits
    goodput -- the fraction of requests whose end-to-end latency met a
    fixed deadline budget -- alongside achieved throughput."""
    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serving.api import Engine, ServeConfig
    cfg = get_smoke_config("mamba2-2.7b")
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n_req, max_new, budget_s = 8, 6, 2.0
    # one shared prompt length: a single prefill trace, so the measured
    # open-loop latency is decode scheduling, not compile time
    prompts = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
               for _ in range(n_req)]

    for rate in (5.0, 50.0):
        eng = Engine(params, cfg, ServeConfig(backend="paged", batch=4,
                                              n_pages=9, n_slabs=9))
        # jit caches are per-engine: warm *this* engine's prefill/decode
        # traces (full batch so the bucketed decode shape compiles too)
        # before the arrival clock starts, so goodput measures scheduling,
        # not XLA compile time
        for p in prompts[:4]:
            eng.submit(p, max_new_tokens=2)
        eng.run()
        arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_req))
        handles = []
        t0 = time.perf_counter()
        nxt = 0
        while nxt < n_req or any(not h.finished for h in handles):
            now = time.perf_counter() - t0
            while nxt < n_req and arrivals[nxt] <= now:
                handles.append(eng.submit(prompts[nxt],
                                          max_new_tokens=max_new))
                nxt += 1
            if eng.has_work():
                eng.step()
            elif nxt < n_req:
                time.sleep(min(arrivals[nxt] - now, 1e-3))
        dt = time.perf_counter() - t0
        # metrics over the measured handles only (the warm-up batch is
        # excluded; engine.stats() would mix it in)
        lats = [h.request.t_done - h.request.t_submit for h in handles
                if h.status == "done"]
        ttfts = [h.request.t_first - h.request.t_submit for h in handles
                 if h.request.t_first > 0]
        goodput = sum(1 for L in lats if L <= budget_s) / n_req
        toks = sum(len(h.output) for h in handles)
        row = {
            "rate_rps": rate, "goodput": goodput,
            "deadline_budget_s": budget_s,
            "tokens_per_s": toks / max(dt, 1e-9),
            "p99_ttft_s": float(np.percentile(ttfts, 99)) if ttfts else 0.0,
            "p99_latency_s": float(np.percentile(lats, 99)) if lats else 0.0,
        }
        SERVING_ARTIFACT[f"open_loop_rate{rate:g}"] = row
        emit(f"serving/open_loop/rate{rate:g}", dt / n_req * 1e6,
             f"goodput={goodput:.2f};tokens_per_s={row['tokens_per_s']:.2f};"
             f"p99_ttft_ms={row['p99_ttft_s']*1e3:.1f}")
    _dump_serving_artifact()


def serving_shared_prefix():
    """Copy-on-write prefix sharing vs N independent submissions of the
    same prompt: fewer prefill tokens (the shared prefix is ingested once)
    and fewer allocated pages (full prefix pages are refcounted, only the
    tail page is copied per fork)."""
    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serving.api import Engine, ServeConfig
    cfg = get_smoke_config("mamba2-2.7b")
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n_forks, max_new = 4, 4
    prompt = rng.integers(0, cfg.vocab_size, 140).astype(np.int32)
    scfg = ServeConfig(backend="paged", batch=4, n_pages=17, n_slabs=11)

    # N independent submissions: every request re-prefills + re-pins
    eng_i = Engine(params, cfg, scfg)
    t0 = time.perf_counter()
    for _ in range(n_forks):
        eng_i.submit(prompt, max_new_tokens=max_new)
    eng_i.run()
    dt_i = time.perf_counter() - t0
    st_i = eng_i.stats()

    # one parent + N copy-on-write forks: prefix prefilled and pinned once
    eng_f = Engine(params, cfg, scfg)
    t0 = time.perf_counter()
    parent = eng_f.submit(prompt, max_new_tokens=1, retain=True)
    parent.result()
    kids = [eng_f.fork(parent, max_new_tokens=max_new)
            for _ in range(n_forks)]
    eng_f.run()
    dt_f = time.perf_counter() - t0
    st_f = eng_f.stats()
    assert all(k.status == "done" for k in kids)

    saved_tokens = st_i["prefill_tokens"] - st_f["prefill_tokens"]
    saved_pages = st_i["pages_allocated"] - st_f["pages_allocated"]
    SERVING_ARTIFACT["shared_prefix"] = {
        "n_forks": n_forks, "prompt_tokens": len(prompt),
        "independent": st_i, "forked": st_f,
        "prefill_tokens_saved": saved_tokens,
        "pages_saved": saved_pages,
        "shared_page_hits": st_f["shared_page_hits"],
        # from the pool's refcount ledger (peak extra references), not a
        # fork-count proxy -- reads non-zero for *any* sharing mechanism
        "shared_page_savings": st_f["shared_page_savings"],
    }
    emit("serving/shared_prefix", dt_f / n_forks * 1e6,
         f"prefill_tokens={st_f['prefill_tokens']:.0f}"
         f"(vs{st_i['prefill_tokens']:.0f});"
         f"pages={st_f['pages_allocated']:.0f}"
         f"(vs{st_i['pages_allocated']:.0f});"
         f"speedup_vs_independent={dt_i/max(dt_f, 1e-9):.2f}")

    # N *independent* submissions with the radix prefix store: no Session,
    # no fork() -- the store matches each later prompt's prefix against the
    # first request's pages and shares them copy-on-write automatically.
    # shared_page_savings comes from the pool's refcount ledger (and the
    # prefix-store hits feeding it), so it reads > 0 here even though the
    # caller never forked anything -- the reporting fix this artifact pins.
    eng_s = Engine(params, cfg, dataclasses.replace(
        scfg, prefix_cache=True, prefix_store_pages=12))
    t0 = time.perf_counter()
    for _ in range(n_forks):
        eng_s.submit(prompt, max_new_tokens=max_new)
    eng_s.run()
    dt_s = time.perf_counter() - t0
    st_s = eng_s.stats()
    assert st_s["prefix_hits"] > 0, "prefix store saw no cross-request hits"
    assert st_s["shared_page_savings"] > 0, \
        "refcount ledger shows no sharing despite prefix hits"
    assert st_s["prefill_tokens"] < st_i["prefill_tokens"], \
        "prefix store did not reduce prefill work"
    SERVING_ARTIFACT["shared_prefix"]["cross_request"] = {
        "n_requests": n_forks,
        "prefill_tokens": st_s["prefill_tokens"],
        "prefill_tokens_baseline": st_i["prefill_tokens"],
        "shared_page_hits": st_s["shared_page_hits"],
        "shared_page_savings": st_s["shared_page_savings"],
        "prefix_hits": st_s["prefix_hits"],
        "prefix_hit_tokens": st_s["prefix_hit_tokens"],
    }
    emit("serving/shared_prefix_xreq", dt_s / n_forks * 1e6,
         f"prefill_tokens={st_s['prefill_tokens']:.0f}"
         f"(vs{st_i['prefill_tokens']:.0f});"
         f"prefix_hits={st_s['prefix_hits']:.0f};"
         f"shared_page_savings={st_s['shared_page_savings']:.0f}")
    _dump_serving_artifact()


def serving_chaos():
    """Goodput under a fixed-seed fault plan vs the clean run.  The hard
    gate (bit-exact non-faulted requests, zero-cost-when-disabled, trace
    schema) lives in benchmarks/chaos_smoke.py / CI's Chaos step; this row
    records the headline resilience numbers into BENCH_serving.json."""
    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serving.api import Engine, ServeConfig
    from repro.serving.sampler import SamplingConfig
    cfg = get_smoke_config("mamba2-2.7b")
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (10, 14, 18, 22)]
    plan = "alloc:nth=1;nan:rid=2;slow_step:step=4,ms=10"

    def run(fault_plan=None):
        eng = Engine(params, cfg, ServeConfig(
            backend="paged", batch=2, n_pages=17, n_slabs=5,
            sampling=SamplingConfig(temperature=0.0), fault_plan=fault_plan,
            step_budget_s=5e-3 if fault_plan else None))
        hs = [eng.submit(p, max_new_tokens=5) for p in prompts]
        t0 = time.perf_counter()
        eng.run()
        return eng, hs, time.perf_counter() - t0

    eng_c, hs_c, dt_c = run()
    eng_f, hs_f, dt_f = run(plan)
    toks_c = sum(len(h.output) for h in hs_c)
    toks_f = sum(len(h.output) for h in hs_f)
    goodput_c = sum(1 for h in hs_c if h.status == "done") / len(hs_c)
    goodput_f = sum(1 for h in hs_f if h.status == "done") / len(hs_f)
    m_f = eng_f.obs.metrics
    injected = dict(eng_f.engine.faults.injected)
    recovered = m_f.family_total("faults_recovered_total")
    st_f = eng_f.stats()
    SERVING_ARTIFACT["chaos"] = {
        "fault_plan": plan, "seed": 0,
        "goodput_clean": goodput_c, "goodput_faulted": goodput_f,
        "tokens_per_s_clean": toks_c / max(dt_c, 1e-9),
        "tokens_per_s_faulted": toks_f / max(dt_f, 1e-9),
        "faults_injected": injected,
        "faults_recovered": recovered,
        "requests_failed": st_f["requests_failed"],
        "requests_rejected": st_f["requests_rejected"],
        "quarantines": m_f.value("quarantines_total"),
        "watchdog_trips": m_f.value("watchdog_trips_total"),
    }
    emit("serving/chaos", dt_f / max(toks_f, 1) * 1e6,
         f"goodput_clean={goodput_c:.2f};goodput_faulted={goodput_f:.2f};"
         f"injected={sum(injected.values())};recovered={recovered:.0f};"
         f"failed={st_f['requests_failed']:.0f}")
    _dump_serving_artifact()


def serving_spec():
    """Speculative decoding: self-drafted greedy serving vs plain decode.

    A repetitive prompt (the n-gram draft's best case) decodes with and
    without ``spec="ngram"``; greedy outputs must be bit-identical and the
    artifact records the schema-stable acceptance counters plus the
    analytical pimsim verify-step model at the measured acceptance rate."""
    from repro.configs import get_smoke_config
    from repro.core import pimsim as PS
    from repro.models import model as M
    from repro.serving.api import Engine, ServeConfig
    from repro.serving.sampler import SamplingConfig
    cfg = get_smoke_config("llama3.2-1b")
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    base = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    prompt = np.concatenate([base, base, base]).astype(np.int32)
    max_new = 32

    def run(spec):
        eng = Engine(params, cfg, ServeConfig(
            backend="paged", batch=2, n_pages=17, n_slabs=5,
            sampling=SamplingConfig(temperature=0.0), spec=spec, spec_k=3))
        h = eng.submit(prompt, max_new_tokens=max_new)
        t0 = time.perf_counter()
        eng.run()
        return eng, h, time.perf_counter() - t0

    eng_p, h_p, dt_p = run(None)
    eng_s, h_s, dt_s = run("ngram")
    assert h_s.output == h_p.output, \
        "speculative greedy output diverged from plain decode"
    st = eng_s.stats()
    assert st["accepted_tokens_per_step"] > 1.0, \
        "self-drafting accepted nothing on its best-case workload"
    sys_cfg = PS.SystemConfig()
    spec_m = PS.PAPER_MODELS["zamba2-7b"]
    model_speedup = (PS.spec_generation_throughput(
        spec_m, 16, 2048, 3, st["acceptance_rate"], sys_cfg, "pimba")
        / PS.generation_throughput(spec_m, 16, 2048, sys_cfg, "pimba"))
    SERVING_ARTIFACT["spec"] = {
        "draft": "ngram", "spec_k": 3,
        "proposed_tokens": st["proposed_tokens"],
        "accepted_tokens": st["accepted_tokens"],
        "acceptance_rate": st["acceptance_rate"],
        "accepted_tokens_per_step": st["accepted_tokens_per_step"],
        "greedy_bit_identical": True,
        "pimsim_speedup_at_rate": model_speedup,
    }
    emit("serving/spec", dt_s / max(len(h_s.output), 1) * 1e6,
         f"acc_per_step={st['accepted_tokens_per_step']:.2f};"
         f"rate={st['acceptance_rate']:.2f};"
         f"proposed={st['proposed_tokens']:.0f};"
         f"pimsim_speedup={model_speedup:.2f}")
    _dump_serving_artifact()


BENCHES = [fig3_latency_breakdown, fig4_swamping, fig5a_pim_designs,
           fig6_area_accuracy, fig12_generation, fig13_latency_reduction,
           fig15_latency_memory, kernel_state_update, kernel_attention,
           serving_throughput, serving_open_loop, serving_shared_prefix,
           serving_chaos, serving_spec]


def main() -> None:
    print("name,us_per_call,derived")
    for bench in BENCHES:
        bench()


if __name__ == "__main__":
    main()
