"""16-token shared-prefix smoke (CI tier 2).

One 130-token prompt, four copy-on-write forked continuations of four
tokens each (16 generated tokens total).  Fails if:

  * the forked path re-prefills the shared prompt (prefill-token ledger
    must show the prompt ingested exactly once, plus one fed parent token
    per fork), or
  * prefix sharing stops saving pages (forks must allocate strictly fewer
    pages than four independent submissions of the same prompt), or
  * a forked continuation diverges from the unshared re-prefill reference
    (greedy, fp32 -- tokens must match bit-for-bit).

    PYTHONPATH=src python benchmarks/prefix_smoke.py
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--forks", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=4)
    args = ap.parse_args(argv)

    import jax
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.core.state_update import StateQuantConfig
    from repro.models import model as M
    from repro.serving.api import Engine, ServeConfig

    cfg = get_smoke_config(args.arch).with_(
        state_quant=StateQuantConfig(fmt="fp32", rounding="nearest",
                                     backend="jnp"))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 130).astype(np.int32)
    scfg = ServeConfig(backend="paged", batch=4, n_pages=17, n_slabs=11)

    # forked: prefix prefilled once, N CoW continuations
    eng = Engine(params, cfg, scfg)
    parent = eng.submit(prompt, max_new_tokens=1, retain=True)
    parent.result()
    kids = [eng.fork(parent, max_new_tokens=args.max_new)
            for _ in range(args.forks)]
    eng.run()
    st = eng.stats()

    # independent baseline: the same continuation context, re-prefilled
    eng_i = Engine(params, cfg, scfg)
    full = np.concatenate([prompt, np.asarray(parent.output, np.int32)])
    refs = [eng_i.submit(full, max_new_tokens=args.max_new)
            for _ in range(args.forks)]
    eng_i.run()
    st_i = eng_i.stats()

    expected_ingest = len(prompt) + args.forks  # prompt once + 1 fed tok/fork
    print(f"forked:      prefill_tokens={st['prefill_tokens']:.0f} "
          f"(floor {expected_ingest}), pages={st['pages_allocated']:.0f}, "
          f"shared_hits={st['shared_page_hits']:.0f}")
    print(f"independent: prefill_tokens={st_i['prefill_tokens']:.0f}, "
          f"pages={st_i['pages_allocated']:.0f}")

    ok = True
    if st["prefill_tokens"] > expected_ingest:
        print("FAIL: forked decode re-prefilled the shared prompt "
              f"({st['prefill_tokens']:.0f} > {expected_ingest} ingested "
              "tokens)", file=sys.stderr)
        ok = False
    if not st["pages_allocated"] < st_i["pages_allocated"]:
        print("FAIL: prefix sharing allocated no fewer pages than "
              "independent submissions", file=sys.stderr)
        ok = False
    if st["shared_page_hits"] < args.forks:
        print("FAIL: forks took no copy-on-write page references",
              file=sys.stderr)
        ok = False
    for k, r in zip(kids, refs):
        if k.output != r.output:
            print(f"FAIL: fork {k.rid} diverged from the unshared "
                  f"re-prefill reference: {k.output} != {r.output}",
                  file=sys.stderr)
            ok = False
    if ok:
        print(f"OK: {args.forks} forks x {args.max_new} tokens bit-exact, "
              f"{st_i['prefill_tokens'] - st['prefill_tokens']:.0f} prefill "
              f"tokens and {st_i['pages_allocated'] - st['pages_allocated']:.0f} "
              "pages saved")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
