"""Shared-prefix smoke (CI tier 2).

Default mode -- explicit copy-on-write forks: one 130-token prompt, four
forked continuations of four tokens each.  Fails if:

  * the forked path re-prefills the shared prompt (prefill-token ledger
    must show the prompt ingested exactly once, plus one fed parent token
    per fork), or
  * prefix sharing stops saving pages (forks must allocate strictly fewer
    pages than four independent submissions of the same prompt), or
  * a forked continuation diverges from the unshared re-prefill reference
    (greedy, fp32 -- tokens must match bit-for-bit).

``--cross-request`` mode -- the radix prefix store: N *independent*
requests sharing a 128-token system prompt (no Session, no fork()).
Fails if:

  * the store saves zero pages (``prefix_hits`` / ``shared_page_hits``
    must be > 0 -- the refcount ledger, not a fork counter), or
  * prefill work is not strictly below the no-store baseline, or
  * any output diverges from the no-store re-prefill reference, or
  * a *cold* store hit (every node demoted to the host tier first) is not
    bit-exact or moves zero promote bytes, or
  * (with ``--max-decode-recompiles N``) the tiered pool added decode
    retraces.

``--trace PATH`` saves the cross-request run's Chrome trace; it carries
the ``tiered`` schema feature (``python -m repro.obs.schema PATH
--require tiered``).

    PYTHONPATH=src python benchmarks/prefix_smoke.py
    PYTHONPATH=src python benchmarks/prefix_smoke.py --cross-request
"""
from __future__ import annotations

import argparse
import sys


def _fork_mode(args, params, cfg, Engine, ServeConfig, np) -> int:
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab_size, 130).astype(np.int32)
    scfg = ServeConfig(backend="paged", batch=4, n_pages=17, n_slabs=11)

    # forked: prefix prefilled once, N CoW continuations
    eng = Engine(params, cfg, scfg)
    parent = eng.submit(prompt, max_new_tokens=1, retain=True)
    parent.result()
    kids = [eng.fork(parent, max_new_tokens=args.max_new)
            for _ in range(args.forks)]
    eng.run()
    st = eng.stats()

    # independent baseline: the same continuation context, re-prefilled
    eng_i = Engine(params, cfg, scfg)
    full = np.concatenate([prompt, np.asarray(parent.output, np.int32)])
    refs = [eng_i.submit(full, max_new_tokens=args.max_new)
            for _ in range(args.forks)]
    eng_i.run()
    st_i = eng_i.stats()

    expected_ingest = len(prompt) + args.forks  # prompt once + 1 fed tok/fork
    print(f"forked:      prefill_tokens={st['prefill_tokens']:.0f} "
          f"(floor {expected_ingest}), pages={st['pages_allocated']:.0f}, "
          f"shared_hits={st['shared_page_hits']:.0f}")
    print(f"independent: prefill_tokens={st_i['prefill_tokens']:.0f}, "
          f"pages={st_i['pages_allocated']:.0f}")

    ok = True
    if st["prefill_tokens"] > expected_ingest:
        print("FAIL: forked decode re-prefilled the shared prompt "
              f"({st['prefill_tokens']:.0f} > {expected_ingest} ingested "
              "tokens)", file=sys.stderr)
        ok = False
    if not st["pages_allocated"] < st_i["pages_allocated"]:
        print("FAIL: prefix sharing allocated no fewer pages than "
              "independent submissions", file=sys.stderr)
        ok = False
    if st["shared_page_hits"] < args.forks:
        print("FAIL: forks took no copy-on-write page references",
              file=sys.stderr)
        ok = False
    for k, r in zip(kids, refs):
        if k.output != r.output:
            print(f"FAIL: fork {k.rid} diverged from the unshared "
                  f"re-prefill reference: {k.output} != {r.output}",
                  file=sys.stderr)
            ok = False
    if ok:
        print(f"OK: {args.forks} forks x {args.max_new} tokens bit-exact, "
              f"{st_i['prefill_tokens'] - st['prefill_tokens']:.0f} prefill "
              f"tokens and {st_i['pages_allocated'] - st['pages_allocated']:.0f} "
              "pages saved")
    return 0 if ok else 1


def _cross_request_mode(args, params, cfg, Engine, ServeConfig, np) -> int:
    rng = np.random.default_rng(0)
    sysp = rng.integers(0, cfg.vocab_size, 128).astype(np.int32)
    tails = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
             for _ in range(args.forks)]
    prompts = [np.concatenate([sysp, t]) for t in tails]
    mk = lambda **kw: ServeConfig(backend="paged", batch=2, n_pages=17,
                                  n_slabs=7, **kw)

    # no-store baseline: every request re-prefills the system prompt
    eng_b = Engine(params, cfg, mk())
    refs = [eng_b.submit(p, max_new_tokens=args.max_new) for p in prompts]
    eng_b.run()
    st_b = eng_b.stats()

    # prefix store on: request 0 prefills, the rest adopt its pages
    eng = Engine(params, cfg, mk(prefix_cache=True, prefix_store_pages=8))
    hs = [eng.submit(p, max_new_tokens=args.max_new) for p in prompts]
    eng.run()
    st = eng.stats()

    print(f"store:    prefill_tokens={st['prefill_tokens']:.0f}, "
          f"prefix_hits={st['prefix_hits']:.0f}, "
          f"shared_hits={st['shared_page_hits']:.0f}, "
          f"savings={st['shared_page_savings']:.0f}")
    print(f"baseline: prefill_tokens={st_b['prefill_tokens']:.0f}")

    ok = True
    if st["prefix_hits"] <= 0 or st["shared_page_hits"] <= 0:
        print("FAIL: independent requests sharing a 128-token system "
              "prompt saved zero pages", file=sys.stderr)
        ok = False
    if st["shared_page_savings"] <= 0:
        print("FAIL: refcount ledger reports zero shared-page savings",
              file=sys.stderr)
        ok = False
    if not st["prefill_tokens"] < st_b["prefill_tokens"]:
        print("FAIL: prefix store did not reduce prefill tokens "
              f"({st['prefill_tokens']:.0f} vs {st_b['prefill_tokens']:.0f})",
              file=sys.stderr)
        ok = False
    for h, r in zip(hs, refs):
        if h.output != r.output:
            print(f"FAIL: prefix-hit request {h.rid} diverged from full "
                  f"re-prefill: {h.output} != {r.output}", file=sys.stderr)
            ok = False

    # cold-store hit: demote every stored page to the host tier, then a
    # fresh request must promote them back and still match the baseline
    pool = eng.engine.pool
    demoted = pool.demote_all()
    tail = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    cold_prompt = np.concatenate([sysp, tail])
    ref = eng_b.submit(cold_prompt, max_new_tokens=args.max_new)
    eng_b.run()
    hit = eng.submit(cold_prompt, max_new_tokens=args.max_new)
    eng.run()
    st2 = eng.stats()
    print(f"cold:     demoted={demoted}, prefix_hits={st2['prefix_hits']:.0f}, "
          f"promote_bytes={st2['promote_bytes']:.0f}")
    if demoted <= 0:
        print("FAIL: nothing to demote -- store held no resident pages",
              file=sys.stderr)
        ok = False
    if st2["prefix_hits"] <= st["prefix_hits"]:
        print("FAIL: cold store produced no prefix hit", file=sys.stderr)
        ok = False
    if hit.output != ref.output:
        print(f"FAIL: cold-store hit diverged from full re-prefill: "
              f"{hit.output} != {ref.output}", file=sys.stderr)
        ok = False
    if pool.page_nbytes > 0 and st2["promote_bytes"] <= 0:
        # attention-free archs have zero page bytes; skip the byte check
        print("FAIL: cold hit moved zero bytes host->device",
              file=sys.stderr)
        ok = False

    if args.max_decode_recompiles is not None:
        n = eng.obs.recompiles.counts().get("pool.decode", 0)
        print(f"decode recompiles: {n} (budget {args.max_decode_recompiles})")
        if n > args.max_decode_recompiles:
            print(f"FAIL: {n} decode retraces > budget "
                  f"{args.max_decode_recompiles}", file=sys.stderr)
            ok = False

    if args.trace:
        eng.save_trace(args.trace)
        print(f"trace saved to {args.trace}")

    if ok:
        print(f"OK: {args.forks} independent requests shared the system "
              f"prompt ({st_b['prefill_tokens'] - st['prefill_tokens']:.0f} "
              "prefill tokens saved), cold-store hit bit-exact")
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--forks", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--cross-request", action="store_true",
                    help="radix prefix store over N independent requests "
                         "(no explicit forks)")
    ap.add_argument("--trace", default=None,
                    help="save the cross-request run's Chrome trace here")
    ap.add_argument("--max-decode-recompiles", type=int, default=None,
                    help="fail if pool.decode retraced more than this")
    args = ap.parse_args(argv)

    import jax
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.core.state_update import StateQuantConfig
    from repro.models import model as M
    from repro.serving.api import Engine, ServeConfig

    cfg = get_smoke_config(args.arch).with_(
        state_quant=StateQuantConfig(fmt="fp32", rounding="nearest",
                                     backend="jnp"))
    params = M.init_model(jax.random.PRNGKey(0), cfg)

    if args.cross_request:
        return _cross_request_mode(args, params, cfg, Engine, ServeConfig, np)
    return _fork_mode(args, params, cfg, Engine, ServeConfig, np)


if __name__ == "__main__":
    sys.exit(main())
