"""§Perf hillclimb driver: the three chosen cells, baseline vs variants.

Cells (chosen per spec from the baseline roofline table):
  1. deepseek-v2-236b x decode_32k  -- most collective-bound cell of the
     fleet (per-token FSDP weight gathers dwarf every other term).
  2. zamba2-2.7b x decode_32k       -- most representative of the paper's
     technique (hybrid Mamba-2 + attention decode = Pimba's headline).
  3. yi-34b x train_4k              -- worst train cell: Megatron-SP
     boundary collectives dominate its roofline.

Each variant re-lowers + re-compiles the production step and records the
three roofline terms; hypotheses/verdicts are written into EXPERIMENTS.md.

Run: PYTHONPATH=src python -m benchmarks.perf_iterations [--out perf_results.json]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json

from repro.core.state_update import StateQuantConfig
from repro.launch.dryrun import lower_cell

FP16_STATE = StateQuantConfig(fmt="fp16", rounding="nearest", backend="jnp")

# (cell-name, arch, shape, variant-name, lower_cell kwargs)
VARIANTS = [
    # --- cell 1: deepseek decode ---
    ("deepseek-decode", "deepseek-v2-236b", "decode_32k", "baseline", {}),
    ("deepseek-decode", "deepseek-v2-236b", "decode_32k",
     "2d-weight-stationary", {"serve_2d": True}),
    ("deepseek-decode", "deepseek-v2-236b", "decode_32k",
     "2d + fp16 cache (paper GPU baseline)",
     {"serve_2d": True, "cfg_overrides": {"state_quant": FP16_STATE}}),
    # --- cell 2: zamba2 decode ---
    ("zamba2-decode", "zamba2-2.7b", "decode_32k",
     "fp16 state+KV (paper GPU baseline)",
     {"cfg_overrides": {"state_quant": FP16_STATE}}),
    ("zamba2-decode", "zamba2-2.7b", "decode_32k", "mx8 (paper-faithful)", {}),
    ("zamba2-decode", "zamba2-2.7b", "decode_32k",
     "mx8 + 2d-weight-stationary", {"serve_2d": True}),
    # --- cell 3: yi-34b train ---
    ("yi34b-train", "yi-34b", "train_4k", "baseline (SP on)", {}),
    ("yi34b-train", "yi-34b", "train_4k", "SP off",
     {"cfg_overrides": {"seq_parallel": False}}),
    ("yi34b-train", "yi-34b", "train_4k", "SP on, q_chunk 2048",
     {"cfg_overrides": {"attn_q_chunk": 2048, "attn_kv_chunk": 2048}}),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="perf_results.json")
    ap.add_argument("--cell", default=None,
                    help="run only one cell group by name")
    args = ap.parse_args()
    results = []
    prior_probe = {}
    for cell, arch, shape, variant, kw in VARIANTS:
        if args.cell and cell != args.cell:
            continue
        print(f"=== {cell} :: {variant} ===", flush=True)
        # FLOPs don't change across these variants (same math): probe once
        pf = prior_probe.get((arch, shape))
        rec = lower_cell(arch, shape, probe_from=pf, verbose=True, **kw)
        if rec.get("status") == "ok" and (arch, shape) not in prior_probe:
            prior_probe[(arch, shape)] = rec
        rec["cell"] = cell
        rec["variant"] = variant
        results.append(rec)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    # summary table
    print("\ncell | variant | t_comp ms | t_mem ms | t_coll ms | bottleneck | "
          "fits(GiB)")
    for r in results:
        if r.get("status") != "ok":
            print(f"{r['cell']} | {r['variant']} | FAILED")
            continue
        rf = r["roofline"]
        mm = r["memory"]
        tot = (mm["argument_bytes"] + mm["temp_bytes"]) / 2**30
        print(f"{r['cell']} | {r['variant']} | {rf['t_compute_s']*1e3:.2f} | "
              f"{rf['t_memory_s']*1e3:.2f} | {rf['t_collective_s']*1e3:.2f} | "
              f"{rf['bottleneck']} | {tot:.1f}")


if __name__ == "__main__":
    main()
