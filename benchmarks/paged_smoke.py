"""16-token paged-vs-slotted throughput smoke (CI tier 2).

Runs the identical workload -- four equal-length prompts, four new tokens
each -- through the fixed-slot engine and the paged engine's
block-table-native decode path, and fails if paged tokens/s drops below
``--min-ratio`` x slots4.  This is the regression guard for the paged
kernels: before they landed, the gather/scatter decode loop ran at ~0.28x
the slotted pool; the floor is deliberately below parity so CI-runner noise
does not flake, while a reintroduced per-step gather still trips it.

    PYTHONPATH=src python benchmarks/paged_smoke.py --min-ratio 0.8
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--min-ratio", type=float, default=0.8,
                    help="fail if paged tokens/s < ratio * slots4 tokens/s")
    ap.add_argument("--max-decode-recompiles", type=int, default=1,
                    help="fail if the paged pool's decode step compiled "
                         "more than this many times over the run (the "
                         "workload is shaped for a single decode shape; "
                         "more means a silent retrace crept in)")
    ap.add_argument("--arch", default="mamba2-2.7b")
    args = ap.parse_args(argv)

    import jax
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serving.engine import (EngineConfig, PagedEngineConfig,
                                      PagedServingEngine, Request,
                                      ServingEngine)

    cfg = get_smoke_config(args.arch)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    # equal-length prompts: one prefill compile per engine, so the ratio
    # measures the decode paths rather than trace counts
    prompts = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
               for _ in range(4)]

    def requests():
        return [Request(rid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]

    slotted = ServingEngine(params, cfg,
                            EngineConfig(slots=4, cache_capacity=128))
    for r in requests():
        slotted.submit(r)
    slotted.run()
    s_stats = slotted.stats()

    paged = PagedServingEngine(params, cfg, PagedEngineConfig(
        max_decode_batch=4, n_pages=9, n_slabs=9, prefill_chunk=128))
    for r in requests():
        paged.submit(r)
    paged.run()
    p_stats = paged.stats()

    ratio = p_stats["tokens_per_s"] / max(s_stats["tokens_per_s"], 1e-9)
    # one source of truth for wrapped jit sites: the module-level registry
    # in repro.obs.recompile (the jit-hazard linter reads the same one)
    from repro.obs import recompile as RC
    site_compiles = RC.site_compile_counts()
    decode_compiles = site_compiles.get("pool.decode", 0)
    print(f"slots4:  {s_stats['tokens']} tokens, "
          f"{s_stats['tokens_per_s']:.2f} tok/s")
    print(f"paged:   {p_stats['tokens']} tokens, "
          f"{p_stats['tokens_per_s']:.2f} tok/s, "
          f"gather_bytes={p_stats['gather_bytes']:.0f}")
    print(f"paged_vs_slots={ratio:.2f} (floor {args.min_ratio})")
    print(f"paged decode compiles={decode_compiles} "
          f"(budget {args.max_decode_recompiles}); "
          f"jit sites: " + " ".join(
              f"{k}={v}" for k, v in sorted(site_compiles.items())))
    ok = True
    if ratio < args.min_ratio:
        print("FAIL: paged decode fell below the throughput floor",
              file=sys.stderr)
        ok = False
    if decode_compiles > args.max_decode_recompiles:
        for ev in paged.obs.recompiles.events:
            if ev.fn == "pool.decode" and not ev.is_warmup:
                print(f"  retrace: {ev.changed}", file=sys.stderr)
        print("FAIL: paged decode step retraced beyond the pinned budget",
              file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
