"""Render the dry-run artifacts into the §Dry-run / §Roofline tables.

Usage: PYTHONPATH=src python -m benchmarks.roofline_report [dryrun_results.json]
"""
from __future__ import annotations

import json
import sys

from repro.analysis.roofline import HBM_BW, LINK_BW, PEAK_FLOPS


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def render(path: str = "dryrun_results.json") -> str:
    recs = json.load(open(path))
    # optional extra artifact files (paper models, perf variants)
    import os
    for extra in ("dryrun_paper_models.json",):
        if os.path.exists(extra) and extra != path:
            recs = recs + json.load(open(extra))
    lines = []
    lines.append("| arch | shape | mesh | fits (args+temp GiB) | t_comp ms | "
                 "t_mem ms | t_coll ms | bottleneck | useful FLOPs frac |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        mesh = "2x16x16" if r.get("multi_pod") else "16x16"
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {mesh} | "
                         f"skipped: {r['reason'][:60]} | | | | | |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {mesh} | "
                         f"FAILED: {r.get('error','?')[:60]} | | | | | |")
            continue
        rf = r["roofline"]
        mm = r["memory"]
        tot = (mm["argument_bytes"] + mm["temp_bytes"]) / 2**30
        frac = rf.get("useful_flops_frac")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {mesh} "
            f"| {'Y' if tot < 16 else 'tight'} ({tot:.1f}) "
            f"| {rf['t_compute_s']*1e3:.2f} | {rf['t_memory_s']*1e3:.2f} "
            f"| {rf['t_collective_s']*1e3:.2f} | {rf['bottleneck']} "
            f"| {frac:.2f} |" if frac else
            f"| {r['arch']} | {r['shape']} | {mesh} "
            f"| {'Y' if tot < 16 else 'tight'} ({tot:.1f}) "
            f"| {rf['t_compute_s']*1e3:.2f} | {rf['t_memory_s']*1e3:.2f} "
            f"| {rf['t_collective_s']*1e3:.2f} | {rf['bottleneck']} | n/a |")
    return "\n".join(lines)


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    print(render(path))
