"""Attention mixers: GQA (llama-family) and MLA (DeepSeek-V2).

Training/prefill use a memory-efficient blockwise ("flash") formulation in
pure JAX -- the paper runs prefill on the GPU in compute-intensive form, and
on TPU the MXU-friendly einsum form is the analogue.  Decode uses the
MX8-quantized KV cache through the registered SPU ops (``kv_append`` +
``attn_decode``/``mla_decode``, repro/ops/attention.py) in one unified step.

MLA runs in *absorbed* form everywhere: queries are projected into the
compressed-latent space so the cache is a single (kv_lora + rope) stream --
this is what makes the MLA decode cache 576 bytes/token instead of
2 * H * dh, and it maps directly onto the kernel's MLA mode.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import ops as OPS
from repro.core import attention_cache as AC
from repro.models import layers as L
from repro.models.config import ModelConfig

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# blockwise causal attention (pure JAX flash-style, memory-efficient VJP)
# ---------------------------------------------------------------------------
#
# The backward pass recomputes score chunks instead of saving them (the
# flash-attention trick); without this, differentiating the nested scans
# saves every (q_chunk x kv_chunk) probability block and the training-step
# memory explodes ~8x (measured in EXPERIMENTS.md §Perf iteration 1).

def _mask_chunk(s, q_idx, k_idx, q_chunk, kv_chunk, prefix_len):
    """Additive mask, (qc, kc) only.

    Deliberately NOT a broadcast boolean `where`: the where-VJP would save
    the mask at the broadcast (B,KVH,G,qc,kc) shape, and being
    input-independent it gets hoisted out of the layer scan and stacked over
    every (q,kv) chunk pair -- a multi-GiB pred buffer (measured; see
    EXPERIMENTS.md §Perf).  An additive f32 (qc,kc) mask has an identity VJP
    and costs 4 bytes per chunk-pair cell."""
    qp = q_idx * q_chunk + jnp.arange(q_chunk)
    kp = k_idx * kv_chunk + jnp.arange(kv_chunk)
    ok = qp[:, None] >= kp[None, :]
    if prefix_len:
        ok = ok | (kp[None, :] < prefix_len)
    return s + jnp.where(ok, 0.0, NEG_INF).astype(s.dtype)


def _flash_fwd_impl(qb, kb, vb, causal, prefix_len, q_chunk, kv_chunk,
                    unroll=False):
    """qb: (nq,B,KVH,G,qc,dh) pre-scaled f32; kb/vb: (nk,B,KVH,kc,d*).

    Returns (out (nq,B,KVH,G,qc,dv), lse (nq,B,KVH,G,qc,1))."""
    nq, B, KVH, G, qc, dh = qb.shape
    nk = kb.shape[0]
    dv = vb.shape[-1]

    def q_body(_, qi_inp):
        qi, q_idx = qi_inp

        def kv_body(carry, kv_inp):
            m, l, acc = carry
            kj, vj, k_idx = kv_inp
            s = jnp.einsum("bngqd,bnkd->bngqk", qi, kj)
            if causal:
                s = _mask_chunk(s, q_idx, k_idx, q_chunk, kv_chunk, prefix_len)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, -1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum("bngqk,bnkv->bngqv", p, vj)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KVH, G, qc, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros_like(m0)
        a0 = jnp.zeros((B, KVH, G, qc, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, a0), (kb, vb, jnp.arange(nk)), unroll=unroll)
        l = jnp.maximum(l, 1e-30)
        return None, (acc / l, m + jnp.log(l))

    _, (out, lse) = jax.lax.scan(q_body, None, (qb, jnp.arange(nq)),
                                 unroll=unroll)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(qb, kb, vb, causal, prefix_len, q_chunk, kv_chunk, unroll=False):
    out, _ = _flash_fwd_impl(qb, kb, vb, causal, prefix_len, q_chunk, kv_chunk,
                             unroll)
    return out


def _flash_fwd(qb, kb, vb, causal, prefix_len, q_chunk, kv_chunk, unroll=False):
    out, lse = _flash_fwd_impl(qb, kb, vb, causal, prefix_len, q_chunk,
                               kv_chunk, unroll)
    return out, (qb, kb, vb, out, lse)


def _flash_bwd(causal, prefix_len, q_chunk, kv_chunk, unroll, res, dout):
    qb, kb, vb, out, lse = res
    nq, B, KVH, G, qc, dh = qb.shape
    nk = kb.shape[0]
    dv = vb.shape[-1]
    # D_i = rowsum(dO * O)
    Dr = jnp.sum(dout * out, axis=-1, keepdims=True)        # (nq,B,KVH,G,qc,1)

    def q_body(carry, qi_inp):
        dk_acc, dv_acc = carry
        qi, doi, lsei, Di, q_idx = qi_inp

        def kv_body(dq_i, kv_inp):
            kj, vj, k_idx = kv_inp
            s = jnp.einsum("bngqd,bnkd->bngqk", qi, kj)
            if causal:
                s = _mask_chunk(s, q_idx, k_idx, q_chunk, kv_chunk, prefix_len)
            p = jnp.exp(s - lsei)                            # (B,KVH,G,qc,kc)
            dvj = jnp.einsum("bngqk,bngqv->bnkv", p, doi)
            dp = jnp.einsum("bngqv,bnkv->bngqk", doi, vj)
            ds = p * (dp - Di)
            dq_i = dq_i + jnp.einsum("bngqk,bnkd->bngqd", ds, kj)
            dkj = jnp.einsum("bngqk,bngqd->bnkd", ds, qi)
            return dq_i, (dkj, dvj)

        dq0 = jnp.zeros_like(qi)
        dq_i, (dks, dvs) = jax.lax.scan(
            kv_body, dq0, (kb, vb, jnp.arange(nk)), unroll=unroll)
        return (dk_acc + dks, dv_acc + dvs), dq_i

    dk0 = jnp.zeros_like(kb)
    dv0 = jnp.zeros_like(vb)
    (dk, dvb), dq = jax.lax.scan(
        q_body, (dk0, dv0), (qb, dout, lse, Dr, jnp.arange(nq)),
        unroll=unroll)
    return dq, dk, dvb


_flash.defvjp(_flash_fwd, _flash_bwd)


def blockwise_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True, prefix_len: int = 0,
                        scale: Optional[float] = None,
                        q_chunk: int = 512, kv_chunk: int = 512,
                        unroll: bool = False) -> jnp.ndarray:
    """q: (B,S,H,dh), k/v: (B,S,KVH,dh|dv) -> (B,S,H,dv).

    Never materializes the (S,S) score matrix, forward or backward; scans q
    chunks (outer) and kv chunks (inner) with running max/sum.  prefix_len >
    0 makes the first prefix_len kv positions visible to every query
    (prefix-LM / VLM).
    """
    B, S, H, dh = q.shape
    KVH = k.shape[2]
    dv = v.shape[-1]
    G = H // KVH
    scale = scale if scale is not None else dh ** -0.5
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, S)
    assert S % q_chunk == 0 and S % kv_chunk == 0
    nq, nk = S // q_chunk, S // kv_chunk

    qb = (q.astype(jnp.float32) * scale).reshape(B, nq, q_chunk, KVH, G, dh)
    qb = qb.transpose(1, 0, 3, 4, 2, 5)               # (nq,B,KVH,G,qc,dh)
    kb = k.astype(jnp.float32).reshape(B, nk, kv_chunk, KVH, dh)
    kb = kb.transpose(1, 0, 3, 2, 4)                   # (nk,B,KVH,kc,dh)
    vb = v.astype(jnp.float32).reshape(B, nk, kv_chunk, KVH, dv)
    vb = vb.transpose(1, 0, 3, 2, 4)

    out = _flash(qb, kb, vb, causal, prefix_len, q_chunk, kv_chunk, unroll)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, H, dv)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, n_heads: Optional[int] = None,
                   n_kv: Optional[int] = None) -> L.Params:
    H = n_heads or cfg.n_heads
    KVH = n_kv or cfg.n_kv_heads
    d, dh = cfg.d_model, cfg.head_dim
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "wq": L.dense_init(ks[0], d, H * dh, dt),
        "wk": L.dense_init(ks[1], d, KVH * dh, dt),
        "wv": L.dense_init(ks[2], d, KVH * dh, dt),
        "wo": L.dense_init(ks[3], H * dh, d, dt, 1.0 / np.sqrt(2 * cfg.n_layers)),
    }


def attention_forward(p: L.Params, x: jnp.ndarray, cfg: ModelConfig,
                      positions: jnp.ndarray,
                      n_heads: Optional[int] = None,
                      n_kv: Optional[int] = None,
                      prefix_len: int = 0) -> jnp.ndarray:
    """Full-sequence attention (train / prefill math)."""
    B, S, d = x.shape
    H = n_heads or cfg.n_heads
    KVH = n_kv or cfg.n_kv_heads
    dh = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, dh)
    k = (x @ p["wk"]).reshape(B, S, KVH, dh)
    v = (x @ p["wv"]).reshape(B, S, KVH, dh)
    if cfg.pos_emb == "rope":
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    o = blockwise_attention(q, k, v, causal=cfg.causal and not cfg.encoder_only,
                            prefix_len=prefix_len,
                            q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
                            unroll=cfg.cost_probe)
    return o.reshape(B, S, H * dh) @ p["wo"]


def attention_prefill_kv(p: L.Params, x: jnp.ndarray, cfg: ModelConfig,
                         positions: jnp.ndarray,
                         n_heads: Optional[int] = None,
                         n_kv: Optional[int] = None
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """K/V streams (post-RoPE) for cache construction during prefill."""
    B, S, _ = x.shape
    KVH = n_kv or cfg.n_kv_heads
    dh = cfg.head_dim
    k = (x @ p["wk"]).reshape(B, S, KVH, dh)
    v = (x @ p["wv"]).reshape(B, S, KVH, dh)
    if cfg.pos_emb == "rope":
        k = L.apply_rope(k, positions, cfg.rope_theta)
    return k, v


def attention_decode(p: L.Params, x: jnp.ndarray, cache: AC.KVCache,
                     cfg: ModelConfig, positions: jnp.ndarray, seed,
                     n_heads: Optional[int] = None,
                     n_kv: Optional[int] = None
                     ) -> Tuple[jnp.ndarray, AC.KVCache]:
    """One-token decode: x (B, 1, d) -> (out (B,1,d), updated cache)."""
    B, _, d = x.shape
    H = n_heads or cfg.n_heads
    KVH = n_kv or cfg.n_kv_heads
    dh = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, 1, H, dh)
    k = (x @ p["wk"]).reshape(B, 1, KVH, dh)
    v = (x @ p["wv"]).reshape(B, 1, KVH, dh)
    if cfg.pos_emb == "rope":
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    # one registered SPU op step: kv_append + attn_decode via the registry
    o, cache = OPS.attention_decode_step(cache, k, v, q.reshape(B, H, dh),
                                         cfg.state_quant, seed=seed)
    return (o.reshape(B, 1, H * dh).astype(x.dtype) @ p["wo"]), cache


def attention_spec_decode(p: L.Params, x: jnp.ndarray, cache: AC.KVCache,
                          cfg: ModelConfig, positions: jnp.ndarray, seed,
                          n_heads: Optional[int] = None,
                          n_kv: Optional[int] = None
                          ) -> Tuple[jnp.ndarray, AC.KVCache]:
    """Speculative decode: x (B, n, d) -> (out (B, n, d), updated cache).

    Appends all n K/V rows (per-position seeds ``seed + i``), then verifies
    the n queries in one ``spec_verify`` pass -- position j's row is
    bit-identical to the j-th sequential :func:`attention_decode` call.
    """
    B, n, d = x.shape
    H = n_heads or cfg.n_heads
    KVH = n_kv or cfg.n_kv_heads
    dh = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, n, H, dh)
    k = (x @ p["wk"]).reshape(B, n, KVH, dh)
    v = (x @ p["wv"]).reshape(B, n, KVH, dh)
    if cfg.pos_emb == "rope":
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    o, cache = OPS.attention_spec_step(cache, k, v, q, cfg.state_quant,
                                       seed=seed)
    return (o.reshape(B, n, H * dh).astype(x.dtype) @ p["wo"]), cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2), absorbed form
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig) -> L.Params:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 7)
    return {
        "wq_a": L.dense_init(ks[0], d, m.q_lora, dt),
        "q_norm": L.init_norm(m.q_lora, "rmsnorm", dt),
        # per-head query heads: nope part + rope part
        "wq_b": L.dense_init(ks[1], m.q_lora, H * (m.nope_dim + m.rope_dim), dt),
        "wkv_a": L.dense_init(ks[2], d, m.kv_lora + m.rope_dim, dt),
        "kv_norm": L.init_norm(m.kv_lora, "rmsnorm", dt),
        # absorbed projections: W_UK (H, nope, kv_lora), W_UV (H, kv_lora, v)
        "w_uk": (jax.random.normal(ks[3], (H, m.nope_dim, m.kv_lora))
                 / np.sqrt(m.nope_dim)).astype(dt),
        "w_uv": (jax.random.normal(ks[4], (H, m.kv_lora, m.v_dim))
                 / np.sqrt(m.kv_lora)).astype(dt),
        "wo": L.dense_init(ks[5], H * m.v_dim, d, dt,
                           1.0 / np.sqrt(2 * cfg.n_layers)),
    }


def _mla_queries(p, x, cfg, positions):
    """Absorbed queries (B,S,H,kv_lora + rope)."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    ql = L.apply_norm(p["q_norm"], x @ p["wq_a"], "rmsnorm", cfg.norm_eps)
    qh = (ql @ p["wq_b"]).reshape(B, S, H, m.nope_dim + m.rope_dim)
    q_nope, q_rope = qh[..., :m.nope_dim], qh[..., m.nope_dim:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    # absorb W_UK: q_eff = q_nope @ W_UK  -> (B,S,H,kv_lora)
    q_eff = jnp.einsum("bshn,hnc->bshc", q_nope, p["w_uk"])
    return jnp.concatenate([q_eff, q_rope], axis=-1)


def _mla_cache_stream(p, x, cfg, positions):
    """Latent cache stream (B,S,kv_lora + rope): values are the first kv_lora."""
    m = cfg.mla
    kv = x @ p["wkv_a"]
    c = L.apply_norm(p["kv_norm"], kv[..., :m.kv_lora], "rmsnorm", cfg.norm_eps)
    k_rope = L.apply_rope(kv[..., m.kv_lora:], positions, cfg.rope_theta)
    return jnp.concatenate([c, k_rope], axis=-1)


def mla_forward(p: L.Params, x: jnp.ndarray, cfg: ModelConfig,
                positions: jnp.ndarray) -> jnp.ndarray:
    """Full-sequence MLA in absorbed form (single latent KV stream)."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q = _mla_queries(p, x, cfg, positions)          # (B,S,H,cw)
    ckv = _mla_cache_stream(p, x, cfg, positions)   # (B,S,cw)
    scale = (m.nope_dim + m.rope_dim) ** -0.5
    kv = ckv[:, :, None, :]                          # KVH = 1
    ctx = blockwise_attention(q, kv, kv[..., :m.kv_lora], causal=True,
                              scale=scale, q_chunk=cfg.attn_q_chunk,
                              kv_chunk=cfg.attn_kv_chunk,
                              unroll=cfg.cost_probe)   # (B,S,H,kv_lora)
    o = jnp.einsum("bshc,hcv->bshv", ctx, p["w_uv"])
    return o.reshape(B, S, H * m.v_dim) @ p["wo"]


def mla_decode(p: L.Params, x: jnp.ndarray, cache: AC.KVCache,
               cfg: ModelConfig, positions: jnp.ndarray, seed
               ) -> Tuple[jnp.ndarray, AC.KVCache]:
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    q = _mla_queries(p, x, cfg, positions).reshape(B, H, -1)
    ckv = _mla_cache_stream(p, x, cfg, positions)[:, :, None, :]  # (B,1,1,cw)
    scale = (m.nope_dim + m.rope_dim) ** -0.5
    # same unified SPU op step as GQA; the cache's v_width selects mla_decode
    ctx, cache = OPS.attention_decode_step(cache, ckv, None, q,
                                           cfg.state_quant, scale=scale,
                                           seed=seed)  # (B,H,kv_lora)
    o = jnp.einsum("bhc,hcv->bhv", ctx.astype(x.dtype), p["w_uv"])
    return o.reshape(B, 1, H * m.v_dim) @ p["wo"], cache


def mla_spec_decode(p: L.Params, x: jnp.ndarray, cache: AC.KVCache,
                    cfg: ModelConfig, positions: jnp.ndarray, seed
                    ) -> Tuple[jnp.ndarray, AC.KVCache]:
    """Speculative MLA decode over n positions (see attention_spec_decode)."""
    m = cfg.mla
    B, n, _ = x.shape
    H = cfg.n_heads
    q = _mla_queries(p, x, cfg, positions)                # (B, n, H, cw)
    ckv = _mla_cache_stream(p, x, cfg, positions)[:, :, None, :]  # (B,n,1,cw)
    scale = (m.nope_dim + m.rope_dim) ** -0.5
    ctx, cache = OPS.attention_spec_step(cache, ckv, None, q, cfg.state_quant,
                                         scale=scale, seed=seed)
    o = jnp.einsum("bnhc,hcv->bnhv", ctx.astype(x.dtype), p["w_uv"])
    return o.reshape(B, n, H * m.v_dim) @ p["wo"], cache
