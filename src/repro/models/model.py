"""Model assembly: block patterns, scan-over-layers, train/prefill/decode.

A model is a repeating ``pattern`` of mixer blocks (attn | mla | mamba2 | gla
| retnet | hgrn2 | mlstm | slstm), optionally followed by a weight-shared
attention block per group (Zamba2).  Parameters of the repeating groups are
stacked along a leading axis and executed with ``jax.lax.scan`` so the HLO
is O(1) in depth (MaxText-style), with per-group remat.

Three step kinds (matching the benchmark shapes):
  * train   -- full-sequence forward + chunked-CE loss
  * prefill -- full-sequence forward that also builds the decode caches
               (quantized KV / recurrent state), returns last-position logits
  * decode  -- one token through the quantized caches (the Pimba fast path)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attention_cache as AC
from repro.core import formats as F
from repro.models import attention as ATT
from repro.models import layers as L
from repro.models import ssm as SSM
from repro.models.config import ModelConfig

Params = dict

_SSM_KINDS = ("mamba2", "gla", "retnet", "hgrn2", "mlstm", "slstm")
_NO_FFN = ("mamba2", "mlstm", "slstm")   # blocks with internal expansion
_SEED_STRIDE = 1000003


def _has_ffn(cfg: ModelConfig, kind: str) -> bool:
    return cfg.ffn_kind != "none" and kind not in _NO_FFN


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_element(key, cfg: ModelConfig, kind: str, layer_idx: int,
                  dense_ffn: bool = False) -> Params:
    k1, k2 = jax.random.split(key)
    dt = jnp.dtype(cfg.param_dtype)
    p: Params = {"norm": L.init_norm(cfg.d_model, cfg.norm_kind, dt)}
    if kind == "attn":
        p["mixer"] = ATT.init_attention(k1, cfg)
    elif kind == "mla":
        p["mixer"] = ATT.init_mla(k1, cfg)
    elif kind == "mamba2":
        p["mixer"] = SSM.init_mamba2(k1, cfg)
    elif kind in ("gla", "retnet", "hgrn2"):
        p["mixer"] = SSM.init_gla_family(k1, cfg, kind)
        if kind == "hgrn2":  # depth-dependent forget-gate lower bound
            p["mixer"]["beta"] = jnp.array(
                [layer_idx / max(cfg.n_layers, 1)], jnp.float32)
    elif kind == "mlstm":
        p["mixer"] = SSM.init_mlstm(k1, cfg)
    elif kind == "slstm":
        p["mixer"] = SSM.init_slstm(k1, cfg)
    else:
        raise ValueError(kind)
    if _has_ffn(cfg, kind):
        kf, kff = jax.random.split(k2)
        p["ffn_norm"] = L.init_norm(cfg.d_model, cfg.norm_kind, dt)
        if cfg.ffn_kind == "moe":
            if dense_ffn:
                p["ffn"] = L.init_ffn(
                    kff, cfg, d_ff=cfg.moe.first_dense_ff or cfg.moe.d_expert)
            else:
                p["ffn"] = L.init_moe(kff, cfg)
        elif dense_ffn:
            p["ffn"] = L.init_ffn(kff, cfg)
        else:
            p["ffn"] = L.init_ffn(kff, cfg)
    return p


def _init_shared_block(key, cfg: ModelConfig) -> Params:
    """Zamba2-style shared attention + MLP block."""
    k1, k2 = jax.random.split(key)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "norm": L.init_norm(cfg.d_model, cfg.norm_kind, dt),
        "attn": ATT.init_attention(k1, cfg),
        "ffn_norm": L.init_norm(cfg.d_model, cfg.norm_kind, dt),
        "ffn": L.init_ffn(k2, cfg),
    }


def init_model(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, cfg.n_groups + 6)
    dt = jnp.dtype(cfg.param_dtype)
    params: Params = {}
    if cfg.frontend is None:
        params["embed"] = L.embed_init(keys[-1], cfg.vocab_size, cfg.d_model, dt)
    else:
        params["frontend_proj"] = L.dense_init(
            keys[-1], cfg.frontend_dim, cfg.d_model, dt)
        if cfg.frontend == "patch":   # VLM also embeds text tokens
            params["embed"] = L.embed_init(
                keys[-2], cfg.vocab_size, cfg.d_model, dt)
    if cfg.pos_emb == "learned":
        params["pos"] = L.embed_init(keys[-3], 32768, cfg.d_model, dt)

    if cfg.prelude:
        pks = jax.random.split(keys[-6], len(cfg.prelude))
        params["prelude"] = tuple(
            _init_element(pks[i], cfg, kind, i, dense_ffn=True)
            for i, kind in enumerate(cfg.prelude))

    # stacked group params
    if cfg.n_groups == 1:
        groups = [tuple(_init_element(kk, cfg, kind, pos)
                        for pos, (kk, kind) in enumerate(
                            zip(jax.random.split(keys[0], len(cfg.pattern)),
                                cfg.pattern)))]
        params["groups"] = jax.tree.map(lambda x: x[None], groups[0])
    else:
        def one_group(key, gidx):
            eks = jax.random.split(key, len(cfg.pattern))
            return tuple(
                _init_element(eks[i], cfg, kind, int(gidx) * len(cfg.pattern) + i)
                for i, kind in enumerate(cfg.pattern))
        gs = [one_group(keys[g], g) for g in range(cfg.n_groups)]
        params["groups"] = jax.tree.map(lambda *xs: jnp.stack(xs), *gs)

    if cfg.shared_attn:
        params["shared"] = _init_shared_block(keys[-4], cfg)
    params["final_norm"] = L.init_norm(cfg.d_model, cfg.norm_kind, dt)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[-5], cfg.d_model, cfg.vocab_size, dt)
    return params


def _lm_head(params: Params, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


# ---------------------------------------------------------------------------
# full-sequence block application (train / prefill)
# ---------------------------------------------------------------------------

def _element_forward(p: Params, x, cfg: ModelConfig, kind: str,
                     positions, prefix_len: int, want_cache: bool,
                     mesh_axes) -> Tuple[jnp.ndarray, Any]:
    h = L.apply_norm(p["norm"], x, cfg.norm_kind, cfg.norm_eps)
    cache = None
    if kind == "attn":
        y = ATT.attention_forward(p["mixer"], h, cfg, positions,
                                  prefix_len=prefix_len)
        if want_cache:
            kv = ATT.attention_prefill_kv(p["mixer"], h, cfg, positions)
            cache = _build_kv_cache(kv[0], kv[1], cfg)
    elif kind == "mla":
        y = ATT.mla_forward(p["mixer"], h, cfg, positions)
        if want_cache:
            ckv = ATT._mla_cache_stream(p["mixer"], h, cfg, positions)
            cache = _build_kv_cache(ckv[:, :, None, :], None, cfg,
                                    v_width=cfg.mla.kv_lora)
    elif kind == "mamba2":
        y, st = SSM.mamba2_forward(p["mixer"], h, cfg, par=mesh_axes)
        cache = st if want_cache else None
    elif kind in ("gla", "retnet", "hgrn2"):
        y, st = SSM.gla_family_forward(p["mixer"], h, cfg, kind, par=mesh_axes)
        cache = st if want_cache else None
    elif kind == "mlstm":
        y, st = SSM.mlstm_forward(p["mixer"], h, cfg, par=mesh_axes)
        cache = st if want_cache else None
    elif kind == "slstm":
        y, st = SSM.slstm_forward(p["mixer"], h, cfg, par=mesh_axes)
        cache = st if want_cache else None
    else:
        raise ValueError(kind)
    x = x + y
    if _has_ffn(cfg, kind):
        h = L.apply_norm(p["ffn_norm"], x, cfg.norm_kind, cfg.norm_eps)
        if cfg.ffn_kind == "moe" and "router" in p["ffn"]:
            y = L.apply_moe(p["ffn"], h, cfg, mesh_axes)
        elif cfg.ffn_kind == "moe":
            y = L.apply_ffn(p["ffn"], h, cfg.ffn_kind_inner)
        else:
            y = L.apply_ffn(p["ffn"], h, cfg.ffn_kind)
        x = x + y
    return x, cache


def _build_kv_cache(k: jnp.ndarray, v: Optional[jnp.ndarray],
                    cfg: ModelConfig, v_width: Optional[int] = None
                    ) -> AC.KVCache:
    """Quantize full-sequence K/V into a cache with tile-aligned capacity."""
    B, S = k.shape[:2]
    cap = -(-S // 128) * 128
    pad = cap - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad)) + ((0, 0),) * (k.ndim - 2))
        if v is not None:
            v = jnp.pad(v, ((0, 0), (0, pad)) + ((0, 0),) * (v.ndim - 2))
    sq = cfg.state_quant
    lengths = jnp.full((B,), S, jnp.int32)
    if sq.quantized:
        qk = F.quantize(k, sq.fmt)
        qv = None if v is None else F.quantize(v, sq.fmt)
        return AC.KVCache(qk, qv, lengths, sq.fmt, v_width)
    dt = {"fp32": jnp.float32, "bf16": jnp.bfloat16, "fp16": jnp.float16}[sq.fmt]
    return AC.KVCache(k.astype(dt), None if v is None else v.astype(dt),
                      lengths, sq.fmt, v_width)


def _shared_block_forward(p: Params, x, cfg: ModelConfig, positions,
                          prefix_len: int, want_cache: bool):
    h = L.apply_norm(p["norm"], x, cfg.norm_kind, cfg.norm_eps)
    y = ATT.attention_forward(p["attn"], h, cfg, positions,
                              prefix_len=prefix_len)
    cache = None
    if want_cache:
        kv = ATT.attention_prefill_kv(p["attn"], h, cfg, positions)
        cache = _build_kv_cache(kv[0], kv[1], cfg)
    x = x + y
    h = L.apply_norm(p["ffn_norm"], x, cfg.norm_kind, cfg.norm_eps)
    return x + L.apply_ffn(p["ffn"], h, cfg.ffn_kind), cache


def _seq_shard(x: jnp.ndarray, par) -> jnp.ndarray:
    """Sequence-parallel constraint on the layer-boundary activations.

    The scan-over-layers carry is the dominant saved residual of the
    backward pass; sharding its sequence dim over the 'model' axis
    (Megatron-SP style) divides that memory by TP.  GSPMD inserts the
    all-gather at attention entry / reduce-scatter at exit.
    """
    if par is None or not hasattr(par, "mesh"):
        return x
    B, S = x.shape[:2]
    if S <= 1 or S % par.tp != 0 or B % par.batch_size_divisor != 0:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        x, par.named(P(par.batch_axes, par.model_axis, None)))


def _run_blocks(params: Params, x: jnp.ndarray, cfg: ModelConfig,
                positions, prefix_len: int, want_cache: bool,
                mesh_axes) -> Tuple[jnp.ndarray, Any]:
    shared = params.get("shared")
    if cfg.seq_parallel:
        x = _seq_shard(x, mesh_axes)

    prelude_caches = []
    for i, kind in enumerate(cfg.prelude):
        x, c = _element_forward(params["prelude"][i], x, cfg, kind, positions,
                                prefix_len, want_cache, mesh_axes)
        prelude_caches.append(c)

    def _maybe_ckpt(fn):
        # nested remat: one element's backward lives at a time, so a group
        # of many elements (zamba2: 6 mamba + shared attn) does not hold
        # every sublayer's cotangents simultaneously
        return jax.checkpoint(fn, prevent_cse=False) if cfg.remat else fn

    def group_body(x, ginp):
        gparams, gidx = ginp
        if cfg.seq_parallel:
            x = _seq_shard(x, mesh_axes)
        caches = []
        for pos, kind in enumerate(cfg.pattern):
            fn = _maybe_ckpt(
                lambda p, xx, kind=kind: _element_forward(
                    p, xx, cfg, kind, positions, prefix_len, want_cache,
                    mesh_axes))
            x, c = fn(gparams[pos], x)
            caches.append(c)
        if shared is not None:
            fn = _maybe_ckpt(
                lambda p, xx: _shared_block_forward(
                    p, xx, cfg, positions, prefix_len, want_cache))
            x, c = fn(shared, x)
            caches.append(c)
        return x, tuple(caches)

    body = group_body
    if cfg.remat:
        body = jax.checkpoint(group_body, prevent_cse=False)

    if cfg.scan_layers:
        x, caches = jax.lax.scan(
            body, x, (params["groups"], jnp.arange(cfg.n_groups)))
    else:
        caches_all = []
        for g in range(cfg.n_groups):
            gp = jax.tree.map(lambda a: a[g], params["groups"])
            x, cs = body(x, (gp, g))
            caches_all.append(cs)
        caches = (jax.tree.map(lambda *xs: jnp.stack(xs), *caches_all)
                  if want_cache else None)
    if cfg.prelude and want_cache:
        caches = {"prelude": tuple(prelude_caches), "groups": caches}
    return x, caches


# ---------------------------------------------------------------------------
# embedding / frontends
# ---------------------------------------------------------------------------

def embed_inputs(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    """Returns (x, positions, prefix_len)."""
    if cfg.frontend == "patch":           # VLM: [patch embeds ; text tokens]
        patches = batch["patches"] @ params["frontend_proj"]
        tok = params["embed"][batch["tokens"]]
        x = jnp.concatenate([patches, tok], axis=1)
        prefix_len = patches.shape[1]
    elif cfg.frontend == "audio_frames":  # audio: precomputed conv features
        x = batch["frames"] @ params["frontend_proj"]
        prefix_len = 0
    else:
        x = params["embed"][batch["tokens"]]
        prefix_len = cfg.prefix_len
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.pos_emb == "learned":
        x = x + params["pos"][positions]
    elif cfg.pos_emb == "sincos":
        x = x + L.sincos_pos_emb(S, cfg.d_model, x.dtype)[None]
    return x, positions, prefix_len


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def train_loss(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
               mesh_axes=None) -> jnp.ndarray:
    x, positions, prefix_len = embed_inputs(params, cfg, batch)
    x, _ = _run_blocks(params, x, cfg, positions, prefix_len,
                       want_cache=False, mesh_axes=mesh_axes)
    x = L.apply_norm(params["final_norm"], x, cfg.norm_kind, cfg.norm_eps)
    labels = batch["targets"]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    if cfg.frontend == "patch":
        # loss over text positions only; hidden states are offset by prefix
        x = x[:, -labels.shape[1]:]
    return L.chunked_softmax_xent(x, _lm_head(params, cfg), labels,
                                  mask.astype(jnp.float32), cfg.logit_chunk,
                                  unroll=cfg.cost_probe)


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            mesh_axes=None) -> Tuple[jnp.ndarray, Any]:
    """Full-sequence forward; returns (last-position logits, caches)."""
    x, positions, prefix_len = embed_inputs(params, cfg, batch)
    x, caches = _run_blocks(params, x, cfg, positions, prefix_len,
                            want_cache=not cfg.encoder_only,
                            mesh_axes=mesh_axes)
    x = L.apply_norm(params["final_norm"], x, cfg.norm_kind, cfg.norm_eps)
    if cfg.encoder_only:
        # encoder models: per-position classification logits
        logits = x @ _lm_head(params, cfg)
        return logits, None
    logits = x[:, -1] @ _lm_head(params, cfg)
    return logits, caches


def init_decode_caches(cfg: ModelConfig, B: int, cache_capacity: int) -> Any:
    """Zeroed caches for decode-from-scratch (dry-run decode cells)."""
    def one_element(kind):
        if kind == "attn":
            return AC.init_kv_cache(B, cache_capacity, cfg.n_kv_heads,
                                    cfg.head_dim, cfg.state_quant)
        if kind == "mla":
            return AC.init_kv_cache(B, cache_capacity, 1,
                                    cfg.mla.cache_width, cfg.state_quant,
                                    mla_v_width=cfg.mla.kv_lora)
        if kind == "mamba2":
            return SSM.mamba2_init_state(B, cfg)
        if kind in ("gla", "retnet", "hgrn2"):
            return SSM.gla_family_init_state(B, cfg)
        if kind == "mlstm":
            return SSM.mlstm_init_state(B, cfg)
        if kind == "slstm":
            return SSM.slstm_init_state(B, cfg)
        raise ValueError(kind)

    per_group = [one_element(k) for k in cfg.pattern]
    if cfg.shared_attn:
        per_group.append(AC.init_kv_cache(B, cache_capacity, cfg.n_kv_heads,
                                          cfg.head_dim, cfg.state_quant))
    # lengths: how many positions already in the caches
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_groups,) + x.shape),
        tuple(per_group))
    if cfg.prelude:
        return {"prelude": tuple(one_element(k) for k in cfg.prelude),
                "groups": stacked}
    return stacked


def abstract_decode_caches(cfg: ModelConfig, B: int, cache_capacity: int) -> Any:
    """Shape/dtype skeleton of :func:`init_decode_caches` without allocating.

    The paged memory pool (``serving/memory``) probes this at several (B, T)
    points to locate every leaf's batch and time axis exactly.
    """
    return jax.eval_shape(lambda: init_decode_caches(cfg, B, cache_capacity))


def set_cache_lengths(caches: Any, lengths: jnp.ndarray) -> Any:
    """Overwrite every KVCache.lengths leaf (e.g. decode over a warm cache)."""
    def fix(c):
        if isinstance(c, AC.KVCache):
            return AC.KVCache(c.k, c.v, jnp.broadcast_to(lengths, c.lengths.shape),
                              c.fmt, c.v_width, c.time_axis)
        return c
    return jax.tree.map(fix, caches,
                        is_leaf=lambda x: isinstance(x, AC.KVCache))


def _element_decode(p: Params, x, cache, cfg: ModelConfig, kind: str,
                    positions, seed) -> Tuple[jnp.ndarray, Any]:
    h = L.apply_norm(p["norm"], x, cfg.norm_kind, cfg.norm_eps)
    if kind == "attn":
        y, cache = ATT.attention_decode(p["mixer"], h, cache, cfg,
                                        positions[:, None], seed)
    elif kind == "mla":
        y, cache = ATT.mla_decode(p["mixer"], h, cache, cfg,
                                  positions[:, None], seed)
    elif kind == "mamba2":
        y, cache = SSM.mamba2_decode(p["mixer"], h, cache, cfg, seed)
    elif kind in ("gla", "retnet", "hgrn2"):
        y, cache = SSM.gla_family_decode(p["mixer"], h, cache, cfg, kind, seed)
    elif kind == "mlstm":
        y, cache = SSM.mlstm_decode(p["mixer"], h, cache, cfg, seed)
    elif kind == "slstm":
        y, cache = SSM.slstm_decode(p["mixer"], h, cache, cfg, seed)
    else:
        raise ValueError(kind)
    x = x + y
    if _has_ffn(cfg, kind):
        h = L.apply_norm(p["ffn_norm"], x, cfg.norm_kind, cfg.norm_eps)
        if cfg.ffn_kind == "moe" and "router" in p["ffn"]:
            y = L.apply_moe(p["ffn"], h, cfg, None)
        elif cfg.ffn_kind == "moe":
            y = L.apply_ffn(p["ffn"], h, cfg.ffn_kind_inner)
        else:
            y = L.apply_ffn(p["ffn"], h, cfg.ffn_kind)
        x = x + y
    return x, cache


def decode_step(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                caches: Any, lengths: jnp.ndarray, seed=0,
                mesh_axes=None) -> Tuple[jnp.ndarray, Any]:
    """One decode step.  tokens: (B,) int32; lengths: (B,) positions so far.

    Returns (logits (B, V), new caches).
    """
    assert not cfg.encoder_only, f"{cfg.name} is encoder-only: no decode step"
    x = params["embed"][tokens][:, None]                       # (B,1,d)
    positions = lengths
    if cfg.pos_emb == "learned":
        x = x + params["pos"][positions][:, None]
    shared = params.get("shared")

    if cfg.prelude:
        prelude_caches, caches = caches["prelude"], caches["groups"]
        new_prelude = []
        for i, kind in enumerate(cfg.prelude):
            x, c = _element_decode(params["prelude"][i], x, prelude_caches[i],
                                   cfg, kind, positions,
                                   jnp.uint32(seed) + jnp.uint32(7919 * (i + 1)))
            new_prelude.append(c)

    def group_body(x, ginp):
        gparams, gcaches, gidx = ginp
        seed_g = jnp.uint32(seed) + gidx.astype(jnp.uint32) * jnp.uint32(_SEED_STRIDE)
        new_caches = []
        for pos, kind in enumerate(cfg.pattern):
            x, c = _element_decode(gparams[pos], x, gcaches[pos], cfg, kind,
                                   positions, seed_g + jnp.uint32(pos + 1))
            new_caches.append(c)
        if shared is not None:
            h = L.apply_norm(shared["norm"], x, cfg.norm_kind, cfg.norm_eps)
            y, c = ATT.attention_decode(shared["attn"], h, gcaches[-1], cfg,
                                        positions[:, None],
                                        seed_g + jnp.uint32(99))
            x = x + y
            h = L.apply_norm(shared["ffn_norm"], x, cfg.norm_kind, cfg.norm_eps)
            x = x + L.apply_ffn(shared["ffn"], h, cfg.ffn_kind)
            new_caches.append(c)
        return x, tuple(new_caches)

    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(
            group_body, x, (params["groups"], caches, jnp.arange(cfg.n_groups)))
    else:
        ncs = []
        for g in range(cfg.n_groups):
            gp = jax.tree.map(lambda a: a[g], params["groups"])
            gc = jax.tree.map(lambda a: a[g], caches,
                              is_leaf=lambda x: isinstance(x, jnp.ndarray))
            x, cs = group_body(x, (gp, gc, jnp.asarray(g)))
            ncs.append(cs)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)

    if cfg.prelude:
        new_caches = {"prelude": tuple(new_prelude), "groups": new_caches}
    x = L.apply_norm(params["final_norm"], x[:, 0], cfg.norm_kind, cfg.norm_eps)
    logits = x @ _lm_head(params, cfg)
    return logits, new_caches


def paged_decode_step(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                      caches: Any, lengths: jnp.ndarray, seed=0,
                      mesh_axes=None) -> Tuple[jnp.ndarray, Any]:
    """One decode step over block-table-native paged cache views.

    ``caches`` mirrors :func:`init_decode_caches`' structure, but KV caches
    are :class:`~repro.core.paged.PagedKVCache` views and recurrent ``"S"``
    leaves are :class:`~repro.core.paged.PagedState` views -- both address
    the serving pool's shared page/slab pools and carry a ``group`` index
    into the scan-over-layers stack; remaining slab leaves (conv tails,
    sLSTM carries) are dense gathered rows in the stacked ``(G, B, ...)``
    layout.  Because the pools cannot be sliced along the group axis without
    copying them, the paged containers ride the scan *carry* (each group
    iteration re-binds ``group`` and updates the same pools in place) while
    the dense leaves scan as xs/ys exactly like :func:`decode_step`.

    Element math, seeds and op dispatch are shared with :func:`decode_step`
    (the container type selects the paged ops), so logits are bit-identical
    to running the dense path over gathered pages.
    """
    from repro.core import paged as PG
    assert not cfg.encoder_only, f"{cfg.name} is encoder-only: no decode step"
    x = params["embed"][tokens][:, None]                       # (B,1,d)
    positions = lengths
    if cfg.pos_emb == "learned":
        x = x + params["pos"][positions][:, None]
    shared = params.get("shared")

    if cfg.prelude:
        prelude_caches, caches = caches["prelude"], caches["groups"]
        new_prelude = []
        for i, kind in enumerate(cfg.prelude):
            c = PG.with_group(prelude_caches[i], 0, lengths)
            x, c = _element_decode(params["prelude"][i], x, c,
                                   cfg, kind, positions,
                                   jnp.uint32(seed) + jnp.uint32(7919 * (i + 1)))
            new_prelude.append(c)

    n_elems = len(cfg.pattern) + (1 if shared is not None else 0)
    carried, scanned = [], []
    for pos in range(n_elems):
        ca, sc = PG.split_paged(caches[pos])
        carried.append(ca)
        scanned.append(sc)
    carried, scanned = tuple(carried), tuple(scanned)

    def group_body(carry, ginp):
        x, kv = carry
        gparams, gstates, gidx = ginp
        seed_g = jnp.uint32(seed) + gidx.astype(jnp.uint32) * jnp.uint32(_SEED_STRIDE)
        new_kv, new_states = [], []
        for pos, kind in enumerate(cfg.pattern):
            c = PG.merge_paged(PG.with_group(kv[pos], gidx, lengths),
                               gstates[pos])
            x, c = _element_decode(gparams[pos], x, c, cfg, kind,
                                   positions, seed_g + jnp.uint32(pos + 1))
            ca, sc = PG.split_paged(c)
            new_kv.append(ca)
            new_states.append(sc)
        if shared is not None:
            h = L.apply_norm(shared["norm"], x, cfg.norm_kind, cfg.norm_eps)
            y, c = ATT.attention_decode(
                shared["attn"], h, PG.with_group(kv[-1], gidx, lengths), cfg,
                positions[:, None], seed_g + jnp.uint32(99))
            x = x + y
            h = L.apply_norm(shared["ffn_norm"], x, cfg.norm_kind, cfg.norm_eps)
            x = x + L.apply_ffn(shared["ffn"], h, cfg.ffn_kind)
            new_kv.append(c)
            new_states.append(None)
        return (x, tuple(new_kv)), tuple(new_states)

    if cfg.scan_layers:
        (x, carried), new_scanned = jax.lax.scan(
            group_body, (x, carried),
            (params["groups"], scanned, jnp.arange(cfg.n_groups)))
    else:
        stacked = []
        for g in range(cfg.n_groups):
            gp = jax.tree.map(lambda a: a[g], params["groups"])
            gs = jax.tree.map(lambda a: a[g], scanned,
                              is_leaf=lambda v: isinstance(v, jnp.ndarray))
            (x, carried), sc = group_body((x, carried),
                                          (gp, gs, jnp.asarray(g)))
            stacked.append(sc)
        new_scanned = jax.tree.map(lambda *xs: jnp.stack(xs), *stacked)

    new_caches = tuple(PG.merge_paged(carried[pos], new_scanned[pos])
                       for pos in range(n_elems))
    if cfg.prelude:
        new_caches = {"prelude": tuple(new_prelude), "groups": new_caches}
    x = L.apply_norm(params["final_norm"], x[:, 0], cfg.norm_kind, cfg.norm_eps)
    logits = x @ _lm_head(params, cfg)
    return logits, new_caches


# ---------------------------------------------------------------------------
# speculative decode: multi-position step with per-position state snapshots
# ---------------------------------------------------------------------------

def _state_snapshot(cache: Any) -> Any:
    """Per-request rows of every recurrent-state leaf of one element's cache.

    PagedState views contribute their viewed slab rows ``pool[slabs, group]``
    ((B, ...); quantized pools yield a plain ``{field: rows}`` dict), dense
    residual leaves (conv tails, sLSTM carries) contribute themselves, and
    KV caches contribute nothing (rejecting drafted tokens only needs the
    host length reset -- the garbage rows are masked and later overwritten).
    """
    from repro.core import paged as PG

    def snap(leaf):
        if isinstance(leaf, PG.PagedState):
            grp = jnp.asarray(leaf.group, jnp.int32)
            if isinstance(leaf.pool, F.QuantizedTensor):
                return {f: a[leaf.slabs, grp]
                        for f, a in leaf.pool.payload.items()}
            return leaf.pool[leaf.slabs, grp]
        if isinstance(leaf, PG.PagedKVCache):
            return None
        return leaf

    return jax.tree.map(snap, cache, is_leaf=PG.is_paged)


def _element_spec_decode(p: Params, x, cache, cfg: ModelConfig, kind: str,
                         positions, seed) -> Tuple[jnp.ndarray, Any, Any]:
    """Multi-position twin of :func:`_element_decode`.

    ``x`` is (B, n, d) -- the current token plus the drafted ones --
    and ``positions`` the (B, n) absolute positions.  Attention scores all
    n positions in one ``spec_verify`` pass over a single cache stream;
    recurrent mixers advance sequentially through the n rows (the state
    update is inherently serial) with the exact per-position seed
    ``seed + i`` of n sequential decode steps, recording a state snapshot
    after each position so rejected drafts can be rolled back bit-exactly.

    Returns ``(x, cache, snap)`` where ``snap`` stacks the per-position
    snapshots to (n, B, ...) leaves (None for attention elements).
    """
    n = x.shape[1]
    h = L.apply_norm(p["norm"], x, cfg.norm_kind, cfg.norm_eps)
    if kind == "attn":
        y, cache = ATT.attention_spec_decode(p["mixer"], h, cache, cfg,
                                             positions, seed)
        snap = None
    elif kind == "mla":
        y, cache = ATT.mla_spec_decode(p["mixer"], h, cache, cfg,
                                       positions, seed)
        snap = None
    else:
        ys, rows = [], []
        for i in range(n):
            hi = h[:, i:i + 1]
            si = seed + jnp.uint32(i)
            if kind == "mamba2":
                yi, cache = SSM.mamba2_decode(p["mixer"], hi, cache, cfg, si)
            elif kind in ("gla", "retnet", "hgrn2"):
                yi, cache = SSM.gla_family_decode(p["mixer"], hi, cache, cfg,
                                                  kind, si)
            elif kind == "mlstm":
                yi, cache = SSM.mlstm_decode(p["mixer"], hi, cache, cfg, si)
            elif kind == "slstm":
                yi, cache = SSM.slstm_decode(p["mixer"], hi, cache, cfg, si)
            else:
                raise ValueError(kind)
            ys.append(yi)
            rows.append(_state_snapshot(cache))
        y = jnp.concatenate(ys, axis=1)
        snap = jax.tree.map(lambda *xs: jnp.stack(xs), *rows)
    x = x + y
    if _has_ffn(cfg, kind):
        h = L.apply_norm(p["ffn_norm"], x, cfg.norm_kind, cfg.norm_eps)
        if cfg.ffn_kind == "moe" and "router" in p["ffn"]:
            y = L.apply_moe(p["ffn"], h, cfg, None)
        elif cfg.ffn_kind == "moe":
            y = L.apply_ffn(p["ffn"], h, cfg.ffn_kind_inner)
        else:
            y = L.apply_ffn(p["ffn"], h, cfg.ffn_kind)
        x = x + y
    return x, cache, snap


def paged_spec_decode_step(params: Params, cfg: ModelConfig,
                           tokens: jnp.ndarray, caches: Any,
                           lengths: jnp.ndarray, seed=0, mesh_axes=None
                           ) -> Tuple[jnp.ndarray, Any, Any]:
    """Speculative verify step: n positions per row through the paged caches.

    tokens (B, n) holds each row's current token followed by its drafted
    (or garbage padding) tokens; lengths (B,) count positions *before* this
    step.  Structure, carry discipline and every element seed mirror
    :func:`paged_decode_step` exactly -- position i of a row runs with the
    seeds of the sequential decode step ``seed + i`` -- so row i's logits
    are bit-identical to decoding the same tokens one step at a time.

    Returns ``(logits (B, n, V), new_caches, snaps)``.  ``snaps`` mirrors
    the cache-tree structure with per-position recurrent-state rows
    normalized to (n, B, ...) leaves ((n, B, G, ...) for scanned groups);
    the engine commits ``snaps[sel]`` to roll rejected positions back.
    """
    from repro.core import paged as PG
    assert not cfg.encoder_only, f"{cfg.name} is encoder-only: no decode step"
    B, n = tokens.shape
    x = params["embed"][tokens]                                # (B,n,d)
    positions = lengths[:, None] + jnp.arange(n, dtype=lengths.dtype)[None]
    if cfg.pos_emb == "learned":
        x = x + params["pos"][positions]
    shared = params.get("shared")

    if cfg.prelude:
        prelude_caches, caches = caches["prelude"], caches["groups"]
        new_prelude, prelude_snaps = [], []
        for i, kind in enumerate(cfg.prelude):
            c = PG.with_group(prelude_caches[i], 0, lengths)
            x, c, sn = _element_spec_decode(
                params["prelude"][i], x, c, cfg, kind, positions,
                jnp.uint32(seed) + jnp.uint32(7919 * (i + 1)))
            new_prelude.append(c)
            prelude_snaps.append(sn)

    n_elems = len(cfg.pattern) + (1 if shared is not None else 0)
    carried, scanned = [], []
    for pos in range(n_elems):
        ca, sc = PG.split_paged(caches[pos])
        carried.append(ca)
        scanned.append(sc)
    carried, scanned = tuple(carried), tuple(scanned)

    def group_body(carry, ginp):
        x, kv = carry
        gparams, gstates, gidx = ginp
        seed_g = jnp.uint32(seed) + gidx.astype(jnp.uint32) * jnp.uint32(_SEED_STRIDE)
        new_kv, new_states, gsnaps = [], [], []
        for pos, kind in enumerate(cfg.pattern):
            c = PG.merge_paged(PG.with_group(kv[pos], gidx, lengths),
                               gstates[pos])
            x, c, sn = _element_spec_decode(gparams[pos], x, c, cfg, kind,
                                            positions,
                                            seed_g + jnp.uint32(pos + 1))
            ca, sc = PG.split_paged(c)
            new_kv.append(ca)
            new_states.append(sc)
            gsnaps.append(sn)
        if shared is not None:
            h = L.apply_norm(shared["norm"], x, cfg.norm_kind, cfg.norm_eps)
            y, c = ATT.attention_spec_decode(
                shared["attn"], h, PG.with_group(kv[-1], gidx, lengths), cfg,
                positions, seed_g + jnp.uint32(99))
            x = x + y
            h = L.apply_norm(shared["ffn_norm"], x, cfg.norm_kind, cfg.norm_eps)
            x = x + L.apply_ffn(shared["ffn"], h, cfg.ffn_kind)
            new_kv.append(c)
            new_states.append(None)
            gsnaps.append(None)
        return (x, tuple(new_kv)), (tuple(new_states), tuple(gsnaps))

    if cfg.scan_layers:
        (x, carried), (new_scanned, gsnaps) = jax.lax.scan(
            group_body, (x, carried),
            (params["groups"], scanned, jnp.arange(cfg.n_groups)))
    else:
        stacked = []
        for g in range(cfg.n_groups):
            gp = jax.tree.map(lambda a: a[g], params["groups"])
            gs = jax.tree.map(lambda a: a[g], scanned,
                              is_leaf=lambda v: isinstance(v, jnp.ndarray))
            (x, carried), ys = group_body((x, carried),
                                          (gp, gs, jnp.asarray(g)))
            stacked.append(ys)
        new_scanned, gsnaps = jax.tree.map(lambda *xs: jnp.stack(xs), *stacked)

    # scan ys stack per-group snapshots as (G, n, B, ...); normalize every
    # snapshot leaf to position-major (n, B, G, ...) for selection
    gsnaps = jax.tree.map(lambda a: jnp.moveaxis(a, 0, 2), gsnaps)

    new_caches = tuple(PG.merge_paged(carried[pos], new_scanned[pos])
                       for pos in range(n_elems))
    snaps: Any = tuple(gsnaps)
    if cfg.prelude:
        new_caches = {"prelude": tuple(new_prelude), "groups": new_caches}
        snaps = {"prelude": tuple(prelude_snaps), "groups": snaps}
    x = L.apply_norm(params["final_norm"], x, cfg.norm_kind, cfg.norm_eps)
    logits = x @ _lm_head(params, cfg)
    return logits, new_caches, snaps
