"""Model configuration dataclasses shared by the whole framework."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.ops.base import StateQuantConfig


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0              # shared (always-on) experts, DeepSeek-style
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    first_dense_ff: int = 0        # layer 0 uses a dense FFN of this width


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora: int = 1536
    kv_lora: int = 512
    rope_dim: int = 64
    nope_dim: int = 128
    v_dim: int = 128

    @property
    def cache_width(self) -> int:          # latent + shared rope key
        return self.kv_lora + self.rope_dim


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Parameters for state-update mixers (mamba2/gla/retnet/hgrn2/mlstm/slstm)."""
    d_state: int = 128        # mamba2 N (== dk of the generalized op)
    head_dim: int = 64        # mamba2 P (== dv)
    expand: int = 2           # d_inner = expand * d_model
    d_conv: int = 4
    n_heads: int = 0          # heads for gla/retnet/hgrn2/mlstm (0 = use model n_heads)
    dk_head: int = 0          # per-head key dim for gla-family (0 = derive)
    dv_head: int = 0          # per-head value dim
    chunk: int = 64           # prefill chunk length
    log_decay_min: float = -1.0  # per-step log-decay clamp (vector-decay path)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense|ssm|moe|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # repeating block pattern; len(pattern) must divide n_layers - len(prelude).
    # elements: attn|mla|mamba2|gla|retnet|hgrn2|mlstm|slstm
    pattern: Tuple[str, ...] = ("attn",)
    # non-repeated leading layers (e.g. DeepSeek-V2's dense-FFN first layer);
    # these always use a dense FFN (moe.first_dense_ff wide if ffn_kind=moe)
    prelude: Tuple[str, ...] = ()
    ffn_kind: str = "swiglu"       # swiglu|geglu|gelu|relu|none|moe
    norm_kind: str = "rmsnorm"     # rmsnorm|layernorm
    pos_emb: str = "rope"          # rope|learned|sincos|none
    rope_theta: float = 10000.0
    causal: bool = True
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (Zamba2): one shared attention+MLP block applied after every
    # pattern group (weights shared across applications)
    shared_attn: bool = False
    # modality frontends are STUBS: input_specs() supplies precomputed
    # patch/frame embeddings of width frontend_dim
    frontend: Optional[str] = None  # patch|audio_frames
    frontend_dim: int = 0
    prefix_len: int = 0             # bidirectional prefix length (VLM)
    encoder_only: bool = False
    # numerics / execution
    state_quant: StateQuantConfig = StateQuantConfig()
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True
    # Megatron-SP constraint on layer-boundary activations (train/prefill):
    # divides saved-residual memory by TP at the cost of AG/RS pairs per
    # layer -- toggleable because the roofline shows it is a memory vs
    # collective tradeoff (see EXPERIMENTS.md §Perf)
    seq_parallel: bool = True
    # cost-probe mode: fully unroll inner scans (flash attention, chunked
    # linear attention, chunked CE) so XLA cost_analysis -- which counts a
    # while body ONCE regardless of trip count -- reports exact FLOPs/bytes.
    # Used by the dry-run roofline at reduced depth; never for real runs.
    cost_probe: bool = False
    logit_chunk: int = 1024
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 512

    # ---- derived ----
    @property
    def n_groups(self) -> int:
        n = self.n_layers - len(self.prelude)
        assert n % len(self.pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} (minus prelude) not "
            f"divisible by pattern of length {len(self.pattern)}")
        return n // len(self.pattern)

    @property
    def qkv_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def ffn_kind_inner(self) -> str:
        """Activation used by expert FFNs when ffn_kind == 'moe'."""
        return "swiglu" if self.ffn_kind == "moe" else self.ffn_kind

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One benchmark cell: an input shape bound to a step kind."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train|prefill|decode

    @property
    def cache_len(self) -> int:
        # decode shapes attend to a cache of seq_len positions
        return self.seq_len


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
