"""Core NN layers (functional, pytree params) shared by all architectures."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig, MoEConfig

Params = dict


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0) -> jnp.ndarray:
    std = scale / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(d: int, kind: str, dtype) -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: Params, x: jnp.ndarray, kind: str, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def rmsnorm_gated(x: jnp.ndarray, scale: jnp.ndarray, gate: jnp.ndarray,
                  eps: float = 1e-5) -> jnp.ndarray:
    """Mamba-2 style RMSNorm(x * silu(gate))."""
    xf = (x * jax.nn.silu(gate.astype(jnp.float32))).astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def head_rmsnorm(x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """Per-head RMSNorm without scale (GLA/RetNet output norm)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps)).astype(x.dtype)


# ---------------------------------------------------------------------------
# positional embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, dh) or (..., S, dh); positions: (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                              # (dh/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs     # (..., S, dh/2)
    if x.ndim == ang.ndim + 1:                                 # head axis present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sincos_pos_emb(S: int, d: int, dtype) -> jnp.ndarray:
    pos = np.arange(S)[:, None]
    div = np.exp(np.arange(0, d, 2) * (-np.log(10000.0) / d))
    pe = np.zeros((S, d), np.float32)
    pe[:, 0::2] = np.sin(pos * div)
    pe[:, 1::2] = np.cos(pos * div)
    return jnp.asarray(pe, dtype)


# ---------------------------------------------------------------------------
# feed-forward variants
# ---------------------------------------------------------------------------

def init_ffn(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d, dff = cfg.d_model, (d_ff or cfg.d_ff)
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    scale_out = 1.0 / np.sqrt(2 * cfg.n_layers)
    if cfg.ffn_kind_inner in ("swiglu", "geglu"):
        return {"wi": dense_init(k1, d, dff, dt),
                "wg": dense_init(k2, d, dff, dt),
                "wo": dense_init(k3, dff, d, dt, scale_out)}
    return {"wi": dense_init(k1, d, dff, dt),
            "wo": dense_init(k3, dff, d, dt, scale_out)}


def apply_ffn(p: Params, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["wi"]) * (x @ p["wg"])
    elif kind == "geglu":
        h = jax.nn.gelu(x @ p["wi"]) * (x @ p["wg"])
    elif kind == "gelu":
        h = jax.nn.gelu(x @ p["wi"])
    elif kind == "relu":
        h = jax.nn.relu(x @ p["wi"])
    else:
        raise ValueError(kind)
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# Mixture-of-Experts (expert-parallel over the 'model' mesh axis)
# ---------------------------------------------------------------------------
#
# Token routing uses the destination->source indirection trick: a cheap int32
# scatter builds, for every (expert, slot), the index of the token assigned
# there; the expensive (E_local, Cap, d) buffer is then a single gather and
# the FFN runs as grouped einsums.  Tokens beyond expert capacity are
# dropped (standard capacity-factor semantics).
#
# Under expert parallelism, tokens are replicated across the 'model' axis
# (the activation layout GSPMD already uses for TP), each shard computes its
# local experts only, and one psum over 'model' combines -- the same
# collective cost as the TP FFN it replaces.

def init_moe(key, cfg: ModelConfig) -> Params:
    mc = cfg.moe
    d, de = cfg.d_model, mc.d_expert
    dt = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 5)
    scale_out = 1.0 / np.sqrt(2 * cfg.n_layers)
    p = {
        "router": dense_init(keys[0], d, mc.n_experts, jnp.float32),
        "wi": _stack_init(keys[1], mc.n_experts, d, de, dt),
        "wg": _stack_init(keys[2], mc.n_experts, d, de, dt),
        "wo": _stack_init(keys[3], mc.n_experts, de, d, dt, scale_out),
    }
    if mc.n_shared:
        p["shared"] = init_ffn(keys[4], cfg, d_ff=mc.d_expert * mc.n_shared)
    return p


def _stack_init(key, n: int, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / np.sqrt(d_in)
    return (jax.random.normal(key, (n, d_in, d_out)) * std).astype(dtype)


def _moe_dispatch_compute(x_flat: jnp.ndarray, sel: jnp.ndarray, w: jnp.ndarray,
                          wi, wg, wo, e_offset, n_local: int, cap: int,
                          kind: str) -> jnp.ndarray:
    """Compute the local experts' contribution for all tokens.

    x_flat (N, d); sel (N, k) global expert ids; w (N, k) combine weights;
    wi/wg/wo (E_local, ...); e_offset: first global id owned locally.
    """
    N, d = x_flat.shape
    k = sel.shape[-1]
    entry_tok = jnp.repeat(jnp.arange(N, dtype=jnp.int32), k)           # (N*k,)
    sel_f = sel.reshape(-1).astype(jnp.int32)
    w_f = w.reshape(-1)
    local_e = sel_f - e_offset
    is_local = (local_e >= 0) & (local_e < n_local)
    # slot within expert: rank among local entries of the same expert
    oh = jax.nn.one_hot(jnp.where(is_local, local_e, n_local), n_local + 1,
                        dtype=jnp.int32)                                 # (N*k, E_l+1)
    slot = (jnp.cumsum(oh, axis=0) - oh)                                  # exclusive
    slot = jnp.take_along_axis(slot, jnp.where(is_local, local_e, n_local)[:, None],
                               axis=1)[:, 0]
    keep = is_local & (slot < cap)
    e_idx = jnp.where(keep, local_e, n_local)                            # OOB drops
    s_idx = jnp.where(keep, slot, cap)

    # destination -> source token index
    src = jnp.full((n_local + 1, cap + 1), N, jnp.int32)
    src = src.at[e_idx, s_idx].set(entry_tok, mode="drop")
    src = src[:n_local, :cap]                                            # (E_l, Cap)
    x_pad = jnp.concatenate([x_flat, jnp.zeros((1, d), x_flat.dtype)], axis=0)
    buf = x_pad[src]                                                     # (E_l,Cap,d)

    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("ecd,edf->ecf", buf, wi)) * jnp.einsum(
            "ecd,edf->ecf", buf, wg)
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, wi))
    y_e = jnp.einsum("ecf,efd->ecd", h, wo)                              # (E_l,Cap,d)

    # combine weights per (expert, slot)
    wbuf = jnp.zeros((n_local + 1, cap + 1), w_f.dtype)
    wbuf = wbuf.at[e_idx, s_idx].set(w_f, mode="drop")[:n_local, :cap]
    y_e = y_e * wbuf[..., None].astype(y_e.dtype)

    out = jnp.zeros((N + 1, d), y_e.dtype)
    out = out.at[src.reshape(-1)].add(y_e.reshape(-1, d), mode="drop")
    return out[:N]


def _moe_local(x: jnp.ndarray, router, wi, wg, wo, cfg: ModelConfig,
               ep_axis: Optional[str]) -> jnp.ndarray:
    """Route + dispatch + expert FFNs for the tokens on this shard.

    With ep_axis set, runs inside shard_map: this shard holds E/tp experts
    and the local batch slice; routing decisions are computed locally (the
    router is replicated) and one psum over ep_axis combines expert outputs.
    Token movement is zero -- each (data, model) shard pair computes exactly
    the (local tokens x local experts) block.
    """
    mc = cfg.moe
    B, S, d = x.shape
    x_flat = x.reshape(-1, d)
    logits = (x_flat.astype(jnp.float32) @ router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, sel = jax.lax.top_k(probs, mc.top_k)                        # (N, k)
    w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)

    n_tokens = x_flat.shape[0]
    cap = int(np.ceil(n_tokens * mc.top_k / mc.n_experts * mc.capacity_factor))
    cap = max(cap, 4)

    if ep_axis is None:
        out = _moe_dispatch_compute(x_flat, sel, w, wi, wg, wo,
                                    e_offset=0, n_local=mc.n_experts,
                                    cap=cap, kind=cfg.ffn_kind_inner)
    else:
        n_shards = jax.lax.axis_size(ep_axis)
        n_local = mc.n_experts // n_shards
        e_offset = jax.lax.axis_index(ep_axis) * n_local
        out = _moe_dispatch_compute(x_flat, sel, w, wi, wg, wo,
                                    e_offset=e_offset, n_local=n_local,
                                    cap=cap, kind=cfg.ffn_kind_inner)
        out = jax.lax.psum(out, ep_axis)
    return out.reshape(B, S, d).astype(x.dtype)


def apply_moe(p: Params, x: jnp.ndarray, cfg: ModelConfig,
              par=None) -> jnp.ndarray:
    """MoE FFN.  x: (B, S, d).  par: repro.dist.sharding.Parallel or None."""
    from jax.sharding import PartitionSpec as P  # local import, no cycle
    mc = cfg.moe
    use_ep = (par is not None and par.tp > 1
              and mc.n_experts % par.tp == 0)
    if use_ep:
        model = par.model_axis
        bspec = P(par.batch_axes, None, None)
        espec = P(model, None, None)
        out = jax.shard_map(
            functools.partial(_moe_local, cfg=cfg, ep_axis=model),
            mesh=par.mesh,
            in_specs=(bspec, P(None, None), espec, espec, espec),
            out_specs=bspec,
            check_vma=False,
        )(x, p["router"], p["wi"], p["wg"], p["wo"])
    else:
        out = _moe_local(x, p["router"], p["wi"], p["wg"], p["wo"], cfg, None)
    if mc.n_shared:
        out = out + apply_ffn(p["shared"], x, cfg.ffn_kind_inner)
    return out


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def chunked_softmax_xent(x: jnp.ndarray, lm_head: jnp.ndarray,
                         labels: jnp.ndarray, mask: jnp.ndarray,
                         chunk: int = 1024, unroll: bool = False) -> jnp.ndarray:
    """Cross-entropy over huge vocabularies without a (B,S,V) logits buffer.

    x: (B, S, d) final hidden states; lm_head: (d, V); labels/mask: (B, S).
    Scans over sequence chunks; each chunk's logits are (B, chunk, V) and die
    immediately.  Essential for paligemma's 257k vocab.
    """
    B, S, d = x.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = x.shape[1] // chunk
    xc = x.reshape(B, nc, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(B, nc, chunk).swapaxes(0, 1)
    mc = mask.reshape(B, nc, chunk).swapaxes(0, 1)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def body(carry, inp):
        # checkpointed: the backward recomputes the chunk logits instead of
        # saving a (B, chunk, V) residual per chunk
        tot, cnt = carry
        xb, lb, mb = inp
        logits = (xb @ lm_head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via masked reduction, not take_along_axis: a gather
        # across the model-sharded vocab dim would force an all-gather of the
        # logits chunk under GSPMD; the masked sum reduces locally and
        # all-reduces a (B, chunk) scalar field instead.
        vocab_ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        gold = jnp.sum(jnp.where(vocab_ids == lb[..., None], logits, 0.0),
                       axis=-1)
        nll = (logz - gold) * mb
        return (tot + nll.sum(), cnt + mb.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 (xc, lc, mc), unroll=unroll)
    return tot / jnp.maximum(cnt, 1.0)
