"""State-update mixers: Mamba-2, GLA, RetNet, HGRN2, mLSTM, sLSTM.

All of these share the generalized state-update decode step (paper Eq. 2,
the ``state_update`` SPU op in repro.ops).  Training/prefill run in the "compute-intensive
form" the paper assigns to the GPU: a chunked linear-attention formulation
(the SSD duality of Dao & Gu) that is MXU-friendly -- quadratic within small
chunks, recurrent across chunks.

Two chunked engines cover every family member:
  * scalar per-step decay (Mamba-2 dt·a, RetNet γ_h, mLSTM sigmoid-f)
  * vector per-step decay  (GLA per-channel gates, HGRN2 forget gates)

Decode routes every family through ONE registered SPU op invocation
(:func:`_spu_state_update` -> ``repro.ops.state_update_step``); what differs
per family is only the decay/gating hook that produces Eq. 2's d_t
(``_DECAY_HOOKS``) and the pre/post projections around the op.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import ops as OPS
from repro.core import formats as F
from repro.models import layers as L
from repro.models.config import ModelConfig

Params = dict
MixerState = Dict[str, object]


def _spu_state_update(state, decay, k, v, q, cfg: ModelConfig, seed):
    """The one decode-time Eq. 2 invocation shared by every state family.

    Dispatches through the SPU op registry (kind ``state_update``, backend
    negotiated from ``cfg.state_quant``); see repro/ops/state_update.py.
    """
    return OPS.state_update_step(state, decay, k, v, q, cfg.state_quant,
                                 seed=seed)


#: per-family decode decay hooks: log-decay (as produced by the shared qkv
#: projections) -> Eq. 2 d_t.  Scalar families feed (B,H,1); vector-gated
#: families feed the per-channel (B,H,dk) gate.
_DECAY_HOOKS = {
    "gla": lambda log_f: jnp.exp(log_f[:, :, 0]),          # (B,H,dk)
    "hgrn2": lambda log_f: jnp.exp(log_f[:, :, 0]),        # (B,H,dk)
    "retnet": lambda log_f: jnp.exp(log_f[..., :1]),       # (B,H,1)
    "mamba2": lambda log_f: jnp.exp(log_f),                # (B,H,1)
    "mlstm": lambda log_f: jnp.exp(log_f),                 # (B,H,1)
}


# ---------------------------------------------------------------------------
# chunked linear attention engines
# ---------------------------------------------------------------------------

def chunked_la_scalar(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      log_a: jnp.ndarray, chunk: int, unroll: bool = False,
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scalar-decay chunked scan.

    q, k: (B,H,S,dk); v: (B,H,S,dv); log_a: (B,H,S) per-step log decay (<=0).
    Returns y: (B,H,S,dv) and the final state (B,H,dk,dv) in f32.
    """
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, S)
    S0_len = S
    pad = (-S) % c
    if pad:  # zero tokens with decay 1 leave the state untouched
        zpad = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 3))
        q, k, v, log_a = zpad(q), zpad(k), zpad(v), zpad(log_a)
        S = S + pad
    nc = S // c

    def to_chunks(x, feat):
        x = x.reshape(B, H, nc, c, *feat)
        return jnp.moveaxis(x, 2, 0)               # (nc, B, H, c, ...)

    # keep q/k/v in their storage dtype (bf16 in production); the decay
    # factors and accumulators are f32.  Full-sequence f32 copies of q/k/v
    # would dominate training-step memory.
    qc = to_chunks(q, (dk,))
    kc = to_chunks(k, (dk,))
    vc = to_chunks(v, (dv,))
    la = to_chunks(log_a.astype(jnp.float32), ())

    cum = jnp.cumsum(la, axis=-1)                  # (nc,B,H,c) inclusive
    total = cum[..., -1:]

    tril = jnp.tril(jnp.ones((c, c), bool))

    def body(S_prev, inp):
        qi, ki, vi, cumi, toti = inp
        # intra-chunk quadratic part
        dmat = jnp.exp(cumi[..., :, None] - cumi[..., None, :])  # (B,H,c,c)
        A = jnp.einsum("bhcd,bhed->bhce", qi, ki,
                       preferred_element_type=jnp.float32) * dmat
        A = jnp.where(tril, A, 0.0)
        y = jnp.einsum("bhce,bhev->bhcv", A.astype(vi.dtype), vi,
                       preferred_element_type=jnp.float32)
        # inter-chunk contribution from the carried state
        q_in = (qi.astype(jnp.float32) * jnp.exp(cumi)[..., None]).astype(qi.dtype)
        y = y + jnp.einsum("bhcd,bhdv->bhcv", q_in, S_prev.astype(qi.dtype),
                           preferred_element_type=jnp.float32)
        # state recurrence to the chunk end
        k_end = (ki.astype(jnp.float32)
                 * jnp.exp(toti - cumi)[..., None]).astype(ki.dtype)
        S_next = jnp.exp(toti)[..., None] * S_prev + jnp.einsum(
            "bhcd,bhcv->bhdv", k_end, vi, preferred_element_type=jnp.float32)
        return S_next, y

    S0 = jnp.zeros((B, H, dk, dv), jnp.float32)
    S_fin, yc = jax.lax.scan(body, S0, (qc, kc, vc, cum, total),
                             unroll=unroll)
    y = jnp.moveaxis(yc, 0, 2).reshape(B, H, S, dv)[:, :, :S0_len]
    return y, S_fin


def chunked_la_vector(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      log_f: jnp.ndarray, chunk: int, unroll: bool = False,
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Vector-decay chunked scan (GLA / HGRN2).

    log_f: (B,H,S,dk) per-channel log decay, clamped >= cfg.log_decay_min by
    the caller so exp(-cum) stays finite within a chunk.
    """
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, S)
    S0_len = S
    pad = (-S) % c
    if pad:
        zpad = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 3))
        q, k, v, log_f = zpad(q), zpad(k), zpad(v), zpad(log_f)
        S = S + pad
    nc = S // c

    def to_chunks(x):
        return jnp.moveaxis(x.reshape(B, H, nc, c, -1), 2, 0)

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    lf = to_chunks(log_f.astype(jnp.float32))
    cum = jnp.cumsum(lf, axis=-2)                  # (nc,B,H,c,dk)
    total = cum[..., -1:, :]

    tril = jnp.tril(jnp.ones((c, c), bool))

    def body(S_prev, inp):
        qi, ki, vi, cumi, toti = inp
        q_in = qi.astype(jnp.float32) * jnp.exp(cumi)
        k_de = ki.astype(jnp.float32) * jnp.exp(-cumi)   # bounded by the clamp
        A = jnp.einsum("bhcd,bhed->bhce", q_in, k_de)
        A = jnp.where(tril, A, 0.0)
        y = jnp.einsum("bhce,bhev->bhcv", A.astype(vi.dtype), vi,
                       preferred_element_type=jnp.float32)
        y = y + jnp.einsum("bhcd,bhdv->bhcv", q_in, S_prev)
        k_end = ki.astype(jnp.float32) * jnp.exp(toti - cumi)
        S_next = jnp.exp(toti[..., 0, :, None]) * S_prev + jnp.einsum(
            "bhcd,bhcv->bhdv", k_end, vi.astype(jnp.float32))
        return S_next, y

    S0 = jnp.zeros((B, H, dk, dv), jnp.float32)
    S_fin, yc = jax.lax.scan(body, S0, (qc, kc, vc, cum, total),
                             unroll=unroll)
    y = jnp.moveaxis(yc, 0, 2).reshape(B, H, S, dv)[:, :, :S0_len]
    return y, S_fin


def shard_heads(x: jnp.ndarray, par) -> jnp.ndarray:
    """Constrain (B, H, S, ...) per-head activations for the chunk engines.

    Two jobs: (1) shard H (or the feature dim when H doesn't divide TP, e.g.
    xLSTM's 4 giant heads) over 'model' so head-shared broadcasts don't
    materialize TP-replicated; (2) pin the SEQUENCE dim unsharded -- the
    chunked scans reshape S into (nc, c) and slice per step, and slicing a
    sharded dim triggers involuntary full resharding every iteration."""
    if par is None or not hasattr(par, "mesh"):
        return x
    B, H = x.shape[:2]
    if B % par.batch_size_divisor != 0:
        return x
    from jax.sharding import PartitionSpec as P
    dims = [par.batch_axes] + [None] * (x.ndim - 1)
    if H % par.tp == 0:
        dims[1] = par.model_axis
    # else: batch-only.  Sharding the feature dim instead (xLSTM's 4 giant
    # heads) makes every chunk-scan einsum a cross-step partitioning puzzle
    # (measured: pathological SPMD compile); H-indivisible mixers replicate
    # over TP -- an inherent limit of 4-head architectures, noted in
    # DESIGN.md §Arch-applicability.
    return jax.lax.with_sharding_constraint(x, par.named(P(*dims)))


def _store_state(S_logical: jnp.ndarray, cfg: ModelConfig) -> OPS.StateLike:
    """(B,H,dk,dv) f32 -> stored container (B,H,dv,dk)."""
    St = jnp.swapaxes(S_logical, -1, -2)
    sq = cfg.state_quant
    if not sq.quantized:
        dt = {"fp32": jnp.float32, "bf16": jnp.bfloat16,
              "fp16": jnp.float16}[sq.fmt]
        return St.astype(dt)
    return F.quantize(St, sq.fmt)


# ---------------------------------------------------------------------------
# causal depthwise conv (mamba2 / mlstm front conv)
# ---------------------------------------------------------------------------

def causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """x: (B,S,C), w: (d_conv, C): y_t = sum_i w_i * x_{t-d_conv+1+i} + b."""
    d_conv = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(d_conv):
        shift = d_conv - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xi * w[i]
    return out + b


def causal_conv_step(x_new: jnp.ndarray, conv_state: jnp.ndarray,
                     w: jnp.ndarray, b: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One-token conv step.  x_new: (B,C); conv_state: (B,d_conv-1,C)."""
    win = jnp.concatenate([conv_state, x_new[:, None]], axis=1)  # (B,d_conv,C)
    y = jnp.einsum("bdc,dc->bc", win, w) + b
    return y, win[:, 1:]


# ---------------------------------------------------------------------------
# Mamba-2
# ---------------------------------------------------------------------------

def _m2_dims(cfg: ModelConfig):
    sc = cfg.ssm
    d_inner = sc.expand * cfg.d_model
    H = d_inner // sc.head_dim
    return d_inner, H, sc.d_state, sc.head_dim


def init_mamba2(key, cfg: ModelConfig) -> Params:
    """Projections are kept as separate matrices (wz/wx/wbc/wdt) rather than
    one fused in_proj so each gets a uniform TP sharding: z/x shard over the
    head (model) axis, B/C are head-shared and replicate, dt shards over H."""
    d = cfg.d_model
    d_inner, H, N, P = _m2_dims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    return {
        "wz": L.dense_init(ks[0], d, d_inner, dt),
        "wx": L.dense_init(ks[1], d, d_inner, dt),
        "wbc": L.dense_init(ks[2], d, 2 * N, dt),
        "wdt": L.dense_init(ks[3], d, H, dt),
        "conv_x_w": (jax.random.normal(ks[4], (cfg.ssm.d_conv, d_inner))
                     * (1.0 / np.sqrt(cfg.ssm.d_conv))).astype(dt),
        "conv_x_b": jnp.zeros((d_inner,), dt),
        "conv_bc_w": (jax.random.normal(ks[5], (cfg.ssm.d_conv, 2 * N))
                      * (1.0 / np.sqrt(cfg.ssm.d_conv))).astype(dt),
        "conv_bc_b": jnp.zeros((2 * N,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), np.log(np.expm1(0.01)), jnp.float32),
        "norm": L.init_norm(d_inner, "rmsnorm", dt),
        "out_proj": L.dense_init(ks[6], d_inner, d, dt,
                                 1.0 / np.sqrt(2 * cfg.n_layers)),
    }


def _m2_project(p, x, cfg):
    d_inner, H, N, P = _m2_dims(cfg)
    z = x @ p["wz"]
    xin = x @ p["wx"]
    bc = x @ p["wbc"]
    dt_ = x @ p["wdt"]
    return z, xin, bc[..., :N], bc[..., N:], dt_


def mamba2_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                   par=None) -> Tuple[jnp.ndarray, MixerState]:
    B, S, d = x.shape
    d_inner, H, N, P = _m2_dims(cfg)
    z, xin, Bv, Cv, dt_ = _m2_project(p, x, cfg)
    xin = jax.nn.silu(causal_conv(xin, p["conv_x_w"], p["conv_x_b"]))
    bc = jax.nn.silu(causal_conv(jnp.concatenate([Bv, Cv], -1),
                                 p["conv_bc_w"], p["conv_bc_b"]))
    Bv, Cv = bc[..., :N], bc[..., N:]

    dt_f = jax.nn.softplus(dt_.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["A_log"])                                        # (H,)
    log_decay = (dt_f * a).transpose(0, 2, 1)                       # (B,H,S)

    # map to the generalized op: k=Bv (dk=N), v=dt*x (dv=P), q=Cv
    k = jnp.broadcast_to(Bv[:, :, None, :], (B, S, H, N)).transpose(0, 2, 1, 3)
    q = jnp.broadcast_to(Cv[:, :, None, :], (B, S, H, N)).transpose(0, 2, 1, 3)
    xh = xin.reshape(B, S, H, P)
    v = (xh * dt_f[..., None].astype(xh.dtype)).transpose(0, 2, 1, 3)  # (B,H,S,P)
    k, q, v = shard_heads(k, par), shard_heads(q, par), shard_heads(v, par)
    log_decay = shard_heads(log_decay, par)

    y, S_fin = chunked_la_scalar(q, k, v, log_decay, cfg.ssm.chunk,
                                 unroll=cfg.cost_probe)
    y = y + p["D"][None, :, None, None] * xh.transpose(0, 2, 1, 3)
    y = y.transpose(0, 2, 1, 3).reshape(B, S, d_inner).astype(x.dtype)
    y = L.rmsnorm_gated(y, p["norm"]["scale"], z, cfg.norm_eps)
    out = y @ p["out_proj"]

    # NOTE: conv caches hold pre-activation inputs of the last d_conv-1 steps
    z2, xin2, Bv2, Cv2, _ = _m2_project(p, x[:, -(cfg.ssm.d_conv - 1):], cfg)
    state = {"S": _store_state(S_fin, cfg),
             "conv_x": xin2,
             "conv_bc": jnp.concatenate([Bv2, Cv2], -1)}
    return out, state


def mamba2_init_state(B: int, cfg: ModelConfig) -> MixerState:
    d_inner, H, N, P = _m2_dims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    return {"S": OPS.init_state(B, H, N, P, cfg.state_quant),
            "conv_x": jnp.zeros((B, cfg.ssm.d_conv - 1, d_inner), dt),
            "conv_bc": jnp.zeros((B, cfg.ssm.d_conv - 1, 2 * N), dt)}


def mamba2_decode(p: Params, x: jnp.ndarray, state: MixerState,
                  cfg: ModelConfig, seed) -> Tuple[jnp.ndarray, MixerState]:
    """x: (B, 1, d) one token."""
    B = x.shape[0]
    d_inner, H, N, P = _m2_dims(cfg)
    z, xin, Bv, Cv, dt_ = _m2_project(p, x[:, 0], cfg)
    xin, conv_x_state = causal_conv_step(xin, state["conv_x"],
                                         p["conv_x_w"], p["conv_x_b"])
    xin = jax.nn.silu(xin)
    bc, conv_bc_state = causal_conv_step(jnp.concatenate([Bv, Cv], -1),
                                         state["conv_bc"],
                                         p["conv_bc_w"], p["conv_bc_b"])
    bc = jax.nn.silu(bc)
    Bv, Cv = bc[..., :N], bc[..., N:]

    dt_f = jax.nn.softplus(dt_.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["A_log"])
    decay = _DECAY_HOOKS["mamba2"]((dt_f * a)[..., None])           # (B,H,1)

    k = jnp.broadcast_to(Bv[:, None, :], (B, H, N))
    q = jnp.broadcast_to(Cv[:, None, :], (B, H, N))
    xh = xin.reshape(B, H, P)
    v = xh * dt_f[..., None]

    Sn, y = _spu_state_update(state["S"], decay, k, v, q, cfg, seed)  # y (B,H,P)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(B, d_inner).astype(x.dtype)
    y = L.rmsnorm_gated(y, p["norm"]["scale"], z, cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None]
    return out, {"S": Sn, "conv_x": conv_x_state, "conv_bc": conv_bc_state}


# ---------------------------------------------------------------------------
# GLA-family (GLA / RetNet / HGRN2) shared projections
# ---------------------------------------------------------------------------

def _gla_dims(cfg: ModelConfig):
    sc = cfg.ssm
    H = sc.n_heads or cfg.n_heads
    dk = sc.dk_head or cfg.head_dim
    dv = sc.dv_head or cfg.head_dim
    return H, dk, dv


def init_gla_family(key, cfg: ModelConfig, kind: str) -> Params:
    d = cfg.d_model
    H, dk, dv = _gla_dims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    p = {
        "wq": L.dense_init(ks[0], d, H * dk, dt),
        "wk": L.dense_init(ks[1], d, H * dk, dt),
        "wv": L.dense_init(ks[2], d, H * dv, dt),
        "wg_out": L.dense_init(ks[3], d, H * dv, dt),
        "wo": L.dense_init(ks[4], H * dv, d, dt,
                           1.0 / np.sqrt(2 * cfg.n_layers)),
    }
    if kind == "gla":
        p["wga"] = L.dense_init(ks[5], d, 16, dt)
        p["wgb"] = L.dense_init(ks[6], 16, H * dk, dt)
        p["gb"] = jnp.full((H * dk,), 4.0, jnp.float32)   # bias gates toward 1
    elif kind == "hgrn2":
        p["wf"] = L.dense_init(ks[5], d, H * dk, dt)
        p["fb"] = jnp.zeros((H * dk,), jnp.float32)
        # depth-dependent forget lower bound (set by the model assembler)
        p["beta"] = jnp.zeros((1,), jnp.float32)
    elif kind == "retnet":
        pass  # fixed per-head decay, no gate params
    else:
        raise ValueError(kind)
    return p


def _retnet_log_gamma(H: int) -> jnp.ndarray:
    return jnp.log1p(-jnp.exp2(-5.0 - jnp.arange(H, dtype=jnp.float32)))


def _gla_family_qkv(p, x, cfg, kind):
    B, S, d = x.shape
    H, dk, dv = _gla_dims(cfg)
    q = (x @ p["wq"]).reshape(B, S, H, dk).transpose(0, 2, 1, 3)
    k = (x @ p["wk"]).reshape(B, S, H, dk).transpose(0, 2, 1, 3)
    v = (x @ p["wv"]).reshape(B, S, H, dv).transpose(0, 2, 1, 3)
    if kind == "gla":
        g = (x @ p["wga"]) @ p["wgb"] + p["gb"]
        log_f = jax.nn.log_sigmoid(g.astype(jnp.float32)) / 16.0
        log_f = jnp.maximum(log_f, cfg.ssm.log_decay_min)
        log_f = log_f.reshape(B, S, H, dk).transpose(0, 2, 1, 3)
        q = q * (dk ** -0.5)
    elif kind == "hgrn2":
        f_pre = (x @ p["wf"]) + p["fb"]
        beta = p["beta"][0]
        fgate = beta + (1.0 - beta) * jax.nn.sigmoid(f_pre.astype(jnp.float32))
        log_f = jnp.maximum(jnp.log(fgate + 1e-9), cfg.ssm.log_decay_min)
        log_f = log_f.reshape(B, S, H, dk).transpose(0, 2, 1, 3)
        # HGRN2: k = 1 - f  (input gate complementary to forget gate)
        k = (1.0 - jnp.exp(log_f)).astype(k.dtype)
        q = q * (dk ** -0.5)
    else:  # retnet: scalar per-head decay
        log_f = jnp.broadcast_to(_retnet_log_gamma(H)[None, :, None], (B, H, S))
        q = q * (dk ** -0.5)
    return q, k, v, log_f


def gla_family_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                       kind: str, par=None) -> Tuple[jnp.ndarray, MixerState]:
    B, S, d = x.shape
    H, dk, dv = _gla_dims(cfg)
    q, k, v, log_f = _gla_family_qkv(p, x, cfg, kind)
    q, k, v = shard_heads(q, par), shard_heads(k, par), shard_heads(v, par)
    log_f = shard_heads(log_f, par)
    if kind == "retnet":
        y, S_fin = chunked_la_scalar(q, k, v, log_f, cfg.ssm.chunk,
                                     unroll=cfg.cost_probe)
    else:
        y, S_fin = chunked_la_vector(q, k, v, log_f, cfg.ssm.chunk,
                                     unroll=cfg.cost_probe)
    y = L.head_rmsnorm(y, cfg.norm_eps)                    # (B,H,S,dv)
    y = y.transpose(0, 2, 1, 3).reshape(B, S, H * dv)
    gate = jax.nn.silu(x @ p["wg_out"])
    out = (y.astype(x.dtype) * gate) @ p["wo"]
    return out, {"S": _store_state(S_fin, cfg)}


def gla_family_init_state(B: int, cfg: ModelConfig) -> MixerState:
    H, dk, dv = _gla_dims(cfg)
    return {"S": OPS.init_state(B, H, dk, dv, cfg.state_quant)}


def gla_family_decode(p: Params, x: jnp.ndarray, state: MixerState,
                      cfg: ModelConfig, kind: str, seed
                      ) -> Tuple[jnp.ndarray, MixerState]:
    B = x.shape[0]
    H, dk, dv = _gla_dims(cfg)
    q, k, v, log_f = _gla_family_qkv(p, x, cfg, kind)      # (B,H,1,*)
    q, k, v = q[:, :, 0], k[:, :, 0], v[:, :, 0]
    decay = _DECAY_HOOKS[kind](log_f)
    Sn, y = _spu_state_update(state["S"], decay, k, v, q, cfg, seed)
    y = L.head_rmsnorm(y, cfg.norm_eps).reshape(B, 1, H * dv)
    gate = jax.nn.silu(x @ p["wg_out"])
    out = (y.astype(x.dtype) * gate) @ p["wo"]
    return out, {"S": Sn}


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory block)
# ---------------------------------------------------------------------------

def _mlstm_dims(cfg: ModelConfig):
    sc = cfg.ssm
    d_up = sc.expand * cfg.d_model
    H = sc.n_heads or cfg.n_heads
    dk = d_up // H
    dv = d_up // H
    dv_aug = dv + 16            # [v, 1, 0...] -- normalizer folded in
    return d_up, H, dk, dv, dv_aug


def init_mlstm(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    d_up, H, dk, dv, _ = _mlstm_dims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    k_extra = jax.random.split(ks[0])
    return {
        "wu": L.dense_init(k_extra[0], d, d_up, dt),
        "wz": L.dense_init(k_extra[1], d, d_up, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm.d_conv, d_up))
                   * (1.0 / np.sqrt(cfg.ssm.d_conv))).astype(dt),
        "conv_b": jnp.zeros((d_up,), dt),
        # block-diagonal per-head projections (xLSTM parameterization):
        # (H, dk, dk) instead of dense (d_up, d_up) -- H x fewer params
        "wq": (jax.random.normal(ks[2], (H, dk, dk)) / np.sqrt(dk)).astype(dt),
        "wk": (jax.random.normal(ks[3], (H, dk, dk)) / np.sqrt(dk)).astype(dt),
        "wv": (jax.random.normal(ks[4], (H, dv, dv)) / np.sqrt(dv)).astype(dt),
        "wi": L.dense_init(ks[5], d_up, H, jnp.float32),
        "wf": L.dense_init(ks[6], d_up, H, jnp.float32),
        "fb": jnp.full((H,), 3.0, jnp.float32),   # bias forget gates open
        "hnorm": jnp.ones((H, dv), dt),
        "down": L.dense_init(ks[7], d_up, d, dt,
                             1.0 / np.sqrt(2 * cfg.n_layers)),
    }


def _mlstm_gates_qkv(p, u, uc, cfg):
    B, S, d_up = u.shape
    _, H, dk, dv, dv_aug = _mlstm_dims(cfg)
    uh = uc.reshape(B, S, H, dk)
    q = jnp.einsum("bshd,hde->bhse", uh, p["wq"])
    k = jnp.einsum("bshd,hde->bhse", uh, p["wk"]) * dk ** -0.5
    v = jnp.einsum("bshd,hde->bhse", u.reshape(B, S, H, dv), p["wv"])
    i_log = jnp.clip((u @ p["wi"]).astype(jnp.float32), -12.0, 4.0)
    log_f = jax.nn.log_sigmoid((u @ p["wf"]).astype(jnp.float32) + p["fb"])
    i_log = i_log.transpose(0, 2, 1)              # (B,H,S)
    log_f = log_f.transpose(0, 2, 1)
    # fold the exp input gate into k; augment v with a ones column so the
    # normalizer n is carried as extra state rows (padded to MX group size)
    k_eff = (k.astype(jnp.float32) * jnp.exp(i_log)[..., None]).astype(k.dtype)
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    zeros = jnp.zeros(v.shape[:-1] + (dv_aug - dv - 1,), v.dtype)
    v_aug = jnp.concatenate([v, ones, zeros], axis=-1)
    return q, k_eff, v_aug, log_f


def mlstm_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                  par=None) -> Tuple[jnp.ndarray, MixerState]:
    B, S, d = x.shape
    d_up, H, dk, dv, dv_aug = _mlstm_dims(cfg)
    u, z = x @ p["wu"], x @ p["wz"]
    uc = jax.nn.silu(causal_conv(u, p["conv_w"], p["conv_b"]))
    q, k_eff, v_aug, log_f = _mlstm_gates_qkv(p, u, uc, cfg)
    q, k_eff, v_aug = (shard_heads(q, par), shard_heads(k_eff, par),
                       shard_heads(v_aug, par))
    y_aug, S_fin = chunked_la_scalar(q, k_eff, v_aug, log_f, cfg.ssm.chunk,
                                     unroll=cfg.cost_probe)
    y, n_dot = y_aug[..., :dv], y_aug[..., dv]
    h = y / jnp.maximum(jnp.abs(n_dot), 1.0)[..., None]
    h = L.head_rmsnorm(h, cfg.norm_eps) * p["hnorm"][None, :, None, :]
    h = h.transpose(0, 2, 1, 3).reshape(B, S, d_up).astype(x.dtype)
    out = (h * jax.nn.silu(z)) @ p["down"]
    state = {"S": _store_state(S_fin, cfg),
             "conv": u[:, -(cfg.ssm.d_conv - 1):, :]}
    return out, state


def mlstm_init_state(B: int, cfg: ModelConfig) -> MixerState:
    d_up, H, dk, dv, dv_aug = _mlstm_dims(cfg)
    return {"S": OPS.init_state(B, H, dk, dv_aug, cfg.state_quant),
            "conv": jnp.zeros((B, cfg.ssm.d_conv - 1, d_up),
                              jnp.dtype(cfg.param_dtype))}


def mlstm_decode(p: Params, x: jnp.ndarray, state: MixerState,
                 cfg: ModelConfig, seed) -> Tuple[jnp.ndarray, MixerState]:
    B = x.shape[0]
    d_up, H, dk, dv, dv_aug = _mlstm_dims(cfg)
    u, z = x[:, 0] @ p["wu"], x[:, 0] @ p["wz"]
    conv_out, conv_state = causal_conv_step(u, state["conv"],
                                            p["conv_w"], p["conv_b"])
    uc = jax.nn.silu(conv_out)
    q, k_eff, v_aug, log_f = _mlstm_gates_qkv(
        p, u[:, None], uc[:, None], cfg)
    q, k_eff, v_aug = q[:, :, 0], k_eff[:, :, 0], v_aug[:, :, 0]
    decay = _DECAY_HOOKS["mlstm"](log_f)                    # (B,H,1)
    Sn, y_aug = _spu_state_update(state["S"], decay, k_eff, v_aug, q,
                                  cfg, seed)
    y, n_dot = y_aug[..., :dv], y_aug[..., dv]
    h = y / jnp.maximum(jnp.abs(n_dot), 1.0)[..., None]
    h = L.head_rmsnorm(h, cfg.norm_eps) * p["hnorm"][None]
    h = h.reshape(B, d_up).astype(x.dtype)
    out = ((h * jax.nn.silu(z)) @ p["down"])[:, None]
    return out, {"S": Sn, "conv": conv_state}


# ---------------------------------------------------------------------------
# sLSTM (vector recurrence; inherently sequential)
# ---------------------------------------------------------------------------

def _slstm_dims(cfg: ModelConfig):
    H = cfg.ssm.n_heads or cfg.n_heads
    dh = cfg.d_model // H
    return H, dh


def init_slstm(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    H, dh = _slstm_dims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    return {
        "wx": L.dense_init(ks[0], d, 4 * d, dt),            # z,i,f,o
        "r": (jax.random.normal(ks[1], (H, dh, 4 * dh))
              / np.sqrt(dh)).astype(dt),
        "b": jnp.zeros((4 * d,), jnp.float32),
        "out": L.dense_init(ks[2], d, d, dt,
                            1.0 / np.sqrt(2 * cfg.n_layers)),
    }


def _slstm_cell(p, gx, carry, cfg):
    """gx: (B,H,4*dh) pre-activations from x; carry: (c,n,m,h)."""
    c_prev, n_prev, m_prev, h_prev = carry
    rec = jnp.einsum("bhd,hde->bhe", h_prev.astype(gx.dtype), p["r"])
    g = (gx + rec).astype(jnp.float32)
    zt, it, ft, ot = jnp.split(g, 4, axis=-1)
    zt = jnp.tanh(zt)
    log_f = jax.nn.log_sigmoid(ft)
    m_t = jnp.maximum(log_f + m_prev, it)
    i_p = jnp.exp(it - m_t)
    f_p = jnp.exp(log_f + m_prev - m_t)
    c_t = f_p * c_prev + i_p * zt
    n_t = f_p * n_prev + i_p
    h_t = jax.nn.sigmoid(ot) * c_t / jnp.maximum(n_t, 1e-6)
    return (c_t, n_t, m_t, h_t)


def slstm_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                  par=None) -> Tuple[jnp.ndarray, MixerState]:
    B, S, d = x.shape
    H, dh = _slstm_dims(cfg)
    gx = ((x @ p["wx"]) + p["b"].astype(x.dtype)).reshape(B, S, H, 4 * dh)

    def run(r_param, gx_local):
        """Per-shard sequential scan over time (batch-split)."""
        gxt = jnp.moveaxis(gx_local, 1, 0)          # (S, B_l, H, 4dh)
        b_l = gx_local.shape[0]
        z0 = jnp.zeros((b_l, H, dh), jnp.float32)
        carry0 = (z0, z0, jnp.full_like(z0, -1e30), z0)

        def body(carry, g):
            new = _slstm_cell({"r": r_param}, g, carry, cfg)
            return new, new[3]

        carry, hs = jax.lax.scan(body, carry0, gxt)
        return jnp.moveaxis(hs, 0, 1), carry        # (B_l, S, H, dh), states

    # The 4096-step recurrence must not be re-partitioned per step: under
    # GSPMD the backward's per-step dynamic slices churn the partitioner
    # into involuntary full rematerializations.  shard_map makes the
    # sharding manual (batch split, everything else replicated) so the loop
    # body is compiled exactly once.
    if par is not None and hasattr(par, "mesh") \
            and B % par.batch_size_divisor == 0:
        from jax.sharding import PartitionSpec as P
        bt = P(par.batch_axes)
        hs, carry = jax.shard_map(
            run, mesh=par.mesh,
            in_specs=(P(), P(par.batch_axes, None, None, None)),
            out_specs=(P(par.batch_axes, None, None, None),
                       (bt, bt, bt, bt)),
            check_vma=False,
        )(p["r"], gx)
    else:
        hs, carry = run(p["r"], gx)
    h = hs.reshape(B, S, d).astype(x.dtype)
    out = h @ p["out"]
    state = {"c": carry[0], "n": carry[1], "m": carry[2], "h": carry[3]}
    return out, state


def slstm_init_state(B: int, cfg: ModelConfig) -> MixerState:
    H, dh = _slstm_dims(cfg)
    z0 = jnp.zeros((B, H, dh), jnp.float32)
    return {"c": z0, "n": z0, "m": jnp.full_like(z0, -1e30), "h": z0}


def slstm_decode(p: Params, x: jnp.ndarray, state: MixerState,
                 cfg: ModelConfig, seed) -> Tuple[jnp.ndarray, MixerState]:
    B = x.shape[0]
    H, dh = _slstm_dims(cfg)
    gx = ((x[:, 0] @ p["wx"]) + p["b"].astype(x.dtype)).reshape(B, H, 4 * dh)
    carry = (state["c"], state["n"], state["m"], state["h"])
    c, n, m, h = _slstm_cell(p, gx, carry, cfg)
    out = (h.reshape(B, cfg.d_model).astype(x.dtype) @ p["out"])[:, None]
    return out, {"c": c, "n": n, "m": m, "h": h}
