"""Finding vocabulary shared by all three lint passes.

A :class:`Finding` is one (code, file, line) diagnostic with a fix hint.
Codes come in three families:

  * ``JH1xx`` -- jit/retrace hazards (pass 1, :mod:`.jit_hazards`)
  * ``PL2xx`` -- page-ledger protocol (pass 2, :mod:`.ledger`; the ``PL25x``
    range is raised at runtime by the shadow-ledger sanitizer)
  * ``RC3xx`` -- op-registry contracts (pass 3, :mod:`.contracts`)

Suppression: a finding is dropped when its line -- or the line directly
above it -- carries ``# lint: disable=<CODE>`` (comma-separated codes, or
``all``).  Suppressions are deliberate, reviewable markers: the linter is
heuristic by design and a justified suppression beats a weakened rule.

Baselines: ``lint_baseline.json`` maps rule code -> accepted count.  A run
is clean when no rule exceeds its baselined count; rules below baseline are
reported as available ratchet room (shrink the committed file).
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

#: code -> (title, fix hint).  The single source of truth the CLI, README
#: table, and tests enumerate.
RULES: Dict[str, Tuple[str, str]] = {
    # --- pass 1: jit hazards -------------------------------------------
    "JH101": ("host-sync-in-step-loop",
              "move the .item()/np.asarray()/block_until_ready() out of the "
              "per-iteration loop body; sync once per step, after dispatch"),
    "JH102": ("traced-python-branch",
              "a Python if/while/len on a traced value retraces per value; "
              "use jnp.where / lax.cond / lax.select, or hoist to a static"),
    "JH103": ("dynamic-shape-feeds-jit",
              "array shape derived from len()/max() of mutating batch state "
              "churns compiled shapes; pad to a fixed bucket set"),
    "JH104": ("missing-donate-on-pool-buffer",
              "jit over a pool/cache-sized buffer without donate_argnums "
              "copies the whole pool every call; donate the buffer"),
    "JH105": ("dict-order-pytree",
              "a dict built from a runtime-ordered iterable is a pytree "
              "whose structure depends on insertion order; sort the keys"),
    "JH106": ("jit-closure-over-mutable-state",
              "a jitted function reading an attribute that is reassigned "
              "outside __init__ bakes a stale constant (no retrace!); pass "
              "it as an argument"),
    # --- pass 2: page-ledger protocol (static) -------------------------
    "PL201": ("alloc-result-unchecked",
              "placement.alloc returns None when pages are short; check "
              "before indexing/extending the block table"),
    "PL202": ("acquire-without-release",
              "this module takes page references (alloc/ref) but never "
              "releases any (unref); every acquire path needs a release "
              "path"),
    "PL203": ("table-pop-without-release",
              "popping a request from page_table without unref()/spill "
              "extraction leaks its pages until process exit"),
    "PL204": ("deprecated-unconditional-free",
              "placement.free is the pre-refcount alias of unref; call "
              "unref so copy-on-write sharers are respected"),
    "PL205": ("spill-without-host-pin",
              "a tiered spill must pin the blob's bytes in the host ledger "
              "(live state may never be dropped); call host.pin"),
    "PL206": ("alloc-without-retry-escalation",
              "pool.register/grow/resume/fork and host.pin can fail "
              "transiently under pressure; wrap the call in a bounded "
              "retry / degradation path (retry_transient or an "
              "escalation wrapper), never assume success"),
    # --- pass 2: page-ledger protocol (runtime shadow ledger) ----------
    "PL250": ("ref-on-free-page",
              "taking a reference on a page that is not live "
              "(use-after-free / use-after-evict acquire)"),
    "PL251": ("double-free",
              "unref below zero: the page was already returned to the free "
              "list"),
    "PL252": ("free-with-live-sharers",
              "a page returned to the free list while the shadow ledger "
              "still sees outstanding references"),
    "PL253": ("double-alloc",
              "allocator handed out a page the shadow ledger already "
              "considers live"),
    "PL254": ("use-after-evict",
              "a block table references a page that is not live in the "
              "shadow ledger"),
    "PL255": ("teardown-leak",
              "pages still live at engine teardown with no owning request, "
              "spill blob, staged prefetch, or store node"),
    # --- pass 3: op-registry contracts ---------------------------------
    "RC301": ("op-missing-impl",
              "a registered op must override execute() and traffic(); the "
              "base class raises"),
    "RC302": ("op-traffic-invalid",
              "traffic(plan) returned a negative/NaN stream; byte "
              "descriptors must be non-negative finite floats"),
    "RC303": ("paged-traffic-not-page-aligned",
              "a paged-layout op's state traffic must be page-granular: "
              "constant within a page, stepping only at page boundaries"),
    "RC304": ("pallas-without-jnp-reference",
              "every pallas quadruple needs a jnp reference twin (parity "
              "tests and non-accelerated fallback)"),
    "RC305": ("config-not-covered",
              "model_traffic.decode_op_plans must enumerate this config's "
              "decode ops; serving traffic accounting is blind to it"),
}

_DISABLE_RE = re.compile(r"#\s*lint:\s*disable=([A-Za-z0-9_,\s]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    code: str
    message: str
    file: str
    line: int

    @property
    def family(self) -> str:
        return self.code[:2]

    @property
    def title(self) -> str:
        return RULES.get(self.code, ("?", ""))[0]

    @property
    def hint(self) -> str:
        return RULES.get(self.code, ("?", ""))[1]

    def render(self) -> str:
        return (f"{self.file}:{self.line}: {self.code} "
                f"[{self.title}] {self.message}\n"
                f"    hint: {self.hint}")

    def as_dict(self) -> dict:
        return {"code": self.code, "title": self.title, "file": self.file,
                "line": self.line, "message": self.message,
                "hint": self.hint}


def iter_python_files(paths: Sequence[str]) -> Iterator[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
        else:
            for root, dirs, names in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                for n in sorted(names):
                    if n.endswith(".py"):
                        yield os.path.join(root, n)


def suppressed_codes(source_lines: Sequence[str], line: int) -> set:
    """Codes disabled at 1-based ``line`` (same line or the line above)."""
    out: set = set()
    for ln in (line, line - 1):
        if 1 <= ln <= len(source_lines):
            m = _DISABLE_RE.search(source_lines[ln - 1])
            if m:
                out |= {c.strip()
                        for c in m.group(1).split(",") if c.strip()}
    return out


def apply_suppressions(findings: Iterable[Finding]) -> List[Finding]:
    """Drop findings whose source line carries a matching disable comment."""
    kept: List[Finding] = []
    cache: Dict[str, List[str]] = {}
    for f in findings:
        if f.file not in cache:
            try:
                with open(f.file) as fh:
                    cache[f.file] = fh.readlines()
            except OSError:
                cache[f.file] = []
        codes = suppressed_codes(cache[f.file], f.line)
        if f.code in codes or "all" in codes:
            continue
        kept.append(f)
    return kept


# ---------------------------------------------------------------------------
# baseline ratchet
# ---------------------------------------------------------------------------


def counts_by_code(findings: Iterable[Finding]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for f in findings:
        out[f.code] = out.get(f.code, 0) + 1
    return out


def load_baseline(path: str) -> Dict[str, int]:
    with open(path) as fh:
        data = json.load(fh)
    return {str(k): int(v) for k, v in data.get("counts", data).items()}


def write_baseline(path: str, findings: Iterable[Finding]) -> None:
    with open(path, "w") as fh:
        json.dump({"counts": counts_by_code(findings)}, fh, indent=2,
                  sort_keys=True)
        fh.write("\n")


def baseline_diff(findings: Iterable[Finding],
                  baseline: Dict[str, int]
                  ) -> Tuple[Dict[str, int], Dict[str, int]]:
    """(regressions, ratchet_room): rule -> count over / under baseline."""
    cur = counts_by_code(findings)
    over = {c: n - baseline.get(c, 0) for c, n in cur.items()
            if n > baseline.get(c, 0)}
    under = {c: b - cur.get(c, 0) for c, b in baseline.items()
             if cur.get(c, 0) < b}
    return over, under
