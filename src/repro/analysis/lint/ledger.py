"""Pass 2 (static half): page-ledger protocol checker (``PL20x``).

Checks the *call-site protocol* around the placement refcount API --
``alloc``/``ref``/``unref``/``free`` -- plus the tiered-pool host-pin
contract.  The runtime half (:mod:`.runtime`, ``PL25x``) catches what
static analysis cannot: actual refcount arithmetic.

Rules (receivers are matched by name -- a call counts as a ledger call
when it goes through something called ``placement``, e.g.
``self.placement.alloc(...)`` or a bare ``placement.ref(...)``):

  * ``PL201`` an ``alloc`` result consumed without a ``None`` guard --
    the allocator returns ``None`` under page pressure, not ``[]``;
  * ``PL202`` a module that acquires references (``alloc``/``ref``) but
    contains no release site (``unref``) at all;
  * ``PL203`` a function that pops a request from ``page_table`` without
    releasing (``unref``) or extracting to a spill -- a structural leak;
  * ``PL204`` any call to ``placement.free`` -- the pre-refcount alias;
    copy-on-write sharers require ``unref``;
  * ``PL205`` a ``spill`` method on a host-tiered class (one that touches
    ``self.host``) that never pins the blob bytes -- live state must not
    be droppable from the host cache;
  * ``PL206`` a transient-failure allocation call (``pool.register`` /
    ``grow``/``resume``/``fork``, ``host.pin``) outside any bounded
    retry / degradation wrapper -- these return falsy under pressure and
    the caller must escalate, not assume success.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Sequence, Set

from repro.analysis.lint.findings import Finding, apply_suppressions

_ACQUIRE = {"alloc", "ref"}
_RELEASE = {"unref"}

#: transient-failure allocation sites: (receiver name, attr names)
_TRANSIENT_SITES = (("pool", {"register", "grow", "resume", "fork"}),
                    ("host", {"pin"}))
#: identifier substrings that mark a retry/escalation context
_ESCALATION_MARKS = ("retry", "degrade", "escalate")


def _is_transient_alloc_call(node: ast.Call) -> bool:
    f = node.func
    if not isinstance(f, ast.Attribute):
        return False
    recv = f.value
    if isinstance(recv, ast.Name):
        recv_name = recv.id
    elif isinstance(recv, ast.Attribute):
        recv_name = recv.attr
    else:
        return False
    return any(recv_name == r and f.attr in ops
               for r, ops in _TRANSIENT_SITES)


def _has_escalation_context(fn, name: Optional[str]) -> bool:
    """The function is itself a retry/escalation wrapper (by name) or
    routes through one (references an identifier carrying a mark)."""
    idents = {(name or "").lower()}
    for node in ast.walk(fn):
        if isinstance(node, ast.Name):
            idents.add(node.id.lower())
        elif isinstance(node, ast.Attribute):
            idents.add(node.attr.lower())
    return any(m in ident for ident in idents for m in _ESCALATION_MARKS)


def _own_nodes(fn):
    """Walk ``fn`` without descending into nested function definitions
    (those are visited on their own, inheriting the parent's escalation
    context); lambdas stay part of the enclosing function."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            stack.extend(ast.iter_child_nodes(node))


def _is_placement_call(node: ast.Call, ops: Set[str]) -> bool:
    f = node.func
    if not (isinstance(f, ast.Attribute) and f.attr in ops):
        return False
    recv = f.value
    if isinstance(recv, ast.Name):
        return recv.id == "placement"
    if isinstance(recv, ast.Attribute):
        return recv.attr == "placement"
    return False


def _fn_name(node: ast.AST) -> Optional[str]:
    return node.name if isinstance(
        node, (ast.FunctionDef, ast.AsyncFunctionDef)) else None


def _guarded_names(fn: ast.AST) -> Set[str]:
    """Names that appear in any if/while/assert test within ``fn`` --
    the conservative notion of 'checked before use'."""
    out: Set[str] = set()
    for node in ast.walk(fn):
        test = None
        if isinstance(node, (ast.If, ast.While)):
            test = node.test
        elif isinstance(node, ast.Assert):
            test = node.test
        if test is not None:
            out |= {n.id for n in ast.walk(test) if isinstance(n, ast.Name)}
    return out


def _check_function(fn, path: str, host_tier_classes: Set[str],
                    cls: Optional[str], out: List[Finding],
                    escalated: bool = False) -> None:
    name = _fn_name(fn)
    guarded = _guarded_names(fn)
    has_release = False
    mentions_spill = "spill" in (name or "").lower()
    pins = False

    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if isinstance(f, ast.Attribute):
            # a direct host.pin or delegation to a pin helper
            # (e.g. _pin_with_retry) satisfies the spill contract
            low = f.attr.lower()
            if f.attr == "pin" or ("pin" in low and "unpin" not in low):
                pins = True
            if _is_placement_call(node, _RELEASE):
                has_release = True
            if _is_placement_call(node, {"free"}):
                out.append(Finding(
                    "PL204",
                    f"`placement.free` in `{name}` is the pre-refcount "
                    f"alias; copy-on-write sharers need `unref`",
                    path, node.lineno))
            if not mentions_spill:
                mentions_spill = "spill" in f.attr.lower()

    # PL201: alloc result assigned to a name never seen in a guard
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and \
                isinstance(node.value, ast.Call) and \
                _is_placement_call(node.value, {"alloc"}):
            targets = [t.id for t in node.targets if isinstance(t, ast.Name)]
            for t in targets:
                if t not in guarded:
                    out.append(Finding(
                        "PL201",
                        f"`{t} = placement.alloc(...)` in `{name}` is "
                        f"consumed without a None guard; alloc returns "
                        f"None under page pressure", path, node.lineno))

    # PL203: page_table.pop without a release path in the same function
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr == "pop" and \
                isinstance(node.func.value, ast.Attribute) and \
                node.func.value.attr == "page_table":
            if not (has_release or mentions_spill):
                out.append(Finding(
                    "PL203",
                    f"`page_table.pop` in `{name}` with no "
                    f"`placement.unref` or spill extraction on any path "
                    f"-- the popped request's pages leak",
                    path, node.lineno))

    # PL206: transient alloc/pin call with no retry/escalation context
    if not (escalated or _has_escalation_context(fn, name)):
        for node in _own_nodes(fn):
            if isinstance(node, ast.Call) and _is_transient_alloc_call(node):
                out.append(Finding(
                    "PL206",
                    f"`{ast.unparse(node.func)}` in `{name}` can fail "
                    f"transiently under pressure; route it through a "
                    f"bounded retry / degradation wrapper",
                    path, node.lineno))

    # PL205: host-tiered spill that never pins
    if cls in host_tier_classes and name and \
            name.lower().startswith("spill") and not pins:
        out.append(Finding(
            "PL205",
            f"`{cls}.{name}` spills on a host-tiered pool without "
            f"pinning the blob bytes (`host.pin`); the host cache may "
            f"drop live state", path, fn.lineno))


def lint_ledger_protocol(files: Sequence[str]) -> List[Finding]:
    out: List[Finding] = []
    for path in files:
        try:
            with open(path) as fh:
                src = fh.read()
            tree = ast.parse(src, filename=path)
        except (OSError, SyntaxError):
            continue

        # classes that touch self.host are host-tiered
        host_tier: Set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef):
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Attribute) and \
                            isinstance(sub.value, ast.Name) and \
                            sub.value.id == "self" and sub.attr == "host":
                        host_tier.add(node.name)
                        break

        acquires = releases = False
        first_acquire_line = 0
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                if _is_placement_call(node, _ACQUIRE):
                    if not acquires:
                        first_acquire_line = node.lineno
                    acquires = True
                elif _is_placement_call(node, _RELEASE):
                    releases = True

        def walk_scope(scope, cls: Optional[str], escalated: bool = False):
            for child in ast.iter_child_nodes(scope):
                if isinstance(child, ast.ClassDef):
                    walk_scope(child, child.name, escalated)
                elif isinstance(child,
                                (ast.FunctionDef, ast.AsyncFunctionDef)):
                    _check_function(child, path, host_tier, cls, out,
                                    escalated)
                    walk_scope(child, cls, escalated or
                               _has_escalation_context(child, child.name))

        walk_scope(tree, None)

        # PL202: module acquires but never releases
        if acquires and not releases:
            out.append(Finding(
                "PL202",
                "module takes page references (placement.alloc/ref) but "
                "contains no release site (placement.unref)",
                path, first_acquire_line))
    return apply_suppressions(out)
