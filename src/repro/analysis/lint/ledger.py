"""Pass 2 (static half): page-ledger protocol checker (``PL20x``).

Checks the *call-site protocol* around the placement refcount API --
``alloc``/``ref``/``unref``/``free`` -- plus the tiered-pool host-pin
contract.  The runtime half (:mod:`.runtime`, ``PL25x``) catches what
static analysis cannot: actual refcount arithmetic.

Rules (receivers are matched by name -- a call counts as a ledger call
when it goes through something called ``placement``, e.g.
``self.placement.alloc(...)`` or a bare ``placement.ref(...)``):

  * ``PL201`` an ``alloc`` result consumed without a ``None`` guard --
    the allocator returns ``None`` under page pressure, not ``[]``;
  * ``PL202`` a module that acquires references (``alloc``/``ref``) but
    contains no release site (``unref``) at all;
  * ``PL203`` a function that pops a request from ``page_table`` without
    releasing (``unref``) or extracting to a spill -- a structural leak;
  * ``PL204`` any call to ``placement.free`` -- the pre-refcount alias;
    copy-on-write sharers require ``unref``;
  * ``PL205`` a ``spill`` method on a host-tiered class (one that touches
    ``self.host``) that never pins the blob bytes -- live state must not
    be droppable from the host cache.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Sequence, Set

from repro.analysis.lint.findings import Finding, apply_suppressions

_ACQUIRE = {"alloc", "ref"}
_RELEASE = {"unref"}


def _is_placement_call(node: ast.Call, ops: Set[str]) -> bool:
    f = node.func
    if not (isinstance(f, ast.Attribute) and f.attr in ops):
        return False
    recv = f.value
    if isinstance(recv, ast.Name):
        return recv.id == "placement"
    if isinstance(recv, ast.Attribute):
        return recv.attr == "placement"
    return False


def _fn_name(node: ast.AST) -> Optional[str]:
    return node.name if isinstance(
        node, (ast.FunctionDef, ast.AsyncFunctionDef)) else None


def _guarded_names(fn: ast.AST) -> Set[str]:
    """Names that appear in any if/while/assert test within ``fn`` --
    the conservative notion of 'checked before use'."""
    out: Set[str] = set()
    for node in ast.walk(fn):
        test = None
        if isinstance(node, (ast.If, ast.While)):
            test = node.test
        elif isinstance(node, ast.Assert):
            test = node.test
        if test is not None:
            out |= {n.id for n in ast.walk(test) if isinstance(n, ast.Name)}
    return out


def _check_function(fn, path: str, host_tier_classes: Set[str],
                    cls: Optional[str], out: List[Finding]) -> None:
    name = _fn_name(fn)
    guarded = _guarded_names(fn)
    has_release = False
    mentions_spill = "spill" in (name or "").lower()
    pins = False

    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if isinstance(f, ast.Attribute):
            if f.attr == "pin":
                pins = True
            if _is_placement_call(node, _RELEASE):
                has_release = True
            if _is_placement_call(node, {"free"}):
                out.append(Finding(
                    "PL204",
                    f"`placement.free` in `{name}` is the pre-refcount "
                    f"alias; copy-on-write sharers need `unref`",
                    path, node.lineno))
            if not mentions_spill:
                mentions_spill = "spill" in f.attr.lower()

    # PL201: alloc result assigned to a name never seen in a guard
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and \
                isinstance(node.value, ast.Call) and \
                _is_placement_call(node.value, {"alloc"}):
            targets = [t.id for t in node.targets if isinstance(t, ast.Name)]
            for t in targets:
                if t not in guarded:
                    out.append(Finding(
                        "PL201",
                        f"`{t} = placement.alloc(...)` in `{name}` is "
                        f"consumed without a None guard; alloc returns "
                        f"None under page pressure", path, node.lineno))

    # PL203: page_table.pop without a release path in the same function
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr == "pop" and \
                isinstance(node.func.value, ast.Attribute) and \
                node.func.value.attr == "page_table":
            if not (has_release or mentions_spill):
                out.append(Finding(
                    "PL203",
                    f"`page_table.pop` in `{name}` with no "
                    f"`placement.unref` or spill extraction on any path "
                    f"-- the popped request's pages leak",
                    path, node.lineno))

    # PL205: host-tiered spill that never pins
    if cls in host_tier_classes and name and \
            name.lower().startswith("spill") and not pins:
        out.append(Finding(
            "PL205",
            f"`{cls}.{name}` spills on a host-tiered pool without "
            f"pinning the blob bytes (`host.pin`); the host cache may "
            f"drop live state", path, fn.lineno))


def lint_ledger_protocol(files: Sequence[str]) -> List[Finding]:
    out: List[Finding] = []
    for path in files:
        try:
            with open(path) as fh:
                src = fh.read()
            tree = ast.parse(src, filename=path)
        except (OSError, SyntaxError):
            continue

        # classes that touch self.host are host-tiered
        host_tier: Set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef):
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Attribute) and \
                            isinstance(sub.value, ast.Name) and \
                            sub.value.id == "self" and sub.attr == "host":
                        host_tier.add(node.name)
                        break

        acquires = releases = False
        first_acquire_line = 0
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                if _is_placement_call(node, _ACQUIRE):
                    if not acquires:
                        first_acquire_line = node.lineno
                    acquires = True
                elif _is_placement_call(node, _RELEASE):
                    releases = True

        def walk_scope(scope, cls: Optional[str]):
            for child in ast.iter_child_nodes(scope):
                if isinstance(child, ast.ClassDef):
                    walk_scope(child, child.name)
                elif isinstance(child,
                                (ast.FunctionDef, ast.AsyncFunctionDef)):
                    _check_function(child, path, host_tier, cls, out)
                    walk_scope(child, cls)

        walk_scope(tree, None)

        # PL202: module acquires but never releases
        if acquires and not releases:
            out.append(Finding(
                "PL202",
                "module takes page references (placement.alloc/ref) but "
                "contains no release site (placement.unref)",
                path, first_acquire_line))
    return apply_suppressions(out)
