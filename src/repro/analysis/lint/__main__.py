"""CLI: ``python -m repro.analysis.lint src/ [--format json] [--baseline f]``.

Exit codes: 0 clean (or within baseline), 1 findings over baseline,
2 bad usage.  ``--write-baseline`` regenerates ``lint_baseline.json`` from
the current findings -- use it once after fixing a rule's sites, then
commit the shrunken file (CI allows the baseline to shrink, never grow).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.lint import (RULES, baseline_diff, load_baseline,
                                 run_lint, write_baseline)
from repro.analysis.lint.findings import counts_by_code


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="jit-hazard linter, page-ledger protocol checker, and "
                    "op-registry contract checker")
    ap.add_argument("paths", nargs="+",
                    help="files or directories of .py files to lint")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--baseline", metavar="FILE",
                    help="accepted per-rule finding counts; fail only on "
                         "counts above the baseline")
    ap.add_argument("--write-baseline", metavar="FILE",
                    help="write current per-rule counts to FILE and exit 0")
    ap.add_argument("--no-contracts", action="store_true",
                    help="skip pass 3 (keeps the run purely static; no "
                         "repro import needed)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for code, (title, hint) in sorted(RULES.items()):
            print(f"{code}  {title}\n       {hint}")
        return 0

    findings = run_lint(args.paths,
                        include_contracts=not args.no_contracts)
    counts = counts_by_code(findings)

    if args.write_baseline:
        write_baseline(args.write_baseline, findings)
        print(f"wrote baseline ({sum(counts.values())} finding(s), "
              f"{len(counts)} rule(s)) to {args.write_baseline}")
        return 0

    regressions, ratchet_room = {}, {}
    if args.baseline:
        try:
            baseline = load_baseline(args.baseline)
        except (OSError, ValueError) as e:
            print(f"error: cannot read baseline {args.baseline}: {e}",
                  file=sys.stderr)
            return 2
        regressions, ratchet_room = baseline_diff(findings, baseline)
        failing = bool(regressions)
    else:
        failing = bool(findings)

    if args.format == "json":
        print(json.dumps({
            "findings": [f.as_dict() for f in findings],
            "counts": counts,
            "regressions": regressions,
            "ratchet_room": ratchet_room,
            "ok": not failing,
        }, indent=2, sort_keys=True))
        return 1 if failing else 0

    for f in findings:
        print(f.render())
    n = len(findings)
    print(f"\n{n} finding(s) across {len(counts)} rule(s)"
          + (f" (baseline: {args.baseline})" if args.baseline else ""))
    if regressions:
        for code, over in sorted(regressions.items()):
            print(f"  REGRESSION {code}: {over} new finding(s) over "
                  f"baseline")
    if ratchet_room:
        room = ", ".join(f"{c}-{n}" for c, n in sorted(ratchet_room.items()))
        print(f"  ratchet room (shrink the baseline): {room}")
    return 1 if failing else 0


if __name__ == "__main__":
    sys.exit(main())
