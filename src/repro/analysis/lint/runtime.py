"""Runtime shadow-ledger sanitizer (``PL25x``).

A :class:`ShadowLedger` mirrors every refcount transition the real
allocator performs -- alloc, ref, unref, free -- in an independent
bookkeeping structure, and raises :class:`SanitizerError` the moment the
two disagree:

  * ``PL250`` ref on a page that is not live (use-after-free acquire)
  * ``PL251`` unref below zero (double-free)
  * ``PL252`` page returned to the free list with live sharers
  * ``PL253`` allocator handed out an already-live page (double-alloc)
  * ``PL254`` a block table references a non-live page (use-after-evict)
  * ``PL255`` pages still live at engine teardown (leak)

Enable with ``REPRO_SANITIZE=1``: :class:`BankAwarePlacement
<repro.serving.memory.placement.BankAwarePlacement>` attaches a ledger to
itself at construction and calls the hooks from ``alloc``/``ref``/``unref``.
The hooks are O(pages touched) dict updates -- roughly 2-5% overhead on the
serving smoke tests, negligible next to a device step.

This module must stay import-light (stdlib only): ``placement`` imports it
lazily, and importing anything from ``repro.serving`` here would cycle.
"""
from __future__ import annotations

import os
from typing import Dict, Iterable, List, Optional

_ENV_FLAG = "REPRO_SANITIZE"


def sanitize_enabled() -> bool:
    """True when the shadow-ledger sanitizer is switched on via env."""
    return os.environ.get(_ENV_FLAG, "").strip() not in ("", "0", "false")


class SanitizerError(AssertionError):
    """A shadow-ledger violation.  ``code`` is the ``PL25x`` rule id."""

    def __init__(self, code: str, message: str):
        super().__init__(f"{code}: {message}")
        self.code = code
        self.message = message


class ShadowLedger:
    """Independent refcount mirror for one placement/allocator instance."""

    def __init__(self, n_pages: Optional[int] = None):
        self.n_pages = n_pages
        self._rc: Dict[int, int] = {}       # live page -> shadow refcount
        self.events = 0                     # transitions observed

    # -- transition hooks (called by the real allocator) ---------------

    def on_alloc(self, pages: Iterable[int]) -> None:
        self.events += 1
        for pid in pages:
            if pid in self._rc:
                raise SanitizerError(
                    "PL253", f"page {pid} allocated while already live "
                             f"(shadow rc={self._rc[pid]})")
            if self.n_pages is not None and not 0 <= pid < self.n_pages:
                raise SanitizerError(
                    "PL253", f"allocator produced out-of-range page {pid} "
                             f"(pool has {self.n_pages})")
            self._rc[pid] = 1

    def on_ref(self, pages: Iterable[int]) -> None:
        self.events += 1
        for pid in pages:
            if pid not in self._rc:
                raise SanitizerError(
                    "PL250", f"ref taken on non-live page {pid} "
                             f"(use-after-free acquire)")
            self._rc[pid] += 1

    def pre_unref(self, pages: Iterable[int]) -> None:
        """Validate an unref *before* the real allocator mutates, so a
        double-free raises ``PL251`` instead of the allocator's KeyError.
        Simulates on a copy: duplicate page ids within one call count."""
        sim = dict(self._rc)
        for pid in pages:
            rc = sim.get(pid, 0)
            if rc <= 0:
                raise SanitizerError(
                    "PL251", f"unref of page {pid} below zero (double-free)")
            sim[pid] = rc - 1

    def on_unref(self, pages: Iterable[int],
                 freed: Iterable[int]) -> None:
        """``freed`` is the subset the real allocator returned to the free
        list; the shadow ledger independently decides who *should* free."""
        self.events += 1
        freed_set = set(freed)
        for pid in pages:
            rc = self._rc.get(pid)
            if rc is None or rc <= 0:
                raise SanitizerError(
                    "PL251", f"unref of page {pid} below zero (double-free)")
            self._rc[pid] = rc - 1
            if self._rc[pid] == 0:
                if pid not in freed_set:
                    raise SanitizerError(
                        "PL251", f"page {pid} reached shadow rc=0 but the "
                                 f"allocator did not free it (leak-by-"
                                 f"divergence)")
                del self._rc[pid]
            elif pid in freed_set:
                raise SanitizerError(
                    "PL252", f"page {pid} returned to the free list with "
                             f"{self._rc[pid]} live sharer(s)")
        stray = freed_set - set(pages)
        if stray:
            raise SanitizerError(
                "PL252", f"allocator freed page(s) {sorted(stray)} that "
                         f"were not part of this unref")

    # -- queries --------------------------------------------------------

    def refcount(self, pid: int) -> int:
        return self._rc.get(pid, 0)

    def live_pages(self) -> List[int]:
        return sorted(self._rc)

    def check_live(self, pages: Iterable[int], what: str = "block table"
                   ) -> None:
        """``PL254``: every page a consumer is about to address must be
        live.  Called on block-table construction before a decode step."""
        dead = [pid for pid in pages if pid not in self._rc]
        if dead:
            raise SanitizerError(
                "PL254", f"{what} references non-live page(s) {dead} "
                         f"(use-after-evict)")

    def assert_no_leaks(self, expected_live: Iterable[int] = (),
                        what: str = "engine teardown") -> None:
        """``PL255``: at teardown, every live page must have a named owner
        (request block table, spill extraction, store node, staged
        prefetch).  ``expected_live`` is the union of those owners' pages."""
        orphans = sorted(set(self._rc) - set(expected_live))
        if orphans:
            raise SanitizerError(
                "PL255", f"{len(orphans)} page(s) still live at {what} "
                         f"with no owner: {orphans[:16]}"
                         f"{'...' if len(orphans) > 16 else ''}")


def attach(placement) -> Optional[ShadowLedger]:
    """Attach a ledger to a placement instance when sanitizing is on.

    Returns the ledger (also stored as ``placement._shadow``), or None when
    ``REPRO_SANITIZE`` is unset.
    """
    if not sanitize_enabled():
        return None
    ledger = ShadowLedger(n_pages=getattr(placement, "n_pages", None))
    placement._shadow = ledger
    return ledger
