"""Pass 3: op-registry contract checker (``RC3xx``).

Unlike passes 1-2 this pass is *live*, not AST-based: it imports the ops
package, walks every registered (kind x backend x format x layout)
quadruple, builds a canonical plan, and checks the protocol the cost models
rely on:

  * ``RC301`` the implementation overrides ``execute`` and ``traffic``
    (the ``SpuOp`` base raises ``NotImplementedError``);
  * ``RC302`` ``traffic(plan)`` returns non-negative, finite byte streams
    and a plan round-trip (``registry.traffic``) agrees with the op's own;
  * ``RC303`` paged-layout state traffic is page-granular: constant within
    a page (``T = PAGE_TOKENS+1`` vs ``T = 2*PAGE_TOKENS`` must read the
    same state bytes) -- pages stream whole or not at all;
  * ``RC304`` every pallas quadruple has a jnp reference twin (parity
    tests and the non-accelerated fallback depend on it);
  * ``RC305`` ``model_traffic.decode_op_plans`` covers every config in
    ``repro.configs`` for both layouts, and every plan it emits resolves
    to a registered op.

Findings point at the implementing class's source line where possible, so
``file:line`` output stays clickable for live-object checks too.
"""
from __future__ import annotations

import inspect
import math
import os
from typing import Dict, List, Tuple

from repro.analysis.lint.findings import Finding

#: canonical dims covering every kind's traffic() accessors (``Kq`` is the
#: spec_verify query width; extra dims are inert for the other kinds)
_CANON_DIMS = dict(B=2, T=None, KVH=4, dk=64, dv=64, n=1, H=8, Kq=4)


def _loc(obj) -> Tuple[str, int]:
    try:
        path = inspect.getsourcefile(type(obj)) or "<registry>"
        _, line = inspect.getsourcelines(type(obj))
        return os.path.relpath(path), line
    except (OSError, TypeError):
        return "<registry>", 1


def _plan_for(op, fmt: str, T: int):
    from repro.ops.base import StateQuantConfig
    dims = dict(_CANON_DIMS, T=T)
    quant = StateQuantConfig(fmt=fmt, rounding="nearest", backend=op.backend)
    return op.plan(dims, quant)


def _streams(t) -> Dict[str, float]:
    return {"state_read": t.state_read, "state_write": t.state_write,
            "operand_read": t.operand_read, "output_write": t.output_write}


def lint_registry_contracts() -> List[Finding]:
    from repro.core.paged import PAGE_TOKENS
    from repro.ops import registry
    from repro.ops.base import SpuOp
    import repro.ops.attention      # noqa: F401  (populate the registry)
    import repro.ops.paged_ops      # noqa: F401
    import repro.ops.spec_verify    # noqa: F401
    import repro.ops.state_update   # noqa: F401

    out: List[Finding] = []
    quads = registry.registered()

    for kind, backend, fmt, layout in quads:
        op = registry.get_op(kind, backend, fmt, layout)
        path, line = _loc(op)
        label = f"{kind}[{backend}:{fmt}:{layout}]"

        # RC301: protocol overrides
        missing = [m for m in ("execute", "traffic")
                   if getattr(type(op), m) is getattr(SpuOp, m)]
        if missing:
            out.append(Finding(
                "RC301", f"{label} does not override {missing}; the base "
                f"class raises NotImplementedError at dispatch",
                path, line))
            continue

        # RC302: descriptor sanity + registry round-trip agreement
        try:
            plan = _plan_for(op, fmt, T=2 * PAGE_TOKENS)
            t = op.traffic(plan)
        except Exception as e:   # a contract checker must not crash
            out.append(Finding(
                "RC302", f"{label} traffic(plan) raised {type(e).__name__}: "
                f"{e}", path, line))
            continue
        bad = {k: v for k, v in _streams(t).items()
               if not math.isfinite(v) or v < 0}
        if bad:
            out.append(Finding(
                "RC302", f"{label} traffic streams invalid: {bad}",
                path, line))
        rt = registry.traffic(plan)
        if _streams(rt) != _streams(t):
            out.append(Finding(
                "RC302", f"{label} registry.traffic(plan) disagrees with "
                f"the op's own traffic() -- plan round-trip is lossy",
                path, line))

        # RC303: paged traffic is page-granular in the cached length T
        if layout == "paged":
            t_lo = op.traffic(_plan_for(op, fmt, T=PAGE_TOKENS + 1))
            t_hi = op.traffic(_plan_for(op, fmt, T=2 * PAGE_TOKENS))
            if not math.isclose(t_lo.state_read, t_hi.state_read,
                                rel_tol=1e-9, abs_tol=1e-6):
                out.append(Finding(
                    "RC303", f"{label} state_read changes within a page "
                    f"(T={PAGE_TOKENS + 1}: {t_lo.state_read:.1f}B vs "
                    f"T={2 * PAGE_TOKENS}: {t_hi.state_read:.1f}B); paged "
                    f"ops stream whole {PAGE_TOKENS}-token pages",
                    path, line))

    # RC304: pallas quadruples need a jnp reference twin
    have = set(quads)
    for kind, backend, fmt, layout in quads:
        if backend != "pallas":
            continue
        if (kind, "jnp", fmt, layout) not in have:
            op = registry.get_op(kind, backend, fmt, layout)
            path, line = _loc(op)
            out.append(Finding(
                "RC304", f"{kind}[pallas:{fmt}:{layout}] has no jnp "
                f"reference twin; parity tests and the fallback path "
                f"cannot cover it", path, line))

    # RC306: spec_verify amortizes the cache stream -- one verify pass over
    # Kq query positions must cost at most what Kq sequential attn_decode
    # steps would read (the speculative path can never be *worse* traffic
    # than the steps it replaces), and every spec_verify quadruple needs an
    # equivalent attn_decode to amortize against
    Kq = _CANON_DIMS["Kq"]
    for kind, backend, fmt, layout in quads:
        if kind != "spec_verify":
            continue
        op = registry.get_op(kind, backend, fmt, layout)
        path, line = _loc(op)
        label = f"{kind}[{backend}:{fmt}:{layout}]"
        if ("attn_decode", backend, fmt, layout) not in have:
            out.append(Finding(
                "RC306", f"{label} has no equivalent attn_decode quadruple; "
                f"the verify pass replaces sequential decode steps and must "
                f"have a baseline to amortize against", path, line))
            continue
        ad = registry.get_op("attn_decode", backend, fmt, layout)
        try:
            sv_t = op.traffic(_plan_for(op, fmt, T=2 * PAGE_TOKENS))
            ad_t = ad.traffic(_plan_for(ad, fmt, T=2 * PAGE_TOKENS))
        except Exception as e:
            out.append(Finding(
                "RC306", f"{label} traffic comparison raised "
                f"{type(e).__name__}: {e}", path, line))
            continue
        if sv_t.state_read > Kq * ad_t.state_read + 1e-6:
            out.append(Finding(
                "RC306", f"{label} reads {sv_t.state_read:.1f}B of cache "
                f"for Kq={Kq} positions, more than the {Kq} sequential "
                f"attn_decode steps it replaces "
                f"({Kq} x {ad_t.state_read:.1f}B); the verify pass must "
                f"stream the cache at most once per step it amortizes",
                path, line))

    # RC305: decode_op_plans covers every config, both layouts
    out += _check_config_coverage()
    return out


def _check_config_coverage() -> List[Finding]:
    from repro import configs
    from repro.ops import model_traffic, registry

    out: List[Finding] = []
    cfg_path = os.path.relpath(inspect.getsourcefile(configs))
    for name in configs.ALL_ARCHS:
        try:
            cfg = configs.get_smoke_config(name)
        except Exception as e:
            out.append(Finding(
                "RC305", f"config {name!r} failed to build: "
                f"{type(e).__name__}: {e}", cfg_path, 1))
            continue
        for layout in ("dense", "paged"):
            try:
                entries = model_traffic.decode_op_plans(
                    cfg, batch=2, seq_len=256, layout=layout)
            except Exception as e:
                out.append(Finding(
                    "RC305", f"decode_op_plans({name!r}, layout={layout!r}) "
                    f"raised {type(e).__name__}: {e}", cfg_path, 1))
                continue
            if not entries:
                out.append(Finding(
                    "RC305", f"decode_op_plans({name!r}, layout={layout!r}) "
                    f"is empty -- serving traffic accounting is blind to "
                    f"this config", cfg_path, 1))
            for e in entries:
                quad = (e.plan.kind, e.plan.backend, e.plan.fmt,
                        e.plan.layout)
                if quad not in set(registry.registered()):
                    out.append(Finding(
                        "RC305", f"decode_op_plans({name!r}) emitted a plan "
                        f"for unregistered quadruple {quad}", cfg_path, 1))
    return out
