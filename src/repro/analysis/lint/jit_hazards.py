"""Pass 1: AST jit-hazard linter (``JH1xx``).

Finds the retrace/perf hazards that keep resurfacing in the serving step
loop, *before* a benchmark run has to discover them as a 1s p99 step:

  * ``JH101`` host syncs (``.item()``, ``np.asarray``,
    ``block_until_ready``) inside per-row loops of step/decode functions;
  * ``JH102`` Python ``if``/``while``/``len`` on traced values inside
    jit-compiled functions;
  * ``JH103`` array shapes derived from ``len()``/``max()`` of mutating
    batch state feeding jitted callables (batch-composition shape churn);
  * ``JH104`` ``jax.jit`` over pool/cache-sized buffers without donation;
  * ``JH105`` dict pytrees built from runtime-ordered (set-derived)
    iterables inside jitted functions;
  * ``JH106`` jitted functions reading ``self`` attributes that some other
    method reassigns -- the closure bakes a stale constant and *never*
    retraces.

Reachability: roots are every function named in a ``jax.jit(...)`` /
``pl.pallas_call(...)`` call or decoration, or handed to a
``RecompileWatcher`` wrap site (``obs.wrap_jit(...)`` / ``watcher.wrap``);
the walk closes over same-module calls (``f(...)`` and ``self.f(...)``).
The rule set is deliberately heuristic -- suppress justified sites with
``# lint: disable=JH1xx`` and let the committed baseline ratchet the rest.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.lint.findings import Finding, apply_suppressions

#: parameters that are static configuration under jit, never traced
_STATIC_PARAM_RE = re.compile(
    r"^(self|cls|cfg|config|.*_cfg|mesh_axes|axis.*|name|mode|fmt|kind|"
    r"backend|layout|plan|quant|options.*|static.*|spec|paging|topo.*)$")

#: attribute reads that return static (trace-time) metadata, killing taint
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "aval"}

#: step-loop function names rule JH101 applies to
_STEP_FN_RE = re.compile(r"(^|_)(step|decode|prefill|run|loop)", re.I)

#: host-synchronizing calls (attribute form / function form)
_SYNC_ATTRS = {"item", "block_until_ready", "copy_to_host_async"}
_SYNC_FUNCS = {("np", "asarray"), ("np", "array"), ("numpy", "asarray"),
               ("numpy", "array"), ("jax", "device_get")}

#: array constructors whose first argument is a shape
_SHAPE_CTORS = {"zeros", "ones", "empty", "full", "zeros_like"}
#: converters whose argument's *slicing* determines the shape
_CONVERTERS = {"asarray", "array"}

#: buffer parameter names whose jit should donate (pool-sized operands)
_POOL_PARAMS = {"pools", "pool", "caches", "buffers"}


def _name_of(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _dotted(node: ast.AST) -> Optional[Tuple[str, str]]:
    """("np", "zeros") for ``np.zeros`` / ("", "zeros") for bare calls."""
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name):
        return node.value.id, node.attr
    if isinstance(node, ast.Name):
        return "", node.id
    return None


class _FunctionInfo:
    def __init__(self, node: ast.AST, qualname: str,
                 cls: Optional[str]):
        self.node = node
        self.qualname = qualname
        self.cls = cls
        self.calls: Set[str] = set()        # local callee names


class _ModuleIndex(ast.NodeVisitor):
    """One file's functions, jit roots, call edges, and class attr writes."""

    def __init__(self):
        self.functions: Dict[str, _FunctionInfo] = {}   # name -> info
        self.jit_roots: Set[str] = set()                # local fn names
        self.jit_calls: List[ast.Call] = []             # jax.jit(...) sites
        #: class -> attrs assigned outside __init__
        self.mutable_attrs: Dict[str, Set[str]] = {}
        self._cls_stack: List[str] = []
        self._fn_stack: List[str] = []

    # -- structure ---------------------------------------------------

    def visit_ClassDef(self, node: ast.ClassDef):
        self._cls_stack.append(node.name)
        self.mutable_attrs.setdefault(node.name, set())
        self.generic_visit(node)
        self._cls_stack.pop()

    def _visit_fn(self, node):
        cls = self._cls_stack[-1] if self._cls_stack else None
        qual = f"{cls}.{node.name}" if cls else node.name
        info = _FunctionInfo(node, qual, cls)
        # last definition wins, mirroring runtime shadowing
        self.functions[node.name] = info
        for dec in node.decorator_list:
            if self._is_jit_expr(dec):
                self.jit_roots.add(node.name)
        self._fn_stack.append(node.name)
        self.generic_visit(node)
        self._fn_stack.pop()

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    # -- jit sites / call edges / attr writes ------------------------

    @staticmethod
    def _is_jit_expr(node: ast.AST) -> bool:
        d = _dotted(node)
        if d in (("jax", "jit"), ("", "jit"), ("pl", "pallas_call"),
                 ("", "pallas_call")):
            return True
        if isinstance(node, ast.Call):
            return _ModuleIndex._is_jit_expr(node.func)
        return False

    def _root_names(self, node: ast.AST) -> Iterable[str]:
        """Local function names an expression hands to jit."""
        if isinstance(node, ast.Name):
            yield node.id
        elif isinstance(node, ast.Attribute):
            # self.method -> method; obj.attr.method unresolvable
            if isinstance(node.value, ast.Name) and node.value.id == "self":
                yield node.attr
        elif isinstance(node, ast.Call):
            d = _dotted(node.func)
            if d and d[1] == "partial" and node.args:
                yield from self._root_names(node.args[0])

    def visit_Call(self, node: ast.Call):
        d = _dotted(node.func)
        if d in (("jax", "jit"), ("", "jit")):
            self.jit_calls.append(node)
            if node.args:
                for n in self._root_names(node.args[0]):
                    self.jit_roots.add(n)
        elif d in (("pl", "pallas_call"), ("", "pallas_call")):
            if node.args:
                for n in self._root_names(node.args[0]):
                    self.jit_roots.add(n)
        elif d and d[1] in ("wrap_jit", "wrap") and node.args:
            # RecompileWatcher wrap sites are jit sites by construction
            for n in self._root_names(node.args[0]):
                self.jit_roots.add(n)
        if self._fn_stack:
            callee = _name_of(node.func)
            if callee:
                self.functions[self._fn_stack[-1]].calls.add(callee)
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign):
        if self._fn_stack and self._cls_stack:
            fn = self._fn_stack[-1]
            if fn != "__init__":
                for t in node.targets:
                    if (isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and t.value.id == "self"):
                        self.mutable_attrs[self._cls_stack[-1]].add(t.attr)
        self.generic_visit(node)

    def reachable(self) -> Set[str]:
        seen: Set[str] = set()
        todo = [n for n in self.jit_roots if n in self.functions]
        while todo:
            n = todo.pop()
            if n in seen:
                continue
            seen.add(n)
            todo.extend(c for c in self.functions[n].calls
                        if c in self.functions and c not in seen)
        return seen


# ---------------------------------------------------------------------------
# taint: names derived from traced parameters / dynamic batch state
# ---------------------------------------------------------------------------


def _param_names(fn) -> List[str]:
    a = fn.args
    return [p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)]


def _collect_tainted(fn, seed: Set[str]) -> Set[str]:
    """Fixed point of 'assigned from an expression mentioning a tainted
    name' -- with taint killed through static metadata attributes."""
    tainted = set(seed)

    def expr_tainted(e: ast.AST) -> bool:
        for sub in ast.walk(e):
            if isinstance(sub, ast.Attribute) and sub.attr in _STATIC_ATTRS:
                return False if sub is e else expr_tainted(sub.value) and False
        return any(isinstance(sub, ast.Name) and sub.id in tainted
                   for sub in ast.walk(e))

    changed = True
    while changed:
        changed = False
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and expr_tainted(node.value):
                for t in node.targets:
                    for n in ast.walk(t):
                        if (isinstance(n, ast.Name)
                                and n.id not in tainted):
                            tainted.add(n.id)
                            changed = True
            elif isinstance(node, ast.AugAssign) and \
                    isinstance(node.target, ast.Name) and \
                    expr_tainted(node.value) and \
                    node.target.id not in tainted:
                tainted.add(node.target.id)
                changed = True
    return tainted


def _mentions(e: ast.AST, names: Set[str]) -> bool:
    for sub in ast.walk(e):
        if isinstance(sub, ast.Attribute) and sub.attr in _STATIC_ATTRS:
            continue
        if isinstance(sub, ast.Name) and sub.id in names:
            # killed when only reached through .shape/.ndim/.dtype --
            # approximate: a Compare/BinOp over x.shape[i] never taints
            parent_static = False
            return not parent_static
    return False


def _static_guard(test: ast.AST, tainted: Set[str]) -> bool:
    """True for tests that are static under jit: `x is None`,
    isinstance(x, T), or metadata-only comparisons."""
    if isinstance(test, ast.Compare) and any(
            isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops):
        return True
    if isinstance(test, ast.Call):
        d = _dotted(test.func)
        if d and d[1] in ("isinstance", "hasattr", "callable"):
            return True
    for sub in ast.walk(test):
        if isinstance(sub, ast.Attribute) and sub.attr in _STATIC_ATTRS:
            return True
    return False


# ---------------------------------------------------------------------------
# the rules
# ---------------------------------------------------------------------------


def _check_jitted_fn(info: _FunctionInfo, idx: _ModuleIndex, path: str,
                     out: List[Finding]) -> None:
    fn = info.node
    seed = {p for p in _param_names(fn)
            if not _STATIC_PARAM_RE.match(p)}
    tainted = _collect_tainted(fn, seed)

    for node in ast.walk(fn):
        # JH102: Python control flow on traced values
        if isinstance(node, (ast.If, ast.While)):
            t = node.test
            if _mentions(t, tainted) and not _static_guard(t, tainted):
                out.append(Finding(
                    "JH102",
                    f"`{info.qualname}` branches in Python on a value "
                    f"derived from traced argument(s) "
                    f"{sorted(seed & tainted) or sorted(seed)}",
                    path, node.lineno))
        elif isinstance(node, ast.Call):
            d = _dotted(node.func)
            # JH102: len()/int()/bool() concretizing a traced value
            if d and d[0] == "" and d[1] in ("len", "int", "bool", "float") \
                    and node.args and _mentions(node.args[0], tainted):
                out.append(Finding(
                    "JH102",
                    f"`{d[1]}()` of a traced value in jitted "
                    f"`{info.qualname}`", path, node.lineno))
            # JH105: runtime-ordered dict pytrees
            elif d and d == ("", "dict") and _set_derived(node):
                out.append(Finding(
                    "JH105",
                    f"dict pytree built from a set-derived iterable in "
                    f"jitted `{info.qualname}`", path, node.lineno))
        elif isinstance(node, ast.DictComp) and _set_derived(node):
            out.append(Finding(
                "JH105",
                f"dict-comprehension pytree over a set-derived iterable "
                f"in jitted `{info.qualname}`", path, node.lineno))
        # JH106: stale closure over mutable enclosing state
        elif isinstance(node, ast.Attribute) and \
                isinstance(node.ctx, ast.Load) and \
                isinstance(node.value, ast.Name) and \
                node.value.id == "self" and info.cls is not None and \
                node.attr in idx.mutable_attrs.get(info.cls, ()):
            out.append(Finding(
                "JH106",
                f"jitted `{info.qualname}` reads `self.{node.attr}`, "
                f"which other methods reassign -- the traced value is a "
                f"stale constant", path, node.lineno))


def _set_derived(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Set):
            return True
        if isinstance(sub, ast.Call):
            d = _dotted(sub.func)
            if d in (("", "set"), ("", "frozenset")):
                return True
    return False


def _check_step_loops(info: _FunctionInfo, path: str,
                      out: List[Finding]) -> None:
    """JH101: host syncs inside per-row loops of step/decode functions."""
    for loop in ast.walk(info.node):
        if not isinstance(loop, (ast.For, ast.While)):
            continue
        for node in ast.walk(loop):
            if not isinstance(node, ast.Call):
                continue
            d = _dotted(node.func)
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr in _SYNC_ATTRS) or d in _SYNC_FUNCS:
                what = d[1] if d else node.func.attr
                out.append(Finding(
                    "JH101",
                    f"host sync `{what}` inside a per-iteration loop of "
                    f"step function `{info.qualname}` -- one device "
                    f"round-trip per row, per step", path, node.lineno))


def _check_dynamic_shapes(info: _FunctionInfo, path: str,
                          out: List[Finding]) -> None:
    """JH103: array shapes / slices sized by len()/max() of batch state."""
    fn = info.node
    dyn: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and _has_dyn_size_call(node.value):
            for t in node.targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name):
                        dyn.add(n.id)
    # second round: names assigned from expressions over dyn names
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and any(
                isinstance(s, ast.Name) and s.id in dyn
                for s in ast.walk(node.value)):
            for t in node.targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name):
                        dyn.add(n.id)

    def dynamic(e: ast.AST) -> bool:
        return _has_dyn_size_call(e) or any(
            isinstance(s, ast.Name) and s.id in dyn for s in ast.walk(e))

    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        d = _dotted(node.func)
        if not d:
            continue
        if d[1] in _SHAPE_CTORS and node.args and dynamic(node.args[0]):
            out.append(Finding(
                "JH103",
                f"`{d[0] + '.' if d[0] else ''}{d[1]}` in "
                f"`{info.qualname}` sized by len()/max() of mutating "
                f"batch state -- compiled shapes churn with batch "
                f"composition", path, node.lineno))
        elif d[1] in _CONVERTERS and node.args and any(
                isinstance(s, ast.Subscript)
                and isinstance(s.slice, ast.Slice)
                and any(b is not None and dynamic(b)
                        for b in (s.slice.lower, s.slice.upper))
                for s in ast.walk(node.args[0])):
            out.append(Finding(
                "JH103",
                f"`{d[1]}` over a dynamically sliced sequence in "
                f"`{info.qualname}` -- the downstream jit compiles one "
                f"executable per distinct length", path, node.lineno))


def _has_dyn_size_call(e: ast.AST) -> bool:
    for sub in ast.walk(e):
        if isinstance(sub, ast.Call):
            d = _dotted(sub.func)
            if d in (("", "len"), ("", "max"), ("", "min")):
                return True
    return False


def _check_jit_donation(idx: _ModuleIndex, path: str,
                        out: List[Finding]) -> None:
    """JH104: jax.jit over resolvable pool-buffer functions, no donate."""
    for call in idx.jit_calls:
        if any(kw.arg in ("donate_argnums", "donate_argnames")
               for kw in call.keywords):
            continue
        if not call.args:
            continue
        targets = [n for n in idx._root_names(call.args[0])
                   if n in idx.functions]
        for name in targets:
            fn = idx.functions[name].node
            pool_params = [p for p in _param_names(fn)
                           if p in _POOL_PARAMS]
            if pool_params:
                out.append(Finding(
                    "JH104",
                    f"jax.jit over `{idx.functions[name].qualname}` "
                    f"(pool-sized parameter(s) {pool_params}) without "
                    f"donate_argnums -- XLA copies the pool every call",
                    path, call.lineno))


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------


def lint_jit_hazards(files: Sequence[str]) -> List[Finding]:
    out: List[Finding] = []
    for path in files:
        try:
            with open(path) as fh:
                src = fh.read()
            tree = ast.parse(src, filename=path)
        except (OSError, SyntaxError):
            continue
        idx = _ModuleIndex()
        idx.visit(tree)
        if not idx.jit_roots and not idx.jit_calls:
            continue
        reach = idx.reachable()
        for name, info in idx.functions.items():
            if name in reach:
                _check_jitted_fn(info, idx, path, out)
            if _STEP_FN_RE.search(info.node.name):
                _check_step_loops(info, path, out)
            _check_dynamic_shapes(info, path, out)
        _check_jit_donation(idx, path, out)
    return apply_suppressions(out)
