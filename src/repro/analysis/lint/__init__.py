"""``repro.analysis.lint`` -- static analysis + sanitizers gating serving.

The serving stack's correctness rests on three contracts that no unit test
enforces *structurally*:

  1. **jit stability** -- the step loop is only fast while it reuses one
     compiled executable.  Host syncs in the decode loop, Python branches on
     traced values, batch-composition-dependent shapes, and missing buffer
     donation all silently retrace (the ``p99_step_s`` ~1s vs p50 ~3ms
     pathology in the ROADMAP).  Pass 1 (:mod:`.jit_hazards`, ``JH1xx``)
     walks every function reachable from a ``jax.jit`` / ``pl.pallas_call``
     / ``obs.wrap_jit`` site and flags these hazards from the AST.
  2. **page-ledger protocol** -- every page acquired (``alloc``/``ref``)
     must have a release path (``unref``), spill blobs must pin host bytes,
     and nothing may free a page other owners still share.  Pass 2
     (:mod:`.ledger`, ``PL2xx``) checks the call-site protocol statically;
     its runtime twin (:mod:`.runtime`, ``PL25x``, enabled by
     ``REPRO_SANITIZE=1``) mirrors every refcount transition of the live
     pools in a shadow ledger and raises on double-free, negative refcount,
     use-after-evict, and teardown leaks.
  3. **op-registry contracts** -- pimsim/roofline numbers are only
     trustworthy if every registered (kind x backend x format x layout)
     quadruple implements the plan/execute/traffic protocol coherently.
     Pass 3 (:mod:`.contracts`, ``RC3xx``) verifies signatures, non-negative
     page-aligned traffic for paged layouts, a jnp reference for every
     pallas op, and that ``model_traffic.decode_op_plans`` covers every
     config in ``repro.configs``.

CLI::

    python -m repro.analysis.lint src/ [--format json] \
        [--baseline lint_baseline.json]

Suppress a single finding with a trailing (or preceding-line) comment::

    bt = np.zeros((B, npg), np.int32)   # lint: disable=JH103  bucketed

The committed ``lint_baseline.json`` pins the accepted finding count per
rule; CI fails if any rule's count grows (the baseline may only shrink).
"""
from __future__ import annotations

from repro.analysis.lint.findings import (Finding, RULES, baseline_diff,
                                          load_baseline, write_baseline)
from repro.analysis.lint.jit_hazards import lint_jit_hazards
from repro.analysis.lint.ledger import lint_ledger_protocol
from repro.analysis.lint.runtime import SanitizerError, ShadowLedger

__all__ = [
    "Finding", "RULES", "run_lint",
    "lint_jit_hazards", "lint_ledger_protocol",
    "SanitizerError", "ShadowLedger",
    "load_baseline", "write_baseline", "baseline_diff",
]


def run_lint(paths, include_contracts: bool = True):
    """All three passes over ``paths`` (files or directories of .py files).

    Returns the suppression-filtered findings, sorted by (file, line, code).
    Pass 3 needs an importable ``repro`` (it introspects the live registry);
    ``include_contracts=False`` keeps the run purely static.
    """
    from repro.analysis.lint.findings import iter_python_files
    files = list(iter_python_files(paths))
    findings = []
    findings += lint_jit_hazards(files)
    findings += lint_ledger_protocol(files)
    if include_contracts:
        from repro.analysis.lint.contracts import lint_registry_contracts
        findings += lint_registry_contracts()
    return sorted(findings, key=lambda f: (f.file, f.line, f.code))
