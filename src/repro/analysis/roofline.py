"""Three-term roofline analysis from compiled dry-run artifacts.

Hardware model: TPU v5e --
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

  compute term    = HLO_FLOPs / (chips x peak)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = per-chip link bytes / link_bw

FLOPs/bytes come from ``compiled.cost_analysis()`` (per-device numbers on the
SPMD-partitioned module, verified below).  Collective bytes are parsed from
the post-partitioning HLO text: for each all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute we take the operand/result
shapes (these are *local* shapes in SPMD output) and a ring-algorithm cost
over the replica-group size.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

# --- TPU v5e hardware constants ------------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link direction

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# result side of an HLO instruction: `%name = bf16[1,2,3]{...} opcode(`
_INSTR_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\](?:\{[^}]*\})?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return float(n * b)


def _tuple_bytes(inner: str) -> float:
    return sum(_shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(inner))


@dataclasses.dataclass
class CollectiveStats:
    """Per-chip ring-model link bytes, by collective kind."""
    by_kind: Dict[str, float]
    op_count: int

    @property
    def total_link_bytes(self) -> float:
        return sum(self.by_kind.values())


def parse_collectives(hlo_text: str, default_group: int = 1) -> CollectiveStats:
    by_kind: Dict[str, float] = {}
    count = 0
    for line in hlo_text.splitlines():
        if not any(c in line for c in _COLLECTIVES):
            continue
        m = _INSTR_RE.search(line)
        if not m:
            continue
        tuple_inner, dtype, dims, kind = m.groups()
        if "-done(" in line:
            continue  # async pair: count the -start only
        size = (_tuple_bytes(tuple_inner) if tuple_inner is not None
                else _shape_bytes(dtype, dims))
        g = default_group
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                g = int(gi.group(2))
        if g <= 1:
            continue
        ring = (g - 1) / g
        if kind == "all-reduce":
            link = 2.0 * size * ring          # reduce-scatter + all-gather
        elif kind == "all-gather":
            link = size * ring                # result is the gathered size
        elif kind == "reduce-scatter":
            link = size * (g - 1)             # result is the scattered size
        elif kind == "all-to-all":
            link = size * ring
        else:                                  # collective-permute
            link = size
        by_kind[kind] = by_kind.get(kind, 0.0) + link
        count += 1
    return CollectiveStats(by_kind, count)


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    link_bytes_per_chip: float
    model_flops: float = 0.0          # 6*N*D (or 6*N_active*D) useful FLOPs
    n_chips: int = 1

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.link_bytes_per_chip / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_frac(self) -> Optional[float]:
        if self.model_flops and self.flops_per_chip:
            return self.model_flops / (self.flops_per_chip * self.n_chips)
        return None

    def row(self) -> Dict[str, object]:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_frac": self.useful_flops_frac,
        }


# ---------------------------------------------------------------------------
# analytic per-chip HBM / ICI byte models
# ---------------------------------------------------------------------------
# XLA:CPU's "bytes accessed" counts unfused operand traffic (no TPU-grade
# fusion), and HLO-parsed collective bytes double-count loop-invariant
# gathers in the unrolled cost probe.  The roofline memory/collective terms
# therefore come from the explicit models below (standard roofline practice);
# the HLO-derived numbers are reported alongside as diagnostics.

def analytic_cost(cfg, sc, *, chips: int, tp: int, fs: int, pods: int,
                  n_params: float, grad_accum: int = 1,
                  serve_2d: bool = False,
                  cache_layout: str = "dense") -> Dict[str, float]:
    """Per-chip, per-step HBM bytes and ICI link bytes.

    Model assumptions (bf16 params/activations, f32 grads+moments):
      * FSDP: params live sharded over (tp x fs); each pass materializes the
        tp-shard via all-gather over fs, so per-chip weight reads ~= P/tp.
      * Megatron-SP: layer-boundary activations shard over tp; each layer
        costs an AG+RS pair per pass.
      * activations: ~c_act tensor r/w passes of (tokens_chip x d) per layer.
      * attention: flash streams K/V once per q-chunk; LA models stream the
        (dk x dv) chunk state instead.
      * decode: weights gathered per token (serving-with-FSDP posture),
        caches read (attention) or read+written (state update) once.
    """
    P = n_params * 2.0                        # bf16 param bytes
    d = cfg.d_model
    L = cfg.n_layers
    S = sc.seq_len
    B = sc.global_batch
    toks_chip = B * S / (fs * pods)
    kind = sc.kind

    # per-layer cache/state streaming bytes for one full sequence pass
    kv_width = 0.0
    state_stream = 0.0
    if any(k in ("attn", "mla") for k in cfg.pattern + cfg.prelude) \
            or cfg.shared_attn:
        if cfg.mla is not None:
            kv_width = cfg.mla.cache_width
        else:
            kv_width = 2 * cfg.n_kv_heads * cfg.head_dim
    n_ssm = sum(cfg.pattern.count(k) for k in
                ("mamba2", "gla", "retnet", "hgrn2", "mlstm")) \
        * cfg.n_groups
    if n_ssm and cfg.ssm is not None:
        from repro.models.config import SSMConfig  # noqa
        H_ssm = (cfg.ssm.n_heads or cfg.n_heads)
        if "mamba2" in cfg.pattern:
            d_inner = cfg.ssm.expand * d
            H_ssm = d_inner // cfg.ssm.head_dim
            dk_, dv_ = cfg.ssm.d_state, cfg.ssm.head_dim
        elif "mlstm" in cfg.pattern:
            d_up = cfg.ssm.expand * d
            dk_ = dv_ = d_up // H_ssm
        else:
            dk_ = cfg.ssm.dk_head or cfg.head_dim
            dv_ = cfg.ssm.dv_head or cfg.head_dim
        chunk = cfg.ssm.chunk
        state_stream = (S / chunk) * H_ssm * dk_ * dv_ * 4 * 2  # r+w, f32
    n_attn_layers = (sum(cfg.pattern.count(k) for k in ("attn", "mla"))
                     * cfg.n_groups + len(cfg.prelude)
                     + (cfg.n_groups if cfg.shared_attn else 0))

    q_chunk = getattr(cfg, "attn_q_chunk", 512)
    attn_stream_per_seq = (S / q_chunk) * S * kv_width * 2.0   # bf16

    # op-registry traffic; cache_layout="paged" scores the block-table ops
    kv_cache, state_rw = _cache_state_bytes(cfg, sc, cache_layout)
    cache = kv_cache + state_rw

    out = {}
    if kind == "train":
        passes = 3.0                                  # fwd + bwd + remat
        hbm = (P / tp * passes * grad_accum           # weight reads
               + 8.0 * n_params * 2 / chips           # f32 grads r/w
               + 20.0 * n_params / chips              # adam moments + update
               + 30.0 * toks_chip * d * 2 * L / tp * 1.0   # activations (SP)
               + n_attn_layers * (B / (fs * pods)) * attn_stream_per_seq * passes
               + n_ssm * (B / (fs * pods)) * state_stream * passes)
        link = ((fs - 1) / fs * P / tp * passes * grad_accum      # FSDP AG
                + (fs - 1) / fs * 4.0 * n_params / tp             # grad RS
                + (2.0 * (pods - 1) / pods * 4.0 * n_params / (tp * fs)
                   if pods > 1 else 0.0))                          # pod AR
        # SP AG/RS pairs: ~4 per layer per pass on (toks_chip x d) bf16;
        # without SP the boundary stays sharded batch-only (TP einsums still
        # pay ~2 ARs per layer)
        sp_ops = 4.0 if getattr(cfg, "seq_parallel", True) else 2.0
        link += sp_ops * passes * (tp - 1) / tp * toks_chip * d * 2 * L
    elif kind == "prefill":
        hbm = (P / tp
               + 10.0 * toks_chip * d * 2 * L / tp
               + n_attn_layers * (B / (fs * pods)) * attn_stream_per_seq
               + n_ssm * (B / (fs * pods)) * state_stream
               + cache / chips)
        sp_ops_p = 2.0 if getattr(cfg, "seq_parallel", True) else 2.0
        link = ((fs - 1) / fs * P / tp
                + sp_ops_p * (tp - 1) / tp * toks_chip * d * 2 * L)
    else:  # decode
        if serve_2d:
            # 2D weight-stationary serving (Pope et al.): weights stay
            # sharded over (data x model); activations all-reduce over both
            # axes per layer; batch replicated, cache time over both axes
            hbm = (P / chips
                   + cache / chips
                   + 2.0 * state_rw / chips
                   + B * cfg.vocab_size * 4 / tp)
            link = (2.0 * ((tp - 1) / tp + (fs - 1) / fs)
                    * B * d * 2 * L)
        else:
            hbm = (P / tp                               # weights per token
                   + cache / chips                       # attention cache read
                   + 2.0 * state_rw / chips              # state read+write
                   + B / (fs * pods) * cfg.vocab_size * 4)  # logits
            link = ((fs - 1) / fs * P / tp               # FSDP weight AG
                    + 2.0 * (tp - 1) / tp * (B / (fs * pods)) * d * 2 * L)
    out["hbm_bytes"] = hbm
    out["link_bytes"] = link
    out["cache_bytes_total"] = cache
    return out


# Decode-time cache/state byte counts are sourced from the SPU op
# registry's own traffic descriptors (repro/ops): one decode step's ops are
# enumerated by ``decode_op_plans(cfg, B, S)`` and each entry's
# ``traffic(plan)`` supplies the bytes -- the roofline scores exactly the
# ops the model dispatches, with no independent per-family byte formulas.

def _cache_state_bytes(cfg, sc, layout: str = "dense") -> Tuple[float, float]:
    """(KV cache bytes, recurrent state bytes) of the decode-time caches.

    One attn/mla decode op streams its whole cache once, so the read side of
    its traffic IS the cache footprint; the state footprint is one read pass
    of every state_update op.  One registry enumeration serves both.
    ``layout="paged"`` scores the block-table-native ops instead: attention
    reads are page-granular (whole 128-token pages, including a partially
    filled tail page), matching what the paged serving engine dispatches.
    """
    from repro.ops import decode_traffic_by_kind
    by_kind = decode_traffic_by_kind(cfg, sc.global_batch, sc.seq_len, layout)
    kv = sum(t.state_read for k, t in by_kind.items()
             if k in ("attn_decode", "mla_decode"))
    state = by_kind.get("state_update")
    return kv, state.state_read if state is not None else 0.0


def model_flops_train(n_params: float, n_tokens: float) -> float:
    return 6.0 * n_params * n_tokens


def model_flops_decode(n_params_active: float, n_tokens: float,
                       state_bytes_touched: float = 0.0) -> float:
    # decode step: 2*N_active per token matmul FLOPs (fwd only)
    return 2.0 * n_params_active * n_tokens


def count_params(shapes_tree) -> float:
    import jax
    import numpy as np
    return float(sum(np.prod(l.shape) for l in jax.tree.leaves(shapes_tree)))
