"""Controlled numerical reproduction of paper Fig. 4 / Table 2.

No pretrained checkpoints or WikiText-2 are available offline, so the
perplexity tables are reproduced at their *mechanism* level: long-horizon
state accumulation under each (format x rounding) pair, measured as relative
error against the fp32 state.  The orderings mirror the paper: fp8 under RNE
diverges (swamping), stochastic rounding rescues it, int8/MX8/fp16 track.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import formats as F
from repro import ops as OPS


def run_swamping_study(T: int = 300, dk: int = 32, dv: int = 32,
                       formats=None):
    """Paper Fig. 4's mechanism as a controlled experiment.

    Long accumulation of per-step increments that are SMALL relative to the
    state magnitude -- the regime of a decayed recurrent state.  Narrow
    mantissas (e4m3/e5m2) swamp: increments below half an ulp vanish under
    round-to-nearest and the state goes stale/biased.  Stochastic rounding
    preserves them in expectation.  Returns {(fmt, rounding): rel_error}.
    Shared by tests and benchmarks/bench_formats.py.
    """
    B, H = 1, 1
    d = jnp.full((B, H, dk), 0.9995)
    formats = formats or [("mx8", "nearest"), ("mx8", "stochastic"),
                          ("int8", "nearest"), ("int8", "stochastic"),
                          ("fp8_e4m3", "nearest"), ("fp8_e4m3", "stochastic"),
                          ("fp8_e5m2", "nearest"), ("fp8_e5m2", "stochastic"),
                          ("fp16", "nearest")]
    errs = {}
    for fmt, rounding in formats:
        cfg = OPS.StateQuantConfig(fmt=fmt, rounding=rounding, backend="jnp")
        qS = OPS.init_state(B, H, dk, dv, cfg)
        Sf = jnp.zeros((B, H, dv, dk))
        for t in range(T):
            # small increments with a persistent direction: the hard case
            kk = (0.5 + 0.1 * jax.random.normal(
                jax.random.PRNGKey(7 * t + 1), (B, H, dk))) * 0.02
            vv = 0.5 + 0.1 * jax.random.normal(
                jax.random.PRNGKey(7 * t + 2), (B, H, dv))
            qq = jax.random.normal(jax.random.PRNGKey(7 * t + 3), (B, H, dk))
            qS, _ = OPS.state_update_step(qS, d, kk, vv, qq, cfg, seed=t)
            Sf, _ = OPS.state_update_float(Sf, d, kk, vv, qq,
                                           dtype=jnp.float32)
        Sq = (F.dequantize(qS) if isinstance(qS, F.QuantizedTensor)
              else qS.astype(jnp.float32))
        errs[(fmt, rounding)] = float(
            jnp.linalg.norm(Sq - Sf) / jnp.linalg.norm(Sf))
    return errs
