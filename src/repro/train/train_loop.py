"""Train-step factory + fault-tolerant training loop."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.train import optimizer as O


def make_train_step(cfg: ModelConfig, opt: O.OptimizerConfig,
                    par=None, grad_accum: int = 1) -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    grad_accum > 1 splits the batch into microbatches scanned sequentially --
    gradients of microbatch i accumulate while XLA overlaps the backward
    collectives of microbatch i with the compute of i+1.
    """

    def loss_fn(params, batch):
        return M.train_loss(params, cfg, batch, mesh_axes=par)

    def step(params, opt_state, batch):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def to_micro(x):
                m = x.reshape((grad_accum, x.shape[0] // grad_accum)
                              + x.shape[1:])
                if par is not None and hasattr(par, "mesh"):
                    # the scan slices dim 0 every step: keep it unsharded
                    # and move the batch sharding to dim 1
                    from jax.sharding import PartitionSpec as P
                    dims = [None, par.batch_axes] + [None] * (m.ndim - 2)
                    m = jax.lax.with_sharding_constraint(
                        m, par.named(P(*dims)))
                return m
            micro = jax.tree.map(to_micro, batch)

            def acc_body(carry, mb):
                loss_acc, grads_acc = carry
                loss_i, grads_i = jax.value_and_grad(loss_fn)(params, mb)
                return (loss_acc + loss_i,
                        jax.tree.map(jnp.add, grads_acc, grads_i)), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.float32(0.0), zeros), micro)
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
        params, opt_state, metrics = O.adamw_update(params, grads, opt_state, opt)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    # straggler/fault watchdog: steps slower than watchdog_factor x the
    # running median are logged (on real fleets: reported to the controller
    # for hot-spare swap); the loop itself never blocks on it.
    watchdog_factor: float = 3.0


def train_loop(step_fn: Callable, params, opt_state, data_iter,
               loop: LoopConfig, checkpoint_mgr=None,
               start_step: int = 0, log=print) -> Tuple[Any, Any, list]:
    """Fault-tolerant loop: periodic atomic checkpoints, resumable data
    order (the iterator is step-indexed), straggler watchdog."""
    history = []
    times = []
    for step in range(start_step, loop.total_steps):
        batch = data_iter(step)
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        times.append(dt)
        med = sorted(times)[len(times) // 2]
        if dt > loop.watchdog_factor * med and len(times) > 5:
            log(f"[watchdog] step {step} took {dt:.3f}s "
                f"(median {med:.3f}s) -- straggler suspected")
        if step % loop.log_every == 0:
            log(f"step {step}: loss={float(metrics['loss']):.4f} "
                f"gnorm={float(metrics['grad_norm']):.3f} "
                f"lr={float(metrics['lr']):.2e} ({dt*1e3:.0f} ms)")
        history.append(float(metrics["loss"]))
        if checkpoint_mgr is not None and (step + 1) % loop.checkpoint_every == 0:
            checkpoint_mgr.save(step + 1, params, opt_state)
    return params, opt_state, history
