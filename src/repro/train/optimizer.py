"""AdamW (from scratch -- no optax offline) with the distributed-training
conveniences a production framework needs: global-norm clipping, cosine
schedule with warmup, gradient accumulation (microbatching), and moment
dtypes configurable for memory (fp32 moments over bf16 params by default;
the ZeRO-style sharding of the moments is applied by dist/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    moment_dtype: str = "float32"


def schedule(opt: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(opt.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - opt.warmup_steps)
                    / max(opt.total_steps - opt.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = opt.min_lr_frac + (1 - opt.min_lr_frac) * cos
    return opt.lr * warm * frac


def init_opt_state(params: Any, opt: OptimizerConfig) -> Dict[str, Any]:
    mdt = jnp.dtype(opt.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def _decay_mask(path) -> bool:
    """No weight decay on norms / biases / scalar gate params."""
    name = str(path[-1]) if path else ""
    return not any(t in name for t in
                   ("scale", "bias", "A_log", "D", "dt_bias", "fb", "gb",
                    "beta", "b"))


def adamw_update(params: Any, grads: Any, state: Dict[str, Any],
                 opt: OptimizerConfig) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    lr = schedule(opt, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, opt.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = opt.b1, opt.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(opt.moment_dtype)

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + opt.eps)
        if _decay_mask(path):
            delta = delta + opt.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(mdt), v_new.astype(mdt)

    p_flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    g_flat = treedef.flatten_up_to(grads)
    m_flat = treedef.flatten_up_to(state["m"])
    v_flat = treedef.flatten_up_to(state["v"])
    out = [upd(path, p, g, m, v)
           for (path, p), g, m, v in zip(p_flat, g_flat, m_flat, v_flat)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
