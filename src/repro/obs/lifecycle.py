"""Request-lifecycle spans: queued -> prefill -> decode -> spilled -> terminal.

Every ``Request`` the engines touch gets a ``RequestRecord`` here: an
ordered chain of phase spans with engine-supplied timestamps (the same
``perf_counter`` stamps the engines put on ``t_submit``/``t_first``/
``t_done``, so derived metrics agree with ``stats()`` exactly).  The
tracker answers the questions the flat percentile stats cannot:

  * **queue delay** -- how long did *this* request wait before admission;
  * **TTFT / TPOT** -- exact per-request first-token and per-token times;
  * **preemption cost** -- total time spent spilled to host.

Phases:

  ``queued``   submitted, waiting for admission (or re-queued post-spill)
  ``prefill``  full-sequence prompt ingestion
  ``decode``   resident in the decode batch (chunked prompt tails, fork
               continuations, and steady-state generation all decode)
  ``spilled``  preempted: pages on host, waiting to resume

A terminal request has a **complete chain**: starts at ``queued``, every
span closed, terminal status recorded.  ``run(max_steps)`` surfacing a
still-active request closes its open span with an explicit
``interrupted`` marker instead -- traces never contain dangling spans;
if stepping later resumes, a fresh span opens.

Closed spans are mirrored to the trace buffer as async ``b``/``e`` pairs
(``cat="request"``, ``id=rid``) so Perfetto shows one row per request.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

__all__ = ["PhaseSpan", "RequestRecord", "LifecycleTracker", "PHASES"]

PHASES = ("queued", "prefill", "decode", "spilled")


@dataclasses.dataclass
class PhaseSpan:
    phase: str
    t0: float                      # perf_counter stamps
    t1: Optional[float] = None
    interrupted: bool = False

    @property
    def closed(self) -> bool:
        return self.t1 is not None

    @property
    def duration(self) -> float:
        return (self.t1 - self.t0) if self.t1 is not None else 0.0


@dataclasses.dataclass
class RequestRecord:
    rid: int
    spans: List[PhaseSpan] = dataclasses.field(default_factory=list)
    status: Optional[str] = None   # done|aborted|truncated once terminal
    n_tokens: int = 0
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    interrupted: bool = False      # ever closed by run(max_steps) surfacing

    # ------------- chain queries -------------

    @property
    def terminal(self) -> bool:
        return self.status is not None

    @property
    def open_span(self) -> Optional[PhaseSpan]:
        if self.spans and not self.spans[-1].closed:
            return self.spans[-1]
        return None

    def complete_chain(self) -> bool:
        """Terminal + every span closed + the chain starts at ``queued``."""
        return (self.terminal and bool(self.spans)
                and self.spans[0].phase == "queued"
                and all(s.closed for s in self.spans))

    def phase_sequence(self) -> List[str]:
        return [s.phase for s in self.spans]

    # ------------- derived metrics -------------

    @property
    def queue_delay_s(self) -> float:
        """Time waiting before *first* admission (the initial queued span)."""
        for s in self.spans:
            if s.phase == "queued":
                return s.duration
        return 0.0

    @property
    def ttft_s(self) -> float:
        return (self.t_first - self.t_submit) if self.t_first > 0 else 0.0

    @property
    def tpot_s(self) -> float:
        """Per-output-token time after the first token."""
        if self.t_done > 0 and self.t_first > 0 and self.n_tokens > 1:
            return (self.t_done - self.t_first) / (self.n_tokens - 1)
        return 0.0

    @property
    def preemption_cost_s(self) -> float:
        """Total time spent spilled (plus re-queued) after preemption."""
        return sum(s.duration for s in self.spans
                   if s.phase in ("spilled",))


class LifecycleTracker:
    """Owns every request's span chain; engines drive the transitions."""

    def __init__(self, tracer=None, metrics=None):
        self.tracer = tracer
        self.metrics = metrics
        self.records: Dict[int, RequestRecord] = {}

    # ------------- internals -------------

    def _now(self) -> float:
        return time.perf_counter()

    def _close_open(self, rec: RequestRecord, t: float,
                    interrupted: bool = False) -> None:
        span = rec.open_span
        if span is None:
            return
        span.t1 = max(t, span.t0)
        span.interrupted = interrupted
        if self.tracer is not None:
            self.tracer.async_span(
                span.phase, rec.rid, "request",
                self.tracer.ts_of(span.t0), self.tracer.ts_of(span.t1),
                rid=rec.rid, interrupted=interrupted)

    # ------------- engine-driven transitions -------------

    def enqueued(self, rid: int, t: Optional[float] = None) -> None:
        t = self._now() if t is None else t
        rec = self.records.get(rid)
        if rec is None:
            rec = RequestRecord(rid, t_submit=t)
            self.records[rid] = rec
        self._close_open(rec, t)
        rec.spans.append(PhaseSpan("queued", t))

    def phase(self, rid: int, phase: str, t: Optional[float] = None) -> None:
        assert phase in PHASES, phase
        t = self._now() if t is None else t
        rec = self.records.setdefault(rid, RequestRecord(rid, t_submit=t))
        if rec.open_span is not None and rec.open_span.phase == phase:
            return                      # already in this phase
        self._close_open(rec, t)
        rec.spans.append(PhaseSpan(phase, t))

    def first_token(self, rid: int, t: Optional[float] = None) -> None:
        rec = self.records.get(rid)
        if rec is None or rec.t_first > 0:
            return
        rec.t_first = self._now() if t is None else t
        if self.metrics is not None:
            self.metrics.histogram("ttft_s").observe(
                rec.t_first - rec.t_submit)
        if self.tracer is not None:
            self.tracer.instant("first_token", cat="request",
                                track="requests",
                                ts=self.tracer.ts_of(rec.t_first), rid=rid)

    def finish(self, rid: int, status: str, n_tokens: int = 0,
               t: Optional[float] = None) -> None:
        t = self._now() if t is None else t
        rec = self.records.setdefault(rid, RequestRecord(rid, t_submit=t))
        self._close_open(rec, t)
        rec.status = status
        rec.n_tokens = n_tokens
        rec.t_done = t
        if self.metrics is not None:
            self.metrics.histogram("queue_delay_s").observe(
                rec.queue_delay_s)
            if rec.tpot_s > 0:
                self.metrics.histogram("tok_latency_s").observe(rec.tpot_s)
        if self.tracer is not None:
            self.tracer.instant("terminal", cat="request", track="requests",
                                ts=self.tracer.ts_of(t), rid=rid,
                                status=status, n_tokens=n_tokens)
            if status == "failed":
                # an explicit failure marker on the fault track: chaos-run
                # triage filters cat="fault" and sees quarantines inline
                # with the injections that caused them
                self.tracer.instant("failure", cat="fault",
                                    track="requests",
                                    ts=self.tracer.ts_of(t), rid=rid)

    def interrupt(self, rid: int, t: Optional[float] = None) -> None:
        """Close a surfaced-but-not-terminal request's open span with an
        explicit ``interrupted`` marker (the ``run(max_steps)`` contract:
        no dangling spans, no fake terminal status)."""
        rec = self.records.get(rid)
        if rec is None or rec.terminal:
            return
        t = self._now() if t is None else t
        if rec.open_span is not None:
            self._close_open(rec, t, interrupted=True)
            rec.interrupted = True

    def reopen(self, rid: int, t: Optional[float] = None) -> None:
        """Resume an interrupted request: open a fresh span in the phase
        the interrupt closed (``run()`` calls this on entry for every
        pending request; a no-op unless the request was interrupted)."""
        rec = self.records.get(rid)
        if (rec is None or rec.terminal or rec.open_span is not None
                or not rec.spans):
            return
        t = self._now() if t is None else t
        rec.spans.append(PhaseSpan(rec.spans[-1].phase, t))

    # ------------- read side -------------

    def record(self, rid: int) -> Optional[RequestRecord]:
        return self.records.get(rid)

    def terminal_records(self) -> List[RequestRecord]:
        return [r for r in self.records.values() if r.terminal]

    def open_spans(self) -> List[PhaseSpan]:
        """Spans still open across all records (should be empty whenever
        the engine has surfaced or finished everything)."""
        return [r.open_span for r in self.records.values()
                if r.open_span is not None]
