"""Recompile watcher: catch every fresh XLA trace/compile of a jitted fn.

The serving step loop is only fast while it reuses one compiled
executable; shifting batch composition, block-table widths, or donated
pool shapes silently retrace and turn a ~3ms step into a ~1s one (the
``p99_step_s`` mystery in the ROADMAP).  ``RecompileWatcher.wrap`` puts a
thin shim around a ``jax.jit`` callable that:

  * detects each fresh compile by watching the jit cache size grow across
    the call;
  * records *which abstract shapes changed* versus the previous compile of
    the same function -- the leaf-level ``path: (old) -> (new)`` diff of
    the argument tree (shape/dtype only, computed lazily so steady-state
    calls pay two integer reads and nothing else);
  * emits a ``recompile`` instant into the trace buffer and bumps the
    ``recompiles_total{fn=...}`` counter.

The wrapper forwards attribute access (``_cache_size`` included), so
existing retrace-pin tests keep working against the wrapped function.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["RecompileEvent", "WatchedFunction", "RecompileWatcher",
           "jit_sites", "site_compile_counts", "clear_jit_sites"]

#: cap on reported changed-leaf entries per event (params trees are huge;
#: the churn is invariably in the handful of data arguments)
MAX_CHANGED = 20

#: module-level registry of every live wrapped jit site (name -> shim).
#: One source of truth for "which jit callables does the serving stack
#: actually step": the smoke benches' ``--max-decode-recompiles`` gate and
#: the jit-hazard linter both read this instead of re-discovering steppers.
#: Later wraps under the same name shadow earlier ones (a rebuilt engine
#: re-wraps its steppers); entries die with the process, not the engine.
_JIT_SITES: Dict[str, "WatchedFunction"] = {}


def jit_sites() -> Dict[str, "WatchedFunction"]:
    """Snapshot of every wrapped jit site: name -> WatchedFunction shim."""
    return dict(_JIT_SITES)


def site_compile_counts() -> Dict[str, int]:
    """name -> accumulated compile count, across every live wrap site."""
    return {name: wfn.n_compiles for name, wfn in _JIT_SITES.items()}


def clear_jit_sites() -> None:
    """Forget all registered sites (test isolation)."""
    _JIT_SITES.clear()


def _describe(args: tuple, kwargs: dict) -> Dict[str, str]:
    """Leaf path -> ``shape:dtype`` for the whole argument tree.

    Donated buffers may already be deleted when this runs (the watcher
    describes lazily, after the call) -- shape/dtype live on the aval and
    stay readable; anything unreadable degrades to its type name.
    """
    import jax
    out: Dict[str, str] = {}
    flat, _ = jax.tree_util.tree_flatten_with_path((args, kwargs))
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        try:
            shape = tuple(getattr(leaf, "shape", ()))
            dtype = getattr(leaf, "dtype", None)
            out[key] = (f"{shape}:{dtype}" if dtype is not None
                        else repr(leaf) if isinstance(leaf, (int, float,
                                                             bool, str))
                        else type(leaf).__name__)
        except Exception:                        # pragma: no cover
            out[key] = type(leaf).__name__
    return out


def _diff(old: Optional[Dict[str, str]],
          new: Dict[str, str]) -> List[str]:
    """Human-readable changed-leaf entries between two signatures."""
    if old is None:
        return ["<first compile>"]
    changed: List[str] = []
    for k, v in new.items():
        prev = old.get(k)
        if prev != v:
            changed.append(f"{k}: {prev or '<absent>'} -> {v}")
    for k in old:
        if k not in new:
            changed.append(f"{k}: {old[k]} -> <absent>")
    if len(changed) > MAX_CHANGED:
        changed = changed[:MAX_CHANGED] + [
            f"... {len(changed) - MAX_CHANGED} more leaves changed"]
    return changed or ["<retrace with identical abstract shapes "
                       "(new static/structure variant)>"]


@dataclasses.dataclass
class RecompileEvent:
    fn: str
    n_compiles: int                 # cache size after this compile
    t: float                        # perf_counter stamp
    changed: List[str]              # leaf-level shape diff vs prior compile
    signature: Dict[str, str]       # full abstract signature of this call

    @property
    def is_warmup(self) -> bool:
        """The function's very first compile (expected, not a regression)."""
        return self.n_compiles == 1


class WatchedFunction:
    """Shim around one jitted callable; transparent except for watching."""

    def __init__(self, fn, name: str, watcher: "RecompileWatcher"):
        self._fn = fn
        self.name = name
        self._watcher = watcher
        self._last_signature: Optional[Dict[str, str]] = None

    @property
    def n_compiles(self) -> int:
        """Compiled executables this function accumulated (cache size)."""
        try:
            return int(self._fn._cache_size())
        except Exception:                        # pragma: no cover
            return 0

    def __call__(self, *args, **kwargs):
        before = self.n_compiles
        out = self._fn(*args, **kwargs)
        after = self.n_compiles
        if after > before:
            sig = _describe(args, kwargs)
            self._watcher._record(self, after, sig)
            self._last_signature = sig
        return out

    def __getattr__(self, item):
        return getattr(self._fn, item)


class RecompileWatcher:
    """All watched functions of one engine share this event log."""

    def __init__(self, tracer=None, metrics=None):
        self.tracer = tracer
        self.metrics = metrics
        self.events: List[RecompileEvent] = []

    def wrap(self, fn, name: str) -> WatchedFunction:
        wfn = WatchedFunction(fn, name, self)
        _JIT_SITES[name] = wfn
        return wfn

    @property
    def n_events(self) -> int:
        return len(self.events)

    @property
    def n_recompiles(self) -> int:
        """Compiles beyond each function's expected first (warmup) one."""
        return sum(1 for e in self.events if not e.is_warmup)

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.events:
            out[e.fn] = out.get(e.fn, 0) + 1
        return out

    def _record(self, wfn: WatchedFunction, n_compiles: int,
                signature: Dict[str, str]) -> None:
        ev = RecompileEvent(
            fn=wfn.name, n_compiles=n_compiles, t=time.perf_counter(),
            changed=_diff(wfn._last_signature, signature),
            signature=signature)
        self.events.append(ev)
        if self.metrics is not None:
            self.metrics.counter("recompiles_total", fn=wfn.name).inc()
        if self.tracer is not None:
            self.tracer.instant(
                "recompile", cat="jit", track="jit",
                ts=self.tracer.ts_of(ev.t), fn=wfn.name,
                n_compiles=n_compiles, warmup=ev.is_warmup,
                changed=ev.changed)
