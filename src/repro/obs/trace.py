"""Per-step structured trace: a bounded ring buffer of Chrome-trace events.

Engines, scheduler, pool, and placement emit events here -- step
boundaries, admissions, evictions, fork/copy-on-write copies, per-bank
traffic counters, recompiles -- and the buffer exports them as

  * **Chrome-trace JSON** (``{"traceEvents": [...]}``) loadable in
    Perfetto / ``chrome://tracing`` (``save("out.json")``), or
  * **JSONL**, one event per line, for ad-hoc grepping
    (``save("out.jsonl")``).

Event vocabulary (Trace Event Format phase codes):

  * ``X`` complete events -- decode steps (``cat="step"``), with duration;
  * ``b``/``e`` async pairs -- request lifecycle phase spans
    (``cat="request"``, ``id=rid``): queued / prefill / decode / spilled;
    and host-tier prefetches (``cat="prefetch"``, ``id=rid``): dispatch of
    a spilled blob's device copy through its commit/cancel.  Prefetch pairs
    are emitted *closed* at commit time with the recorded dispatch
    timestamp (``async_span``), so an uncommitted prefetch can never leave
    a dangling ``b`` in the trace;
  * ``i`` instants -- admissions, evictions, forks, recompiles; tier
    movement (``cat="tier"``): promote / demote / prefix_hit / evict;
  * ``C`` counters -- per-bank traffic + ``conflict_factor`` each step.

Tracks (Perfetto rows) are logical: engine, scheduler, pool, requests.
The buffer is a ``deque(maxlen=capacity)`` -- a long serve run keeps the
most recent window; ``dropped`` counts what aged out.  Timestamps are
microseconds since the buffer's construction (``perf_counter``-based).
"""
from __future__ import annotations

import json
import time
from collections import deque
from typing import Dict, List, Optional

__all__ = ["TraceBuffer"]

#: stable track (Chrome "tid") assignment for the logical emitters
_TRACKS = ("engine", "requests", "scheduler", "pool", "counters", "jit")


class TraceBuffer:
    """Bounded ring of trace events with Chrome-trace / JSONL export."""

    def __init__(self, capacity: int = 65536, pid: int = 1):
        self.capacity = capacity
        self.pid = pid
        self._events: deque = deque(maxlen=capacity)
        self._emitted = 0
        self._t0 = time.perf_counter()
        self._tids: Dict[str, int] = {}
        self._meta: List[dict] = []     # thread_name events survive eviction
        for track in _TRACKS:
            self._tid(track)

    # ------------- time & tracks -------------

    def now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def ts_of(self, t_abs: float) -> float:
        """Convert an absolute ``perf_counter()`` stamp to buffer time."""
        return (t_abs - self._t0) * 1e6

    def _tid(self, track: str) -> int:
        tid = self._tids.get(track)
        if tid is None:
            tid = len(self._tids)
            self._tids[track] = tid
            self._meta.append({
                "ph": "M", "name": "thread_name", "pid": self.pid,
                "tid": tid, "args": {"name": track},
            })
        return tid

    # ------------- emission -------------

    @property
    def dropped(self) -> int:
        """Events that aged out of the ring."""
        return self._emitted - len(self._events)

    def _push(self, ev: dict) -> None:
        self._events.append(ev)
        self._emitted += 1

    def instant(self, name: str, cat: str = "event", track: str = "engine",
                ts: Optional[float] = None, **args) -> None:
        self._push({"ph": "i", "name": name, "cat": cat,
                    "ts": self.now_us() if ts is None else ts, "s": "t",
                    "pid": self.pid, "tid": self._tid(track),
                    "args": args})

    def complete(self, name: str, cat: str, ts: float, dur: float,
                 track: str = "engine", **args) -> None:
        """One ``X`` event: ``ts``/``dur`` in buffer microseconds."""
        self._push({"ph": "X", "name": name, "cat": cat, "ts": ts,
                    "dur": max(dur, 0.0), "pid": self.pid,
                    "tid": self._tid(track), "args": args})

    def counter(self, name: str, values: Dict[str, float],
                cat: str = "counter", track: str = "counters",
                ts: Optional[float] = None) -> None:
        self._push({"ph": "C", "name": name, "cat": cat,
                    "ts": self.now_us() if ts is None else ts,
                    "pid": self.pid, "tid": self._tid(track),
                    "args": {k: float(v) for k, v in values.items()}})

    def async_span(self, name: str, span_id, cat: str, ts0: float,
                   ts1: float, track: str = "requests", **args) -> None:
        """A closed async span as a ``b``/``e`` pair (Perfetto groups pairs
        of one ``cat`` + ``id`` onto one async track)."""
        tid = self._tid(track)
        sid = str(span_id)
        self._push({"ph": "b", "name": name, "cat": cat, "id": sid,
                    "ts": ts0, "pid": self.pid, "tid": tid, "args": args})
        self._push({"ph": "e", "name": name, "cat": cat, "id": sid,
                    "ts": max(ts1, ts0), "pid": self.pid, "tid": tid,
                    "args": {}})

    # ------------- export -------------

    def events(self) -> List[dict]:
        """Metadata + ring contents, oldest first."""
        return self._meta + list(self._events)

    def to_chrome(self) -> dict:
        return {"traceEvents": self.events(), "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def save(self, path: str) -> None:
        """Write the trace: ``*.jsonl`` gets one event per line, anything
        else gets Chrome-trace JSON (open in https://ui.perfetto.dev)."""
        if str(path).endswith(".jsonl"):
            with open(path, "w") as f:
                for ev in self.events():
                    f.write(json.dumps(ev) + "\n")
        else:
            with open(path, "w") as f:
                json.dump(self.to_chrome(), f)
