"""Labeled metrics registry: counters, gauges, histograms.

One ``MetricsRegistry`` per engine holds every serving-side number the
stack emits -- the engines' ``stats()`` dicts are schema-stable *views*
over it, and ``prometheus_text()`` renders the same families for
scrape-style consumption (``launch/serve.py --metrics``).

Design constraints, in order:

  * **cheap on the hot path** -- ``counter(...).inc()`` in the decode loop
    must cost a dict lookup and a float add, nothing more;
  * **percentile-exact at serving scale** -- histograms retain raw samples
    (decimated 2x whenever the reservoir fills, so memory is bounded while
    long runs keep a uniform subsample) and compute percentiles with
    ``np.percentile``, matching what the engines previously computed from
    ad-hoc lists bit-for-bit until the first decimation;
  * **schema-stable** -- a metric read before any write reports 0.0, so
    views built over the registry never key-error on an idle engine.

Well-known families (beyond the engine/pool basics): the tiered pool
(:mod:`repro.serving.memory.tiered`) emits ``tier_hit_total`` /
``tier_miss_total`` (label ``kind``: prefetch / prefix / resume),
``promote_bytes_total`` / ``demote_bytes_total`` (host<->device traffic),
and the ``host_tier_bytes`` gauge; read a whole family with
:meth:`MetricsRegistry.family_total`.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]

#: default histogram reservoir; at 2x decimation a week-long run still
#: holds a uniform ~8k-sample view of the distribution
HISTOGRAM_CAP = 8192


class Counter:
    """Monotonic float counter."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter increment must be >= 0, got {v}")
        self.value += v


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self.value += v

    def dec(self, v: float = 1.0) -> None:
        self.value -= v


class Histogram:
    """Sample-retaining histogram with bounded memory.

    Keeps every ``stride``-th observation; when the reservoir hits
    ``cap`` it is decimated 2x and the stride doubles, so the retained
    samples stay a uniform subsample of the full series.  ``count`` and
    ``sum`` are always exact.
    """

    __slots__ = ("count", "sum", "_samples", "_stride", "_phase", "cap")

    def __init__(self, cap: int = HISTOGRAM_CAP):
        self.count = 0
        self.sum = 0.0
        self.cap = cap
        self._samples: List[float] = []
        self._stride = 1
        self._phase = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self._phase += 1
        if self._phase >= self._stride:
            self._phase = 0
            self._samples.append(v)
            if len(self._samples) >= self.cap:
                self._samples = self._samples[::2]
                self._stride *= 2

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    @property
    def samples(self) -> List[float]:
        return list(self._samples)

    def percentile(self, q: float) -> float:
        if not self._samples:
            return 0.0
        return float(np.percentile(self._samples, q))

    def summary(self) -> Dict[str, float]:
        return {
            "count": float(self.count), "sum": self.sum, "mean": self.mean,
            "p50": self.percentile(50), "p90": self.percentile(90),
            "p99": self.percentile(99),
            "max": float(max(self._samples)) if self._samples else 0.0,
        }


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Families of labeled metrics, created on first touch.

    ``registry.counter("requests_total", status="done").inc()`` -- the
    family ``requests_total`` is fixed to kind=counter and label set
    ``("status",)`` at first use; a later touch with a different kind or
    label set is a bug and raises.
    """

    def __init__(self):
        # name -> (kind, label_names, {label_values_tuple: metric})
        self._families: Dict[str, Tuple[str, Tuple[str, ...], Dict]] = {}

    # ------------- touch-or-create -------------

    def _get(self, kind: str, name: str, labels: Dict[str, str]):
        label_names = tuple(sorted(labels))
        fam = self._families.get(name)
        if fam is None:
            fam = (kind, label_names, {})
            self._families[name] = fam
        if fam[0] != kind:
            raise ValueError(f"metric {name!r} is a {fam[0]}, not a {kind}")
        if fam[1] != label_names:
            raise ValueError(f"metric {name!r} has labels {fam[1]}, "
                             f"got {label_names}")
        key = tuple(str(labels[k]) for k in label_names)
        child = fam[2].get(key)
        if child is None:
            child = _KINDS[kind]()
            fam[2][key] = child
        return child

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get("histogram", name, labels)

    # ------------- read side -------------

    def value(self, name: str, **labels) -> float:
        """Current value of a counter/gauge; 0.0 if never touched."""
        fam = self._families.get(name)
        if fam is None:
            return 0.0
        key = tuple(str(labels[k]) for k in fam[1])
        child = fam[2].get(key)
        return child.value if child is not None else 0.0

    def family_samples(self, name: str) -> List[float]:
        """Concatenated retained samples across all children of a
        histogram family (e.g. ``step_s`` over both compile labels)."""
        fam = self._families.get(name)
        if fam is None:
            return []
        out: List[float] = []
        for child in fam[2].values():
            out.extend(child._samples)
        return out

    def family_total(self, name: str) -> float:
        """Summed value across all children of a counter/gauge family --
        e.g. ``tier_hit_total`` over every ``kind=...`` label."""
        fam = self._families.get(name)
        if fam is None or fam[0] == "histogram":
            return 0.0
        return float(sum(c.value for c in fam[2].values()))

    def family_count(self, name: str) -> float:
        fam = self._families.get(name)
        if fam is None:
            return 0.0
        return float(sum(c.count for c in fam[2].values()))

    def as_dict(self) -> Dict[str, float]:
        """Flat ``name{label="v"} -> value`` snapshot (histograms summarize
        as ``name_count`` / ``name_sum``)."""
        out: Dict[str, float] = {}
        for name, (kind, label_names, children) in sorted(
                self._families.items()):
            for key, child in sorted(children.items()):
                lbl = ",".join(f'{k}="{v}"'
                               for k, v in zip(label_names, key))
                suffix = "{" + lbl + "}" if lbl else ""
                if kind == "histogram":
                    out[f"{name}_count{suffix}"] = float(child.count)
                    out[f"{name}_sum{suffix}"] = child.sum
                else:
                    out[f"{name}{suffix}"] = child.value
        return out

    def summaries(self) -> Dict[str, Dict[str, float]]:
        """Histogram summaries keyed by ``name{labels}`` -- what
        ``benchmarks/run.py`` embeds into ``BENCH_serving.json``."""
        out: Dict[str, Dict[str, float]] = {}
        for name, (kind, label_names, children) in sorted(
                self._families.items()):
            if kind != "histogram":
                continue
            for key, child in sorted(children.items()):
                lbl = ",".join(f'{k}="{v}"'
                               for k, v in zip(label_names, key))
                full = name + ("{" + lbl + "}" if lbl else "")
                out[full] = child.summary()
        return out

    # ------------- prometheus text exposition -------------

    def prometheus_text(self) -> str:
        """The registry in Prometheus text exposition format (histograms
        render as summaries: quantile children + _count/_sum)."""
        lines: List[str] = []
        for name, (kind, label_names, children) in sorted(
                self._families.items()):
            pname = _prom_name(name)
            ptype = "summary" if kind == "histogram" else kind
            lines.append(f"# TYPE {pname} {ptype}")
            for key, child in sorted(children.items()):
                base = list(zip(label_names, key))
                if kind == "histogram":
                    for q in (0.5, 0.9, 0.99):
                        lbl = _prom_labels(base + [("quantile", str(q))])
                        lines.append(f"{pname}{lbl} "
                                     f"{child.percentile(q * 100):g}")
                    lbl = _prom_labels(base)
                    lines.append(f"{pname}_count{lbl} {child.count}")
                    lines.append(f"{pname}_sum{lbl} {child.sum:g}")
                else:
                    lines.append(
                        f"{pname}{_prom_labels(base)} {child.value:g}")
        return "\n".join(lines) + ("\n" if lines else "")


def _prom_name(name: str) -> str:
    out = "".join(c if (c.isalnum() or c in "_:") else "_" for c in name)
    return out if not out[:1].isdigit() else "_" + out


def _prom_labels(pairs: Iterable[Tuple[str, str]]) -> str:
    pairs = list(pairs)
    if not pairs:
        return ""
    inner = ",".join(f'{_prom_name(k)}="{v}"' for k, v in pairs)
    return "{" + inner + "}"
