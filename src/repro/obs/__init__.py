"""``repro.obs`` -- observability for the serving stack.

One :class:`Observability` object per engine bundles the four pieces the
stack threads through itself:

  * :class:`~repro.obs.metrics.MetricsRegistry` -- labeled counters /
    gauges / histograms; ``Engine.stats()`` is a schema-stable view over
    it and ``prometheus_text()`` renders it for scraping;
  * :class:`~repro.obs.trace.TraceBuffer` -- a bounded ring of per-step
    structured events (steps, admissions, evictions, forks, per-bank
    traffic counters), exportable as Chrome-trace JSON (Perfetto) or
    JSONL;
  * :class:`~repro.obs.lifecycle.LifecycleTracker` -- per-request phase
    spans (queued -> prefill -> decode -> spilled -> terminal) with exact
    TTFT / TPOT / queue-delay / preemption-cost per request;
  * :class:`~repro.obs.recompile.RecompileWatcher` -- wraps the jitted
    steppers and records every fresh trace/compile with the changed
    abstract-shape signature.

Usage (the serving engines do all of this internally):

    obs = Observability()
    fn = obs.wrap_jit(jax.jit(step), "engine.decode")
    ...
    obs.save_trace("out.json")          # load in https://ui.perfetto.dev
    print(obs.prometheus_text())
"""
from __future__ import annotations

from repro.obs.lifecycle import (PHASES, LifecycleTracker, PhaseSpan,
                                 RequestRecord)
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.recompile import (RecompileEvent, RecompileWatcher,
                                 WatchedFunction)
from repro.obs.schema import trace_features, validate_chrome_trace
from repro.obs.trace import TraceBuffer

__all__ = [
    "Observability",
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "TraceBuffer",
    "LifecycleTracker", "RequestRecord", "PhaseSpan", "PHASES",
    "RecompileWatcher", "RecompileEvent", "WatchedFunction",
    "validate_chrome_trace", "trace_features",
]


class Observability:
    """The per-engine bundle: metrics + trace + lifecycle + recompiles."""

    def __init__(self, trace_capacity: int = 65536):
        self.metrics = MetricsRegistry()
        self.tracer = TraceBuffer(capacity=trace_capacity)
        self.lifecycle = LifecycleTracker(self.tracer, self.metrics)
        self.recompiles = RecompileWatcher(self.tracer, self.metrics)

    def wrap_jit(self, fn, name: str) -> WatchedFunction:
        """Put the recompile watcher around a jitted callable."""
        return self.recompiles.wrap(fn, name)

    def save_trace(self, path: str) -> None:
        """Chrome-trace JSON (or JSONL for ``*.jsonl`` paths)."""
        self.tracer.save(path)

    def prometheus_text(self) -> str:
        return self.metrics.prometheus_text()
