"""Chrome-trace JSON schema validation (hand-rolled: no jsonschema dep).

``validate_chrome_trace`` checks structural validity of a trace emitted by
:class:`repro.obs.trace.TraceBuffer` (and, deliberately, of any
Trace-Event-Format JSON): phase codes, required fields per phase, numeric
timestamps.  ``trace_features`` reports which observability signals the
trace actually contains, so CI can require them:

    PYTHONPATH=src python -m repro.obs.schema out.json \
        --require steps,spans,bank,recompile

exits non-zero if the trace is structurally invalid or any required
feature is missing.
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List, Set

__all__ = ["validate_chrome_trace", "trace_features", "main"]

_ALLOWED_PH = {"X", "B", "E", "i", "I", "C", "b", "e", "n", "s", "t", "f",
               "M", "P", "N", "O", "D"}
_NUMERIC = (int, float)

#: feature name -> human description (see ``trace_features``)
FEATURES = {
    "steps": "decode-step X events (cat='step')",
    "spans": "request lifecycle b/e span pairs (cat='request')",
    "bank": "per-bank traffic C counter events",
    "recompile": "recompile instant events (cat='jit')",
    "recompile_signature": "a recompile event carrying a changed-shape "
                           "signature",
    "tiered": "host-tier events: prefetch b/e spans (cat='prefetch') or "
              "tier promote/demote/hit instants (cat='tier')",
    "resilience": "fault-layer instants (cat='fault'): injections, "
                  "quarantines, watchdog trips, degradation rungs",
    "speculation": "per-step 'spec' C counter events (proposed/accepted "
                   "draft tokens from the speculative decode path)",
}


def _check_event(i: int, ev, errors: List[str]) -> None:
    where = f"traceEvents[{i}]"
    if not isinstance(ev, dict):
        errors.append(f"{where}: not an object")
        return
    ph = ev.get("ph")
    if ph not in _ALLOWED_PH:
        errors.append(f"{where}: unknown phase {ph!r}")
        return
    if not isinstance(ev.get("name"), str) or not ev["name"]:
        errors.append(f"{where}: missing/empty name")
    for field in ("pid", "tid"):
        if not isinstance(ev.get(field), int):
            errors.append(f"{where}: {field} must be an int")
    if ph != "M":                    # metadata events carry no timestamp
        if not isinstance(ev.get("ts"), _NUMERIC):
            errors.append(f"{where}: ts must be numeric")
    if ph == "X":
        dur = ev.get("dur")
        if not isinstance(dur, _NUMERIC) or dur < 0:
            errors.append(f"{where}: X event needs dur >= 0")
    if ph in ("b", "e", "n"):
        if "id" not in ev:
            errors.append(f"{where}: async event needs an id")
        if not isinstance(ev.get("cat"), str) or not ev.get("cat"):
            errors.append(f"{where}: async event needs a cat")
    if ph == "C":
        args = ev.get("args")
        if not isinstance(args, dict) or not args:
            errors.append(f"{where}: counter event needs non-empty args")
        elif not all(isinstance(v, _NUMERIC) for v in args.values()):
            errors.append(f"{where}: counter args must be numeric")
    if "args" in ev and not isinstance(ev["args"], dict):
        errors.append(f"{where}: args must be an object")


def validate_chrome_trace(obj) -> List[str]:
    """Structural errors in a Chrome-trace JSON object ([] == valid)."""
    errors: List[str] = []
    if not isinstance(obj, dict):
        return ["top level must be an object"]
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        return ["missing traceEvents list"]
    if not events:
        errors.append("traceEvents is empty")
    for i, ev in enumerate(events):
        _check_event(i, ev, errors)
    # async b/e pairing per (cat, id, name): every begin needs an end
    open_spans: Dict[tuple, int] = {}
    for ev in events:
        if not isinstance(ev, dict):
            continue
        key = (ev.get("cat"), ev.get("id"), ev.get("name"))
        if ev.get("ph") == "b":
            open_spans[key] = open_spans.get(key, 0) + 1
        elif ev.get("ph") == "e":
            open_spans[key] = open_spans.get(key, 0) - 1
    dangling = {k: n for k, n in open_spans.items() if n > 0}
    for (cat, sid, name), n in sorted(dangling.items(),
                                      key=lambda kv: str(kv[0])):
        errors.append(f"dangling async span: {n} unclosed "
                      f"'{name}' (cat={cat}, id={sid})")
    return errors


def trace_features(obj) -> Set[str]:
    """Which observability signals the trace contains (see ``FEATURES``)."""
    feats: Set[str] = set()
    for ev in obj.get("traceEvents", []):
        if not isinstance(ev, dict):
            continue
        ph, cat = ev.get("ph"), ev.get("cat")
        if ph == "X" and cat == "step":
            feats.add("steps")
        if ph in ("b", "e") and cat == "request":
            feats.add("spans")
        if (ph in ("b", "e") and cat == "prefetch") or \
                (ph in ("i", "I") and cat == "tier"):
            feats.add("tiered")
        if ph in ("i", "I") and cat == "fault":
            feats.add("resilience")
        if ph == "C" and "bank" in str(ev.get("name", "")):
            feats.add("bank")
        if ph == "C" and ev.get("name") == "spec":
            feats.add("speculation")
        if ph in ("i", "I") and cat == "jit":
            feats.add("recompile")
            args = ev.get("args") or {}
            if args.get("changed"):
                feats.add("recompile_signature")
    return feats


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="Validate a Chrome-trace JSON emitted by repro.obs")
    ap.add_argument("path")
    ap.add_argument("--require", default="",
                    help="comma-separated features that must be present: "
                         + ", ".join(sorted(FEATURES)))
    args = ap.parse_args(argv)

    with open(args.path) as f:
        obj = json.load(f)
    errors = validate_chrome_trace(obj)
    for e in errors:
        print(f"INVALID: {e}", file=sys.stderr)

    required = [r for r in args.require.split(",") if r]
    unknown = [r for r in required if r not in FEATURES]
    if unknown:
        print(f"unknown --require features: {unknown} "
              f"(known: {sorted(FEATURES)})", file=sys.stderr)
        return 2
    feats = trace_features(obj)
    missing = [r for r in required if r not in feats]
    for r in missing:
        print(f"MISSING: {r} -- {FEATURES[r]}", file=sys.stderr)

    n = len(obj.get("traceEvents", []) if isinstance(obj, dict) else [])
    if not errors and not missing:
        print(f"OK: {n} events, features: "
              f"{','.join(sorted(feats)) or '(none)'}")
        return 0
    return 1


if __name__ == "__main__":
    sys.exit(main())
