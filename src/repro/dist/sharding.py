"""Sharding layout for train / serve / dry-run steps.

One :class:`Parallel` describes the mesh topology (which axes carry data
parallelism, which one carries tensor parallelism); the builder functions
below turn it into concrete ``NamedSharding`` trees for every pytree a step
function touches:

* :func:`param_shardings`     -- Megatron-TP on the trailing weight dim plus
  FSDP/ZeRO over the intra-pod data axis (params live sharded over tp x fs;
  GSPMD all-gathers the fs shards per use, so per-chip weight reads ~= P/tp
  -- see analysis/roofline.py).
* :func:`opt_state_shardings` -- AdamW moments follow the param layout
  (ZeRO: the fs factor already shards them), ``step`` replicated.
* :func:`batch_shardings`     -- leading batch dim over all data axes.
* :func:`cache_shardings`     -- decode caches; batch/time axes are located
  exactly by probing :func:`repro.models.model.abstract_decode_caches` at
  two batch sizes and two capacities (same technique as serving/memory),
  never guessed from shapes.
* :func:`replicated`          -- the trivial layout.

Layout rules only ever shard a dim that divides evenly; anything else
falls back to replication, so every builder is total over the model zoo.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Parallel:
    """A mesh plus the roles of its axes.

    ``data_axes`` carry pure data parallelism (the optional leading 'pod'
    axis is the inter-pod DCN network -- see launch/mesh.py); ``model_axis``
    carries tensor/expert parallelism.
    """

    mesh: jax.sharding.Mesh
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"

    @property
    def tp(self) -> int:
        """Tensor-parallel degree (size of the model axis)."""
        return int(self.mesh.shape[self.model_axis])

    @property
    def fsdp_axes(self) -> Tuple[str, ...]:
        """Data axes that participate in param/ZeRO sharding.

        The 'pod' axis is excluded: params are replicated across pods so
        only gradient all-reduces cross the slow inter-pod network.
        """
        return tuple(a for a in self.data_axes if a != "pod")

    @property
    def fsdp(self) -> int:
        """FSDP/ZeRO degree (intra-pod data-parallel size)."""
        out = 1
        for a in self.fsdp_axes:
            out *= int(self.mesh.shape[a])
        return out

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        """PartitionSpec entry for the batch dim (all data axes)."""
        return tuple(self.data_axes)

    @property
    def batch_size_divisor(self) -> int:
        """Global batch sizes must divide this to shard over batch_axes."""
        out = 1
        for a in self.data_axes:
            out *= int(self.mesh.shape[a])
        return out

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)


def replicated(par: Parallel) -> NamedSharding:
    return par.named(P())


# ---------------------------------------------------------------------------
# params / optimizer
# ---------------------------------------------------------------------------

def _key_names(path) -> list:
    names = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            names.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            names.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            names.append(k.name)
        else:
            names.append(str(k))
    return names


def param_shardings(params: Any, cfg, par: Parallel) -> Any:
    """NamedSharding tree mirroring ``params`` (arrays or SDS).

    Per weight: the larger of the two trailing dims that divides tp is
    tensor-parallel; the first logical dim (after the scan-stack axis of
    ``groups`` leaves, which must stay unsharded -- the layer scan slices
    it every step) is FSDP-sharded over the intra-pod data axes.  MoE
    expert banks shard their expert dim over the model axis instead, the
    layout ``apply_moe``'s expert-parallel shard_map consumes directly.
    1-D leaves (norm scales, gates) are replicated.
    """
    tp, fsdp = par.tp, par.fsdp

    def one(path, leaf):
        names = _key_names(path)
        shape = tuple(leaf.shape)
        off = 1 if names and names[0] == "groups" else 0
        logical = shape[off:]
        if len(logical) < 2:
            return replicated(par)
        dims: list = [None] * len(shape)
        # a true (E, d_in, d_out) expert bank -- MoE archs also carry 2-D
        # dense wi/wg/wo under 'ffn' (prelude dense layers, shared experts)
        # which take the generic TP+FSDP layout below
        moe_expert = (getattr(cfg, "moe", None) is not None
                      and names[-1] in ("wi", "wg", "wo") and "ffn" in names
                      and len(logical) == 3)
        if moe_expert and tp > 1 and logical[0] % tp == 0:
            dims[off] = par.model_axis
        elif tp > 1:
            cands = [i for i in (len(shape) - 1, len(shape) - 2)
                     if i >= off and shape[i] % tp == 0 and shape[i] >= tp]
            if cands:
                dims[max(cands, key=lambda i: shape[i])] = par.model_axis
        if fsdp > 1 and dims[off] is None and shape[off] % fsdp == 0 \
                and shape[off] >= fsdp:
            dims[off] = par.fsdp_axes
        return par.named(P(*dims))

    return jax.tree_util.tree_map_with_path(one, params)


def opt_state_shardings(opt_state: Any, p_shard: Any, par: Parallel) -> Any:
    """AdamW state layout: moments follow the param shardings exactly
    (which already carry the fs factor, i.e. ZeRO over the data axis);
    the step counter is replicated."""
    del opt_state  # structure is {'m': params, 'v': params, 'step': scalar}
    return {"m": p_shard, "v": p_shard, "step": replicated(par)}


# ---------------------------------------------------------------------------
# batches / caches
# ---------------------------------------------------------------------------

def batch_shardings(batch: Any, par: Parallel) -> Any:
    """Leading (batch) dim over all data axes; indivisible leaves replicate."""
    div = par.batch_size_divisor

    def one(leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        if not shape or shape[0] % div != 0:
            return replicated(par)
        return par.named(P(par.batch_axes, *([None] * (len(shape) - 1))))

    return jax.tree.map(one, batch)


def _probe_cache_axes(cfg) -> list:
    """Locate (batch_dim, time_dim) for every decode-cache leaf exactly.

    Evaluates the cache skeleton at two batch sizes and two capacities; a
    dim is the batch (time) axis iff it moves with B (T).  Works for any
    container -- packed QuantizedTensor payloads scale their group dims
    with T and are found just as reliably as plain arrays.
    """
    from repro.models import model as M
    a = jax.tree.leaves(M.abstract_decode_caches(cfg, 2, 128))
    b = jax.tree.leaves(M.abstract_decode_caches(cfg, 6, 128))
    c = jax.tree.leaves(M.abstract_decode_caches(cfg, 2, 256))
    out = []
    for la, lb, lc in zip(a, b, c):
        bdim = next((i for i, (x, y) in enumerate(zip(la.shape, lb.shape))
                     if x != y), None)
        tdim = next((i for i, (x, y) in enumerate(zip(la.shape, lc.shape))
                     if x != y), None)
        out.append((bdim, tdim))
    return out


def cache_shardings(cache_shapes: Any, cfg, par: Parallel,
                    global_batch: int) -> Any:
    """Decode-cache layout for a warm cache of ``global_batch`` sequences.

    Batch axis over the data axes when the global batch divides.  Leaves
    WITH a time axis (KV caches) shard it over the model axis -- every
    leaf of one container shares that axis, so the whole cache keeps one
    layout; time-less SSM state slabs shard their largest head-like dim
    instead (matching models/ssm.py shard_heads).  When the batch cannot
    shard (e.g. the 2D weight-stationary serving mode compiles with
    global_batch=1), the time axis spreads over BOTH data and model axes
    so the cache stream still scales with the whole mesh.
    """
    leaves, treedef = jax.tree_util.tree_flatten(cache_shapes)
    axes = _probe_cache_axes(cfg)
    assert len(axes) == len(leaves), (
        f"cache skeleton mismatch: probed {len(axes)} leaves, "
        f"got {len(leaves)}")
    div, tp = par.batch_size_divisor, par.tp
    shard_batch = global_batch % div == 0

    out = []
    for (bdim, tdim), leaf in zip(axes, leaves):
        shape = tuple(leaf.shape)
        dims: list = [None] * len(shape)
        batch_ok = (shard_batch and bdim is not None
                    and shape[bdim] % div == 0)
        if batch_ok:
            dims[bdim] = par.batch_axes
        if tp > 1:
            # dims before the batch axis are scan-stack axes: never sharded
            start = bdim + 1 if bdim is not None else 0
            if tdim is not None:
                # KV caches: every leaf of one cache (payload, scales,
                # micro-exponents) has the time axis, so sharding it keeps
                # the whole container on ONE layout -- mixing per-leaf
                # choices churns the partitioner inside the append scatter
                if shape[tdim] % tp == 0:
                    if not batch_ok and div > 1 \
                            and shape[tdim] % (div * tp) == 0:
                        dims[tdim] = par.batch_axes + (par.model_axis,)
                    else:
                        dims[tdim] = par.model_axis
            else:
                # SSM state slabs: largest head-like dim over the model
                # axis, matching models/ssm.py shard_heads
                heads = [i for i in range(start, len(shape))
                         if dims[i] is None and shape[i] % tp == 0
                         and shape[i] >= tp]
                if heads:
                    dims[max(heads, key=lambda i: shape[i])] = par.model_axis
        out.append(par.named(P(*dims)))
    return jax.tree_util.tree_unflatten(treedef, out)
