"""Backfills for jax APIs this tree codes against that are absent from the
pinned jax 0.4.x: ``jax.shard_map``, ``jax.sharding.AxisType``, and the
``axis_types=`` keyword of ``jax.make_mesh``.

Every sharded path in the repo reaches a ``Parallel`` (and therefore this
package) before touching those APIs, so installing the backfills from
``repro.dist.__init__`` covers all call sites -- including the subprocess
bodies in ``tests/test_sharding.py`` -- without editing them.

:func:`install` is idempotent and only adds what is missing; on a jax that
already ships these APIs it is a no-op.
"""
from __future__ import annotations

import enum
import inspect

import jax


class _AxisType(enum.Enum):
    """Stand-in for ``jax.sharding.AxisType`` (sharding-in-types modes).

    Pre-AxisType jax has exactly one behavior -- GSPMD auto propagation --
    which is what every mesh in this tree requests (``Auto``), so the value
    is accepted and dropped by the :func:`install`'d ``make_mesh`` wrapper.
    """

    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def install() -> None:
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _AxisType

    if "axis_types" not in inspect.signature(jax.make_mesh).parameters \
            and not getattr(jax.make_mesh, "_repro_compat", False):
        _make_mesh = jax.make_mesh

        def make_mesh(axis_shapes, axis_names, *, axis_types=None,
                      devices=None):
            del axis_types            # pre-AxisType jax: every axis is Auto
            return _make_mesh(axis_shapes, axis_names, devices=devices)

        make_mesh._repro_compat = True
        jax.make_mesh = make_mesh

    if not hasattr(jax.lax, "axis_size"):
        def axis_size(axis_name):
            # psum of a Python constant folds to the static axis size
            return jax.lax.psum(1, axis_name)

        jax.lax.axis_size = axis_size

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kw):
            # check_vma is the new-jax name for check_rep
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma, **kw)

        shard_map._repro_compat = True
        jax.shard_map = shard_map
