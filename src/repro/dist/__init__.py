"""Distribution layer: mesh-role description, sharding builders, and
compressed collectives.

Importing this package also backfills the handful of new-jax APIs the
sharded call sites use (``jax.shard_map``, ``jax.sharding.AxisType``,
``jax.make_mesh(axis_types=...)``) when running on the pinned jax 0.4.x
-- see :mod:`repro.dist.compat`.
"""
from repro.dist import compat as _compat

_compat.install()

from repro.dist import compression  # noqa: E402,F401
from repro.dist.sharding import (  # noqa: E402,F401
    Parallel,
    batch_shardings,
    cache_shardings,
    opt_state_shardings,
    param_shardings,
    replicated,
)
