"""Int8-compressed gradient all-reduce with error feedback.

launch/mesh.py's multi-pod design: the 'pod' axis is pure data parallelism
over the DCN-class inter-pod network, and only gradient all-reduces cross
it.  At bf16 that link moves 2 bytes/param/step; quantizing the gradients
to int8 with a per-tensor scale halves the wire cost, and carrying the
quantization residual into the next step (error feedback a la EF-SGD /
1-bit Adam) keeps the compression bias from accumulating: what is rounded
away this step is added back before rounding the next.

Mesh-free by construction: :func:`compressed_allreduce_mean` with
``axis_name=None`` applies the same quantize -> dequantize -> residual
pipeline without a collective, so the numerics are unit-testable on one
device (tests/test_dist_compression.py).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

INT8_MAX = 127
_SCALE_BYTES = 4                      # one fp32 scale per tensor on the wire


def init_error_feedback(grads: Any) -> Any:
    """Zeroed residual carriers, one per gradient leaf (fp32)."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor max-abs int8 quantization: returns (q, scale)."""
    x = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x)) / INT8_MAX
    scale = jnp.where(scale > 0, scale, jnp.float32(1.0))   # all-zero tensor
    q = jnp.clip(jnp.round(x / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_allreduce_mean(grads: Any, error_fb: Any,
                              axis_name: Optional[str] = None
                              ) -> Tuple[Any, Any]:
    """Mean-reduce ``grads`` over ``axis_name`` through an int8 wire format.

    Per leaf: quantize (grad + carried residual) to int8, keep the new
    residual locally, and mean the dequantized payload across the axis.
    Returns (reduced grads, new error feedback).  With ``axis_name=None``
    (no mesh) the reduction is the identity -- the compression numerics
    are unchanged, which is what the unit tests exercise.
    """
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error_fb)
    reds, efs = [], []
    for g, e in zip(flat_g, flat_e):
        x = g.astype(jnp.float32) + e
        q, scale = quantize_int8(x)
        deq = dequantize_int8(q, scale)
        efs.append(x - deq)
        reds.append(deq if axis_name is None
                    else jax.lax.pmean(deq, axis_name))
    return (jax.tree_util.tree_unflatten(treedef, reds),
            jax.tree_util.tree_unflatten(treedef, efs))


def compressed_bytes(tree: Any) -> int:
    """Wire bytes for one compressed all-reduce of ``tree``'s leaves
    (1 byte/value + the per-tensor scale); compare against 2*size for
    the bf16 baseline."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        total += int(leaf.size) + _SCALE_BYTES
    return total
