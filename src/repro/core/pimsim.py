"""Analytical DRAM-timing model of the Pimba PIM designs (paper §4--§6).

This container has no DRAM to instrument, so the paper's *architecture*
claims are reproduced with a first-principles timing model parameterized by
the paper's Table 1.  It models, per state-update invocation:

  * **GPU**       -- pure bandwidth: read+write state over HBM at fp16/MX8.
  * **time-multiplexed PIM** (HBM-PIM-style) -- per-bank unit executes the
    decay/outer/add/GEMV micro-ops sequentially, one column-burst each.
  * **pipelined PIM** -- per-bank 4-stage pipeline; read and write of the
    same bank cannot overlap, so the pipeline stalls every row-buffer turn.
  * **Pimba** -- one SPU per two banks with access interleaving: reads from
    the upper bank overlap writes to the bottom bank, sustaining one
    column-burst per t_CCD_L with half the units (paper Fig. 8), plus
    command scheduling that hides REG_WRITE in tFAW gaps and RESULT_READ
    under tRP (paper Fig. 11).

Reproduced results (benchmarks/bench_pim.py):
  Fig. 5a  -- time-mux ~2.8x GPU, pipelined ~4.3x GPU throughput;
  Fig. 12  -- Pimba vs GPU / GPU+Q / GPU+PIM end-to-end generation gains;
  Fig. 13  -- latency breakdown; Fig. 15 -- latency/memory vs output length.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np


@dataclasses.dataclass(frozen=True)
class HBMConfig:
    """Paper Table 1 (HBM2E) in memory-bus cycles @ bus_freq."""
    banks_per_bankgroup: int = 4
    bankgroups_per_pch: int = 4
    pseudo_channels: int = 16 * 2      # 40 stacks-worth scaled per device
    bus_freq_hz: float = 1.512e9
    tRP: int = 14
    tRAS: int = 34
    tCCD_S: int = 2
    tCCD_L: int = 4
    tWR: int = 16
    tRTP_L: int = 6
    tFAW: int = 30
    tRCD: int = 14
    burst_bytes: int = 32              # one column access per pseudo-channel
    row_bytes: int = 1024

    @property
    def banks(self) -> int:
        return self.banks_per_bankgroup * self.bankgroups_per_pch

    @property
    def cycle_s(self) -> float:
        return 1.0 / self.bus_freq_hz


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    """A100-class host + 40 PIM-enabled HBM modules (paper §6.1)."""
    hbm: HBMConfig = HBMConfig()
    n_stacks: int = 40
    hbm_bw_bytes: float = 2.0e12       # aggregate channel bandwidth (A100 HBM2E 40 stacks)
    gpu_flops: float = 312e12          # A100 fp16


# ---------------------------------------------------------------------------
# workload: one generation step's state updates for a whole model
# ---------------------------------------------------------------------------

#: storage format each paper system keeps its state/KV in
SYSTEM_FMT = {"gpu": "fp16", "gpu_q": "int8", "gpu_pim": "fp16",
              "pimba": "mx8"}


def _op_plan(kind: str, fmt: str, dims: Dict[str, int],
             layout: str = "dense"):
    """Plan one SPU op on the jnp backend (timing model scores logical ops).

    ``layout="paged"`` plans the block-table-native op instead: its traffic
    is page-granular (whole 128-token pages stream), which is what the
    bank-conflict model scores for the paged serving pool -- see
    ``PagedStatePool.bank_traffic``, which feeds
    :func:`placement_step_latency` bursts derived from those descriptors.
    """
    from repro import ops as OPS
    quant = OPS.StateQuantConfig(fmt=fmt, rounding="stochastic",
                                 backend="jnp")
    return OPS.plan(kind, dims, quant, "jnp", layout=layout)


def _op_traffic(plan):
    from repro import ops as OPS
    return OPS.traffic(plan)


@dataclasses.dataclass(frozen=True)
class StateWorkload:
    """One generation step's Eq. 2 invocations, one plan per layer.

    Byte counts come from the registered state-update op's own
    ``traffic(plan)`` descriptor -- the same numbers the executing call
    sites are accounted with -- not from a local formula.
    """
    batch: int
    n_layers: int
    n_heads: int
    dk: int                 # dim_head in the paper's Eq. 2
    dv: int                 # dim_state
    fmt: str = "fp16"       # storage format (fp16 GPU, int8 GPU+Q, mx8 Pimba)
    layout: str = "dense"   # operand layout (paged = block-table pools)

    @property
    def plan(self):
        return _op_plan("state_update", self.fmt,
                        dict(B=self.batch, H=self.n_heads,
                             dk=self.dk, dv=self.dv), self.layout)

    @property
    def state_bytes(self) -> float:
        """One pass over all layers' state (read side of traffic(plan))."""
        return self.n_layers * _op_traffic(self.plan).state_read

    @property
    def flops(self) -> float:
        # decay + outer + add + GEMV ≈ 6 ops per state element
        return (self.batch * self.n_layers * self.n_heads
                * self.dk * self.dv * 6.0)


#: the unfused GPU state update (decay / outer+add / GEMV as separate
#: kernels, as in the PyTorch baselines of paper Fig. 3) re-touches the
#: state between kernels; 1.7 effective passes matches the paper's measured
#: GPU latencies against pure-bandwidth time.
GPU_STATE_PASSES = 1.7
GPU_ATTN_PASSES = 1.2


def gpu_state_update_latency(w: StateWorkload, sys: SystemConfig) -> float:
    """GPU baseline: bandwidth-bound read+write of the state + operands."""
    traffic = _op_traffic(w.plan)
    bytes_moved = w.n_layers * traffic.state_total * GPU_STATE_PASSES
    t_bw = bytes_moved / sys.hbm_bw_bytes
    t_fl = w.flops / sys.gpu_flops
    return max(t_bw, t_fl)


def _bursts_per_device(w: StateWorkload, sys: SystemConfig) -> float:
    """Column accesses per pseudo-channel-bank-group pipeline."""
    h = sys.hbm
    total_bursts = w.state_bytes / h.burst_bytes
    pipes = sys.n_stacks * h.pseudo_channels
    return total_bursts / pipes


def _cycles_per_burst(h: HBMConfig, design: str) -> float:
    """Cost of one state sub-chunk (one column burst) on the owning unit.

    * ``time_multiplexed`` -- the non-pipelined unit issues read / decay /
      outer / add / dot / write as separate serialized micro-ops
      (6 x tCCD_L) and pays the read->write bus turnaround
      (tWR/2 + tRTP) per sub-chunk.
    * ``pipelined`` -- 4-stage per-bank pipeline: compute is hidden, but
      each sub-chunk still needs a read burst + a write burst on the same
      bank's row buffer plus write recovery before the next read (tWR).
    * ``pimba`` -- access interleaving: the SPU's read (upper bank) and the
      write of the previous result (bottom bank) overlap, so the write
      burst and its recovery vanish from the critical path -- same
      throughput as per-bank pipelined with HALF the units (the paper's
      headline claim is area, throughput is preserved); command scheduling
      (Fig. 11) removes the operand/result transfer overhead separately.
    """
    if design == "time_multiplexed":
        return 6 * h.tCCD_L + h.tWR / 2 + h.tRTP_L
    if design in ("pipelined", "pimba"):
        return 2 * h.tCCD_L + h.tWR
    raise ValueError(design)


def pim_state_update_latency(w: StateWorkload, sys: SystemConfig,
                             design: str) -> float:
    """Latency of the in-PIM state update under the three designs.

    Per sub-chunk (one column burst) the SPU must:
      read S, compute decay+outer+add, write S', dot-product for y.
    Column accesses across a pseudo-channel serialize on I/O gating at
    tCCD_L; what differs per design is the cost of one state sub-chunk
    (see :func:`_cycles_per_burst`).
    """
    h = sys.hbm
    bursts = _bursts_per_device(w, sys)       # per pseudo-channel
    compute_cycles = bursts * _cycles_per_burst(h, design)
    # row activate/precharge + operand (REG_WRITE) / result (RESULT_READ)
    # transfer overheads; Pimba hides them inside tFAW/tRP windows.
    rows = w.state_bytes / (h.row_bytes * sys.n_stacks * h.pseudo_channels)
    row_overhead = rows * (h.tRCD + h.tRP) / h.banks
    operand_cycles = 0.0 if design == "pimba" else bursts * h.tCCD_L * 0.5
    total_cycles = compute_cycles + row_overhead + operand_cycles
    return total_cycles * h.cycle_s


def placement_step_latency(bursts: "np.ndarray", sys: SystemConfig,
                           design: str = "pimba") -> Dict[str, float]:
    """Bank-conflict-aware latency of one decode step for a *real* page map.

    ``bursts`` is a (pseudo_channels, bank_pairs) array of column bursts the
    step issues against each bank pair -- produced by the paged pool's
    placement bookkeeping (``PagedStatePool.bank_traffic``), i.e. actual
    allocations rather than the idealized uniform traffic the closed-form
    model above assumes.

    Two serialization points govern the step:

      * each SPU (one per bank pair) retires its own bursts at
        ``cycles_per_burst(design)`` -- a hot bank pair is a straggler;
      * all bursts of a pseudo-channel share its I/O gating and serialize at
        ``tCCD_L`` -- a hot pseudo-channel bounds the step even when its
        pairs are individually balanced.

    Returns real vs. ideal (same total traffic, perfectly spread) latency
    and their ratio: ``conflict_factor`` = 1.0 means the placement costs
    nothing; the fixed-slot pool's clustered allocations score worse.
    """
    h = sys.hbm
    bursts = np.asarray(bursts, float)
    cpb = _cycles_per_burst(h, design)
    pair_cycles = bursts * cpb                          # SPU-bound
    bus_cycles = bursts.sum(axis=1) * h.tCCD_L          # pch I/O gating
    per_pch = np.maximum(bus_cycles, pair_cycles.max(axis=1, initial=0.0))
    t_real = float(per_pch.max(initial=0.0) * h.cycle_s)

    total = bursts.sum()
    n_pch, n_pairs = bursts.shape
    uniform_pair = total / (n_pch * n_pairs)
    uniform_bus = total / n_pch
    t_ideal = float(max(uniform_pair * cpb, uniform_bus * h.tCCD_L)
                    * h.cycle_s)
    return {"t_real_s": t_real, "t_ideal_s": t_ideal,
            "conflict_factor": t_real / t_ideal if t_ideal > 0 else 1.0}


def bank_trace_counters(bursts: "np.ndarray",
                        sys: SystemConfig = None,
                        design: str = "pimba") -> Dict[str, float]:
    """One decode step's bank traffic as a flat numeric dict for a
    Chrome-trace ``C`` counter event (``repro.obs``): per-pseudo-channel
    burst totals (Perfetto stacks the series), total bursts, and the
    placement model's ``conflict_factor`` / real step latency for the same
    map.  Per-bank-pair detail stays in ``placement_step_latency``; the
    per-step counter keeps a bounded key count."""
    if sys is None:
        sys = SystemConfig()
    bursts = np.asarray(bursts, float)
    rep = placement_step_latency(bursts, sys, design)
    out = {f"pch{p:02d}_bursts": float(b)
           for p, b in enumerate(bursts.sum(axis=1))}
    out["total_bursts"] = float(bursts.sum())
    out["conflict_factor"] = rep["conflict_factor"]
    out["t_real_us"] = rep["t_real_s"] * 1e6
    return out


# ---------------------------------------------------------------------------
# end-to-end generation model (Figs. 12/13/15)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelSpec:
    name: str
    n_params: float
    n_layers: int
    n_heads: int
    dk: int
    dv: int
    attn_layers: int = 0       # attention layers (hybrid / transformer)
    attn_kv_heads: int = 0     # KV heads per attention layer
    attn_head_dim: int = 0


PAPER_MODELS = {
    "retnet-2.7b": ModelSpec("retnet-2.7b", 2.7e9, 32, 10, 256, 512),
    "gla-2.7b": ModelSpec("gla-2.7b", 2.7e9, 32, 4, 320, 640),
    "hgrn2-2.7b": ModelSpec("hgrn2-2.7b", 2.7e9, 32, 20, 128, 128),
    "mamba2-2.7b": ModelSpec("mamba2-2.7b", 2.7e9, 64, 80, 128, 64),
    "zamba2-7b": ModelSpec("zamba2-7b", 7.0e9, 54, 80, 64, 64,
                           attn_layers=9, attn_kv_heads=32, attn_head_dim=80),
    "opt-6.7b": ModelSpec("opt-6.7b", 6.7e9, 0, 0, 0, 0,
                          attn_layers=32, attn_kv_heads=32, attn_head_dim=128),
}


def generation_step_latency(spec: ModelSpec, batch: int, seq_len: int,
                            sys: SystemConfig, system: str) -> Dict[str, float]:
    """One token step: projections/FFN on GPU + state update + attention.

    system: gpu | gpu_q | gpu_pim | pimba
    Returns {"proj": s, "state": s, "attn": s, "total": s}.
    """
    # GPU part: weight-bound GEMMs (batch amortizes weights)
    w_bytes = 2.0 * spec.n_params
    t_proj = max(w_bytes / sys.hbm_bw_bytes,
                 2.0 * spec.n_params * batch / sys.gpu_flops)

    fmt = SYSTEM_FMT[system]
    t_state = 0.0
    if spec.n_layers:
        w = StateWorkload(batch, spec.n_layers, spec.n_heads, spec.dk,
                          spec.dv, fmt)
        if system in ("gpu", "gpu_q"):
            t_state = gpu_state_update_latency(w, sys)
        elif system == "gpu_pim":
            t_state = pim_state_update_latency(w, sys, "time_multiplexed")
        else:
            t_state = pim_state_update_latency(w, sys, "pimba")

    t_attn = 0.0
    if spec.attn_layers:
        # one attn_decode op per layer; its traffic(plan) streams the whole
        # valid cache once (score + attend phases, read-only)
        attn_plan = _op_plan("attn_decode", fmt,
                             dict(B=batch, T=seq_len, H=spec.attn_kv_heads,
                                  KVH=spec.attn_kv_heads,
                                  dk=spec.attn_head_dim,
                                  dv=spec.attn_head_dim, n=1))
        kv_bytes = _op_traffic(attn_plan).state_read * spec.attn_layers
        if system in ("gpu", "gpu_q"):
            t_attn = kv_bytes * GPU_ATTN_PASSES / sys.hbm_bw_bytes
        else:
            # PIM attention: score+attend are read-only GEMV streams (no
            # write-back), so no tWR recovery; the host softmax bounce adds
            # a second pass over the scores for non-Pimba designs (§6.2:
            # interleaving gains less here, MX8 is the main win)
            h = sys.hbm
            bursts = kv_bytes / h.burst_bytes / (sys.n_stacks * h.pseudo_channels)
            per_burst = h.tCCD_L if system == "pimba" else h.tCCD_L * 1.5
            t_attn = bursts * per_burst * h.cycle_s
    return {"proj": t_proj, "state": t_state, "attn": t_attn,
            "total": t_proj + t_state + t_attn}


def generation_throughput(spec: ModelSpec, batch: int, seq_len: int,
                          sys: SystemConfig, system: str) -> float:
    lat = generation_step_latency(spec, batch, seq_len, sys, system)["total"]
    return batch / lat


# ---------------------------------------------------------------------------
# speculative decoding (spec_verify workload)
# ---------------------------------------------------------------------------

def spec_verify_step_latency(spec: ModelSpec, batch: int, seq_len: int,
                             k: int, sys: SystemConfig,
                             system: str) -> Dict[str, float]:
    """One speculative verify step over ``Kq = k + 1`` query positions.

    The weight streams of projections/FFN are unchanged (weights stream
    once regardless of how many positions ride the GEMM -- that is why
    verification is nearly free on a bandwidth-bound step), recurrent state
    updates run once per position, and attention streams the cache ONCE for
    all positions through the ``spec_verify`` op's own traffic descriptor.
    """
    Kq = k + 1
    w_bytes = 2.0 * spec.n_params
    t_proj = max(w_bytes / sys.hbm_bw_bytes,
                 2.0 * spec.n_params * batch * Kq / sys.gpu_flops)

    fmt = SYSTEM_FMT[system]
    t_state = 0.0
    if spec.n_layers:
        w = StateWorkload(batch, spec.n_layers, spec.n_heads, spec.dk,
                          spec.dv, fmt)
        if system in ("gpu", "gpu_q"):
            t_state = gpu_state_update_latency(w, sys) * Kq
        elif system == "gpu_pim":
            t_state = pim_state_update_latency(w, sys,
                                               "time_multiplexed") * Kq
        else:
            t_state = pim_state_update_latency(w, sys, "pimba") * Kq

    t_attn = 0.0
    if spec.attn_layers:
        plan = _op_plan("spec_verify", fmt,
                        dict(B=batch, T=seq_len, H=spec.attn_kv_heads,
                             KVH=spec.attn_kv_heads, dk=spec.attn_head_dim,
                             dv=spec.attn_head_dim, n=1, Kq=Kq))
        kv_bytes = _op_traffic(plan).state_read * spec.attn_layers
        if system in ("gpu", "gpu_q"):
            t_attn = kv_bytes * GPU_ATTN_PASSES / sys.hbm_bw_bytes
        else:
            h = sys.hbm
            bursts = kv_bytes / h.burst_bytes / (sys.n_stacks
                                                 * h.pseudo_channels)
            per_burst = h.tCCD_L if system == "pimba" else h.tCCD_L * 1.5
            t_attn = bursts * per_burst * h.cycle_s
    return {"proj": t_proj, "state": t_state, "attn": t_attn,
            "total": t_proj + t_state + t_attn}


def expected_tokens_per_spec_step(k: int, acceptance: float) -> float:
    """Expected emitted tokens of one verify step at per-draft acceptance
    probability ``a``: 1 + a + a^2 + ... + a^k (every step emits at least
    the model's own token, each consecutive accepted draft adds one)."""
    assert 0.0 <= acceptance <= 1.0
    if acceptance >= 1.0:
        return float(k + 1)
    return (1.0 - acceptance ** (k + 1)) / (1.0 - acceptance)


def spec_generation_throughput(spec: ModelSpec, batch: int, seq_len: int,
                               k: int, acceptance: float, sys: SystemConfig,
                               system: str) -> float:
    """Tokens/s of speculative serving: verify-step latency amortized over
    the expected accepted tokens (draft-source cost assumed off-device)."""
    lat = spec_verify_step_latency(spec, batch, seq_len, k, sys,
                                   system)["total"]
    return batch * expected_tokens_per_spec_step(k, acceptance) / lat
