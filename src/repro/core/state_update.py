"""The paper's core abstraction: the generalized state-update operation.

Post-transformer mixers (Mamba-2, GLA, RetNet, HGRN2, mLSTM) all reduce at
decode time to paper Eq. 2:

    S_t = d_t ⊙ S_{t-1} + k_t v_tᵀ ;   y_t = S_tᵀ q_t

This module provides the *stateful container* and the step function that the
model zoo and the serving engine build on.  The state lives in a configurable
storage format (fp32/bf16/fp16 baselines, int8, or the paper's MX8) and is
re-quantized with stochastic rounding every step -- the property Pimba's
accuracy results rest on (paper §3.2).

Storage layout for quantized states is ``(B, H, dv, dk)`` (Sᵀ) with MX groups
along dk; see kernels/mx_state_update.py for why.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import formats as F
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class StateQuantConfig:
    """How recurrent state (and KV caches) are stored."""
    fmt: str = "mx8"                 # fp32|bf16|fp16|fp8_e4m3|fp8_e5m2|int8|mx8
    rounding: str = "stochastic"     # nearest|stochastic
    backend: str = "pallas"          # pallas|jnp

    @property
    def quantized(self) -> bool:
        return self.fmt in ("mx8", "int8", "fp8_e4m3", "fp8_e5m2")


StateLike = Union[F.QuantizedTensor, jnp.ndarray]


def init_state(B: int, H: int, dk: int, dv: int,
               cfg: StateQuantConfig) -> StateLike:
    """Zero-initialized recurrent state, stored layout (B, H, dv, dk)."""
    zeros = jnp.zeros((B, H, dv, dk), jnp.float32)
    if not cfg.quantized:
        dt = {"fp32": jnp.float32, "bf16": jnp.bfloat16,
              "fp16": jnp.float16}[cfg.fmt]
        return zeros.astype(dt)
    return F.quantize(zeros, cfg.fmt)


def state_update_step(
    state: StateLike,
    d: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, q: jnp.ndarray,
    cfg: StateQuantConfig, seed=0,
) -> Tuple[StateLike, jnp.ndarray]:
    """One decode step of Eq. 2 on the stored state.

    d: (B,H,dk) or (B,H,1); k,q: (B,H,dk); v: (B,H,dv)  ->  y: (B,H,dv) f32.
    """
    if isinstance(state, F.QuantizedTensor):
        if state.fmt == "mx8":
            return ops.state_update(state, d, k, v, q, seed,
                                    rounding=cfg.rounding, backend=cfg.backend)
        # int8 / fp8 paths: jnp reference semantics (used by the format study)
        B, H, dv, dk = state.shape
        St = F.dequantize(state)
        d_ = jnp.broadcast_to(d.astype(jnp.float32), (B, H, dk))[:, :, None, :]
        Sn = St * d_ + (v.astype(jnp.float32)[..., :, None]
                        * k.astype(jnp.float32)[..., None, :])
        bits = F.sr_bits(Sn.shape, seed) if cfg.rounding == "stochastic" else None
        qSn = F.quantize(Sn, state.fmt, cfg.rounding, bits)
        y = jnp.einsum("bhvk,bhk->bhv", F.dequantize(qSn), q.astype(jnp.float32))
        return qSn, y
    Sn, y = ops.state_update_float(state, d, k, v, q, dtype=state.dtype)
    return Sn, y


def state_nbytes(B: int, H: int, dk: int, dv: int, cfg: StateQuantConfig) -> float:
    """Logical storage bytes of one layer's state (bandwidth accounting)."""
    return B * H * dk * dv * F.FORMAT_BITS[cfg.fmt] / 8.0
