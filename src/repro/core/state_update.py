"""DEPRECATED shim -- the generalized state update moved to ``repro.ops``.

The paper's core abstraction (Eq. 2)

    S_t = d_t ⊙ S_{t-1} + k_t v_tᵀ ;   y_t = S_tᵀ q_t

is now a registered SPU operator: see ``repro/ops/state_update.py`` for the
implementations and ``repro/ops/registry.py`` for (kind x backend x format)
dispatch.  This module remains importable so external scripts keep working:

* ``StateQuantConfig`` / ``StateLike`` / ``init_state`` / ``state_nbytes``
  re-export the canonical ``repro.ops`` objects (no warning -- they are
  configuration, not dispatch).
* ``state_update_step`` still works but emits
  :class:`~repro.ops.base.SpuDeprecationWarning` and forwards to
  ``repro.ops.state_update_step`` (results are identical -- it *is* the
  same registered op underneath).
"""
from __future__ import annotations

import warnings
from typing import Tuple

import jax.numpy as jnp

from repro.ops.base import SpuDeprecationWarning, StateQuantConfig  # noqa: F401
from repro.ops.state_update import (StateLike, init_state,  # noqa: F401
                                    state_nbytes)

__all__ = ["StateQuantConfig", "StateLike", "init_state", "state_nbytes",
           "state_update_step"]


def state_update_step(
    state: StateLike,
    d: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, q: jnp.ndarray,
    cfg: StateQuantConfig, seed=0,
) -> Tuple[StateLike, jnp.ndarray]:
    """Deprecated: use :func:`repro.ops.state_update_step`."""
    warnings.warn(
        "repro.core.state_update.state_update_step is deprecated; use "
        "repro.ops.state_update_step (registry-dispatched SPU op)",
        SpuDeprecationWarning, stacklevel=2)
    from repro.ops.state_update import state_update_step as _step
    return _step(state, d, k, v, q, cfg, seed=seed)
