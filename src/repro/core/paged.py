"""Paged decode-cache *views*: block-table-native operand containers.

The paged serving pool (``repro/serving/memory``) stores every KV leaf as a
page pool ``(n_pages, ..., 128, ...)`` and every recurrent-state leaf as a
slab pool ``(n_slabs, ...)``.  Until the block-table-native kernels landed,
the decode step gathered those pools into dense per-step cache trees and
scattered one token back -- tripling the decode path's own DRAM traffic.

The two containers here make the paged layout a first-class *kernel* layout
instead of a host-side compatibility shim:

``PagedKVCache``
    One attention layer's K/V page pools plus the step's block table.  The
    ``layout="paged"`` SPU ops (``repro/ops/paged_ops.py``) walk
    ``bt[B, npg]`` directly -- the Pallas kernels scalar-prefetch the page
    ids and stream each 128-token page out of the pool in place; the
    ``kv_append`` op writes the new token's K/V row into its page slot via
    ``input_output_aliases``.  No dense copy of the context ever exists.

``PagedState``
    One mixer's recurrent-state slab pool plus the step's slab ids.  The
    paged ``state_update`` op updates exactly the ``B`` owned slab rows in
    place (the slabs are per-request already, so this is the minimal
    traffic), running the same fused kernel as the dense layout on the rows.

Both carry a ``group`` index: scanned models stack their per-group leaves
``(G, ...)`` inside the pool content, and one container is shared by all
``G`` layers of a pattern position -- the decode loop re-binds ``group``
(and the step's base ``lengths``) per scan iteration via :func:`with_group`.

``PAGE_TOKENS`` is defined here (the serving layer re-exports it): 128
tokens per page *is* the MX tile, which is what lets the Pallas grid walk
the block table with one page per tile.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import formats as F

#: tokens per KV page == the MX tile / kernel alignment unit.  The paged
#: attention grid assigns exactly one page to each flash tile.
PAGE_TOKENS = 128


def pages_for(n_tokens: int) -> int:
    """Pages that hold (and stream for) an ``n_tokens`` context.

    The single definition shared by the serving allocator, the paged ops'
    traffic descriptors, and the engines' traffic meter -- these must agree
    bit-for-bit, so the ceil/min-1 semantics live in exactly one place.
    """
    return max(1, -(-int(n_tokens) // PAGE_TOKENS))


def _payload_dims(k) -> Tuple[int, ...]:
    """Pool shape of a (possibly quantized) pooled stream."""
    if isinstance(k, F.QuantizedTensor):
        return tuple(k.payload["mantissa"].shape)
    return tuple(k.shape)


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class PagedKVCache:
    """Block-table view of one attention layer's shared K/V page pools.

    ``k``/``v`` hold the *whole pool* in the normalized physical layout
    ``(n_pages, G, PAGE_TOKENS, KVH, d)`` (``G = 1`` for unstacked layers;
    quantized streams keep one pool per payload field).  ``bt`` is the
    step's dense block table, ``lengths`` the valid context per row, and
    ``group`` selects which stacked layer this view addresses.
    """
    k: object
    v: Optional[object]
    bt: jnp.ndarray                  # (B, npg) int32 physical page ids
    lengths: jnp.ndarray             # (B,) int32 valid cached positions
    group: jnp.ndarray               # () int32 stacked-layer index
    fmt: str = "mx8"
    v_width: Optional[int] = None    # MLA only
    lead_shape: Tuple[int, ...] = ()  # original group-axis shape (commit)

    def tree_flatten_with_keys(self):
        GK = jax.tree_util.GetAttrKey
        return ([(GK("k"), self.k), (GK("v"), self.v), (GK("bt"), self.bt),
                 (GK("lengths"), self.lengths), (GK("group"), self.group)],
                (self.fmt, self.v_width, self.lead_shape))

    @classmethod
    def tree_unflatten(cls, aux, children):
        k, v, bt, lengths, group = children
        return cls(k, v, bt, lengths, group, *aux)

    # -- logical geometry (read off the physical pools) -----------------

    @property
    def batch(self) -> int:
        return int(self.bt.shape[0])

    @property
    def n_page_slots(self) -> int:
        """Block-table width: pages the attention grid walks per row."""
        return int(self.bt.shape[1])

    @property
    def max_len(self) -> int:
        return self.n_page_slots * PAGE_TOKENS

    @property
    def kv_heads(self) -> int:
        return _payload_dims(self.k)[3]

    @property
    def dk(self) -> int:
        return _payload_dims(self.k)[4]

    @property
    def dv(self) -> int:
        if self.v is None:
            assert self.v_width is not None
            return self.v_width
        return _payload_dims(self.v)[4]

    def with_step(self, group, lengths: jnp.ndarray) -> "PagedKVCache":
        """Re-bind the view to one scan iteration: stacked-layer index plus
        the step's base lengths (the previous group's append bumped ours)."""
        return dataclasses.replace(self, group=jnp.asarray(group, jnp.int32),
                                   lengths=lengths)


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class PagedState:
    """Slab-pool view of one mixer's recurrent state (stored ``(B,H,dv,dk)``
    rows living at ``pool[slab_id, group]``)."""
    pool: object                     # (n_slabs, G, H, dv, d) pool (QT or array)
    slabs: jnp.ndarray               # (B,) int32 slab ids
    group: jnp.ndarray               # () int32 stacked-layer index
    fmt: str = "mx8"
    lead_shape: Tuple[int, ...] = ()

    def tree_flatten_with_keys(self):
        GK = jax.tree_util.GetAttrKey
        return ([(GK("pool"), self.pool), (GK("slabs"), self.slabs),
                 (GK("group"), self.group)],
                (self.fmt, self.lead_shape))

    @classmethod
    def tree_unflatten(cls, aux, children):
        pool, slabs, group = children
        return cls(pool, slabs, group, *aux)

    @property
    def batch(self) -> int:
        return int(self.slabs.shape[0])

    @property
    def shape(self) -> Tuple[int, int, int, int]:
        """Logical dense-state shape (B, H, dv, dk) of the viewed rows."""
        n_slabs, g, h, dv, dk = _payload_dims(self.pool)
        return (self.batch, h, dv, dk)

    def with_step(self, group, lengths=None) -> "PagedState":
        return dataclasses.replace(self, group=jnp.asarray(group, jnp.int32))


def is_paged(x) -> bool:
    return isinstance(x, (PagedKVCache, PagedState))


def split_paged(cache):
    """Split one element's cache tree into (carried, scanned) halves.

    Paged containers address shared pools and must live in the decode scan's
    *carry* (every group iteration updates the same pool); plain array
    leaves (conv tails, sLSTM carries) stay in the stacked ``(G, B, ...)``
    layout and scan as xs/ys.  Exactly one half is non-None at every node.
    """
    if cache is None:
        return None, None
    if is_paged(cache):
        return cache, None
    if isinstance(cache, dict):
        parts = {k: split_paged(v) for k, v in cache.items()}
        return ({k: p[0] for k, p in parts.items()},
                {k: p[1] for k, p in parts.items()})
    if isinstance(cache, tuple):
        parts = tuple(split_paged(v) for v in cache)
        return tuple(p[0] for p in parts), tuple(p[1] for p in parts)
    return None, cache


def merge_paged(carried, scanned):
    """Inverse of :func:`split_paged` (structure-directed overlay)."""
    if carried is None:
        return scanned
    if scanned is None or is_paged(carried):
        return carried
    if isinstance(carried, dict):
        return {k: merge_paged(carried[k], scanned.get(k))
                for k in carried}
    if isinstance(carried, tuple):
        return tuple(merge_paged(c, s) for c, s in zip(carried, scanned))
    return carried


def with_group(cache, group, lengths=None):
    """Re-bind every paged container in a carried tree to one scan step."""
    if cache is None:
        return None
    if isinstance(cache, PagedKVCache):
        return cache.with_step(group, cache.lengths if lengths is None
                               else lengths)
    if isinstance(cache, PagedState):
        return cache.with_step(group)
    if isinstance(cache, dict):
        return {k: with_group(v, group, lengths) for k, v in cache.items()}
    if isinstance(cache, tuple):
        return tuple(with_group(v, group, lengths) for v in cache)
    return cache
