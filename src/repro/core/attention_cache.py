"""Quantized KV-cache container for decode attention (paper §5.4 layout).

Keys/values are packed MX8 along the head dimension (one 16-value group per
DRAM-column-sized sub-chunk in the paper's terms).  Supports GQA caches
(separate K and V streams) and MLA caches (a single compressed latent stream
whose first ``v_width`` lanes double as values).

This module owns the *container* (:class:`KVCache`, init, recapacity, the
scatter primitive).  The decode-time *operators* on it -- token append and
attention -- are registered SPU ops (``repro/ops/attention.py``); the
:func:`append` / :func:`attend` functions here are thin wrappers kept for
callers that hold a cache directly (imported lazily to avoid an import
cycle: ``repro.ops.attention`` imports this module for the container).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import formats as F
from repro.ops.base import StateQuantConfig


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class KVCache:
    """Decode-time KV cache for one attention layer.

    k/v are either `QuantizedTensor` (packed) or plain arrays (baseline
    formats).  `lengths` is (B,) -- the number of valid cached positions per
    sequence.  For MLA, `v` is None and `k` holds the latent stream.
    """
    k: object
    v: Optional[object]
    lengths: jnp.ndarray
    fmt: str = "mx8"
    v_width: Optional[int] = None     # MLA only
    time_axis: int = 1                # time dim in the logical (B, T, ...) layout

    def tree_flatten_with_keys(self):
        GK = jax.tree_util.GetAttrKey
        return ([(GK("k"), self.k), (GK("v"), self.v),
                 (GK("lengths"), self.lengths)],
                (self.fmt, self.v_width, self.time_axis))

    @classmethod
    def tree_unflatten(cls, aux, children):
        k, v, lengths = children
        return cls(k, v, lengths, *aux)

    @property
    def max_len(self) -> int:
        shape = self.k.shape
        return shape[self.time_axis]

    @property
    def stack_offset(self) -> int:
        """How many group-stack axes prefix the logical layout.

        Leaves of a scanned model are stacked (G, B, T, ...) while the cache's
        logical layout stays (B, T, ...); ``lengths`` is logically (B,), so any
        extra leading axes on it are the stack depth.
        """
        return self.lengths.ndim - 1


def init_kv_cache(B: int, T: int, KVH: int, dk: int,
                  cfg: StateQuantConfig, dv: Optional[int] = None,
                  mla_v_width: Optional[int] = None) -> KVCache:
    """Preallocate a zeroed cache of capacity T (multiple of 128)."""
    assert T % 128 == 0, "cache capacity must be tile-aligned"
    dv = dv if dv is not None else dk
    lengths = jnp.zeros((B,), jnp.int32)
    if cfg.quantized:
        zk = F.quantize(jnp.zeros((B, T, KVH, dk), jnp.float32), cfg.fmt)
        zv = (None if mla_v_width is not None else
              F.quantize(jnp.zeros((B, T, KVH, dv), jnp.float32), cfg.fmt))
        return KVCache(zk, zv, lengths, cfg.fmt, mla_v_width)
    dt = {"fp32": jnp.float32, "bf16": jnp.bfloat16, "fp16": jnp.float16}[cfg.fmt]
    zk = jnp.zeros((B, T, KVH, dk), dt)
    zv = None if mla_v_width is not None else jnp.zeros((B, T, KVH, dv), dt)
    return KVCache(zk, zv, lengths, cfg.fmt, mla_v_width)


def _update_at(buf: jnp.ndarray, rows: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Write rows (B, n, ...) into buf (B, T, ...) at per-batch offsets idx."""
    def upd(b, r, i):
        return jax.lax.dynamic_update_slice(b, r.astype(b.dtype),
                                            (i,) + (0,) * (b.ndim - 1))
    return jax.vmap(upd)(buf, rows, idx)


def append(cache: KVCache, k_new: jnp.ndarray,
           v_new: Optional[jnp.ndarray], cfg: StateQuantConfig,
           seed=0) -> KVCache:
    """Append one (or n) token(s): k_new (B, n, KVH, dk).

    Registry-dispatched (op kind ``kv_append``); see repro/ops/attention.py.
    """
    from repro.ops.attention import kv_append
    return kv_append(cache, k_new, v_new, cfg, seed=seed)


def recapacity(caches, capacity: int):
    """Pad/trim every KV-cache time axis to ``capacity`` (exact, no guessing).

    Works on any pytree containing KVCache nodes, including group-stacked ones
    ((G, B, T, ...) leaves): the time axis of a leaf is the cache's declared
    ``time_axis`` shifted by the stack depth read off ``lengths``.  Quantized
    payload leaves all share the stacked layout, so one shift applies to every
    payload field.
    """
    assert capacity % 128 == 0, "cache capacity must be tile-aligned"

    def fix(c):
        if not isinstance(c, KVCache):
            return c
        ax = c.stack_offset + c.time_axis

        def pad_t(leaf):
            T = leaf.shape[ax]
            if T == capacity:
                return leaf
            if T > capacity:
                idx = [slice(None)] * leaf.ndim
                idx[ax] = slice(0, capacity)
                return leaf[tuple(idx)]
            pad = [(0, 0)] * leaf.ndim
            pad[ax] = (0, capacity - T)
            return jnp.pad(leaf, pad)

        def fix_stream(s):
            if s is None:
                return None
            if isinstance(s, F.QuantizedTensor):
                payload = {f: pad_t(v) for f, v in s.payload.items()}
                shape = list(s.shape)
                shape[c.time_axis] = capacity
                return F.QuantizedTensor(s.fmt, tuple(shape), payload)
            return pad_t(s)

        return KVCache(fix_stream(c.k), fix_stream(c.v), c.lengths,
                       c.fmt, c.v_width, c.time_axis)

    return jax.tree.map(fix, caches, is_leaf=lambda x: isinstance(x, KVCache))


def attend(cache: KVCache, q: jnp.ndarray, cfg: StateQuantConfig,
           scale: Optional[float] = None) -> jnp.ndarray:
    """Decode attention of current-token queries q (B,H,dk) vs the cache.

    Registry-dispatched (op kind ``attn_decode`` / ``mla_decode``); backend
    negotiation replaces the old inline mx8-vs-ref branching.
    """
    from repro.ops.attention import attn_decode
    return attn_decode(cache, q, cfg, scale=scale)
