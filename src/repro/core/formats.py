"""Low-precision numeric formats for state / KV-cache quantization.

Implements the formats studied in Pimba §3.2 / §4.2 (paper Figs. 4 and 6):

* ``mx8``      -- Microsoft MX, 8-bit average: groups of 16 values share an
                  8-bit exponent, pairs of values share a 1-bit micro-exponent,
                  each value stores sign + 6-bit mantissa.  The Pareto-optimal
                  format chosen by the paper.
* ``int8``     -- 8-bit integer with a per-32-element scale (the "GPU+Q"
                  baseline format).
* ``fp8_e4m3`` / ``fp8_e5m2`` -- 8-bit floats (shown by the paper to suffer
                  from swamping in state-update workloads).
* ``fp16`` / ``bf16`` / ``fp32`` -- reference formats.

Each format supports round-to-nearest-even and stochastic rounding (SR).
SR consumes caller-supplied uniform uint32 bits so that the host path and the
Pallas kernel path (which generates bits with the same counter-based hash,
see :func:`counter_hash_u32`) are bit-compatible and reproducible.

All quantization groups run along the **last** axis of the input.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Format registry
# ---------------------------------------------------------------------------

MX8_GROUP = 16          # values per shared exponent
MX8_PAIR = 2            # values per micro-exponent
MX8_MBITS = 6           # mantissa magnitude bits (sign stored separately)
INT8_GROUP = 32         # values per scale in the int8-scaled format

FORMATS = ("fp32", "bf16", "fp16", "fp8_e4m3", "fp8_e5m2", "int8", "mx8")
ROUNDINGS = ("nearest", "stochastic")

#: average storage bits per value, used for memory/bandwidth accounting.
FORMAT_BITS: Dict[str, float] = {
    "fp32": 32.0,
    "bf16": 16.0,
    "fp16": 16.0,
    "fp8_e4m3": 8.0,
    "fp8_e5m2": 8.0,
    # 8 bits + fp16 scale per 32 values
    "int8": 8.0 + 16.0 / INT8_GROUP,
    # sign+6b mantissa + 8b exponent / 16 + 1b microexponent / 2
    "mx8": (1 + MX8_MBITS) + 8.0 / MX8_GROUP + 1.0 / MX8_PAIR,
}

_FP8_MAX = {"fp8_e4m3": 448.0, "fp8_e5m2": 57344.0}
_FP8_MBITS = {"fp8_e4m3": 3, "fp8_e5m2": 2}
_FP8_EMIN = {"fp8_e4m3": -6, "fp8_e5m2": -14}   # min normal exponent
_FP8_DTYPE = {"fp8_e4m3": jnp.float8_e4m3fn, "fp8_e5m2": jnp.float8_e5m2}

#: bias applied to the stored MX group exponent (uint8).
MX8_EXP_BIAS = 127


# ---------------------------------------------------------------------------
# QuantizedTensor pytree
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class QuantizedTensor:
    """An opaque quantized array.  ``payload`` holds format-specific parts."""

    fmt: str
    shape: tuple
    payload: Dict[str, jnp.ndarray]

    def tree_flatten_with_keys(self):
        keys = tuple(sorted(self.payload))
        children = [(jax.tree_util.DictKey(k), self.payload[k]) for k in keys]
        return children, (self.fmt, self.shape, keys)

    @classmethod
    def tree_unflatten(cls, aux, children):
        fmt, shape, keys = aux
        return cls(fmt, shape, dict(zip(keys, children)))

    @property
    def nbytes_logical(self) -> float:
        """Logical storage bytes (as a real packed implementation would use)."""
        n = float(np.prod(self.shape))
        return n * FORMAT_BITS[self.fmt] / 8.0


# ---------------------------------------------------------------------------
# Random bits for stochastic rounding
# ---------------------------------------------------------------------------

def counter_hash_u32(counter: jnp.ndarray, seed) -> jnp.ndarray:
    """Counter-based stateless PRNG ("lowbias32" integer hash).

    This is the software analogue of Pimba's per-SPE LFSR: cheap, stateless,
    and identical between the host reference path and the Pallas kernels (it
    uses only elementwise uint32 ops, so it lowers to the TPU VPU directly).
    """
    x = counter.astype(jnp.uint32) ^ (jnp.uint32(seed) * jnp.uint32(0x9E3779B9))
    x ^= x >> 16
    x *= jnp.uint32(0x7FEB352D)
    x ^= x >> 15
    x *= jnp.uint32(0x846CA68B)
    x ^= x >> 16
    return x


def sr_bits(shape, seed, offset=0) -> jnp.ndarray:
    """Uniform uint32 bits for SR over an array of ``shape``."""
    n = int(np.prod(shape))
    idx = jnp.arange(n, dtype=jnp.uint32) + jnp.uint32(offset)
    return counter_hash_u32(idx, seed).reshape(shape)


def _u32_to_unit(bits: jnp.ndarray) -> jnp.ndarray:
    """uint32 -> uniform in [0, 1)."""
    return bits.astype(jnp.float32) * jnp.float32(2.0 ** -32)


def _round(x: jnp.ndarray, rounding: str, bits: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Round float values to integers with RNE or SR."""
    if rounding == "nearest":
        return jnp.round(x)  # round-half-to-even
    if bits is None:
        raise ValueError("stochastic rounding requires random bits")
    return jnp.floor(x + _u32_to_unit(bits))


# ---------------------------------------------------------------------------
# MX8
# ---------------------------------------------------------------------------

def _frexp_exponent(x: jnp.ndarray) -> jnp.ndarray:
    """e such that 2^(e-1) <= x < 2^e for normal x>0 (0 -> very small exponent).

    Implemented by exponent-field extraction (not ``jnp.frexp``) so the exact
    same integer ops run inside Pallas kernels and on the host -- this is what
    makes kernel-vs-reference comparisons bitwise, and it is also how the
    hardware exponent unit works.
    """
    raw = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
    e = ((raw >> 23) & 0xFF) - 126
    return jnp.where(x > 0, e, -MX8_EXP_BIAS + 1).astype(jnp.int32)


def mx8_quantize(x: jnp.ndarray, rounding: str = "nearest",
                 bits: Optional[jnp.ndarray] = None) -> QuantizedTensor:
    """Quantize to MX8 along the last axis (length must divide MX8_GROUP)."""
    orig_shape = x.shape
    n = x.shape[-1]
    assert n % MX8_GROUP == 0, f"last dim {n} not divisible by {MX8_GROUP}"
    xf = x.astype(jnp.float32)
    g = xf.reshape(*x.shape[:-1], n // MX8_GROUP, MX8_GROUP)
    gmax = jnp.max(jnp.abs(g), axis=-1)                       # (..., G)
    e = _frexp_exponent(gmax)                                  # shared exponent
    e = jnp.clip(e, -MX8_EXP_BIAS + 1, 127)

    p = g.reshape(*g.shape[:-1], MX8_GROUP // MX8_PAIR, MX8_PAIR)
    pmax = jnp.max(jnp.abs(p), axis=-1)                        # (..., G, 8)
    # micro-exponent: 1 => pair magnitudes fit in half the group range, so we
    # can shift the pair scale down one binade and gain a mantissa bit.
    micro = (pmax < jnp.exp2((e - 1)[..., None].astype(jnp.float32))).astype(jnp.int32)
    scale = jnp.exp2((e[..., None] - MX8_MBITS - micro).astype(jnp.float32))  # (...,G,8)
    q = p / scale[..., None]
    if bits is not None:
        bits = bits.reshape(p.shape)
    q = _round(q, rounding, bits)
    q = jnp.clip(q, -63, 63).astype(jnp.int8)

    mant = q.reshape(*x.shape[:-1], n)
    exp_stored = (e + MX8_EXP_BIAS).astype(jnp.uint8)
    # pack the 8 pair-bits of each group into one byte (iota-based so the
    # same code can run inside Pallas kernel bodies)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, micro.shape, micro.ndim - 1)
    micro_packed = jnp.sum(
        jnp.left_shift(micro.astype(jnp.uint32), shifts), axis=-1).astype(jnp.uint8)
    return QuantizedTensor("mx8", orig_shape, {
        "mantissa": mant, "exponent": exp_stored, "micro": micro_packed,
    })


def mx8_dequantize(qt: QuantizedTensor) -> jnp.ndarray:
    mant = qt.payload["mantissa"].astype(jnp.float32)
    e = qt.payload["exponent"].astype(jnp.int32) - MX8_EXP_BIAS   # (..., G)
    mp = qt.payload["micro"].astype(jnp.int32)                     # (..., G)
    bshape = mp.shape + (MX8_GROUP // MX8_PAIR,)
    shifts = jax.lax.broadcasted_iota(jnp.int32, bshape, mp.ndim)
    micro = (mp[..., None] >> shifts) & 1                          # (..., G, 8)
    scale = jnp.exp2((e[..., None] - MX8_MBITS - micro).astype(jnp.float32))
    n = qt.shape[-1]
    p = mant.reshape(*mant.shape[:-1], n // MX8_GROUP, MX8_GROUP // MX8_PAIR, MX8_PAIR)
    out = p * scale[..., None]
    return out.reshape(qt.shape)


# ---------------------------------------------------------------------------
# int8 with per-group scale
# ---------------------------------------------------------------------------

def int8_quantize(x: jnp.ndarray, rounding: str = "nearest",
                  bits: Optional[jnp.ndarray] = None) -> QuantizedTensor:
    orig_shape = x.shape
    n = x.shape[-1]
    assert n % INT8_GROUP == 0, f"last dim {n} not divisible by {INT8_GROUP}"
    xf = x.astype(jnp.float32)
    g = xf.reshape(*x.shape[:-1], n // INT8_GROUP, INT8_GROUP)
    gmax = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    scale = jnp.where(gmax > 0, gmax / 127.0, 1.0)
    q = g / scale
    if bits is not None:
        bits = bits.reshape(g.shape)
    q = jnp.clip(_round(q, rounding, bits), -127, 127).astype(jnp.int8)
    return QuantizedTensor("int8", orig_shape, {
        "q": q.reshape(*x.shape[:-1], n),
        "scale": scale.squeeze(-1).astype(jnp.float16),
    })


def int8_dequantize(qt: QuantizedTensor) -> jnp.ndarray:
    q = qt.payload["q"].astype(jnp.float32)
    scale = qt.payload["scale"].astype(jnp.float32)
    n = qt.shape[-1]
    g = q.reshape(*q.shape[:-1], n // INT8_GROUP, INT8_GROUP)
    return (g * scale[..., None]).reshape(qt.shape)


# ---------------------------------------------------------------------------
# fp8 (emulated)
# ---------------------------------------------------------------------------

def _fp8_quantize_values(x: jnp.ndarray, fmt: str, rounding: str,
                         bits: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Returns fp8 values stored as their own dtype."""
    xf = x.astype(jnp.float32)
    fmax = _FP8_MAX[fmt]
    xf = jnp.clip(xf, -fmax, fmax)
    if rounding == "nearest":
        return xf.astype(_FP8_DTYPE[fmt])
    # Stochastic rounding: snap to the ulp grid of the target format, then the
    # exact cast is value-preserving.
    mbits = _FP8_MBITS[fmt]
    _, e = jnp.frexp(xf)
    e = jnp.where(xf != 0, e, _FP8_EMIN[fmt])
    # exponent of the representable binade: 2^(e-1) <= |x| < 2^e
    ulp_exp = jnp.maximum(e - 1, _FP8_EMIN[fmt]) - mbits
    ulp = jnp.exp2(ulp_exp.astype(jnp.float32))
    q = jnp.floor(xf / ulp + _u32_to_unit(bits)) * ulp
    q = jnp.clip(q, -fmax, fmax)
    return q.astype(_FP8_DTYPE[fmt])


def fp8_quantize(x: jnp.ndarray, fmt: str, rounding: str = "nearest",
                 bits: Optional[jnp.ndarray] = None) -> QuantizedTensor:
    return QuantizedTensor(fmt, x.shape,
                           {"x": _fp8_quantize_values(x, fmt, rounding, bits)})


# ---------------------------------------------------------------------------
# Unified entry points
# ---------------------------------------------------------------------------

def quantize(x: jnp.ndarray, fmt: str, rounding: str = "nearest",
             bits: Optional[jnp.ndarray] = None) -> QuantizedTensor:
    """Quantize ``x`` (groups along the last axis) into ``fmt``."""
    if fmt == "mx8":
        return mx8_quantize(x, rounding, bits)
    if fmt == "int8":
        return int8_quantize(x, rounding, bits)
    if fmt in _FP8_DTYPE:
        return fp8_quantize(x, fmt, rounding, bits)
    if fmt in ("fp16", "bf16"):
        dt = jnp.float16 if fmt == "fp16" else jnp.bfloat16
        return QuantizedTensor(fmt, x.shape, {"x": x.astype(dt)})
    if fmt == "fp32":
        return QuantizedTensor(fmt, x.shape, {"x": x.astype(jnp.float32)})
    raise ValueError(f"unknown format {fmt!r}")


def dequantize(qt: QuantizedTensor) -> jnp.ndarray:
    if qt.fmt == "mx8":
        return mx8_dequantize(qt)
    if qt.fmt == "int8":
        return int8_dequantize(qt)
    return qt.payload["x"].astype(jnp.float32)


def quantize_like(x: jnp.ndarray, qt: QuantizedTensor, rounding: str = "nearest",
                  bits: Optional[jnp.ndarray] = None) -> QuantizedTensor:
    return quantize(x, qt.fmt, rounding, bits)


# ---------------------------------------------------------------------------
# "Strict" MX arithmetic (paper §5.3 adder/multiplier semantics)
# ---------------------------------------------------------------------------
# Pimba's SPE computes directly on MX operands with shift-aligned integer
# add/multiply.  On TPU we compute in f32 between MX8 load/store (see
# DESIGN.md §2); the functions below emulate the *stricter* hardware
# semantics -- every intermediate re-enters MX8 -- for the accuracy study.

def strict_mx_add(a: jnp.ndarray, b: jnp.ndarray, rounding: str = "nearest",
                  bits: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """(quantize(a) + quantize(b)) requantized: models the MX adder path."""
    s = dequantize(mx8_quantize(a)) + dequantize(mx8_quantize(b))
    return dequantize(mx8_quantize(s, rounding, bits))


def strict_mx_mul(a: jnp.ndarray, b: jnp.ndarray, rounding: str = "nearest",
                  bits: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    p = dequantize(mx8_quantize(a)) * dequantize(mx8_quantize(b))
    return dequantize(mx8_quantize(p, rounding, bits))
