"""Deterministic, resumable synthetic data pipeline.

Real deployments swap in a tokenized corpus reader; the contract this module
fixes is the part that matters for fault tolerance and reproducibility:

  * **step-indexed**: batch(step) is a pure function of (seed, step), so a
    restarted job resumes mid-epoch with zero pipeline state to checkpoint
    and identical data order.
  * **shard-aware**: each data-parallel host can materialize only its slice
    (``host_slice``) -- nothing global is required in memory.
  * **structured synthetic text**: tokens follow a Zipfian unigram mixed
    with a copy/induction pattern so language models have actual structure
    to learn (losses fall well below uniform entropy; used by the examples
    and convergence tests).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    zipf_a: float = 1.2
    copy_period: int = 64      # induction-head structure


def _rng(cfg: DataConfig, step: int, host: int = 0) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, host]))


def _zipf_probs(cfg: DataConfig) -> np.ndarray:
    ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
    p = ranks ** (-cfg.zipf_a)
    return p / p.sum()


class SyntheticLM:
    """batch(step) -> {'tokens','targets','mask'} with LM structure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._probs = _zipf_probs(cfg)

    def batch(self, step: int, host: int = 0, n_hosts: int = 1
              ) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        assert cfg.global_batch % n_hosts == 0
        b = cfg.global_batch // n_hosts
        rng = _rng(cfg, step, host)
        toks = rng.choice(cfg.vocab_size, size=(b, cfg.seq_len + 1),
                          p=self._probs).astype(np.int32)
        # copy structure: second half of each period repeats the first half
        P = cfg.copy_period
        half = P // 2
        n_per = (cfg.seq_len + 1) // P
        for i in range(n_per):
            s = i * P
            toks[:, s + half:s + P] = toks[:, s:s + half]
        return {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
            "mask": np.ones((b, cfg.seq_len), np.float32),
        }


def make_batch_fn(model_cfg: ModelConfig, seq_len: int, global_batch: int,
                  seed: int = 0):
    """Step-indexed batch function for any model family (train loop input)."""
    if model_cfg.family == "vlm":
        s_text = seq_len - model_cfg.prefix_len
        lm = SyntheticLM(DataConfig(s_text, global_batch,
                                    model_cfg.vocab_size, seed))

        def fn(step: int):
            b = lm.batch(step)
            rng = _rng(lm.cfg, step, host=999)
            b["patches"] = rng.standard_normal(
                (global_batch, model_cfg.prefix_len,
                 model_cfg.frontend_dim)).astype(np.float32)
            return b
        return fn
    if model_cfg.family == "audio":
        lm = SyntheticLM(DataConfig(seq_len, global_batch,
                                    model_cfg.vocab_size, seed))

        def fn(step: int):
            b = lm.batch(step)
            rng = _rng(lm.cfg, step, host=998)
            frames = rng.standard_normal(
                (global_batch, seq_len, model_cfg.frontend_dim)).astype(np.float32)
            # masked-prediction objective: loss on a random ~8% span mask
            mask = (rng.random((global_batch, seq_len)) < 0.08).astype(np.float32)
            return {"frames": frames, "targets": b["targets"], "mask": mask}
        return fn
    lm = SyntheticLM(DataConfig(seq_len, global_batch,
                                model_cfg.vocab_size, seed))
    return lambda step: lm.batch(step)
