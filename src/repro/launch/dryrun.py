import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init).  This module is the only place that requests 512
placeholder devices; smoke tests and benchmarks see the single real CPU.

Per cell this driver:
  1. builds the production mesh ((16,16) or (2,16,16)),
  2. builds ShapeDtypeStruct stand-ins for params / optimizer / inputs,
  3. jits the step with explicit in/out shardings and ``.lower().compile()``,
  4. records memory_analysis(), cost_analysis(), and the collective schedule
     parsed from the partitioned HLO into a JSON artifact for §Roofline.
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import ops as OPS
from repro.analysis import roofline as RL
from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, cell_supported, get_config
from repro.dist import sharding as SH
from repro.launch import specs as SP
from repro.launch.mesh import make_parallel
from repro.models import model as M
from repro.models.config import SHAPES
from repro.train import optimizer as O
from repro.train.train_loop import make_train_step

# the dry run forces the jnp backend: interpret-mode pallas would trace its
# grid as an unrolled Python loop (compile-time explosion at production
# sizes) and distort cost analysis -- see repro/ops/state_update.py
DRYRUN_QUANT = OPS.StateQuantConfig(fmt="mx8", rounding="stochastic",
                                    backend="jnp")


def dryrun_config(arch: str, **overrides):
    # fail fast if the forced (op, format, backend) triple ever unregisters
    for kind in OPS.OP_KINDS:
        OPS.resolve_backend(kind, DRYRUN_QUANT.fmt, DRYRUN_QUANT.backend,
                            strict=True)
    cfg = get_config(arch).with_(
        param_dtype="bfloat16",
        state_quant=DRYRUN_QUANT,
        scan_layers=True,
        remat=True,
    )
    return cfg.with_(**overrides) if overrides else cfg


def _mem_dict(mem) -> Dict[str, float]:
    return {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "peak_estimate_bytes": (mem.argument_size_in_bytes
                                + mem.output_size_in_bytes
                                + mem.temp_size_in_bytes
                                - mem.alias_size_in_bytes),
    }


# production tuning choices per cell (recorded in EXPERIMENTS.md):
# zamba2 train microbatches 2x -- its 6-mamba+shared-attn group holds the
# largest per-group working set of the fleet.
CELL_TUNING = {
    ("zamba2-2.7b", "train_4k"): {"grad_accum": 2},
    # 236B on 256 chips: ZeRO moments alone are 7.4 GiB/chip; microbatch 4x
    # to bound activations
    ("deepseek-v2-236b", "train_4k"): {"grad_accum": 8},
    # the mLSTM chunk-state residuals are the big ticket; microbatch 2x
    ("xlstm-1.3b", "train_4k"): {"grad_accum": 8},
}


def _compile_step(cfg, sc, par, p_shapes, p_shard, grad_accum: int = 1,
                  serve_2d: bool = False):
    """jit+lower+compile the cell's step function; returns compiled exe.

    serve_2d: Pope-style 2D weight-stationary serving -- weights stay
    sharded over (data x model), the batch is replicated, caches shard their
    time axis over BOTH mesh axes, and per-layer activations are all-reduced
    instead of gathering P/tp weight bytes every token."""
    if sc.kind == "train":
        opt = O.OptimizerConfig()
        o_shapes = jax.eval_shape(lambda p: O.init_opt_state(p, opt), p_shapes)
        o_shard = SH.opt_state_shardings(o_shapes, p_shard, par)
        b_shapes = SP.batch_struct(cfg, sc)
        b_shard = SH.batch_shardings(b_shapes, par)
        step = make_train_step(cfg, opt, par=par, grad_accum=grad_accum)
        out_shapes = jax.eval_shape(step, p_shapes, o_shapes, b_shapes)
        m_shard = jax.tree.map(lambda _: SH.replicated(par), out_shapes[2])
        jitted = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                         out_shardings=(p_shard, o_shard, m_shard),
                         donate_argnums=(0, 1))
        return jitted.lower(p_shapes, o_shapes, b_shapes).compile()
    if sc.kind == "prefill":
        b_shapes = SP.batch_struct(cfg, sc)
        b_shard = SH.batch_shardings(b_shapes, par)

        def prefill_step(params, batch):
            return M.prefill(params, cfg, batch, mesh_axes=par)

        out_shapes = jax.eval_shape(prefill_step, p_shapes, b_shapes)
        out_shard = _prefill_out_shardings(out_shapes, cfg, par, sc)
        jitted = jax.jit(prefill_step, in_shardings=(p_shard, b_shard),
                         out_shardings=out_shard)
        return jitted.lower(p_shapes, b_shapes).compile()
    # decode
    tok_s, len_s, cache_shapes = SP.decode_struct(cfg, sc)
    if serve_2d:
        # batch replicated; cache time axis over (data x model)
        c_shard = SH.cache_shardings(cache_shapes, cfg, par, 1)
        t_shard = SH.replicated(par)
    else:
        c_shard = SH.cache_shardings(cache_shapes, cfg, par, sc.global_batch)
        t_shard = SH.batch_shardings(tok_s, par) \
            if sc.global_batch % par.batch_size_divisor == 0 \
            else SH.replicated(par)

    def serve_step(params, tokens, lengths, caches):
        return M.decode_step(params, cfg, tokens, caches, lengths,
                             seed=0, mesh_axes=par)

    out_shapes = jax.eval_shape(serve_step, p_shapes, tok_s, len_s,
                                cache_shapes)
    logits_shard = _logits_sharding(out_shapes[0], cfg, par,
                                    sc if not serve_2d else
                                    dataclasses.replace(sc, global_batch=1))
    jitted = jax.jit(serve_step,
                     in_shardings=(p_shard, t_shard, t_shard, c_shard),
                     out_shardings=(logits_shard, c_shard),
                     donate_argnums=(3,))
    return jitted.lower(p_shapes, tok_s, len_s, cache_shapes).compile()


def _probe_costs(compiled, par) -> Dict[str, float]:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):      # jax 0.4.x wraps it per-device
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = RL.parse_collectives(hlo, default_group=par.mesh.shape[par.model_axis])
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "link_bytes": coll.total_link_bytes,
            "collectives": coll.by_kind,
            "n_collectives": coll.op_count}


def _slstm_correction(cfg, sc, par) -> Dict[str, float]:
    """Analytic cost for sLSTM inner time-step loops.

    The per-token recurrence cannot be unrolled for the cost probe (S steps);
    its per-step cost is added analytically (recurrent einsum + gates)."""
    n_sl = cfg.pattern.count("slstm") * cfg.n_groups
    if n_sl == 0 or sc.kind == "decode":
        return {"flops": 0.0, "bytes": 0.0}
    from repro.models.ssm import _slstm_dims
    H, dh = _slstm_dims(cfg)
    data_sz = par.batch_size_divisor
    b_loc = max(sc.global_batch // data_sz, 1)
    per_step_flops = 2.0 * b_loc * H * dh * 4 * dh + 30.0 * b_loc * H * dh
    per_step_bytes = 12.0 * b_loc * H * dh * 4
    mult = 3.0 if sc.kind == "train" else 1.0     # fwd + bwd + remat
    steps = sc.seq_len - 1                         # probe counted one step
    return {"flops": n_sl * steps * per_step_flops * mult,
            "bytes": n_sl * steps * per_step_bytes * mult}


def lower_cell(arch: str, shape: str, multi_pod: bool = False,
               cfg_overrides: Optional[dict] = None,
               verbose: bool = True, skip_probe: bool = False,
               probe_from: Optional[Dict[str, Any]] = None,
               serve_2d: bool = False) -> Dict[str, Any]:
    """Lower+compile one cell; returns the roofline record.

    Compilations per cell:
      1. the production step (scan-over-layers, flash chunking) -- this is
         the deployment artifact; memory_analysis comes from here, and this
         compile succeeding IS the dry-run pass criterion.
      2. a FLOPs probe (XLA's cost_analysis counts while bodies ONCE, so the
         production HLO under-reports FLOPs): inner scans unrolled, layer
         loop unrolled at 1- and 2-group depth, extrapolated linearly to the
         full depth.  sLSTM time loops are corrected analytically.

    HBM and ICI byte terms use the analytic models in analysis/roofline.py
    (XLA:CPU's bytes-accessed reflects CPU-backend fusion, not TPU); the
    HLO-parsed numbers are kept in the record as diagnostics.

    ``probe_from``: reuse another mesh's probe, rescaled by per-chip token
    share (used for the multi-pod pass: same model, 2x the data shards).
    """
    sc = SHAPES[shape]
    ok, reason = cell_supported(arch, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                "status": "skipped", "reason": reason}

    t0 = time.time()
    par = make_parallel(multi_pod=multi_pod)
    cfg = dryrun_config(arch, **(cfg_overrides or {}))
    tuning = CELL_TUNING.get((arch, shape), {})
    grad_accum = tuning.get("grad_accum", 1)
    n_chips = int(np.prod(list(par.mesh.shape.values())))
    pods = par.mesh.shape.get("pod", 1)

    p_shapes = SP.params_struct(cfg)
    p_shard = SH.param_shardings(p_shapes, cfg, par)
    n_params = RL.count_params(p_shapes)

    with par.mesh:
        compiled = _compile_step(cfg, sc, par, p_shapes, p_shard, grad_accum,
                                 serve_2d=serve_2d)
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    hlo_diag = _probe_costs(compiled, par)

    # ---- FLOPs probe ----
    pat = len(cfg.pattern)
    pre = len(cfg.prelude)
    if probe_from is not None and probe_from.get("status") == "ok":
        scale = probe_from["n_chips"] / n_chips
        flops_per_chip = probe_from["flops_per_chip"] * scale
        probe_diag = {"reused_from_chips": probe_from["n_chips"]}
    elif not skip_probe:
        # attention-free architectures have FLOPs linear in S (chunked LA is
        # O(S*c) intra + O(S/c * dk*dv) inter); probe at reduced seq and
        # scale back -- exact, and keeps the unrolled probe compile tractable
        has_attn = (any(k in ("attn", "mla") for k in cfg.pattern + cfg.prelude)
                    or cfg.shared_attn)
        if not has_attn and sc.kind in ("train", "prefill") \
                and sc.seq_len > 4096:
            sc_probe = dataclasses.replace(sc, seq_len=4096)
            s_scale = sc.seq_len / sc_probe.seq_len
        else:
            sc_probe, s_scale = sc, 1.0
        ks = (2, 4) if cfg.n_groups >= 4 else (1, 2)
        probes = {}
        # probe with large LA chunks: the unrolled chunk count drives probe
        # compile time, while intra-chunk FLOPs (the only c-dependent term,
        # O(S*c*dk) vs the O(S*dk*dv) state term) shift by <2% of the total
        probe_ssm = (dataclasses.replace(cfg.ssm, chunk=512)
                     if cfg.ssm is not None else None)
        for k in ks:
            cfg_k = cfg.with_(cost_probe=True, scan_layers=False,
                              n_layers=pre + k * pat, ssm=probe_ssm,
                              attn_q_chunk=4096, attn_kv_chunk=4096)
            pk_shapes = SP.params_struct(cfg_k)
            pk_shard = SH.param_shardings(pk_shapes, cfg_k, par)
            with par.mesh:
                # grad_accum=1: the microbatch loop is a while body that
                # cost_analysis counts once; accumulation doesn't change FLOPs
                ck = _compile_step(cfg_k, sc_probe, par, pk_shapes, pk_shard, 1)
            probes[k] = _probe_costs(ck, par)
        corr = _slstm_correction(cfg, sc_probe, par)
        k1, k2 = ks
        delta = (probes[k2]["flops"] - probes[k1]["flops"]) / (k2 - k1)
        if delta > 0:
            flops = probes[k2]["flops"] + (cfg.n_groups - k2) * delta
        else:
            # GSPMD partitioned the two probe depths differently; fall back
            # to scaling the deeper probe by group count
            flops = probes[k2]["flops"] * cfg.n_groups / k2
        flops_per_chip = (flops + corr["flops"]) * s_scale
        probe_diag = {f"probe{k1}_flops": probes[k1]["flops"],
                      f"probe{k2}_flops": probes[k2]["flops"],
                      "slstm_corr_flops": corr["flops"],
                      "seq_scale": s_scale}
    else:
        flops_per_chip = hlo_diag["flops"]
        probe_diag = {"unscaled_hlo": True}

    ac = RL.analytic_cost(cfg, sc, chips=n_chips, tp=par.tp, fs=par.fsdp,
                          pods=pods, n_params=n_params, grad_accum=grad_accum,
                          serve_2d=serve_2d)

    if sc.kind == "train":
        tokens = sc.global_batch * sc.seq_len
        model_flops = RL.model_flops_train(_active_params(cfg, n_params), tokens)
    elif sc.kind == "prefill":
        tokens = sc.global_batch * sc.seq_len
        model_flops = (2.0 / 6.0) * RL.model_flops_train(
            _active_params(cfg, n_params), tokens)
    else:
        model_flops = RL.model_flops_decode(_active_params(cfg, n_params),
                                            sc.global_batch)

    rf = RL.Roofline(flops_per_chip, ac["hbm_bytes"], ac["link_bytes"],
                     model_flops=model_flops, n_chips=n_chips)
    rec = {
        "arch": arch, "shape": shape, "multi_pod": multi_pod,
        "status": "ok", "n_chips": n_chips, "n_params": n_params,
        "kind": sc.kind, "tuning": tuning, "serve_2d": serve_2d,
        "flops_per_chip": flops_per_chip,
        "hbm_bytes_per_chip": ac["hbm_bytes"],
        "link_bytes_per_chip": ac["link_bytes"],
        "cache_bytes_total": ac["cache_bytes_total"],
        "hlo_diag": hlo_diag,            # CPU-backend cost/collective parse
        "probe": probe_diag,
        "memory": _mem_dict(mem),
        "roofline": rf.row(),
        "model_flops": model_flops,
        "elapsed_s": round(time.time() - t0, 1),
        "hlo_bytes": len(hlo),
    }
    if verbose:
        mm = rec["memory"]
        print(f"[{arch} x {shape} x {'2pod' if multi_pod else '1pod'}] OK "
              f"args={mm['argument_bytes']/2**30:.2f}GiB "
              f"temp={mm['temp_bytes']/2**30:.2f}GiB "
              f"t_comp={rf.t_compute*1e3:.2f}ms t_mem={rf.t_memory*1e3:.2f}ms "
              f"t_coll={rf.t_collective*1e3:.2f}ms -> {rf.bottleneck} "
              f"({rec['elapsed_s']:.0f}s)",
              flush=True)
    return rec


def _active_params(cfg, n_params: float) -> float:
    """Active params per token (MoE: routed top_k + shared only)."""
    if cfg.moe is None:
        return n_params
    mc = cfg.moe
    expert_p = 3 * cfg.d_model * mc.d_expert      # wi, wg, wo per expert
    n_moe_layers = cfg.n_layers - len(cfg.prelude)
    inactive = (mc.n_experts - mc.top_k) * expert_p * n_moe_layers
    return n_params - inactive


def _logits_sharding(logits_shape, cfg, par, sc):
    dims = [None] * len(logits_shape.shape)
    if sc.global_batch % par.batch_size_divisor == 0:
        dims[0] = par.batch_axes
    if logits_shape.shape[-1] % par.tp == 0:
        dims[-1] = par.model_axis
    return par.named(jax.sharding.PartitionSpec(*dims))


def _prefill_out_shardings(out_shapes, cfg, par, sc):
    logits_s, cache_s = out_shapes
    lsh = _logits_sharding(logits_s, cfg, par, sc)
    if cache_s is None:
        return (lsh, None)
    csh = SH.cache_shardings(cache_s, cfg, par, sc.global_batch)
    return (lsh, csh)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch (default: sweep)")
    ap.add_argument("--archs", default=None,
                    help="comma-separated subset to sweep")
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--include-paper-models", action="store_true")
    ap.add_argument("--resume", default=None,
                    help="existing results json: completed cells are kept")
    args = ap.parse_args(argv)

    if args.arch:
        archs = [args.arch]
    elif args.archs:
        archs = args.archs.split(",")
    else:
        archs = list(ALL_ARCHS if args.include_paper_models else ASSIGNED_ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    done = {}
    if args.resume:
        try:
            for rec in json.load(open(args.resume)):
                if rec.get("status") in ("ok", "skipped"):
                    done[(rec["arch"], rec["shape"],
                          bool(rec.get("multi_pod")))] = rec
            print(f"resuming: {len(done)} cells already complete", flush=True)
        except FileNotFoundError:
            pass

    results = []
    failures = 0
    for arch in archs:
        for shape in shapes:
            prior = None
            for mp in sorted(meshes):         # single-pod first: probe reuse
                if (arch, shape, mp) in done:
                    rec = done[(arch, shape, mp)]
                    if not mp:
                        prior = rec
                    results.append(rec)
                    continue
                try:
                    rec = lower_cell(arch, shape, multi_pod=mp,
                                     probe_from=prior if mp else None)
                    if not mp:
                        prior = rec
                except Exception as e:  # a failure here is a framework bug
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                           "status": "FAILED", "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                    print(f"[{arch} x {shape} x "
                          f"{'2pod' if mp else '1pod'}] FAILED: {e}",
                          flush=True)
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    print(f"\n{len(results)} cells, {failures} failures -> {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
