"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is pure
data parallelism over the inter-pod (DCN-class) network -- only gradient
all-reduces cross it, optionally int8-compressed (dist/compression.py).

Functions, not module-level constants: importing this module must never
touch jax device state (the dry run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro.dist.sharding import Parallel


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_parallel(*, multi_pod: bool = False) -> Parallel:
    mesh = make_production_mesh(multi_pod=multi_pod)
    data_axes = ("pod", "data") if multi_pod else ("data",)
    return Parallel(mesh=mesh, data_axes=data_axes, model_axis="model")


def make_local_parallel(data: int = 2, model: int = 4) -> Parallel:
    """Small mesh over host devices (tests)."""
    mesh = jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
    return Parallel(mesh=mesh, data_axes=("data",), model_axis="model")
