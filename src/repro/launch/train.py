"""Production training launcher.

On a real fleet each host runs this under `jax.distributed.initialize()`
(the mesh helpers below then see all pods' devices); in this container it
runs the same code on the local device(s), optionally with a host-platform
mesh for rehearsal.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 50 --seq-len 256 --global-batch 8 --ckpt-dir /tmp/ckpt

Fault tolerance: atomic checkpoints every --ckpt-every steps, auto-resume
from the newest valid checkpoint, step-indexed data order (restart-stable),
straggler watchdog in the loop.
"""
import argparse
import os
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke-size", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default=None,
                    help="'data x model', e.g. 2x4 (needs that many devices)")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="fake host devices for mesh rehearsal (sets XLA_FLAGS)")
    args = ap.parse_args(argv)

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices}")

    import jax
    import numpy as np
    from repro.checkpoint.manager import CheckpointManager
    from repro.configs import get_config, get_smoke_config
    from repro.data.pipeline import make_batch_fn
    from repro.dist import sharding as SH
    from repro.models import model as M
    from repro.train import optimizer as O
    from repro.train.train_loop import LoopConfig, make_train_step, train_loop

    cfg = (get_smoke_config(args.arch) if args.smoke_size
           else get_config(args.arch))
    opt = O.OptimizerConfig(lr=args.lr, total_steps=args.steps,
                            warmup_steps=max(args.steps // 20, 1))

    par = None
    if args.mesh:
        d, m = (int(v) for v in args.mesh.split("x"))
        from repro.launch.mesh import make_local_parallel
        par = make_local_parallel(data=d, model=m)

    params = M.init_model(jax.random.PRNGKey(0), cfg)
    opt_state = O.init_opt_state(params, opt)
    n = sum(np.prod(p.shape) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n/1e6:.1f}M mesh={args.mesh or 'single'}")

    step_fn = make_train_step(cfg, opt, par=par, grad_accum=args.grad_accum)
    if par is not None:
        p_shard = SH.param_shardings(params, cfg, par)
        o_shard = SH.opt_state_shardings(opt_state, p_shard, par)
        params = jax.device_put(params, p_shard)
        opt_state = jax.device_put(opt_state, o_shard)
        step_fn = jax.jit(step_fn, in_shardings=(p_shard, o_shard, None),
                          donate_argnums=(0, 1))
        ctx = par.mesh
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
        import contextlib
        ctx = contextlib.nullcontext()

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if mgr is not None and mgr.latest_step() is not None:
        restored, start = mgr.restore({"params": params,
                                       "opt_state": opt_state})
        params, opt_state = restored["params"], restored["opt_state"]
        print(f"auto-resumed from step {start}")

    batch_fn = make_batch_fn(cfg, args.seq_len, args.global_batch)
    with ctx:
        params, opt_state, hist = train_loop(
            step_fn, params, opt_state, batch_fn,
            LoopConfig(total_steps=args.steps, log_every=10,
                       checkpoint_every=args.ckpt_every),
            checkpoint_mgr=mgr, start_step=start)
    print(f"done: loss {hist[0]:.3f} -> {hist[-1]:.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
