"""Production serving launcher: the Pimba system loop.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b \
        --smoke-size --requests 12 --slots 4 --state-format mx8

Weights come from --ckpt-dir (a training checkpoint) or random init.
"""
import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke-size", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-capacity", type=int, default=256)
    ap.add_argument("--state-format", default="mx8",
                    choices=["mx8", "int8", "fp16", "fp32"])
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    import jax
    import numpy as np
    from repro.configs import get_config, get_smoke_config
    from repro.core.state_update import StateQuantConfig
    from repro.models import model as M
    from repro.serving.engine import EngineConfig, Request, ServingEngine
    from repro.serving.sampler import SamplingConfig

    cfg = (get_smoke_config(args.arch) if args.smoke_size
           else get_config(args.arch))
    if cfg.encoder_only:
        raise SystemExit(f"{cfg.name} is encoder-only: nothing to serve")
    backend = "pallas" if args.state_format == "mx8" else "jnp"
    cfg = cfg.with_(state_quant=StateQuantConfig(
        fmt=args.state_format, rounding="stochastic", backend=backend))

    params = M.init_model(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir:
        from repro.checkpoint.manager import CheckpointManager
        mgr = CheckpointManager(args.ckpt_dir)
        restored, step = mgr.restore({"params": params, "opt_state": None})
        params = restored["params"]
        print(f"loaded checkpoint step {step}")

    eng = ServingEngine(params, cfg, EngineConfig(
        slots=args.slots, cache_capacity=args.cache_capacity,
        sampling=SamplingConfig(temperature=args.temperature, top_k=40)))
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, 8 + i % 24).astype(np.int32),
            max_new_tokens=args.max_new))
    t0 = time.perf_counter()
    done = eng.run()
    stats = eng.stats()
    print(f"{len(done)} requests, {stats['tokens']} tokens, "
          f"{stats['tokens_per_s']:.1f} tok/s "
          f"(wall {time.perf_counter()-t0:.1f}s, state={args.state_format})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
