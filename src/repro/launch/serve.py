"""Production serving launcher: the Pimba system loop.

Paged, bank-aware pool (default) with the preempting scheduler:

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --smoke-size --paged --pages 33 --requests 16 --mixed \
        --policy priority --top-p 0.95 --seed 7

Fixed-slot pool (legacy):

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b \
        --smoke-size --requests 12 --slots 4 --state-format mx8

Both serve through the request-lifecycle facade (`repro.serving.api.Engine`):
`--stream` drives the engine open-loop and prints tokens as they are
sampled; `--turns N` runs a multi-turn session on copy-on-write prefix
sharing after the batch drains (paged only).

Weights come from --ckpt-dir (a training checkpoint) or random init.
"""
import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke-size", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-capacity", type=int, default=256)
    ap.add_argument("--state-format", default="mx8",
                    choices=["mx8", "int8", "fp16", "fp32"])
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "pallas", "jnp"],
                    help="SPU op backend; 'auto' asks the op registry for "
                         "the preferred backend capable of --state-format "
                         "in the served layout (dense, or paged under "
                         "--paged). A concrete choice errors if any SPU "
                         "compute op the model runs (state_update / "
                         "attn_decode / mla_decode) lacks that (op, format, "
                         "backend, layout) registration; kv_append always "
                         "negotiates (dense kv_append is jnp-only; the "
                         "paged one has an in-place pallas impl for mx8)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 disables)")
    ap.add_argument("--seed", type=int, default=0,
                    help="sampling PRNG seed for reproducible runs")
    # paged pool + scheduler
    ap.add_argument("--paged", action="store_true",
                    help="serve from the paged, bank-aware state/KV pool")
    ap.add_argument("--pages", type=int, default=33,
                    help="pool size in 128-token pages (incl. 1 scratch)")
    ap.add_argument("--slabs", type=int, default=None,
                    help="state slabs (default: 2*slots + 1)")
    ap.add_argument("--prefill-chunk", type=int, default=128,
                    help="longest full-sequence prefill; longer prompts "
                         "stream their tail through the decode batch")
    ap.add_argument("--policy", default="fcfs",
                    choices=["fcfs", "priority", "deadline"])
    ap.add_argument("--mixed", action="store_true",
                    help="mixed workload: short and long prompts")
    # request-lifecycle demos
    ap.add_argument("--stream", action="store_true",
                    help="drive the engine open-loop (step()) and print "
                         "each request's tokens as they are sampled")
    ap.add_argument("--turns", type=int, default=0,
                    help="after the batch: run a --turns-turn chat session "
                         "on copy-on-write prefix sharing (paged only)")
    # observability
    ap.add_argument("--trace", default=None, metavar="OUT",
                    help="write the structured trace at exit: Chrome-trace "
                         "JSON (open in https://ui.perfetto.dev), or JSONL "
                         "if OUT ends in .jsonl")
    ap.add_argument("--metrics", default=None, metavar="OUT",
                    help="dump the metrics registry in Prometheus text "
                         "exposition format at exit ('-' for stdout)")
    args = ap.parse_args(argv)

    import jax
    import numpy as np
    from repro import ops as OPS
    from repro.configs import get_config, get_smoke_config
    from repro.models import model as M
    from repro.serving.api import Engine, ServeConfig
    from repro.serving.sampler import SamplingConfig
    from repro.serving.scheduler import SchedulerConfig

    cfg = (get_smoke_config(args.arch) if args.smoke_size
           else get_config(args.arch))
    if cfg.encoder_only:
        raise SystemExit(f"{cfg.name} is encoder-only: nothing to serve")
    if args.turns and not args.paged:
        raise SystemExit("--turns needs --paged (sessions are built on "
                         "copy-on-write prefix sharing in the paged pool)")
    # capability lookup in the SPU op registry (replaces the old inline
    # "pallas if mx8 else jnp" heuristic): every SPU *compute* op this model
    # dispatches must support a concrete requested triple, so a bad
    # --backend fails up front; kv_append (a scatter, jnp-only by design)
    # always negotiates, as does everything under --backend auto
    requested = None if args.backend == "auto" else args.backend
    # --paged serves through the block-table-native ops, so the capability
    # check runs against the layout actually dispatched
    layout = "paged" if args.paged else "dense"
    compute_kinds = sorted({e.kind for e in OPS.decode_op_plans(cfg, 1, 128)}
                           - {"kv_append"})
    try:
        resolved = [OPS.resolve_backend(kind, args.state_format, requested,
                                        layout=layout,
                                        strict=requested is not None)
                    for kind in compute_kinds]
        backend = resolved[0] if resolved else OPS.resolve_backend(
            "state_update", args.state_format, requested, layout=layout)
    except ValueError as e:
        raise SystemExit(f"--backend {args.backend}: {e}")
    cfg = cfg.with_(state_quant=OPS.StateQuantConfig(
        fmt=args.state_format, rounding="stochastic", backend=backend))

    params = M.init_model(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir:
        from repro.checkpoint.manager import CheckpointManager
        mgr = CheckpointManager(args.ckpt_dir)
        restored, step = mgr.restore({"params": params, "opt_state": None})
        params = restored["params"]
        print(f"loaded checkpoint step {step}")

    sampling = SamplingConfig(temperature=args.temperature,
                              top_k=40 if args.temperature > 0 else 0,
                              top_p=args.top_p)
    scfg = ServeConfig(
        backend="paged" if args.paged else "slots",
        batch=args.slots,
        cache_capacity=args.cache_capacity,
        n_pages=args.pages,
        n_slabs=args.slabs,
        prefill_chunk=args.prefill_chunk,
        sampling=sampling,
        scheduler=SchedulerConfig(policy=args.policy),
        seed=args.seed)
    eng = Engine(params, cfg, scfg)

    rng = np.random.default_rng(args.seed)
    handles = []
    for i in range(args.requests):
        if args.mixed:
            # alternate short prompts with multi-page long ones
            n = 8 + i % 24 if i % 3 else 130 + 16 * (i % 4)
        else:
            n = 8 + i % 24
        handles.append(eng.submit(
            rng.integers(0, cfg.vocab_size, n).astype(np.int32),
            max_new_tokens=args.max_new,
            priority=i % 3 if args.policy == "priority" else 0,
            deadline=(time.time() + 1 + i % 5
                      if args.policy == "deadline" else None)))
    t0 = time.perf_counter()
    if args.stream:
        # open-loop: one step at a time, tokens printed as they surface
        running = True
        while running:
            running = eng.step()
            for h in handles:
                got = h.new_tokens()
                if got:
                    print(f"  req {h.rid} [{h.status}] += {got}")
        done = [h.request for h in handles]
    else:
        done = eng.run()
    stats = eng.stats()
    pool = "paged" if args.paged else "slots"
    print(f"{len(done)} requests, {stats['tokens']:.0f} tokens, "
          f"{stats['tokens_per_s']:.1f} tok/s "
          f"(wall {time.perf_counter()-t0:.1f}s, state={args.state_format}, "
          f"backend={backend}, pool={pool})")
    print(f"  steps: p99={stats['p99_step_s']*1e3:.1f}ms "
          f"p99_nocompile={stats['p99_step_nocompile_s']*1e3:.1f}ms "
          f"({int(stats['compile_steps'])} compile steps, "
          f"{int(stats['recompiles'])} jit compiles)")
    traffic = {k.split("/", 1)[1]: v for k, v in stats.items()
               if k.startswith("op_traffic_bytes/")}
    if traffic:
        total = sum(traffic.values())
        parts = " ".join(f"{k}={v/1e6:.1f}MB" for k, v in traffic.items())
        print(f"  spu op traffic: {parts} (total {total/1e6:.1f}MB)")
    for k in ("mean_ttft_s", "p50_ttft_s", "p99_ttft_s",
              "p50_tok_latency_s", "p99_tok_latency_s"):
        if k in stats:
            print(f"  {k}={stats[k]*1e3:.1f}ms", end="")
    print()
    if args.paged:
        print(f"  occupancy={stats['occupancy']:.2f} "
              f"fragmentation={stats['fragmentation']:.2f} "
              f"preemptions={int(stats['preemptions'])}")
        rep = eng.engine.bank_report()
        print(f"  pimsim page-map: step={rep['t_real_s']*1e6:.2f}us "
              f"ideal={rep['t_ideal_s']*1e6:.2f}us "
              f"conflict_factor={rep['conflict_factor']:.2f} "
              f"bank_imbalance={rep['imbalance']:.2f}")

    if args.turns:
        print(f"-- {args.turns}-turn session (copy-on-write prefix "
              "sharing; turn N skips re-prefilling the history) --")
        chat = eng.session()
        before = eng.stats()["prefill_tokens"]
        for t in range(args.turns):
            turn = rng.integers(0, cfg.vocab_size, 8 + t).astype(np.int32)
            h = chat.send(turn, max_new_tokens=args.max_new)
            print(f"  turn {t}: sent {len(turn)} tokens -> "
                  f"{list(h)}")
        after = eng.stats()["prefill_tokens"]
        chat.close()
        print(f"  session ingested {after - before:.0f} fresh tokens "
              f"({eng.stats()['shared_page_hits']:.0f} shared-page hits; "
              "an unshared engine would re-prefill the whole history "
              "every turn)")

    if args.trace:
        eng.save_trace(args.trace)
        counts = eng.obs.recompiles.counts()
        print(f"trace: {len(eng.obs.tracer.events())} events -> "
              f"{args.trace} (jit compiles: "
              + (" ".join(f"{k}={v}" for k, v in sorted(counts.items()))
                 or "none") + ")")
    if args.metrics:
        text = eng.prometheus_text()
        if args.metrics == "-":
            sys.stdout.write(text)
        else:
            with open(args.metrics, "w") as f:
                f.write(text)
            print(f"metrics: {args.metrics}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
