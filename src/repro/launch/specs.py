"""ShapeDtypeStruct stand-ins for every model input: the dry-run contract.

``input_specs(cfg, shape)`` returns weak-type-correct, shardable SDS trees --
no device allocation ever happens for full-size configs.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig, SHAPES, ShapeConfig


def batch_struct(cfg: ModelConfig, sc: ShapeConfig,
                 dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """Inputs of train/prefill steps."""
    B, S = sc.global_batch, sc.seq_len
    i32 = jnp.int32
    if cfg.family == "vlm":
        S_text = S - cfg.prefix_len
        return {
            "patches": jax.ShapeDtypeStruct((B, cfg.prefix_len,
                                             cfg.frontend_dim), dtype),
            "tokens": jax.ShapeDtypeStruct((B, S_text), i32),
            "targets": jax.ShapeDtypeStruct((B, S_text), i32),
        }
    if cfg.family == "audio":
        return {
            "frames": jax.ShapeDtypeStruct((B, S, cfg.frontend_dim), dtype),
            "targets": jax.ShapeDtypeStruct((B, S), i32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((B, S), i32),
        "targets": jax.ShapeDtypeStruct((B, S), i32),
    }


def decode_struct(cfg: ModelConfig, sc: ShapeConfig) -> Tuple:
    """(tokens, lengths, caches) SDS for a decode step with a warm cache of
    sc.seq_len positions."""
    B = sc.global_batch
    cap = -(-sc.seq_len // 128) * 128
    tokens = jax.ShapeDtypeStruct((B,), jnp.int32)
    lengths = jax.ShapeDtypeStruct((B,), jnp.int32)
    caches = jax.eval_shape(
        lambda: M.init_decode_caches(cfg, B, cap))
    return tokens, lengths, caches


def params_struct(cfg: ModelConfig, key=None) -> Any:
    key = key if key is not None else jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: M.init_model(k, cfg), key)
