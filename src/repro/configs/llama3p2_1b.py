"""Llama-3.2-1B: small llama3 GQA [hf:meta-llama/Llama-3.2-1B; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab_size=128256,
    pattern=("attn",), ffn_kind="swiglu", rope_theta=500_000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="llama3.2-1b-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=512,
    pattern=("attn",), ffn_kind="swiglu", rope_theta=500_000.0,
    tie_embeddings=True,
)
