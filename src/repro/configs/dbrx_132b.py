"""DBRX-132B: 16 experts top-4 fine-grained MoE [hf:databricks/dbrx-base]."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=10752, vocab_size=100352,
    pattern=("attn",), ffn_kind="moe", rope_theta=500_000.0,
    moe=MoEConfig(n_experts=16, top_k=4, d_expert=10752,
                  capacity_factor=1.25),
)

SMOKE = ModelConfig(
    name="dbrx-132b-smoke", family="moe",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=128, vocab_size=512,
    pattern=("attn",), ffn_kind="moe",
    moe=MoEConfig(n_experts=4, top_k=2, d_expert=128, capacity_factor=1.5),
)
