"""OPT-6.7B (paper's attention-based baseline) [arXiv:2205.01068]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="opt-6.7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=16384, vocab_size=50272,
    pattern=("attn",), ffn_kind="relu", norm_kind="layernorm",
    pos_emb="learned",
)

SMOKE = ModelConfig(
    name="opt-6.7b-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=256, vocab_size=512,
    pattern=("attn",), ffn_kind="relu", norm_kind="layernorm",
    pos_emb="learned",
)
