"""xLSTM-1.3B: sLSTM + mLSTM blocks, 7:1 ratio [arXiv:2405.04517; unverified]."""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, head_dim=512,
    d_ff=0, vocab_size=50304,
    pattern=("mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "mlstm",
             "slstm"),
    ffn_kind="none", pos_emb="none",
    # chunk=512: the mLSTM matrix state is ~4 MB/head/seq in f32;
    # the chunked scan saves nc=S/chunk carries for the backward, so
    # large chunks bound that memory (intra cost c*dk stays below the
    # dk*dv state-update cost for c <= dv).
    ssm=SSMConfig(expand=2, n_heads=4, d_conv=4, chunk=512),
)

SMOKE = ModelConfig(
    name="xlstm-1.3b-smoke", family="ssm",
    n_layers=8, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
    d_ff=0, vocab_size=512,
    pattern=("mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "mlstm",
             "slstm"),
    ffn_kind="none", pos_emb="none",
    ssm=SSMConfig(expand=2, n_heads=2, d_conv=4, chunk=16),
)
