"""Mamba-2 2.7B (paper eval model) [arXiv:2405.21060]."""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=80, n_kv_heads=80, head_dim=64,
    d_ff=0, vocab_size=50288,
    pattern=("mamba2",), ffn_kind="none", pos_emb="none",
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, d_conv=4, chunk=64),
)

SMOKE = ModelConfig(
    name="mamba2-2.7b-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=0, vocab_size=512,
    pattern=("mamba2",), ffn_kind="none", pos_emb="none",
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, d_conv=4, chunk=16),
)
