"""Zamba2-2.7B: Mamba-2 backbone + shared attention block every 6 layers
[arXiv:2411.15242; hf].  The paper's headline hybrid workload."""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=32000,
    pattern=("mamba2",) * 6, ffn_kind="swiglu", shared_attn=True,
    rope_theta=10_000.0,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, d_conv=4, chunk=64),
)

SMOKE = ModelConfig(
    name="zamba2-2.7b-smoke", family="hybrid",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512,
    pattern=("mamba2",) * 3, ffn_kind="swiglu", shared_attn=True,
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, d_conv=4, chunk=16),
)
