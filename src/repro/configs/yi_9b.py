"""Yi-9B: llama-arch dense GQA [arXiv:2403.04652; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b", family="dense",
    n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11008, vocab_size=64000,
    pattern=("attn",), ffn_kind="swiglu", rope_theta=5_000_000.0,
)

SMOKE = ModelConfig(
    name="yi-9b-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=512,
    pattern=("attn",), ffn_kind="swiglu", rope_theta=5_000_000.0,
)
