"""RetNet 2.7B (paper eval model) [arXiv:2307.08621]: fixed per-head decay."""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="retnet-2.7b", family="ssm",
    n_layers=32, d_model=2560, n_heads=10, n_kv_heads=10, head_dim=256,
    d_ff=5120, vocab_size=50257,
    pattern=("retnet",), ffn_kind="swiglu", pos_emb="none",
    ssm=SSMConfig(n_heads=10, dk_head=256, dv_head=512, chunk=64),
)

SMOKE = ModelConfig(
    name="retnet-2.7b-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
    d_ff=128, vocab_size=512,
    pattern=("retnet",), ffn_kind="swiglu", pos_emb="none",
    ssm=SSMConfig(n_heads=2, dk_head=32, dv_head=64, chunk=16),
)
