"""Architecture registry: ``get_config(name)`` / ``--arch <name>``.

Assigned architectures (public-literature configs) plus the paper's own
evaluation models.  Each module defines CONFIG (full size) and SMOKE (a
reduced same-family config for CPU tests).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig, SHAPES, ShapeConfig

_ASSIGNED = [
    "yi-9b", "llama3.2-1b", "yi-34b", "smollm-360m", "xlstm-1.3b",
    "deepseek-v2-236b", "dbrx-132b", "zamba2-2.7b", "paligemma-3b",
    "hubert-xlarge",
]
_PAPER = [
    "mamba2-2.7b", "retnet-2.7b", "gla-2.7b", "hgrn2-2.7b", "opt-6.7b",
]

ASSIGNED_ARCHS = tuple(_ASSIGNED)
PAPER_ARCHS = tuple(_PAPER)
ALL_ARCHS = ASSIGNED_ARCHS + PAPER_ARCHS


def _module_name(arch: str) -> str:
    return "repro.configs." + arch.replace("-", "_").replace(".", "p")


def get_config(arch: str) -> ModelConfig:
    if arch not in ALL_ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {ALL_ARCHS}")
    return importlib.import_module(_module_name(arch)).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in ALL_ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {ALL_ARCHS}")
    return importlib.import_module(_module_name(arch)).SMOKE


# ---------------------------------------------------------------------------
# shape-cell applicability (see DESIGN.md §4)
# ---------------------------------------------------------------------------

_FULL_ATTENTION = {
    "yi-9b", "llama3.2-1b", "yi-34b", "smollm-360m", "deepseek-v2-236b",
    "dbrx-132b", "paligemma-3b", "opt-6.7b",
}
_ENCODER_ONLY = {"hubert-xlarge"}


def cell_supported(arch: str, shape: str) -> tuple:
    """(supported, reason) for an (arch x shape) dry-run cell."""
    sc = SHAPES[shape]
    if arch in _ENCODER_ONLY and sc.kind == "decode":
        return False, "encoder-only: no autoregressive decode step"
    if shape == "long_500k" and arch in _FULL_ATTENTION:
        return False, ("pure full-attention arch: 524k context needs "
                       "sub-quadratic attention (skipped per spec)")
    return True, ""


def all_cells(archs=ASSIGNED_ARCHS) -> List[tuple]:
    return [(a, s) for a in archs for s in SHAPES]
