"""SmolLM-360M: llama-arch small GQA [hf:HuggingFaceTB/SmolLM; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, head_dim=64,
    d_ff=2560, vocab_size=49152,
    pattern=("attn",), ffn_kind="swiglu", rope_theta=10_000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="smollm-360m-smoke", family="dense",
    n_layers=2, d_model=96, n_heads=3, n_kv_heads=1, head_dim=32,
    d_ff=192, vocab_size=512,
    pattern=("attn",), ffn_kind="swiglu", rope_theta=10_000.0,
    tie_embeddings=True,
)
