"""HuBERT-XLarge: encoder-only audio transformer [arXiv:2106.07447].

Pimba's technique is inapplicable (no decode phase / no cache); implemented
without it per DESIGN.md §4.  Frontend stub supplies conv frame features."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab_size=504,
    pattern=("attn",), ffn_kind="gelu", norm_kind="layernorm",
    pos_emb="sincos", causal=False, encoder_only=True,
    frontend="audio_frames", frontend_dim=512,
)

SMOKE = ModelConfig(
    name="hubert-xlarge-smoke", family="audio",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=256, vocab_size=64,
    pattern=("attn",), ffn_kind="gelu", norm_kind="layernorm",
    pos_emb="sincos", causal=False, encoder_only=True,
    frontend="audio_frames", frontend_dim=64,
)
