"""Yi-34B: llama-arch dense GQA [arXiv:2403.04652; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab_size=64000,
    pattern=("attn",), ffn_kind="swiglu", rope_theta=5_000_000.0,
)

SMOKE = ModelConfig(
    name="yi-34b-smoke", family="dense",
    n_layers=2, d_model=112, n_heads=7, n_kv_heads=1, head_dim=16,
    d_ff=224, vocab_size=512,
    pattern=("attn",), ffn_kind="swiglu", rope_theta=5_000_000.0,
)
