"""DeepSeek-V2 236B: MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434; hf]."""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=1536, vocab_size=102400,
    pattern=("mla",), prelude=("mla",), ffn_kind="moe", rope_theta=10_000.0,
    mla=MLAConfig(q_lora=1536, kv_lora=512, rope_dim=64, nope_dim=128,
                  v_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_expert=1536, n_shared=2,
                  capacity_factor=1.25, first_dense_ff=12288),
)

SMOKE = ModelConfig(
    name="deepseek-v2-236b-smoke", family="moe",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=64, vocab_size=512,
    pattern=("mla",), prelude=("mla",), ffn_kind="moe",
    mla=MLAConfig(q_lora=64, kv_lora=64, rope_dim=16, nope_dim=32, v_dim=32),
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=64, n_shared=1,
                  capacity_factor=1.5, first_dense_ff=128),
)
