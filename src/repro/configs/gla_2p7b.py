"""GLA 2.7B (paper eval model) [arXiv:2312.06635]: per-channel gated decay."""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="gla-2.7b", family="ssm",
    n_layers=32, d_model=2560, n_heads=4, n_kv_heads=4, head_dim=320,
    d_ff=6912, vocab_size=50257,
    pattern=("gla",), ffn_kind="swiglu", pos_emb="none",
    ssm=SSMConfig(n_heads=4, dk_head=320, dv_head=640, chunk=64),
)

SMOKE = ModelConfig(
    name="gla-2.7b-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
    d_ff=128, vocab_size=512,
    pattern=("gla",), ffn_kind="swiglu", pos_emb="none",
    ssm=SSMConfig(n_heads=2, dk_head=32, dv_head=32, chunk=16),
)
