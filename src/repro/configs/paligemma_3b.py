"""PaliGemma-3B: SigLIP frontend (stub) + gemma decoder, prefix-LM
[arXiv:2407.07726; hf].  input_specs() supplies precomputed patch embeddings."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=257216,
    pattern=("attn",), ffn_kind="geglu", rope_theta=10_000.0,
    frontend="patch", frontend_dim=1152, prefix_len=256,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="paligemma-3b-smoke", family="vlm",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=1, head_dim=32,
    d_ff=256, vocab_size=512,
    pattern=("attn",), ffn_kind="geglu",
    frontend="patch", frontend_dim=64, prefix_len=16,
    tie_embeddings=True,
)
