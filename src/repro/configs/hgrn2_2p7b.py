"""HGRN2 2.7B (paper eval model) [arXiv:2404.07904]: gated linear RNN with
state expansion; forget-gate lower bound grows with depth."""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hgrn2-2.7b", family="ssm",
    n_layers=32, d_model=2560, n_heads=20, n_kv_heads=20, head_dim=128,
    d_ff=6912, vocab_size=50257,
    pattern=("hgrn2",), ffn_kind="swiglu", pos_emb="none",
    ssm=SSMConfig(n_heads=20, dk_head=128, dv_head=128, chunk=64),
)

SMOKE = ModelConfig(
    name="hgrn2-2.7b-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
    d_ff=128, vocab_size=512,
    pattern=("hgrn2",), ffn_kind="swiglu", pos_emb="none",
    ssm=SSMConfig(n_heads=2, dk_head=32, dv_head=32, chunk=16),
)
