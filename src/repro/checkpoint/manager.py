"""Fault-tolerant checkpointing: atomic, resumable, mesh-elastic.

Durability protocol (survives SIGKILL at any point):
  1. write every array + a manifest into ``step_N.tmp/``
  2. fsync, then atomically ``rename`` to ``step_N/``
  3. update ``LATEST`` via write-tmp + rename

Restore never trusts a directory without a complete manifest; a torn write
leaves only a ``.tmp`` dir that is ignored (and garbage-collected).

Elasticity: arrays are stored as full logical tensors (gathered), so a
checkpoint written on one mesh restores onto any other mesh/new sharding --
scale-up/scale-down is a pure re-``device_put``.  (At >10k-chip scale you
would write per-shard files + a reshard-on-read index; the manifest format
has a ``shards`` field reserved for that.)
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._gc_tmp()

    # ---------------- save ----------------

    def save(self, step: int, params: Any, opt_state: Any = None,
             extra: Optional[dict] = None) -> str:
        tree = {"params": params, "opt_state": opt_state}
        leaves, treedef = _flatten(tree)
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        arrays = {}
        manifest = {"step": step, "n_leaves": len(leaves),
                    "treedef": str(treedef), "shards": None,
                    "extra": extra or {}}
        dtypes = []
        for i, leaf in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            dtypes.append(str(arr.dtype))
            if arr.dtype == np.dtype("bfloat16"):
                arr = arr.view(np.uint16)        # npz-safe encoding
            arrays[f"leaf_{i}"] = arr
        manifest["dtypes"] = dtypes
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._set_latest(step)
        self._gc_old()
        return final

    # ---------------- restore ----------------

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.dir, "LATEST")
        if not os.path.exists(path):
            return None
        try:
            step = int(open(path).read().strip())
        except ValueError:
            return None
        if not self._valid(step):
            # fall back to newest valid checkpoint on disk
            steps = sorted(self._steps_on_disk(), reverse=True)
            for s in steps:
                if self._valid(s):
                    return s
            return None
        return step

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, int]:
        """Restore into the structure of ``like``; reshard via ``shardings``.

        ``like`` = {'params': ..., 'opt_state': ...} template (shapes/dtypes
        may be ShapeDtypeStructs).  Returns (tree, step).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint under {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        manifest = json.load(open(os.path.join(d, "manifest.json")))
        data = np.load(os.path.join(d, "arrays.npz"))
        leaves_like, treedef = _flatten(like)
        assert manifest["n_leaves"] == len(leaves_like), (
            f"checkpoint has {manifest['n_leaves']} leaves, template has "
            f"{len(leaves_like)} -- model/optimizer structure changed")
        shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                        if shardings is not None else [None] * len(leaves_like))
        out = []
        for i, (tmpl, shd) in enumerate(zip(leaves_like, shard_leaves)):
            arr = data[f"leaf_{i}"]
            dt = manifest["dtypes"][i]
            if dt == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            if shd is not None:
                out.append(jax.device_put(arr, shd))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out), step

    # ---------------- internals ----------------

    def _valid(self, step: int) -> bool:
        d = os.path.join(self.dir, f"step_{step:08d}")
        return (os.path.exists(os.path.join(d, "manifest.json"))
                and os.path.exists(os.path.join(d, "arrays.npz")))

    def _steps_on_disk(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return out

    def _set_latest(self, step: int):
        tmp = os.path.join(self.dir, "LATEST.tmp")
        with open(tmp, "w") as f:
            f.write(str(step))
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, os.path.join(self.dir, "LATEST"))

    def _gc_tmp(self):
        for name in os.listdir(self.dir):
            if name.endswith(".tmp"):
                p = os.path.join(self.dir, name)
                (shutil.rmtree if os.path.isdir(p) else os.remove)(p)

    def _gc_old(self):
        steps = sorted(self._steps_on_disk(), reverse=True)
        for s in steps[self.keep:]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"))
