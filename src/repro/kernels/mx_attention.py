"""Pallas TPU kernel: flash-decoding attention over an MX8-packed KV cache.

Implements Pimba's attention mode (paper §5.4) as one fused kernel instead of
the paper's two-phase GPU⇄PIM handoff (score -> host softmax -> attend):

  * score phase  : q · Kᵀ on dequantized MX8 key tiles (the in-pipeline dot
                   product unit)
  * softmax      : streaming (flash) max/sum accumulators in VMEM -- on TPU
                   there is no reason to bounce partial scores to the host,
                   which removes the paper's §8 "blocked GPU/PIM" bubble
  * attend phase : probability-weighted accumulation of dequantized MX8
                   value tiles (the SPE multiplier/adder path)

GQA is handled by processing all G = H / KV_heads query heads of a KV head
together against each KV tile (operand reuse across the chunk group, the
analogue of Pimba broadcasting shared operands once per chunk group).

MLA mode (DeepSeek-V2): the cache is a single compressed latent stream; the
same tiles serve as keys (full width) and values (first ``v_width`` lanes),
so pass ``v_width`` and leave the V refs aliased to the K refs at call site
is not needed -- the kernel reads the K refs for both phases.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import formats as F

MXG = F.MX8_GROUP
NEG_INF = -1e30


def _deq(mant, exp, micro):
    qt = F.QuantizedTensor("mx8", mant.shape,
                           {"mantissa": mant, "exponent": exp, "micro": micro})
    return F.mx8_dequantize(qt)


def _attn_kernel(
    # inputs
    len_ref, q_ref, km_ref, ke_ref, kmi_ref, vm_ref, ve_ref, vmi_ref,
    # outputs
    y_ref,
    # scratch
    m_scr, l_scr, acc_scr,
    *, t_blk: int, n_t: int, v_width: int, mla: bool,
):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qv = q_ref[0, 0].astype(jnp.float32)                        # (G, dk)
    K = _deq(km_ref[0, :, 0, :], ke_ref[0, :, 0, :], kmi_ref[0, :, 0, :])
    if mla:
        V = K[:, :v_width]
    else:
        V = _deq(vm_ref[0, :, 0, :], ve_ref[0, :, 0, :], vmi_ref[0, :, 0, :])

    scores = jax.lax.dot_general(
        qv, K, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                     # (G, t_blk)
    pos = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1) + t * t_blk
    valid = pos < len_ref[0, 0]
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_scr[...]                                         # (G, 1)
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                                 # (G, t_blk)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p, V, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                     # (G, dv)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_scr[...] * alpha + pv

    @pl.when(t == n_t - 1)
    def _finish():
        y_ref[0, 0] = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)


@functools.partial(
    jax.jit,
    static_argnames=("t_block", "interpret", "v_width", "scale"),
)
def mx_attention_decode(
    q: jnp.ndarray,                 # (B, H, dk) current-token queries
    qK: F.QuantizedTensor,          # (B, T, KVH, dk) packed keys
    qV: Optional[F.QuantizedTensor],  # (B, T, KVH, dv) packed values; None => MLA
    lengths: jnp.ndarray,           # (B,) int32 valid cache length
    *, scale: Optional[float] = None, v_width: Optional[int] = None,
    t_block: int = 128, interpret: bool = True,
) -> jnp.ndarray:
    """Fused decode attention; returns (B, H, dv) f32."""
    B, H, dk = q.shape
    _, T, KVH, dkc = qK.shape
    assert dk == dkc and H % KVH == 0 and T % t_block == 0
    G = H // KVH
    n_t = T // t_block
    mla = qV is None
    dv = v_width if mla else qV.shape[-1]
    assert dv is not None

    scale = scale if scale is not None else dk ** -0.5
    qg = (q.astype(jnp.float32) * scale).reshape(B, KVH, G, dk)
    lens = lengths.astype(jnp.int32).reshape(B, 1)

    km = qK.payload["mantissa"]
    ke = qK.payload["exponent"]
    kmi = qK.payload["micro"]
    if mla:
        vm, ve, vmi = km[:, :1], ke[:, :1], kmi[:, :1]   # dummies (unused)
        vgroups = dkc // MXG
    else:
        vm = qV.payload["mantissa"]
        ve = qV.payload["exponent"]
        vmi = qV.payload["micro"]
        vgroups = dv // MXG

    v_t_blk = 1 if mla else t_block
    kernel = functools.partial(
        _attn_kernel, t_blk=t_block, n_t=n_t, v_width=dv, mla=mla)

    y = pl.pallas_call(
        kernel,
        grid=(B, KVH, n_t),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, t: (b, 0)),                    # len
            pl.BlockSpec((1, 1, G, dk), lambda b, h, t: (b, h, 0, 0)),       # q
            pl.BlockSpec((1, t_block, 1, dk), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, t_block, 1, dk // MXG), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, t_block, 1, dk // MXG), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, v_t_blk, 1, vgroups * MXG),
                         lambda b, h, t: (b, 0 if v_t_blk == 1 else t, h, 0)),
            pl.BlockSpec((1, v_t_blk, 1, vgroups),
                         lambda b, h, t: (b, 0 if v_t_blk == 1 else t, h, 0)),
            pl.BlockSpec((1, v_t_blk, 1, vgroups),
                         lambda b, h, t: (b, 0 if v_t_blk == 1 else t, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dv), lambda b, h, t: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, dv), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, dv), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qg, km, ke, kmi, vm, ve, vmi)

    return y.reshape(B, H, dv)
