"""Pallas TPU kernel: fused MX8 state update (the SPU/SPE analogue).

One kernel invocation performs, for every (batch, head) and every dv-tile of
the state, the full Pimba SPU pipeline of paper Fig. 8:

  (1) fetch packed MX8 state tile            (HBM -> VMEM DMA)
  (2) dequantize; decay + outer product      (SPE multipliers)
  (3) add                                    (SPE adders)
  (4) requantize w/ stochastic rounding, write back, and S'ᵀq dot product

The state is *stored* transposed, ``(B, H, dv, dk)`` with MX groups along
``dk`` -- the analogue of the paper's layout that splits each state column
along ``dim_head`` into DRAM-column-sized sub-chunks.  In this layout the
output GEMV reduces along the minor (lane) axis and the decay vector
broadcasts along it, both VPU-friendly.

Pimba's access interleaving (two banks sharing one SPU so reads of bank A
overlap writes of bank B) maps to the Pallas grid pipeline: the next tile's
DMA-in and the previous tile's DMA-out overlap compute on the current tile
via double buffering.  ``input_output_aliases`` keeps the update in place,
mirroring the PIM read-modify-write of the same rows.

Validation runs in ``interpret=True`` mode on CPU; the quantization math is
shared with :mod:`repro.core.formats`, so results are bitwise equal to the
pure-jnp oracle in :mod:`repro.kernels.ref`.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import formats as F

MXG = F.MX8_GROUP


def _dequant_tile(mant, exp, micro):
    """(R, C) int8 mantissas + per-group exponent/micro bytes -> f32."""
    qt = F.QuantizedTensor("mx8", mant.shape,
                           {"mantissa": mant, "exponent": exp, "micro": micro})
    return F.mx8_dequantize(qt)


def _quant_tile(x, rounding, bits):
    qt = F.mx8_quantize(x, rounding, bits)
    return qt.payload["mantissa"], qt.payload["exponent"], qt.payload["micro"]


def _state_update_kernel(
    # inputs
    seed_ref, mant_ref, exp_ref, micro_ref, d_ref, k_ref, v_ref, q_ref,
    # outputs
    o_mant_ref, o_exp_ref, o_micro_ref, y_ref,
    *, dk: int, dv: int, dv_blk: int, rounding: str,
):
    bh = pl.program_id(0)
    j = pl.program_id(1)

    # ----- fetch + dequantize (stage 1) -----
    S = _dequant_tile(mant_ref[0], exp_ref[0], micro_ref[0])   # (dv_blk, dk)
    d = d_ref[...].astype(jnp.float32)                         # (1, dk)
    k = k_ref[...].astype(jnp.float32)                         # (1, dk)
    q = q_ref[...].astype(jnp.float32)                         # (1, dk)
    v = v_ref[...].astype(jnp.float32)                         # (1, dv_blk)

    # ----- decay ∥ outer product (stage 2), update (stage 3) -----
    Sn = S * d + jnp.transpose(v) * k                          # (dv_blk, dk)

    # ----- requantize with stochastic rounding (LFSR analogue) -----
    bits = None
    if rounding == "stochastic":
        seed = seed_ref[0, 0].astype(jnp.uint32)
        row = jax.lax.broadcasted_iota(jnp.uint32, (dv_blk, dk), 0)
        col = jax.lax.broadcasted_iota(jnp.uint32, (dv_blk, dk), 1)
        gv = bh.astype(jnp.uint32) * jnp.uint32(dv) \
            + jnp.uint32(j * dv_blk) + row                      # global dv index
        flat = gv * jnp.uint32(dk) + col
        bits = F.counter_hash_u32(flat, seed)
    nm, ne, nmi = _quant_tile(Sn, rounding, bits)
    o_mant_ref[0] = nm
    o_exp_ref[0] = ne
    o_micro_ref[0] = nmi

    # ----- output GEMV on the *stored* (requantized) state (stage 4) -----
    Snq = _dequant_tile(nm, ne, nmi)
    y_ref[...] = jnp.sum(Snq * q, axis=-1)[None, :]            # (1, dv_blk)


def _pick_dv_block(dv: int) -> int:
    for cand in (256, 128, 64, 32, 16):
        if dv % cand == 0:
            return min(cand, dv)
    raise ValueError(f"dv={dv} must be a multiple of 16")


@functools.partial(
    jax.jit,
    static_argnames=("rounding", "interpret", "dv_block"),
)
def mx_state_update(
    qS: F.QuantizedTensor,
    d: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, q: jnp.ndarray,
    seed: jnp.ndarray,
    *, rounding: str = "stochastic", interpret: bool = True,
    dv_block: int | None = None,
) -> Tuple[F.QuantizedTensor, jnp.ndarray]:
    """Fused quantized state update.

    Args:
      qS: packed MX8 state, logical shape ``(B, H, dv, dk)`` (stored layout).
      d:  decay, ``(B, H, dk)`` or ``(B, H, 1)`` (broadcast for scalar decay).
      k, q: ``(B, H, dk)``;  v: ``(B, H, dv)``.
      seed: int32 scalar; vary per token step for fresh SR randomness.
    Returns:
      (new packed state, y) with y ``(B, H, dv)`` float32.
    """
    B, H, dv, dk = qS.shape
    assert dk % MXG == 0
    dv_blk = dv_block or _pick_dv_block(dv)
    assert dv % dv_blk == 0
    n_tiles = dv // dv_blk
    BH = B * H

    mant = qS.payload["mantissa"].reshape(BH, dv, dk)
    exp = qS.payload["exponent"].reshape(BH, dv, dk // MXG)
    micro = qS.payload["micro"].reshape(BH, dv, dk // MXG)
    d = jnp.broadcast_to(d.astype(jnp.float32), (B, H, dk)).reshape(BH, dk)
    k = k.astype(jnp.float32).reshape(BH, dk)
    q = q.astype(jnp.float32).reshape(BH, dk)
    v = v.astype(jnp.float32).reshape(BH, dv)
    seed_arr = jnp.asarray(seed, jnp.int32).reshape(1, 1)

    grid = (BH, n_tiles)
    kernel = functools.partial(
        _state_update_kernel, dk=dk, dv=dv, dv_blk=dv_blk, rounding=rounding)

    out_shapes = [
        jax.ShapeDtypeStruct((BH, dv, dk), jnp.int8),
        jax.ShapeDtypeStruct((BH, dv, dk // MXG), jnp.uint8),
        jax.ShapeDtypeStruct((BH, dv, dk // MXG), jnp.uint8),
        jax.ShapeDtypeStruct((BH, dv), jnp.float32),
    ]
    in_specs = [
        pl.BlockSpec((1, 1), lambda i, j: (0, 0)),                      # seed
        pl.BlockSpec((1, dv_blk, dk), lambda i, j: (i, j, 0)),          # mant
        pl.BlockSpec((1, dv_blk, dk // MXG), lambda i, j: (i, j, 0)),   # exp
        pl.BlockSpec((1, dv_blk, dk // MXG), lambda i, j: (i, j, 0)),   # micro
        pl.BlockSpec((1, dk), lambda i, j: (i, 0)),                     # d
        pl.BlockSpec((1, dk), lambda i, j: (i, 0)),                     # k
        pl.BlockSpec((1, dv_blk), lambda i, j: (i, j)),                 # v
        pl.BlockSpec((1, dk), lambda i, j: (i, 0)),                     # q
    ]
    out_specs = [
        pl.BlockSpec((1, dv_blk, dk), lambda i, j: (i, j, 0)),
        pl.BlockSpec((1, dv_blk, dk // MXG), lambda i, j: (i, j, 0)),
        pl.BlockSpec((1, dv_blk, dk // MXG), lambda i, j: (i, j, 0)),
        pl.BlockSpec((1, dv_blk), lambda i, j: (i, j)),
    ]

    nm, ne, nmi, y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        # in-place state update: read bank / write bank of the same rows
        input_output_aliases={1: 0, 2: 1, 3: 2},
        interpret=interpret,
    )(seed_arr, mant, exp, micro, d, k, v, q)

    qSn = F.QuantizedTensor("mx8", qS.shape, {
        "mantissa": nm.reshape(B, H, dv, dk),
        "exponent": ne.reshape(B, H, dv, dk // MXG),
        "micro": nmi.reshape(B, H, dv, dk // MXG),
    })
    return qSn, y.reshape(B, H, dv)
