"""Pallas kernel: MX8 quantizer (the host memory-controller "Quantization
Unit" of paper §5.5 REG_WRITE).  Streams f32/bf16 rows and emits packed MX8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import formats as F

MXG = F.MX8_GROUP


def _quant_kernel(seed_ref, x_ref, m_ref, e_ref, mi_ref, *,
                  cols: int, r_blk: int, rounding: str):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)          # (r_blk, cols)
    bits = None
    if rounding == "stochastic":
        seed = seed_ref[0, 0].astype(jnp.uint32)
        row = jax.lax.broadcasted_iota(jnp.uint32, x.shape, 0)
        col = jax.lax.broadcasted_iota(jnp.uint32, x.shape, 1)
        flat = (i.astype(jnp.uint32) * jnp.uint32(r_blk) + row) * jnp.uint32(cols) + col
        bits = F.counter_hash_u32(flat, seed)
    qt = F.mx8_quantize(x, rounding, bits)
    m_ref[...] = qt.payload["mantissa"]
    e_ref[...] = qt.payload["exponent"]
    mi_ref[...] = qt.payload["micro"]


@functools.partial(jax.jit, static_argnames=("rounding", "interpret", "row_block"))
def mx_quantize(x: jnp.ndarray, seed=0, *, rounding: str = "nearest",
                row_block: int = 256, interpret: bool = True) -> F.QuantizedTensor:
    """Quantize a 2D-reshapeable array to MX8 (groups along the last axis)."""
    orig_shape = x.shape
    cols = x.shape[-1]
    assert cols % MXG == 0
    rows = int(x.size // cols)
    x2 = x.reshape(rows, cols)
    r_blk = min(row_block, rows)
    # pad rows to a block multiple
    pad = (-rows) % r_blk
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    n_blk = x2.shape[0] // r_blk
    seed_arr = jnp.asarray(seed, jnp.int32).reshape(1, 1)

    kernel = functools.partial(_quant_kernel, cols=cols, r_blk=r_blk,
                               rounding=rounding)
    m, e, mi = pl.pallas_call(
        kernel,
        grid=(n_blk,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((r_blk, cols), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((r_blk, cols), lambda i: (i, 0)),
            pl.BlockSpec((r_blk, cols // MXG), lambda i: (i, 0)),
            pl.BlockSpec((r_blk, cols // MXG), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((x2.shape[0], cols), jnp.int8),
            jax.ShapeDtypeStruct((x2.shape[0], cols // MXG), jnp.uint8),
            jax.ShapeDtypeStruct((x2.shape[0], cols // MXG), jnp.uint8),
        ],
        interpret=interpret,
    )(seed_arr, x2)

    if pad:
        m, e, mi = m[:rows], e[:rows], mi[:rows]
    gshape = orig_shape[:-1] + (cols // MXG,)
    return F.QuantizedTensor("mx8", orig_shape, {
        "mantissa": m.reshape(orig_shape),
        "exponent": e.reshape(gshape),
        "micro": mi.reshape(gshape),
    })
