"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are tested against (shape/dtype sweeps
with assert_allclose) and double as the slow-but-obviously-correct fallback.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import formats as F


# ---------------------------------------------------------------------------
# Generalized state update (paper Eq. 2), float path
# ---------------------------------------------------------------------------

def state_update_ref(S: jnp.ndarray, d: jnp.ndarray, k: jnp.ndarray,
                     v: jnp.ndarray, q: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One token step of  S' = d ⊙ S + k vᵀ ;  y = S'ᵀ q.

    Shapes (B = batch, H = heads):
      S: (B, H, dk, dv) f32      d: (B, H, dk) or (B, H, 1)
      k, q: (B, H, dk)           v: (B, H, dv)
    Returns (S', y) with y: (B, H, dv).
    """
    S = S.astype(jnp.float32)
    d_ = d.astype(jnp.float32)[..., None]                    # (B,H,dk,1)
    Sn = d_ * S + k.astype(jnp.float32)[..., None] * v.astype(jnp.float32)[..., None, :]
    y = jnp.einsum("bhkv,bhk->bhv", Sn, q.astype(jnp.float32))
    return Sn, y


# ---------------------------------------------------------------------------
# Quantized state update: dequant -> update -> requant(SR) -> output GEMV
# ---------------------------------------------------------------------------

def quantized_state_update_ref(
    qS: F.QuantizedTensor,
    d: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, q: jnp.ndarray,
    *, rounding: str = "stochastic", seed=0,
    strict: bool = False,
) -> Tuple[F.QuantizedTensor, jnp.ndarray]:
    """Oracle for the fused MX state-update kernel.

    The *stored* state passes through the quantizer every step (the property
    Pimba's accuracy claims rest on).  ``strict=True`` additionally quantizes
    the decayed state and the outer product before the add, emulating the
    hardware MX adder datapath (paper §5.3).
    """
    S = F.dequantize(qS)
    d_ = d.astype(jnp.float32)[..., None]
    kv = k.astype(jnp.float32)[..., None] * v.astype(jnp.float32)[..., None, :]
    if strict and qS.fmt == "mx8":
        dec = F.dequantize(F.mx8_quantize(d_ * S))
        kvq = F.dequantize(F.mx8_quantize(kv))
        Sn = dec + kvq
    else:
        Sn = d_ * S + kv
    bits = None
    if rounding == "stochastic":
        bits = F.sr_bits(Sn.shape, seed)
    qSn = F.quantize(Sn, qS.fmt, rounding, bits)
    y = jnp.einsum("bhkv,bhk->bhv", F.dequantize(qSn), q.astype(jnp.float32))
    return qSn, y


def quantized_state_update_stored_ref(
    qS: F.QuantizedTensor,
    d: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, q: jnp.ndarray,
    *, rounding: str = "stochastic", seed=0,
) -> Tuple[F.QuantizedTensor, jnp.ndarray]:
    """Oracle for the fused kernel, in the kernel's *stored* layout.

    qS holds Sᵀ with shape (B, H, dv, dk), MX groups along dk (the paper's
    dim_head-major sub-chunk layout).  Bitwise-matches the Pallas kernel.
    """
    B, H, dv, dk = qS.shape
    St = F.dequantize(qS)                                     # (B,H,dv,dk)
    d_ = jnp.broadcast_to(d.astype(jnp.float32), (B, H, dk))[:, :, None, :]
    Sn = St * d_ + v.astype(jnp.float32)[..., :, None] * k.astype(jnp.float32)[..., None, :]
    bits = None
    if rounding == "stochastic":
        bits = F.sr_bits(Sn.shape, seed)
    qSn = F.quantize(Sn, qS.fmt, rounding, bits)
    y = jnp.einsum("bhvk,bhk->bhv", F.dequantize(qSn), q.astype(jnp.float32))
    return qSn, y


# ---------------------------------------------------------------------------
# Decode attention over a quantized KV cache (score + attend phases)
# ---------------------------------------------------------------------------

def attention_decode_ref(
    q: jnp.ndarray,                 # (B, H, dh)
    k_cache: jnp.ndarray,           # (B, T, KVH, dk)  f32 (already dequantized)
    v_cache: jnp.ndarray,           # (B, T, KVH, dv)
    lengths: jnp.ndarray,           # (B,) valid cache lengths
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Single-token attention: softmax(q·Kᵀ)·V with GQA; returns (B, H, dv)."""
    B, H, dh = q.shape
    _, T, KVH, dk = k_cache.shape
    assert dh == dk
    G = H // KVH
    scale = scale if scale is not None else dh ** -0.5
    qg = q.reshape(B, KVH, G, dh).astype(jnp.float32)
    scores = jnp.einsum("bngd,btnd->bngt", qg, k_cache.astype(jnp.float32)) * scale
    mask = jnp.arange(T)[None, :] < lengths[:, None]          # (B, T)
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngt,btnv->bngv", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, -1)


def mx_attention_decode_ref(
    q: jnp.ndarray,
    qK: F.QuantizedTensor,          # (B, T, KVH, dk) packed
    qV: F.QuantizedTensor,          # (B, T, KVH, dv) packed
    lengths: jnp.ndarray,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    return attention_decode_ref(q, F.dequantize(qK), F.dequantize(qV),
                                lengths, scale)


# ---------------------------------------------------------------------------
# MX8 quantization (host "Quantization Unit" analogue)
# ---------------------------------------------------------------------------

def mx_quantize_ref(x: jnp.ndarray, rounding: str = "nearest",
                    seed=0) -> F.QuantizedTensor:
    bits = F.sr_bits(x.shape, seed) if rounding == "stochastic" else None
    return F.mx8_quantize(x, rounding, bits)
