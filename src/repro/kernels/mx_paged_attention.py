"""Pallas TPU kernels that read/write the paged KV pools *in place*.

The paged serving pool stores every KV stream as a page pool
``(n_pages, G, 128, KVH, d)`` -- page id ``p`` holds one 128-token,
MX-tile-aligned chunk, ``G`` is the scan-over-layers stack.  Until these
kernels existed, every decode step gathered the full context out of the
pools into a dense cache tree and scattered one token back, tripling the
decode path's own DRAM traffic (the opposite of Pimba's premise that decode
is bandwidth-bound, paper §3).

``PAGE_TOKENS == 128`` was chosen to equal the MX tile, so the flash grid
can walk the block table directly:

``mx_paged_attention_decode``
    Same score -> streaming softmax -> attend pipeline as
    :func:`repro.kernels.mx_attention.mx_attention_decode`, but the grid's
    time dimension walks ``bt[B, npg]``: the block table (and the stacked
    layer index) are **scalar-prefetched**, so each tile's index map
    dequantizes one 128-token page straight out of the shared pool -- no
    dense copy of the context ever exists.  Accumulation order per row is
    identical to the dense kernel (page ``t`` of row ``b`` holds exactly
    tile ``t`` of the gathered layout), so outputs are bit-identical.

``mx_paged_kv_append``
    Writes the new token's already-quantized K/V payload rows into their
    page slot ``pool[bt[b, len//128], g, len%128]`` in place via
    ``input_output_aliases`` -- the software analogue of the PIM
    read-modify-write of a single DRAM column, and the reason the steady
    state decode loop moves one row, not the whole pool.

Both run ``interpret=True`` on CPU; quantization math is shared with
:mod:`repro.core.formats`, so results match the jnp reference bitwise.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import formats as F
from repro.core.paged import PAGE_TOKENS
from repro.kernels.mx_attention import NEG_INF, _deq

MXG = F.MX8_GROUP


def _paged_attn_kernel(
    # scalar prefetch
    bt_ref, grp_ref,
    # inputs
    len_ref, q_ref, km_ref, ke_ref, kmi_ref, vm_ref, ve_ref, vmi_ref,
    # outputs
    y_ref,
    # scratch
    m_scr, l_scr, acc_scr,
    *, t_blk: int, n_t: int, v_width: int, mla: bool,
):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qv = q_ref[0, 0].astype(jnp.float32)                        # (G, dk)
    K = _deq(km_ref[0, 0, :, 0, :], ke_ref[0, 0, :, 0, :],
             kmi_ref[0, 0, :, 0, :])                            # (t_blk, dk)
    if mla:
        V = K[:, :v_width]
    else:
        V = _deq(vm_ref[0, 0, :, 0, :], ve_ref[0, 0, :, 0, :],
                 vmi_ref[0, 0, :, 0, :])

    scores = jax.lax.dot_general(
        qv, K, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                     # (G, t_blk)
    pos = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1) + t * t_blk
    valid = pos < len_ref[0, 0]
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_scr[...]                                         # (G, 1)
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                                 # (G, t_blk)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p, V, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                     # (G, dv)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_scr[...] * alpha + pv

    @pl.when(t == n_t - 1)
    def _finish():
        y_ref[0, 0] = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)


@functools.partial(
    jax.jit,
    static_argnames=("interpret", "v_width", "scale"),
)
def mx_paged_attention_decode(
    q: jnp.ndarray,                 # (B, H, dk) current-token queries
    k_pool: F.QuantizedTensor,      # pools (P, G, 128, KVH, dk) MX8 payloads
    v_pool: Optional[F.QuantizedTensor],  # like k_pool; None => MLA
    bt: jnp.ndarray,                # (B, npg) int32 physical page ids
    group,                          # () int32 stacked-layer index
    lengths: jnp.ndarray,           # (B,) int32 valid cache length
    *, scale: Optional[float] = None, v_width: Optional[int] = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused paged decode attention; returns (B, H, dv) f32.

    Bit-identical to ``mx_attention_decode`` over the gathered dense layout
    of the same pages (same tile order, same flash accumulators).
    """
    B, H, dk = q.shape
    km = k_pool.payload["mantissa"]
    P, G, TB, KVH, dkc = km.shape
    assert dk == dkc and H % KVH == 0 and TB == PAGE_TOKENS
    Gq = H // KVH
    npg = int(bt.shape[1])
    mla = v_pool is None
    dv = v_width if mla else v_pool.payload["mantissa"].shape[-1]
    assert dv is not None

    scale = scale if scale is not None else dk ** -0.5
    qg = (q.astype(jnp.float32) * scale).reshape(B, KVH, Gq, dk)
    lens = lengths.astype(jnp.int32).reshape(B, 1)
    grp = jnp.asarray(group, jnp.int32).reshape(1)

    ke, kmi = k_pool.payload["exponent"], k_pool.payload["micro"]
    if mla:
        vm, ve, vmi = km, ke, kmi        # dummies (kernel reads K for V)
        v_blk, vgroups = 1, dkc // MXG
    else:
        vm = v_pool.payload["mantissa"]
        ve, vmi = v_pool.payload["exponent"], v_pool.payload["micro"]
        v_blk, vgroups = TB, dv // MXG

    # index maps see (grid indices..., *scalar-prefetch refs): the page id
    # comes straight off the prefetched block table, the stacked-layer
    # coordinate off the prefetched group index
    kpage = lambda b, h, t, bt_ref, g_ref: (bt_ref[b, t], g_ref[0], 0, h, 0)
    vpage = ((lambda b, h, t, bt_ref, g_ref: (0, 0, 0, h, 0)) if mla
             else kpage)

    kernel = functools.partial(_paged_attn_kernel, t_blk=TB, n_t=npg,
                               v_width=dv, mla=mla)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KVH, npg),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, t, *_: (b, 0)),            # len
            pl.BlockSpec((1, 1, Gq, dk), lambda b, h, t, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, TB, 1, dk), kpage),                      # km
            pl.BlockSpec((1, 1, TB, 1, dk // MXG), kpage),               # ke
            pl.BlockSpec((1, 1, TB, 1, dk // MXG), kpage),               # kmi
            pl.BlockSpec((1, 1, v_blk, 1, vgroups * MXG), vpage),        # vm
            pl.BlockSpec((1, 1, v_blk, 1, vgroups), vpage),              # ve
            pl.BlockSpec((1, 1, v_blk, 1, vgroups), vpage),              # vmi
        ],
        out_specs=pl.BlockSpec((1, 1, Gq, dv), lambda b, h, t, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Gq, 1), jnp.float32),
            pltpu.VMEM((Gq, 1), jnp.float32),
            pltpu.VMEM((Gq, dv), jnp.float32),
        ],
    )
    y = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, Gq, dv), jnp.float32),
        interpret=interpret,
    )(bt, grp, lens, qg, km, ke, kmi, vm, ve, vmi)
    return y.reshape(B, H, dv)


# ---------------------------------------------------------------------------
# in-place paged token append
# ---------------------------------------------------------------------------

def _append_kernel(bt_ref, pos_ref, grp_ref, *refs):
    """Write each row's new-token block into its page slot (one column)."""
    n = len(refs) // 3
    val_refs, pool_refs, out_refs = refs[:n], refs[n:2 * n], refs[2 * n:]
    del pool_refs  # aliased storage; present only to seed the outputs
    for v_ref, o_ref in zip(val_refs, out_refs):
        o_ref[0, 0, 0] = v_ref[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def mx_paged_kv_append(
    pools: Sequence[jnp.ndarray],   # each (P, G, 128, KVH, w)
    rows: Sequence[jnp.ndarray],    # each (B, KVH, w) quantized payload rows
    bt: jnp.ndarray,                # (B, npg) int32
    group,                          # () int32
    lengths: jnp.ndarray,           # (B,) append position per row
    *, interpret: bool = True,
) -> Tuple[jnp.ndarray, ...]:
    """Scatter one token's payload rows into their page slots in place.

    The pools are aliased input->output (``input_output_aliases``), so the
    unwritten 99.9% of every pool is never touched -- the paged analogue of
    the dense path's full-cache scatter, at one-slot write traffic.
    """
    pools = tuple(pools)
    rows = tuple(rows)
    assert len(pools) == len(rows) and pools
    B = bt.shape[0]
    P, G, TB, KVH, _ = pools[0].shape
    assert TB == PAGE_TOKENS
    pos = lengths.astype(jnp.int32)
    grp = jnp.asarray(group, jnp.int32).reshape(1)

    def slot(b, bt_ref, pos_ref, g_ref):
        return (bt_ref[b, pos_ref[b] // TB], g_ref[0], pos_ref[b] % TB, 0, 0)

    n = len(pools)
    in_specs = (
        [pl.BlockSpec((1, KVH, r.shape[-1]), lambda b, *_: (b, 0, 0))
         for r in rows]
        + [pl.BlockSpec((1, 1, 1, KVH, p.shape[-1]), slot) for p in pools])
    out_specs = [pl.BlockSpec((1, 1, 1, KVH, p.shape[-1]), slot)
                 for p in pools]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B,),
        in_specs=in_specs,
        out_specs=out_specs,
    )
    out = pl.pallas_call(
        _append_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(p.shape, p.dtype) for p in pools],
        # alias pool i (input index: 3 scalars + n value rows + i) to out i
        input_output_aliases={3 + n + i: i for i in range(n)},
        interpret=interpret,
    )(bt, pos, grp, *rows, *pools)
    return tuple(out)
