"""DEPRECATED shim -- kernel entry points moved to the ``repro.ops`` registry.

The ``backend=`` keyword dispatch that used to live here is now capability
negotiation in ``repro/ops/registry.py`` (op kind x backend x format), and
the implementations are registered SpuOps in ``repro/ops/state_update.py``
and ``repro/ops/attention.py``.  These wrappers keep external scripts
working: they emit :class:`~repro.ops.base.SpuDeprecationWarning` and
forward to the registry, returning bit-identical results.
"""
from __future__ import annotations

import warnings
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core import formats as F
from repro.ops.base import SpuDeprecationWarning, StateQuantConfig

DEFAULT_BACKEND = "pallas"


def _warn(old: str, new: str):
    warnings.warn(f"repro.kernels.ops.{old} is deprecated; use {new}",
                  SpuDeprecationWarning, stacklevel=3)


def state_update(
    qS: F.QuantizedTensor,
    d: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, q: jnp.ndarray,
    seed, *, rounding: str = "stochastic", backend: str = DEFAULT_BACKEND,
) -> Tuple[F.QuantizedTensor, jnp.ndarray]:
    """Deprecated: use repro.ops.state_update_step."""
    _warn("state_update", "repro.ops.state_update_step")
    from repro import ops as OPS
    cfg = StateQuantConfig(fmt=qS.fmt, rounding=rounding, backend=backend)
    return OPS.state_update_step(qS, d, k, v, q, cfg, seed=seed)


def state_update_float(S: jnp.ndarray, d, k, v, q,
                       dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Deprecated: use repro.ops.state_update_float."""
    _warn("state_update_float", "repro.ops.state_update_float")
    from repro.ops.state_update import state_update_float as _f
    return _f(S, d, k, v, q, dtype=dtype)


def attention_decode(
    q: jnp.ndarray,
    qK: F.QuantizedTensor, qV: Optional[F.QuantizedTensor],
    lengths: jnp.ndarray,
    *, scale: Optional[float] = None, v_width: Optional[int] = None,
    t_block: int = 128, backend: str = DEFAULT_BACKEND,
) -> jnp.ndarray:
    """Deprecated: use repro.ops.attn_decode on a KVCache."""
    _warn("attention_decode", "repro.ops.attn_decode")
    from repro.core.attention_cache import KVCache
    from repro.ops.attention import attn_decode
    cache = KVCache(qK, qV, lengths, qK.fmt, v_width)
    cfg = StateQuantConfig(fmt=qK.fmt, rounding="nearest", backend=backend)
    return attn_decode(cache, q, cfg, scale=scale, t_block=t_block)


def quantize_mx8(x: jnp.ndarray, seed=0, *, rounding: str = "nearest",
                 backend: str = DEFAULT_BACKEND) -> F.QuantizedTensor:
    """Deprecated: use repro.core.formats.quantize / kernels.mx_quant."""
    _warn("quantize_mx8", "repro.core.formats.quantize")
    if backend == "pallas":
        from repro.kernels.mx_quant import mx_quantize as _quant_pallas
        return _quant_pallas(x, seed, rounding=rounding, interpret=True)
    from repro.kernels import ref as _ref
    return _ref.mx_quantize_ref(x, rounding=rounding, seed=seed)
