"""Public jit'd entry points for the Pallas kernels, with pure-jnp fallbacks.

Dispatch policy
---------------
``backend='pallas'``  -- the fused Pallas kernels (``interpret=True`` here on
                         CPU; compiled natively on real TPUs).
``backend='jnp'``     -- mathematically identical pure-jnp path.  This is what
                         the multi-pod **dry-run lowers**: interpret-mode
                         pallas would trace its grid as an unrolled Python
                         loop (compile-time explosion at production sizes)
                         and would distort cost analysis.  XLA fuses the
                         dequant→update→requant chain, so HLO bytes match the
                         kernel's logical traffic closely (verified in
                         EXPERIMENTS.md §Roofline).

Numerics are identical between backends (bitwise for the packed state).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import formats as F
from repro.kernels import ref as _ref
from repro.kernels.mx_attention import mx_attention_decode as _attn_pallas
from repro.kernels.mx_quant import mx_quantize as _quant_pallas
from repro.kernels.mx_state_update import mx_state_update as _su_pallas

DEFAULT_BACKEND = "pallas"


def state_update(
    qS: F.QuantizedTensor,
    d: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, q: jnp.ndarray,
    seed, *, rounding: str = "stochastic", backend: str = DEFAULT_BACKEND,
) -> Tuple[F.QuantizedTensor, jnp.ndarray]:
    """Fused quantized state update; state layout (B, H, dv, dk)."""
    if backend == "pallas":
        return _su_pallas(qS, d, k, v, q, jnp.asarray(seed, jnp.int32),
                          rounding=rounding, interpret=True)
    return _ref.quantized_state_update_stored_ref(
        qS, d, k, v, q, rounding=rounding, seed=seed)


def state_update_float(S: jnp.ndarray, d, k, v, q,
                       dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Unquantized baseline (the paper's "GPU" fp16 configuration).

    State layout (B, H, dv, dk) to match the quantized path.
    """
    St = S.astype(jnp.float32)
    d_ = jnp.broadcast_to(d.astype(jnp.float32), St.shape[:2] + St.shape[-1:])
    Sn = St * d_[:, :, None, :] + (v.astype(jnp.float32)[..., :, None]
                                   * k.astype(jnp.float32)[..., None, :])
    y = jnp.einsum("bhvk,bhk->bhv", Sn, q.astype(jnp.float32))
    return Sn.astype(dtype), y


def attention_decode(
    q: jnp.ndarray,
    qK: F.QuantizedTensor, qV: Optional[F.QuantizedTensor],
    lengths: jnp.ndarray,
    *, scale: Optional[float] = None, v_width: Optional[int] = None,
    t_block: int = 128, backend: str = DEFAULT_BACKEND,
) -> jnp.ndarray:
    """Fused decode attention over packed MX8 KV cache (GQA or MLA)."""
    if backend == "pallas":
        return _attn_pallas(q, qK, qV, lengths, scale=scale,
                            v_width=v_width, t_block=t_block, interpret=True)
    if qV is None:  # MLA: values are a prefix slice of the latent cache
        kf = F.dequantize(qK)
        return _ref.attention_decode_ref(q, kf, kf[..., :v_width], lengths, scale)
    return _ref.mx_attention_decode_ref(q, qK, qV, lengths, scale)


def quantize_mx8(x: jnp.ndarray, seed=0, *, rounding: str = "nearest",
                 backend: str = DEFAULT_BACKEND) -> F.QuantizedTensor:
    """MX8 quantization (groups along last axis)."""
    if backend == "pallas":
        return _quant_pallas(x, seed, rounding=rounding, interpret=True)
    return _ref.mx_quantize_ref(x, rounding=rounding, seed=seed)
