"""Pallas TPU kernels: speculative-verify attention over MX8 KV caches.

Speculative decoding verifies ``Kq`` drafted tokens in one pass: the cache
already holds the ``Kq`` appended rows, and query position ``j`` attends over
every position strictly before ``lengths - (Kq-1-j)`` (its own row included).
``Kq == 1`` degenerates exactly to the plain decode kernels.

Both kernels reuse the flash score -> streaming-softmax -> attend pipeline of
:mod:`repro.kernels.mx_attention` / :mod:`repro.kernels.mx_paged_attention`
by folding the query axis into the GQA group axis: the query block becomes
``(Kq*G, dk)`` and the VMEM accumulators ``(Kq*G, .)``, so every query row
keeps its own private max/sum/acc lane.  Row-wise the arithmetic is
identical to running the single-query kernel once per position with the
per-position length -- which is what makes greedy speculative decode
bit-identical to sequential decode.

The bandwidth story (paper §3, ISSUE 10): the K/V pages stream through the
grid ONCE for all ``Kq`` queries -- the verify pass re-reads the same bytes
one decode step does, amortized over the drafted tokens.  That is the whole
reason speculation is nearly free in the memory-bound decode regime.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import formats as F
from repro.core.paged import PAGE_TOKENS
from repro.kernels.mx_attention import NEG_INF, _deq

MXG = F.MX8_GROUP


def _spec_attn_body(len_ref, q_ref, K, V, y_ref, m_scr, l_scr, acc_scr,
                    *, t: int, t_blk: int, n_t: int, n_q: int, g: int):
    """Shared flash body over a ``(n_q*g, dk)`` query block: query row ``r``
    belongs to draft position ``r // g`` and masks positions accordingly."""
    @pl.when(t == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qv = q_ref[0, 0].astype(jnp.float32)                    # (n_q*g, dk)
    scores = jax.lax.dot_general(
        qv, K, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                 # (n_q*g, t_blk)
    pos = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1) + t * t_blk
    qidx = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0) // g
    valid = pos < len_ref[0, 0] - (n_q - 1 - qidx)
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_scr[...]                                     # (n_q*g, 1)
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p, V, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                 # (n_q*g, dv)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_scr[...] * alpha + pv

    @pl.when(t == n_t - 1)
    def _finish():
        y_ref[0, 0] = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)


def _spec_kernel(len_ref, q_ref, km_ref, ke_ref, kmi_ref,
                 vm_ref, ve_ref, vmi_ref, y_ref, m_scr, l_scr, acc_scr,
                 *, t_blk, n_t, n_q, g, v_width, mla):
    t = pl.program_id(2)
    K = _deq(km_ref[0, :, 0, :], ke_ref[0, :, 0, :], kmi_ref[0, :, 0, :])
    if mla:
        V = K[:, :v_width]
    else:
        V = _deq(vm_ref[0, :, 0, :], ve_ref[0, :, 0, :], vmi_ref[0, :, 0, :])
    _spec_attn_body(len_ref, q_ref, K, V, y_ref, m_scr, l_scr, acc_scr,
                    t=t, t_blk=t_blk, n_t=n_t, n_q=n_q, g=g)


def _paged_spec_kernel(bt_ref, grp_ref, len_ref, q_ref, km_ref, ke_ref,
                       kmi_ref, vm_ref, ve_ref, vmi_ref, y_ref,
                       m_scr, l_scr, acc_scr,
                       *, t_blk, n_t, n_q, g, v_width, mla):
    t = pl.program_id(2)
    K = _deq(km_ref[0, 0, :, 0, :], ke_ref[0, 0, :, 0, :],
             kmi_ref[0, 0, :, 0, :])
    if mla:
        V = K[:, :v_width]
    else:
        V = _deq(vm_ref[0, 0, :, 0, :], ve_ref[0, 0, :, 0, :],
                 vmi_ref[0, 0, :, 0, :])
    _spec_attn_body(len_ref, q_ref, K, V, y_ref, m_scr, l_scr, acc_scr,
                    t=t, t_blk=t_blk, n_t=n_t, n_q=n_q, g=g)


def _fold_queries(q: jnp.ndarray, KVH: int, scale: float) -> jnp.ndarray:
    """(B, Kq, H, dk) -> (B, KVH, Kq*G, dk) with query-major row order."""
    B, Kq, H, dk = q.shape
    G = H // KVH
    qg = (q.astype(jnp.float32) * scale).reshape(B, Kq, KVH, G, dk)
    return jnp.transpose(qg, (0, 2, 1, 3, 4)).reshape(B, KVH, Kq * G, dk)


def _unfold_outputs(y: jnp.ndarray, Kq: int) -> jnp.ndarray:
    """(B, KVH, Kq*G, dv) -> (B, Kq, H, dv)."""
    B, KVH, QG, dv = y.shape
    G = QG // Kq
    y = y.reshape(B, KVH, Kq, G, dv)
    return jnp.transpose(y, (0, 2, 1, 3, 4)).reshape(B, Kq, KVH * G, dv)


@functools.partial(
    jax.jit, static_argnames=("t_block", "interpret", "v_width", "scale"))
def mx_spec_attention_decode(
    q: jnp.ndarray,                 # (B, Kq, H, dk) verify-position queries
    qK: F.QuantizedTensor,          # (B, T, KVH, dk) packed keys
    qV: Optional[F.QuantizedTensor],  # packed values; None => MLA
    lengths: jnp.ndarray,           # (B,) valid length INCLUDING the Kq rows
    *, scale: Optional[float] = None, v_width: Optional[int] = None,
    t_block: int = 128, interpret: bool = True,
) -> jnp.ndarray:
    """Fused dense spec-verify attention; returns (B, Kq, H, dv) f32."""
    B, Kq, H, dk = q.shape
    _, T, KVH, dkc = qK.shape
    assert dk == dkc and H % KVH == 0 and T % t_block == 0
    G = H // KVH
    n_t = T // t_block
    mla = qV is None
    dv = v_width if mla else qV.shape[-1]
    assert dv is not None

    scale = scale if scale is not None else dk ** -0.5
    qg = _fold_queries(q, KVH, scale)
    lens = lengths.astype(jnp.int32).reshape(B, 1)

    km = qK.payload["mantissa"]
    ke = qK.payload["exponent"]
    kmi = qK.payload["micro"]
    if mla:
        vm, ve, vmi = km[:, :1], ke[:, :1], kmi[:, :1]
        vgroups = dkc // MXG
    else:
        vm = qV.payload["mantissa"]
        ve = qV.payload["exponent"]
        vmi = qV.payload["micro"]
        vgroups = dv // MXG
    v_t_blk = 1 if mla else t_block
    QG = Kq * G

    kernel = functools.partial(_spec_kernel, t_blk=t_block, n_t=n_t,
                               n_q=Kq, g=G, v_width=dv, mla=mla)
    y = pl.pallas_call(
        kernel,
        grid=(B, KVH, n_t),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, t: (b, 0)),
            pl.BlockSpec((1, 1, QG, dk), lambda b, h, t: (b, h, 0, 0)),
            pl.BlockSpec((1, t_block, 1, dk), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, t_block, 1, dk // MXG),
                         lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, t_block, 1, dk // MXG),
                         lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, v_t_blk, 1, vgroups * MXG),
                         lambda b, h, t: (b, 0 if v_t_blk == 1 else t, h, 0)),
            pl.BlockSpec((1, v_t_blk, 1, vgroups),
                         lambda b, h, t: (b, 0 if v_t_blk == 1 else t, h, 0)),
            pl.BlockSpec((1, v_t_blk, 1, vgroups),
                         lambda b, h, t: (b, 0 if v_t_blk == 1 else t, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, QG, dv), lambda b, h, t: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KVH, QG, dv), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((QG, 1), jnp.float32),
            pltpu.VMEM((QG, 1), jnp.float32),
            pltpu.VMEM((QG, dv), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qg, km, ke, kmi, vm, ve, vmi)
    return _unfold_outputs(y, Kq)


@functools.partial(
    jax.jit, static_argnames=("interpret", "v_width", "scale"))
def mx_paged_spec_attention_decode(
    q: jnp.ndarray,                 # (B, Kq, H, dk)
    k_pool: F.QuantizedTensor,      # pools (P, G, 128, KVH, dk)
    v_pool: Optional[F.QuantizedTensor],  # like k_pool; None => MLA
    bt: jnp.ndarray,                # (B, npg) int32 physical page ids
    group,                          # () int32 stacked-layer index
    lengths: jnp.ndarray,           # (B,) valid length INCLUDING the Kq rows
    *, scale: Optional[float] = None, v_width: Optional[int] = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused paged spec-verify attention; returns (B, Kq, H, dv) f32.

    The pages stream through the grid once for all ``Kq`` queries: the grid
    is the same ``(B, KVH, npg)`` as the single-query paged kernel, only the
    query block and the VMEM accumulators widen by ``Kq``.
    """
    B, Kq, H, dk = q.shape
    km = k_pool.payload["mantissa"]
    P, G, TB, KVH, dkc = km.shape
    assert dk == dkc and H % KVH == 0 and TB == PAGE_TOKENS
    Gq = H // KVH
    npg = int(bt.shape[1])
    mla = v_pool is None
    dv = v_width if mla else v_pool.payload["mantissa"].shape[-1]
    assert dv is not None

    scale = scale if scale is not None else dk ** -0.5
    qg = _fold_queries(q, KVH, scale)
    lens = lengths.astype(jnp.int32).reshape(B, 1)
    grp = jnp.asarray(group, jnp.int32).reshape(1)

    ke, kmi = k_pool.payload["exponent"], k_pool.payload["micro"]
    if mla:
        vm, ve, vmi = km, ke, kmi
        v_blk, vgroups = 1, dkc // MXG
    else:
        vm = v_pool.payload["mantissa"]
        ve, vmi = v_pool.payload["exponent"], v_pool.payload["micro"]
        v_blk, vgroups = TB, dv // MXG

    kpage = lambda b, h, t, bt_ref, g_ref: (bt_ref[b, t], g_ref[0], 0, h, 0)
    vpage = ((lambda b, h, t, bt_ref, g_ref: (0, 0, 0, h, 0)) if mla
             else kpage)
    QG = Kq * Gq

    kernel = functools.partial(_paged_spec_kernel, t_blk=TB, n_t=npg,
                               n_q=Kq, g=Gq, v_width=dv, mla=mla)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KVH, npg),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, t, *_: (b, 0)),
            pl.BlockSpec((1, 1, QG, dk), lambda b, h, t, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, TB, 1, dk), kpage),
            pl.BlockSpec((1, 1, TB, 1, dk // MXG), kpage),
            pl.BlockSpec((1, 1, TB, 1, dk // MXG), kpage),
            pl.BlockSpec((1, 1, v_blk, 1, vgroups * MXG), vpage),
            pl.BlockSpec((1, 1, v_blk, 1, vgroups), vpage),
            pl.BlockSpec((1, 1, v_blk, 1, vgroups), vpage),
        ],
        out_specs=pl.BlockSpec((1, 1, QG, dv),
                               lambda b, h, t, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((QG, 1), jnp.float32),
            pltpu.VMEM((QG, 1), jnp.float32),
            pltpu.VMEM((QG, dv), jnp.float32),
        ],
    )
    y = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, QG, dv), jnp.float32),
        interpret=interpret,
    )(bt, grp, lens, qg, km, ke, kmi, vm, ve, vmi)
    return _unfold_outputs(y, Kq)
