"""Request-lifecycle serving API: one streaming ``Engine`` facade.

The batch-offline engines (``submit()`` everything, ``run()`` to drain,
read ``done`` at the end) become a request-lifecycle API shaped like a real
serving front-end:

  * one ``ServeConfig`` subsumes ``EngineConfig`` / ``PagedEngineConfig``
    and selects the fixed-slot or paged backend;
  * ``Engine.submit()`` returns a ``RequestHandle`` that streams tokens as
    they are sampled each ``step()``, exposes the terminal status
    (``done`` / ``aborted`` / ``truncated``) and can ``abort()`` mid-decode
    (pages/slots free immediately, spilled victims included);
  * ``Engine.step()`` is the explicit event loop -- drive it open-loop,
    interleaving submits/aborts between steps; ``run()`` stays as the
    drain-to-empty wrapper;
  * ``Engine.fork()`` (paged backend) starts a continuation of a retained
    parent via copy-on-write prefix sharing: the child references the
    parent's full prefix pages and copies only the partial tail page, so N
    sampled continuations of one prompt or the next turn of a chat skip
    re-prefilling the shared context entirely.  ``Session`` wraps that into
    multi-turn chat;
  * ``ServeConfig(prefix_cache=True)`` (paged backend) makes that sharing
    *automatic and cross-request*: a radix prefix store remembers every
    full prompt page served, and any later ``submit()`` whose prompt shares
    the prefix adopts the stored pages -- no explicit ``fork()``.  Stored
    pages outlive their request under ``prefix_store_pages`` (LRU), can be
    demoted to a ``host_tier_bytes``-budgeted host tier, and come back via
    scheduler-lookahead async prefetch (see ``serving/memory/tiered``).

    eng = Engine(params, cfg, ServeConfig(backend="paged"))
    h = eng.submit(prompt, max_new_tokens=32)
    for tok in h:                      # drives eng.step() under the hood
        print(tok)

    chat = eng.session()
    first = chat.send(user_turn_1).result()
    reply = chat.send(user_turn_2)     # forks -- no re-prefill of turn 1
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.models.config import ModelConfig
from repro.obs import Observability, RequestRecord
from repro.serving.engine import (EngineConfig, PagedEngineConfig,
                                  PagedServingEngine, Request, ServingEngine,
                                  TERMINAL_STATUSES)
from repro.serving.sampler import SamplingConfig
from repro.serving.scheduler import SchedulerConfig

__all__ = ["ServeConfig", "Engine", "RequestHandle", "Session", "Request"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """One config for both serving backends.

    ``backend="slots"`` serves from the fixed ``batch x cache_capacity``
    cache pool; ``backend="paged"`` serves from the paged, bank-aware
    state/KV pool (preempting scheduler, chunked prefill, copy-on-write
    prefix sharing / sessions).
    """
    backend: str = "paged"             # "paged" | "slots"
    batch: int = 4                     # decode rows (slots / decode batch)
    cache_capacity: int = 256          # slots backend: max context per slot
    n_pages: Optional[int] = 33        # paged: pool pages (incl. 1 scratch)
    n_slabs: Optional[int] = None      # paged: state slabs (default 2B+1)
    byte_budget: Optional[int] = None  # paged: alternative to n_pages
    prefill_chunk: int = 128           # paged: longest full-seq prefill
    prefill_buckets: Optional[Tuple[int, ...]] = None
                                       # paged: snap prefill lengths down to
                                       # this bucket set (bounded compile
                                       # count); tail streams through decode
    sampling: SamplingConfig = SamplingConfig()
    scheduler: SchedulerConfig = SchedulerConfig()
    seed: int = 0
    # --- tiered memory hierarchy (paged backend only) ---
    prefix_cache: bool = False         # radix prefix store: requests that
                                       # share a prompt prefix with earlier
                                       # requests adopt its pages, no fork()
    prefix_store_pages: int = 64       # store capacity in pages (LRU)
    host_tier_bytes: Optional[int] = None  # host DRAM budget (None = off)
    prefetch_window: int = 2           # lookahead prefetch depth
    # --- resilience / fault injection (paged backend only) ---
    fault_plan: Optional[str] = None   # fault spec string (see
                                       # serving/faults); REPRO_FAULTS env
                                       # applies when unset
    nan_guard: Optional[bool] = None   # post-step non-finite-logits guard
                                       # (None = on iff faults active)
    max_queued: Optional[int] = None   # admission control: queue-depth cap,
                                       # excess submits end ``rejected``
    request_timeout_s: Optional[float] = None  # max queue wait -> rejected
    step_budget_s: Optional[float] = None      # watchdog wall-clock budget
    # --- speculative decoding (paged backend only) ---
    spec: Optional[str] = None         # draft source: "ngram" (self-draft)
                                       # or "model:<arch>" (small model)
    spec_k: int = 3                    # max drafts verified per step
    spec_window: int = 8               # k-controller acceptance window

    def __post_init__(self):
        if self.backend not in ("paged", "slots"):
            raise ValueError(f"backend must be 'paged' or 'slots', "
                             f"got {self.backend!r}")
        if self.backend == "slots" and self.prefix_cache:
            raise ValueError("prefix_cache needs the paged backend "
                             "(page refcounts / block tables)")
        if self.backend == "slots" and self.spec is not None:
            raise ValueError("speculative decoding needs the paged backend "
                             "(the spec_verify step walks block tables and "
                             "rolls state slabs back)")
        if self.backend == "slots":
            for f in ("fault_plan", "nan_guard", "max_queued",
                      "request_timeout_s", "step_budget_s"):
                if getattr(self, f) is not None:
                    raise ValueError(
                        f"{f} needs the paged backend (the resilience "
                        "layer lives in the paged engine/pool)")

    def engine_config(self):
        """The backend-specific config this ServeConfig lowers to."""
        if self.backend == "slots":
            return EngineConfig(slots=self.batch,
                                cache_capacity=self.cache_capacity,
                                sampling=self.sampling, seed=self.seed)
        return PagedEngineConfig(
            max_decode_batch=self.batch,
            n_pages=None if self.byte_budget is not None else self.n_pages,
            n_slabs=(self.n_slabs if self.n_slabs is not None
                     else 2 * self.batch + 1),
            byte_budget=self.byte_budget,
            prefill_chunk=self.prefill_chunk,
            prefill_buckets=self.prefill_buckets,
            sampling=self.sampling,
            scheduler=self.scheduler,
            seed=self.seed,
            prefix_cache=self.prefix_cache,
            prefix_store_pages=self.prefix_store_pages,
            host_tier_bytes=self.host_tier_bytes,
            prefetch_window=self.prefetch_window,
            fault_plan=self.fault_plan,
            nan_guard=self.nan_guard,
            max_queued=self.max_queued,
            request_timeout_s=self.request_timeout_s,
            step_budget_s=self.step_budget_s,
            spec=self.spec,
            spec_k=self.spec_k,
            spec_window=self.spec_window)


class RequestHandle:
    """A live view of one submitted request.

    Tokens surface here as the engine samples them each ``step()``:
    ``new_tokens()`` drains whatever arrived since the last call (for
    open-loop callers driving ``Engine.step()`` themselves); iterating the
    handle drives the engine until this request finishes (other requests in
    the batch make progress on the same steps -- that *is* continuous
    batching).
    """

    def __init__(self, engine: "Engine", req: Request):
        self._engine = engine
        self._req = req
        self._cursor = 0

    # ------------- state -------------

    @property
    def rid(self) -> int:
        return self._req.rid

    @property
    def request(self) -> Request:
        return self._req

    @property
    def status(self) -> str:
        """queued | running | done | aborted | truncated | failed |
        rejected.  ``failed``: the engine quarantined the request after an
        unrecoverable fault (``request.detail`` says why); ``rejected``:
        admission control shed it before it decoded."""
        return self._req.status

    @property
    def finished(self) -> bool:
        return self._req.status in TERMINAL_STATUSES

    @property
    def output(self) -> List[int]:
        """All tokens sampled so far (does not move the stream cursor)."""
        return list(self._req.output)

    # ------------- streaming -------------

    def new_tokens(self) -> List[int]:
        """Tokens sampled since the last call (empty if none yet)."""
        out = self._req.output[self._cursor:]
        self._cursor += len(out)
        return out

    def __iter__(self) -> Iterator[int]:
        """Stream tokens, driving ``Engine.step()`` while none are pending.
        Terminates when this request reaches a terminal status (or the
        engine drains entirely, e.g. after an abort)."""
        while True:
            for tok in self.new_tokens():
                yield tok
            if self.finished:
                break
            if not self._engine.step():
                break                   # engine idle: nothing more can come
        for tok in self.new_tokens():   # tokens from the terminal step
            yield tok

    def result(self) -> Request:
        """Drive the engine until this request is terminal; returns it."""
        while not self.finished and self._engine.step():
            pass
        return self._req

    # ------------- control -------------

    def abort(self) -> bool:
        """Cancel now: frees pages/slots immediately (spilled state too);
        tokens already streamed stay available.  Status -> ``aborted``."""
        return self._engine.abort(self)


class Engine:
    """The one serving facade over both backends."""

    def __init__(self, params, cfg: ModelConfig,
                 scfg: ServeConfig = ServeConfig(), mesh_axes=None,
                 obs: Optional[Observability] = None):
        self.scfg = scfg
        obs = obs if obs is not None else Observability()
        ecfg = scfg.engine_config()
        if scfg.backend == "slots":
            self._eng = ServingEngine(params, cfg, ecfg, mesh_axes=mesh_axes,
                                      obs=obs)
        else:
            self._eng = PagedServingEngine(params, cfg, ecfg,
                                           mesh_axes=mesh_axes, obs=obs)
        self._rids = itertools.count()

    # ------------- properties -------------

    @property
    def backend(self) -> str:
        return self._eng.backend

    @property
    def engine(self):
        """The backing engine (escape hatch: pool, scheduler, bank_report)."""
        return self._eng

    @property
    def obs(self) -> Observability:
        """The observability bundle: metrics registry, trace buffer,
        lifecycle tracker, recompile watcher."""
        return self._eng.obs

    # ------------- observability -------------

    def save_trace(self, path: str) -> None:
        """Write the structured trace: Chrome-trace JSON (Perfetto) or
        JSONL for ``*.jsonl`` paths."""
        self.obs.save_trace(path)

    def prometheus_text(self) -> str:
        """The metrics registry in Prometheus text exposition format."""
        return self.obs.prometheus_text()

    def lifecycle(self, handle) -> Optional["RequestRecord"]:
        """Per-request span record (queue delay, TTFT, preemption cost)."""
        rid = handle.rid if isinstance(handle, RequestHandle) else int(handle)
        return self.obs.lifecycle.record(rid)

    # ------------- request lifecycle -------------

    def submit(self, prompt, *, max_new_tokens: int = 16,
               eos_id: Optional[int] = None, priority: int = 0,
               deadline: Optional[float] = None,
               retain: bool = False) -> RequestHandle:
        """Queue a new request; returns its streaming handle.

        ``retain=True`` (paged backend) keeps the finished request's pages
        pinned so it can serve as a ``fork()`` parent; pair it with
        ``release()`` when the prefix is no longer needed.
        """
        req = Request(rid=next(self._rids),
                      prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, eos_id=eos_id,
                      priority=priority, deadline=deadline, retain=retain)
        self._eng.submit(req)
        return RequestHandle(self, req)

    def fork(self, parent: RequestHandle, tokens: Sequence[int] = (), *,
             max_new_tokens: int = 16, eos_id: Optional[int] = None,
             priority: int = 0, deadline: Optional[float] = None,
             retain: bool = False) -> RequestHandle:
        """Continue a finished, retained parent without re-prefilling.

        The child shares the parent's full prefix pages copy-on-write and
        feeds only ``tokens`` (the next user turn; may be empty for a pure
        sampled continuation) after the parent's final sampled token.  Its
        context is exactly ``parent.prompt + parent.output + tokens``.
        Paged backend only.
        """
        if self.backend != "paged":
            raise ValueError("fork() needs the paged backend "
                             "(copy-on-write prefix sharing)")
        if not parent.finished or parent.status != "done":
            raise ValueError(f"fork parent {parent.rid} is not done "
                             f"(status={parent.status}); drive it with "
                             "result() first")
        req = Request(rid=next(self._rids),
                      prompt=np.asarray(list(tokens), np.int32),
                      max_new_tokens=max_new_tokens, eos_id=eos_id,
                      priority=priority, deadline=deadline, retain=retain,
                      parent_rid=parent.rid)
        self._eng.submit(req)
        return RequestHandle(self, req)

    def abort(self, handle) -> bool:
        rid = handle.rid if isinstance(handle, RequestHandle) else int(handle)
        return self._eng.abort(rid)

    def release(self, handle) -> None:
        """Free a retained parent's pages (shared pages stay alive until the
        last fork drops its reference)."""
        rid = handle.rid if isinstance(handle, RequestHandle) else int(handle)
        self._eng.release_retained(rid)

    # ------------- event loop -------------

    def step(self) -> bool:
        """One event-loop iteration (admit + one batched decode step).
        Returns True while any request is queued or running."""
        return self._eng.step()

    def has_work(self) -> bool:
        return self._eng.has_work()

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drain-to-empty wrapper around ``step()`` (see the engines' docs
        for the ``max_steps`` still-active surfacing contract)."""
        return self._eng.run(max_steps=max_steps)

    def stats(self) -> Dict[str, float]:
        return self._eng.stats()

    # ------------- sessions -------------

    def session(self) -> "Session":
        if self.backend != "paged":
            raise ValueError("sessions need the paged backend "
                             "(copy-on-write prefix sharing)")
        return Session(self)


class Session:
    """Multi-turn chat on copy-on-write prefix sharing.

    Each ``send()`` forks the previous turn instead of re-prefilling the
    conversation so far: turn N costs one tail-page copy + the new tokens,
    regardless of how long the history is.  The previous turn's pages are
    released as soon as the fork holds its own references.
    """

    def __init__(self, engine: Engine):
        assert engine.backend == "paged"
        self._engine = engine
        self._prev: Optional[RequestHandle] = None

    @property
    def turns(self) -> Optional[RequestHandle]:
        """Handle of the latest turn (None before the first send)."""
        return self._prev

    def send(self, tokens, *, max_new_tokens: int = 16,
             eos_id: Optional[int] = None) -> RequestHandle:
        """Feed the next user turn; returns the reply's streaming handle."""
        if self._prev is None:
            h = self._engine.submit(tokens, max_new_tokens=max_new_tokens,
                                    eos_id=eos_id, retain=True)
            self._prev = h
            return h
        prev = self._prev
        prev.result()                        # finish the previous turn
        if prev.status != "done":
            raise RuntimeError(f"previous turn ended {prev.status}; "
                               "session context is gone")
        h = self._engine.fork(prev, tokens, max_new_tokens=max_new_tokens,
                              eos_id=eos_id, retain=True)
        # the fork takes its page references at admission: drive until the
        # child is running, then the old turn's pages can drop
        while h.status == "queued" and self._engine.step():
            pass
        if h.status != "queued":
            self._engine.release(prev)
        self._prev = h
        return h

    def close(self) -> None:
        """Release the last retained turn's pages."""
        if self._prev is not None:
            if self._prev.status == "done":
                self._engine.release(self._prev)
            self._prev = None
