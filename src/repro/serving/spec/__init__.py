"""Speculative decoding: draft sources and the acceptance-aware controller.

The target-model side (the ``spec_verify`` SPU op, the multi-position
paged step with state snapshots, and the engine's accept/rollback logic)
lives in :mod:`repro.ops.spec_verify`, :mod:`repro.models.model` and
:mod:`repro.serving.engine`; this package holds the host-side pieces that
decide *what* to draft and *how much*:

  * :class:`DraftSource` -- the protocol the engine drives
  * :class:`NGramDraft` -- self-drafting suffix matcher (no second model)
  * :class:`ModelDraft` -- small-model drafting over a private paged pool
  * :class:`KController` -- per-request draft length from acceptance history

See the README's "Speculative decoding" section for the greedy-exactness
guarantee and how to enable it (``ServeConfig(spec="ngram")`` or
``spec="model:<arch>"``).
"""
from repro.serving.spec.controller import KController
from repro.serving.spec.draft import DraftSource, ModelDraft, NGramDraft

__all__ = ["DraftSource", "KController", "ModelDraft", "NGramDraft"]
