"""Draft sources for speculative decoding.

A draft source proposes up to ``k`` likely next tokens per request; the
engine verifies them in one ``spec_verify`` pass of the target model and
accepts the matching prefix.  Two sources live behind one protocol:

:class:`NGramDraft`
    Self-drafting: match the request's recent token suffix against its own
    history and propose the continuation that followed the longest matching
    n-gram last time.  No second model, no extra memory -- works for every
    architecture (including the SSM families, where small draft models are
    scarce) and shines on repetitive text (code, structured output).

:class:`ModelDraft`
    A small attention-only draft model (e.g. ``smollm-360m`` drafting for
    ``yi-9b``) decoded greedily token by token through its own small
    :class:`~repro.serving.memory.PagedStatePool`.  The draft pool is
    separate from the target pool -- the two models' cache leaves have
    different shapes, so the pages are physically unshareable -- but it is
    slab/page-accounted the same way and torn down through the same PL255
    leak check.  Rejected drafts roll back by resetting the host-side
    consumed counter: the stale KV rows beyond it are masked by the next
    call's lengths and overwritten in place.

Both are host-side and deterministic; neither touches the target model's
jitted step.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Protocol, Sequence

import jax
import jax.numpy as jnp
import numpy as np


class DraftSource(Protocol):
    """What the engine needs from a draft source.

    ``propose`` receives the request's full decoded context (prompt +
    emitted tokens) and never sees verification results directly -- accepted
    tokens simply show up in the next call's context, which is also how
    rollback of rejected drafts happens for stateless sources.
    """

    def admit(self, rid: int, prompt: Sequence[int]) -> bool:
        """Take on a request (allocate draft-side state).  False = the
        source cannot serve it now; the engine decodes it normally."""
        ...

    def release(self, rid: int) -> None:
        """Drop a request's draft-side state (finish/abort/failure)."""
        ...

    def suspend(self, rid: int) -> None:
        """The request was preempted: drop reconstructible draft state now,
        keep serving the rid after the engine resumes it."""
        ...

    def propose(self, rid: int, context: Sequence[int],
                k: int) -> List[int]:
        """Up to ``k`` drafted continuations of ``context`` (may be [])."""
        ...


class NGramDraft:
    """Suffix-match self-drafting (no draft model).

    For gram lengths 3, 2, 1 (longest first): find the most recent earlier
    occurrence of the context's trailing gram and propose the ``k`` tokens
    that followed it.  Stateless per request -- admit/release/suspend only
    gate a membership set, so preemption and abort are trivially clean.
    """

    def __init__(self, max_gram: int = 3):
        assert max_gram >= 1
        self.max_gram = max_gram
        self._rids: set = set()

    def admit(self, rid: int, prompt: Sequence[int]) -> bool:
        self._rids.add(rid)
        return True

    def release(self, rid: int) -> None:
        self._rids.discard(rid)

    def suspend(self, rid: int) -> None:
        pass                      # nothing cached outside the context

    def propose(self, rid: int, context: Sequence[int],
                k: int) -> List[int]:
        if rid not in self._rids or k <= 0:
            return []
        ctx = list(context)
        n = len(ctx)
        for g in range(min(self.max_gram, n - 1), 0, -1):
            tail = ctx[n - g:]
            # most recent earlier occurrence of the trailing gram
            for start in range(n - g - 1, -1, -1):
                if ctx[start:start + g] == tail:
                    out = ctx[start + g:start + g + k]
                    if out:
                        return out
        return []


class ModelDraft:
    """Small-model drafting through a private paged pool.

    The draft model decodes greedily, one token at a time, over its own
    :class:`PagedStatePool`.  Per request it tracks how many context tokens
    its cache has consumed; each ``propose`` first catches up on tokens the
    target accepted since the last call (rejected drafts are *behind* the
    counter and simply get overwritten), then rolls out ``k`` greedy
    drafts.  After the rollout the counter is reset to the verified context
    length, which is the whole rollback story: KV beyond it is dead weight
    the next catch-up masks and overwrites.

    Restricted to attention-only draft architectures -- recurrent draft
    state cannot be rolled back by a host counter reset.
    """

    def __init__(self, cfg, params=None, *, max_requests: int = 8,
                 max_len: int = 4096, seed: int = 0):
        from repro.models import model as M
        from repro.serving.memory import PagedStatePool, pages_for
        bad = [k for k in (tuple(cfg.pattern) + tuple(cfg.prelude or ()))
               if k not in ("attn", "mla")]
        assert not bad, \
            f"draft model must be attention-only, {cfg.name} has {bad}"
        self.cfg = cfg
        self.params = (M.init_model(jax.random.PRNGKey(seed), cfg)
                       if params is None else params)
        self._prefill = jax.jit(
            lambda p, b: M.prefill(p, cfg, b))
        self.pool = PagedStatePool(
            cfg, n_pages=1 + max_requests * pages_for(max_len),
            n_slabs=1 + max_requests)
        self._pages_for = pages_for
        self.consumed: Dict[int, int] = {}     # rid -> cached context length
        self._step = 0

    # -- DraftSource protocol -------------------------------------------

    def admit(self, rid: int, prompt: Sequence[int]) -> bool:
        if rid in self.consumed:
            return True
        npg = self._pages_for(len(prompt))
        if not self.pool.can_admit(npg):
            return False
        # drafting is best-effort: a failed claim means "no drafts this
        # round" (the engine decodes normally), not a request to escalate
        if not self.pool.register(rid, npg):  # lint: disable=PL206
            return False
        pr = jnp.asarray(np.asarray(prompt, np.int32))[None]
        _, row = self._prefill(self.params, {"tokens": pr, "targets": pr})
        self.pool.insert_prefill(rid, row)
        self.consumed[rid] = len(prompt)
        return True

    def release(self, rid: int) -> None:
        if rid in self.consumed:
            self.pool.release(rid)
            del self.consumed[rid]

    def suspend(self, rid: int) -> None:
        # preemption: the draft cache is reconstructible from the context,
        # so free the pages now and re-admit lazily on the next propose
        self.release(rid)

    def propose(self, rid: int, context: Sequence[int],
                k: int) -> List[int]:
        if k <= 0:
            return []
        if rid not in self.consumed:       # suspended earlier: re-admit
            if not self.admit(rid, list(context)):
                return []
        ctx = list(context)
        if self.consumed[rid] > len(ctx):
            # the engine rewound this request (e.g. resumed from an older
            # snapshot): our cache is ahead of the truth, rebuild it
            self.release(rid)
            if not self.admit(rid, ctx):
                return []
        drafts: List[int] = []
        # catch up on accepted-but-unconsumed context, then roll out k
        # greedy drafts; both are the same B=1 decode loop.  When nothing
        # is pending, re-decode the last context row (same position, so
        # the overwrite is harmless) to recover its next-token prediction.
        start = min(self.consumed[rid], len(ctx) - 1)
        length = start
        tok = None
        for t in ctx[start:]:
            tok = self._decode_one(rid, t, length)
            if tok is None:
                return []
            length += 1
        for i in range(k):
            drafts.append(tok)
            if i + 1 == k:
                break
            tok = self._decode_one(rid, tok, length)
            if tok is None:
                break
            length += 1
        self.consumed[rid] = len(ctx)
        return drafts

    # -- internals ------------------------------------------------------

    def _decode_one(self, rid: int, token: int,
                    length: int) -> Optional[int]:
        need = length // 128 + 1
        while need > len(self.pool.page_table[rid]):
            # best-effort (see admit): no page -> no draft, never escalate
            if not self.pool.grow(rid, 1):  # lint: disable=PL206
                return None
        self._step += 1
        lg = self.pool.decode(self.params, [rid],
                              np.array([token], np.int32),
                              np.array([length], np.int32),
                              seed=self._step)
        return int(jnp.argmax(lg[0]))

    def sanitizer_check_leaks(self, what: str = "draft teardown") -> None:
        self.pool.sanitizer_check_leaks(what)
