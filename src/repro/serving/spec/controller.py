"""Acceptance-aware draft-length controller.

Verification cost is one fused pass regardless of how many drafts ride in
it (the verify step always runs at the compiled ``spec_k + 1`` positions,
padding with garbage), but every *drafted* token costs draft-source work
and every *rejected* one is pure waste.  The controller therefore modulates
only how many drafts are requested per row, from that row's recent
acceptance history -- the compiled step shape never changes, so the
recompile watcher stays at the warmup count.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, Tuple


class KController:
    """Per-request draft length from a sliding acceptance window.

    Deterministic: ``k = clip(floor(mean accepted per speculative step)
    + 1, 1, k_max)`` over the last ``window`` steps, starting at ``k_max``
    (optimistic -- a fresh request has no evidence against drafting).
    A request that stops accepting decays to ``k = 1`` within a window;
    one that accepts everything climbs back just as fast.
    """

    def __init__(self, k_max: int, window: int = 8):
        assert k_max >= 1 and window >= 1
        self.k_max = int(k_max)
        self.window = int(window)
        self._hist: Dict[int, Deque[Tuple[int, int]]] = {}

    def k_for(self, rid: int) -> int:
        hist = self._hist.get(rid)
        if not hist:
            return self.k_max
        accepted = sum(a for _, a in hist)
        mean = accepted / len(hist)
        return max(1, min(self.k_max, int(mean) + 1))

    def observe(self, rid: int, proposed: int, accepted: int) -> None:
        """Record one speculative step's outcome for ``rid``.

        Steps with no drafts carry no acceptance evidence (nothing was
        risked) and are not recorded.
        """
        if proposed <= 0:
            return
        hist = self._hist.setdefault(rid, deque(maxlen=self.window))
        hist.append((proposed, accepted))

    def forget(self, rid: int) -> None:
        self._hist.pop(rid, None)
