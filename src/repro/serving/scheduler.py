"""Preempting continuous-batching scheduler for the paged serving engine.

Separates *policy* (who runs next, who gets evicted) from the engine's
*mechanics* (prefill, decode, page bookkeeping):

  * ``fcfs``     -- arrival order, no preemption on admission.
  * ``priority`` -- lower ``Request.priority`` runs first; an urgent waiting
    request may evict the least-urgent running one when the pool is full.
  * ``deadline`` -- earliest ``Request.deadline`` first (EDF); latest
    deadline is the preferred victim.

Preemption itself is page eviction: the engine spills the victim's
pages+slab to host memory and this queue gets the request back, to be
re-admitted (re-pinned to fresh pages) when capacity frees up.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import List, Optional, Set, Tuple


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    policy: str = "fcfs"            # fcfs | priority | deadline
    preemption: bool = True         # allow admission-driven eviction
    resume_boost: bool = True       # preempted work re-queues ahead of
                                    # equal-key fresh arrivals


class Scheduler:
    """An ordered waiting queue plus the victim-selection policy."""

    def __init__(self, cfg: SchedulerConfig = SchedulerConfig()):
        assert cfg.policy in ("fcfs", "priority", "deadline"), cfg.policy
        self.cfg = cfg
        self._heap: List[Tuple[tuple, int, object]] = []
        self._seq = itertools.count()
        # aborted rids: removal from a heap is lazy -- tombstoned entries are
        # skipped by peek/pop and pruned as they surface
        self._gone: Set[int] = set()
        self._n_live = 0
        #: optional repro.obs.Observability -- the owning engine attaches
        #: its bundle so queue transitions land on the scheduler track
        self.obs = None

    def _instant(self, name: str, **args):
        if self.obs is not None:
            self.obs.tracer.instant(name, cat="sched", track="scheduler",
                                    **args)

    def _key(self, req, resumed: bool = False) -> tuple:
        boost = -1 if (resumed and self.cfg.resume_boost) else 0
        if self.cfg.policy == "priority":
            return (req.priority, boost, req.t_submit)
        if self.cfg.policy == "deadline":
            dl = req.deadline if req.deadline is not None else float("inf")
            return (dl, boost, req.t_submit)
        return (0, boost, req.t_submit)

    # ------------- queue -------------

    def push(self, req, resumed: bool = False):
        # a tombstoned rid still has a stale entry in the heap; re-pushing
        # it would revive that entry as a duplicate.  Engines never reuse an
        # aborted rid, so fail loudly rather than corrupt the queue.
        assert req.rid not in self._gone, f"rid {req.rid} reuse after abort"
        heapq.heappush(self._heap,
                       (self._key(req, resumed), next(self._seq), req))
        self._n_live += 1
        self._instant("sched.enqueue", rid=req.rid, resumed=resumed,
                      policy=self.cfg.policy)

    def _prune(self):
        while self._heap and self._heap[0][2].rid in self._gone:
            _, _, req = heapq.heappop(self._heap)
            self._gone.discard(req.rid)

    def peek(self):
        self._prune()
        return self._heap[0][2] if self._heap else None

    def pop(self):
        self._prune()
        self._n_live -= 1
        req = heapq.heappop(self._heap)[2]
        self._instant("sched.dispatch", rid=req.rid)
        return req

    def remove(self, rid: int):
        """Abort support: drop a waiting request from the heap.  Returns the
        removed request, or None if ``rid`` is not queued.  O(n) scan to hand
        the caller its Request; the heap itself is cleaned lazily."""
        for _, _, req in self._heap:
            if req.rid == rid and rid not in self._gone:
                self._gone.add(rid)
                self._n_live -= 1
                self._instant("sched.cancel", rid=rid)
                return req
        return None

    def requests(self) -> List[object]:
        """Live (non-tombstoned) waiting requests, unordered."""
        return [req for _, _, req in self._heap if req.rid not in self._gone]

    def lookahead(self, n: int) -> List[object]:
        """The next ``n`` requests in dispatch order, without popping --
        the admission window the tiered pool prefetches for (spilled blobs
        staged to device, demoted prefix pages promoted) so their data is
        resident before they win admission."""
        self._prune()
        live = [e for e in self._heap if e[2].rid not in self._gone]
        return [e[2] for e in heapq.nsmallest(n, live,
                                              key=lambda e: (e[0], e[1]))]

    def __len__(self) -> int:
        return self._n_live

    def __bool__(self) -> bool:
        return self._n_live > 0

    # ------------- preemption policy -------------

    def choose_victim(self, running: List[object],
                      exclude: Optional[object] = None):
        """The least-urgent running request (never ``exclude``), or None."""
        cands = [r for r in running if r is not exclude]
        if not cands:
            return None
        return max(cands, key=self._key)

    def should_preempt(self, waiting, victim) -> bool:
        """Evict ``victim`` to admit ``waiting``?  Only when the policy says
        the waiting request is strictly more urgent -- FCFS never preempts
        on admission (capacity-driven eviction is the engine's call)."""
        if not self.cfg.preemption or victim is None:
            return False
        if self.cfg.policy == "fcfs":
            return False
        return self._key(waiting) < self._key(victim)
