"""Preempting continuous-batching scheduler for the paged serving engine.

Separates *policy* (who runs next, who gets evicted) from the engine's
*mechanics* (prefill, decode, page bookkeeping):

  * ``fcfs``     -- arrival order, no preemption on admission.
  * ``priority`` -- lower ``Request.priority`` runs first; an urgent waiting
    request may evict the least-urgent running one when the pool is full.
  * ``deadline`` -- earliest ``Request.deadline`` first (EDF); latest
    deadline is the preferred victim.

Preemption itself is page eviction: the engine spills the victim's
pages+slab to host memory and this queue gets the request back, to be
re-admitted (re-pinned to fresh pages) when capacity frees up.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    policy: str = "fcfs"            # fcfs | priority | deadline
    preemption: bool = True         # allow admission-driven eviction
    resume_boost: bool = True       # preempted work re-queues ahead of
                                    # equal-key fresh arrivals


class Scheduler:
    """An ordered waiting queue plus the victim-selection policy."""

    def __init__(self, cfg: SchedulerConfig = SchedulerConfig()):
        assert cfg.policy in ("fcfs", "priority", "deadline"), cfg.policy
        self.cfg = cfg
        self._heap: List[Tuple[tuple, int, object]] = []
        self._seq = itertools.count()

    def _key(self, req, resumed: bool = False) -> tuple:
        boost = -1 if (resumed and self.cfg.resume_boost) else 0
        if self.cfg.policy == "priority":
            return (req.priority, boost, req.t_submit)
        if self.cfg.policy == "deadline":
            dl = req.deadline if req.deadline is not None else float("inf")
            return (dl, boost, req.t_submit)
        return (0, boost, req.t_submit)

    # ------------- queue -------------

    def push(self, req, resumed: bool = False):
        heapq.heappush(self._heap,
                       (self._key(req, resumed), next(self._seq), req))

    def peek(self):
        return self._heap[0][2] if self._heap else None

    def pop(self):
        return heapq.heappop(self._heap)[2]

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    # ------------- preemption policy -------------

    def choose_victim(self, running: List[object],
                      exclude: Optional[object] = None):
        """The least-urgent running request (never ``exclude``), or None."""
        cands = [r for r in running if r is not exclude]
        if not cands:
            return None
        return max(cands, key=self._key)

    def should_preempt(self, waiting, victim) -> bool:
        """Evict ``victim`` to admit ``waiting``?  Only when the policy says
        the waiting request is strictly more urgent -- FCFS never preempts
        on admission (capacity-driven eviction is the engine's call)."""
        if not self.cfg.preemption or victim is None:
            return False
        if self.cfg.policy == "fcfs":
            return False
        return self._key(waiting) < self._key(victim)
