"""Graceful degradation primitives for the serving stack.

The defense half of the resilience layer (:mod:`repro.serving.faults` is
the attack half).  Nothing here knows about engines or pools; it provides
the small, dependency-free mechanisms they compose:

  * **blob checksums** -- every host-side blob (preemption spill, prefix
    store demotion) carries a CRC32 recorded at extraction and verified at
    resume/promote, so a corrupted byte is *detected* at the tier boundary
    instead of silently poisoning decode.  :class:`BlobCorruption` is the
    typed failure the engine recovers from (bounded re-prefill from the
    request's retained token ids).
  * **bounded retry** -- :func:`retry_transient` wraps an allocation-style
    call (returns falsy on transient failure) in a bounded retry loop with
    optional backoff; the PL206 lint rule requires alloc/pin call sites to
    go through a wrapper like this (or an equivalent escalation path)
    instead of asserting success.
  * **the degradation ladder** -- :data:`LADDER` names the escalation
    rungs admission walks when retries are exhausted: drop prefix-store
    admission, demote store pages, preempt live work, shed queued work
    with a ``rejected`` status.  The engine drives the walk; the ladder is
    data so obs counters and docs stay in one vocabulary.
  * **the step watchdog** -- :class:`StepWatchdog` flags steps exceeding a
    wall-clock budget into the metrics/trace stream (it never kills work:
    a slow step is a symptom to surface, not a request to drop).
"""
from __future__ import annotations

import time
import zlib
from typing import Callable, List, Optional, Sequence

import numpy as np

__all__ = ["BlobCorruption", "crc_blob", "corrupt_blob", "verify_blob",
           "retry_transient", "LADDER", "StepWatchdog",
           "RETRY_ATTEMPTS", "REPREFILL_CAP"]

#: bounded-retry attempts at transient alloc/pin sites before escalating
RETRY_ATTEMPTS = 3

#: bounded re-prefills of one request after blob corruption before the
#: request is declared ``failed``
REPREFILL_CAP = 2

#: the graceful-degradation ladder admission escalates through once
#: bounded retries are exhausted, least to most disruptive
LADDER = ("drop_prefix", "demote_store", "preempt", "shed")


class BlobCorruption(RuntimeError):
    """A host-tier blob failed its checksum at the device boundary."""

    def __init__(self, what: str, rid: Optional[int] = None,
                 expect: Optional[int] = None, got: Optional[int] = None):
        self.what = what
        self.rid = rid
        self.expect = expect
        self.got = got
        where = f" (rid {rid})" if rid is not None else ""
        super().__init__(
            f"checksum mismatch on {what}{where}: "
            f"expected {expect:#010x}, got {got:#010x}"
            if expect is not None and got is not None
            else f"checksum mismatch on {what}{where}")


def crc_blob(blob: Sequence[np.ndarray]) -> int:
    """CRC32 chained over a blob's arrays (order- and shape-sensitive)."""
    crc = 0
    for arr in blob:
        a = np.ascontiguousarray(arr)
        crc = zlib.crc32(str(a.shape).encode(), crc)
        crc = zlib.crc32(a.view(np.uint8).reshape(-1), crc)
    return crc & 0xFFFFFFFF


def corrupt_blob(blob: List[np.ndarray]) -> None:
    """Flip one byte of the first non-empty array (the injected
    ``blob_corrupt`` payload; the blob's recorded CRC no longer matches).
    Host blobs may be read-only views of device buffers, so the poisoned
    array replaces the list entry instead of mutating in place."""
    for i, arr in enumerate(blob):
        if arr.size:
            bad = np.array(arr)                   # writable copy
            bad.reshape(-1).view(np.uint8)[0] ^= 0xFF
            blob[i] = bad
            return


def verify_blob(blob: Sequence[np.ndarray], crc: Optional[int], what: str,
                rid: Optional[int] = None) -> None:
    """Raise :class:`BlobCorruption` when ``blob`` no longer matches the
    ``crc`` recorded at extraction (None = unchecked legacy blob)."""
    if crc is None:
        return
    got = crc_blob(blob)
    if got != crc:
        raise BlobCorruption(what, rid=rid, expect=crc, got=got)


def retry_transient(fn: Callable[[], object], attempts: int = RETRY_ATTEMPTS,
                    backoff_s: float = 0.0,
                    on_retry: Optional[Callable[[int], None]] = None):
    """Call ``fn`` until it returns truthy, up to ``attempts`` times.

    The contract of allocation-style calls (``pool.register``/``grow``/
    ``resume``, ``host.pin``): falsy means a *transient* shortage, an
    exception means a real fault -- exceptions propagate immediately.
    ``on_retry(k)`` observes the k-th retry (metrics).  Returns the last
    result (falsy when every attempt failed: the caller escalates through
    the degradation ladder)."""
    result = fn()
    for k in range(1, max(1, attempts)):
        if result:
            return result
        if on_retry is not None:
            on_retry(k)
        if backoff_s > 0.0:
            time.sleep(backoff_s * (2 ** (k - 1)))
        result = fn()
    return result


class StepWatchdog:
    """Wall-clock budget check for engine steps.

    ``observe(step, dt)`` compares a step's duration against the budget
    and reports trips through the supplied hooks; disabled (zero cost)
    when the budget is None.  The watchdog only *flags* -- a slow step
    feeds the obs stream (``watchdog_trips_total``, a ``cat="fault"``
    instant), it never aborts work.
    """

    def __init__(self, budget_s: Optional[float], obs=None):
        self.budget_s = budget_s
        self.obs = obs
        self.trips = 0
        self.slowest_s = 0.0

    @property
    def enabled(self) -> bool:
        return self.budget_s is not None

    def observe(self, step: int, dt: float) -> bool:
        """True when the step blew its budget (after reporting it)."""
        if self.budget_s is None:
            return False
        self.slowest_s = max(self.slowest_s, dt)
        if dt <= self.budget_s:
            return False
        self.trips += 1
        if self.obs is not None:
            self.obs.metrics.counter("watchdog_trips_total").inc()
            self.obs.tracer.instant(
                "watchdog.slow_step", cat="fault", track="engine",
                step=step, dt_s=dt, budget_s=self.budget_s)
        return True
