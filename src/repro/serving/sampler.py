"""Token samplers for the serving engine."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    temperature: float = 0.0       # 0 => greedy
    top_k: int = 0                 # 0 => full distribution
    top_p: float = 1.0             # 1.0 => no nucleus truncation


def _apply_top_p(logits: jnp.ndarray, top_p: float) -> jnp.ndarray:
    """Nucleus filtering: keep the smallest prefix of the sorted distribution
    whose probability mass reaches ``top_p`` (the argmax always survives)."""
    sorted_logits = jnp.flip(jnp.sort(logits, axis=-1), axis=-1)
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # token i is kept iff the mass *before* it is still below top_p;
    # the argmax always survives (so top_p=0 degrades to greedy, not to
    # an all-masked distribution)
    keep = (cum - probs) < top_p
    keep = keep.at[..., 0].set(True)
    thr = jnp.min(jnp.where(keep, sorted_logits, jnp.inf),
                  axis=-1, keepdims=True)
    return jnp.where(logits < thr, -jnp.inf, logits)


def filtered_probs(logits: jnp.ndarray, cfg: SamplingConfig) -> jnp.ndarray:
    """Post-filter sampling distribution over the last axis.

    The exact temperature/top-k/top-p chain of :func:`sample`, stopped
    before the categorical draw -- the speculative-decode engine needs the
    distribution itself for host-side rejection sampling (accepting a
    drafted token with its target probability keeps the sampled stream
    distributed exactly as non-speculative sampling).  Greedy (temperature
    <= 0) degenerates to a point mass on the argmax.
    """
    if cfg.temperature <= 0.0:
        return jax.nn.one_hot(jnp.argmax(logits, axis=-1), logits.shape[-1])
    logits = logits / cfg.temperature
    if cfg.top_k > 0:
        vals, _ = jax.lax.top_k(logits, cfg.top_k)
        kth = vals[..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if cfg.top_p < 1.0:
        logits = _apply_top_p(logits, cfg.top_p)
    return jax.nn.softmax(logits, axis=-1)


def sample(logits: jnp.ndarray, cfg: SamplingConfig, key) -> jnp.ndarray:
    """logits (B, V) -> tokens (B,) int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k > 0:
        vals, _ = jax.lax.top_k(logits, cfg.top_k)
        kth = vals[..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if cfg.top_p < 1.0:
        logits = _apply_top_p(logits, cfg.top_p)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
