"""Token samplers for the serving engine."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    temperature: float = 0.0       # 0 => greedy
    top_k: int = 0                 # 0 => full distribution


def sample(logits: jnp.ndarray, cfg: SamplingConfig, key) -> jnp.ndarray:
    """logits (B, V) -> tokens (B,) int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k > 0:
        vals, _ = jax.lax.top_k(logits, cfg.top_k)
        kth = vals[..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
