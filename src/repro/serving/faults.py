"""Deterministic fault injection for the serving stack.

A :class:`FaultPlan` is a seeded, reproducible schedule of failures the
engine and pools *ask about* at well-defined injection sites.  It is the
attack half of the resilience layer (:mod:`repro.serving.resilience` is
the defense half): chaos runs construct a plan from a spec string, every
``should_fire`` decision is a pure function of (spec, seed, call order),
and re-running the same workload with the same plan reproduces the same
faults byte for byte.

Spec grammar (one clause per site, ``;``-separated)::

    "alloc:step=7;host_pin:p=0.05;nan:rid=3;blob_corrupt:nth=2;slow_step:ms=500"

    site   := alloc | host_pin | blob_corrupt | prefetch_commit | nan
            | slow_step
    clause := site [":" key "=" value ("," key "=" value)*]

Trigger keys (combinable; all present triggers must agree):

    ``step=N``   fire while the engine's step counter is ``N``
    ``nth=K``    fire on the K-th check of this site (1-based)
    ``p=F``      fire each check with probability ``F`` (seeded PCG64)
    ``rid=R``    only fire for request id ``R``
    ``n=C``      cap total fires at ``C`` (default: 1 for deterministic
                 triggers ``step``/``nth``/``rid``, unlimited for ``p``)
    ``ms=M``     payload (``slow_step``: injected stall in milliseconds)

Injection sites (who checks, what a fire means):

    ``alloc``            page/slab allocation in the paged pool
                         (register / grow / resume / promote) reports a
                         transient failure -- callers retry + escalate
    ``host_pin``         pinning a spill blob in the host tier fails
                         transiently -- the spill path retries, then
                         force-pins (live state is never dropped)
    ``blob_corrupt``     a host blob (spill or store demotion) gets one
                         byte flipped *after* its checksum was recorded --
                         detected at resume/promote, recovered by
                         re-prefill / store eviction
    ``prefetch_commit``  a staged prefetch fails to commit -- the staging
                         pages are returned and resume falls back to the
                         synchronous path
    ``nan``              one active request's post-step logits become NaN
                         -- the guard quarantines exactly that request
    ``slow_step``        the engine sleeps ``ms`` before the step -- the
                         wall-clock watchdog must flag it

All checks are no-ops costing one ``is None`` test when no plan is
installed; a plan is installed via ``ServeConfig(fault_plan=...)`` or the
``REPRO_FAULTS`` environment variable.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional

import numpy as np

__all__ = ["FaultPlan", "FaultSpecError", "SITES"]

#: the injection sites a plan may name, in documentation order
SITES = ("alloc", "host_pin", "blob_corrupt", "prefetch_commit", "nan",
         "slow_step")

#: environment variable holding a fault spec (chaos runs under CI)
ENV_VAR = "REPRO_FAULTS"


class FaultSpecError(ValueError):
    """A fault spec string that does not parse / names unknown sites."""


@dataclasses.dataclass
class _SiteRule:
    """One parsed clause: the triggers for a single site."""
    site: str
    step: Optional[int] = None
    nth: Optional[int] = None
    p: Optional[float] = None
    rid: Optional[int] = None
    n: Optional[int] = None            # max fires (None = unlimited)
    ms: float = 0.0                    # payload (slow_step)
    # runtime state
    checks: int = 0
    fires: int = 0

    def cap(self) -> Optional[int]:
        if self.n is not None:
            return self.n
        # deterministic one-shot triggers default to a single fire;
        # probabilistic rules keep firing until capped explicitly
        if self.p is None and (self.step is not None or self.nth is not None
                               or self.rid is not None):
            return 1
        return None


_INT_KEYS = ("step", "nth", "rid", "n")
_FLOAT_KEYS = ("p", "ms")


def _parse_clause(clause: str) -> _SiteRule:
    head, _, rest = clause.partition(":")
    site = head.strip()
    if site not in SITES:
        raise FaultSpecError(
            f"unknown fault site {site!r} (known: {', '.join(SITES)})")
    rule = _SiteRule(site)
    if rest.strip():
        for kv in rest.split(","):
            key, sep, val = kv.partition("=")
            key, val = key.strip(), val.strip()
            if not sep or not val:
                raise FaultSpecError(f"bad trigger {kv!r} in {clause!r}")
            if key in _INT_KEYS:
                setattr(rule, key, int(val))
            elif key in _FLOAT_KEYS:
                setattr(rule, key, float(val))
            else:
                raise FaultSpecError(
                    f"unknown trigger key {key!r} in {clause!r} "
                    f"(known: {', '.join(_INT_KEYS + _FLOAT_KEYS)})")
    if rule.p is not None and not (0.0 <= rule.p <= 1.0):
        raise FaultSpecError(f"p={rule.p} out of [0, 1] in {clause!r}")
    return rule


class FaultPlan:
    """A parsed, seeded fault schedule.

    The plan is consulted through :meth:`should_fire` at each injection
    site; every consult is deterministic given the construction arguments
    and the sequence of prior consults (probabilistic triggers draw from a
    private ``PCG64(seed)`` stream).  ``injected`` tallies fires per site
    so chaos harnesses can report exactly what they unleashed.
    """

    def __init__(self, spec: str, seed: int = 0):
        spec = (spec or "").strip()
        clauses = [c for c in spec.split(";") if c.strip()]
        if not clauses:
            raise FaultSpecError("empty fault spec")
        self.spec = spec
        self.seed = int(seed)
        self.rules: Dict[str, _SiteRule] = {}
        for c in clauses:
            rule = _parse_clause(c.strip())
            if rule.site in self.rules:
                raise FaultSpecError(f"duplicate site {rule.site!r}")
            self.rules[rule.site] = rule
        self._rng = np.random.Generator(np.random.PCG64(self.seed))
        self._step = -1
        #: site -> number of faults actually fired
        self.injected: Dict[str, int] = {s: 0 for s in self.rules}

    # ------------- construction helpers -------------

    @classmethod
    def from_env(cls, seed: int = 0,
                 env: Optional[dict] = None) -> Optional["FaultPlan"]:
        """Plan from ``REPRO_FAULTS`` (None when unset/empty)."""
        spec = (env if env is not None else os.environ).get(ENV_VAR, "")
        return cls(spec, seed=seed) if spec.strip() else None

    @classmethod
    def maybe(cls, spec: Optional[str], seed: int = 0,
              use_env: bool = True) -> Optional["FaultPlan"]:
        """The engine-side constructor: explicit spec wins, else the
        environment, else None (faults disabled, zero overhead)."""
        if spec:
            return cls(spec, seed=seed)
        return cls.from_env(seed=seed) if use_env else None

    # ------------- the injection-site protocol -------------

    def set_step(self, step: int) -> None:
        """Engine hook: the current step counter (for ``step=N`` rules)."""
        self._step = int(step)

    def should_fire(self, site: str, rid: Optional[int] = None) -> bool:
        """One consult at ``site`` (optionally for a request): True means
        the caller must inject the fault now.  Counts the fire."""
        rule = self.rules.get(site)
        if rule is None:
            return False
        rule.checks += 1
        cap = rule.cap()
        if cap is not None and rule.fires >= cap:
            return False
        if rule.rid is not None and (rid is None or int(rid) != rule.rid):
            return False
        if rule.step is not None and self._step != rule.step:
            return False
        if rule.nth is not None and rule.checks != rule.nth:
            return False
        if rule.p is not None and not (self._rng.random() < rule.p):
            return False
        rule.fires += 1
        self.injected[site] += 1
        return True

    def param(self, site: str, key: str, default: float = 0.0) -> float:
        """A payload parameter of a site's clause (e.g. slow_step ms)."""
        rule = self.rules.get(site)
        if rule is None:
            return default
        return float(getattr(rule, key, default))

    @property
    def total_injected(self) -> int:
        return sum(self.injected.values())

    def __repr__(self) -> str:
        return f"FaultPlan({self.spec!r}, seed={self.seed})"
