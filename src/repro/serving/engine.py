"""Batched serving engines: the Pimba system loop (paper Fig. 7).

Two engines share the request-lifecycle machinery (``_EngineCore``): an
explicit ``step()`` event loop (admit + one batched decode step) that
callers can drive open-loop, ``submit`` / ``abort`` with terminal statuses
(``done`` / ``aborted`` / ``truncated``), and a ``run()`` drain wrapper.
The streaming facade over them lives in :mod:`repro.serving.api`.

``ServingEngine`` -- the original fixed-slot pool: continuous batching over
``slots x cache_capacity`` preallocated caches.  One long request dictates
everyone's memory footprint and admission is FCFS.

``PagedServingEngine`` -- the paged pool (``serving/memory``): state/KV
memory is block/page granular with a block table per request, so short and
long prompts coexist in the same byte budget, admission follows a
priority/deadline scheduler (``serving/scheduler``), prefill is chunked
(the tail of a long prompt streams through the shared decode step instead
of blocking the batch), and the pool preempts by page eviction -- victim
pages spill to host bit-exactly and resume re-pins them.  It additionally
supports **retained** requests (finished but still pinning their pages) and
copy-on-write ``fork`` of a retained parent: the child shares the parent's
full prefix pages by reference and skips re-prefill entirely (multi-turn
sessions, N parallel continuations of one prompt).

The cache pool is MX8 by default -- the 8-bit state is what makes slot
memory ~2x smaller than the fp16 baseline (paper Fig. 1a, 15b).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import ops as OPS
from repro.core import attention_cache as AC
from repro.core import pimsim
from repro.core.paged import PAGE_TOKENS, pages_for
from repro.models import model as M
from repro.obs import Observability
from repro.models.config import ModelConfig
from repro.serving.faults import FaultPlan
from repro.serving.resilience import (REPREFILL_CAP, BlobCorruption,
                                      StepWatchdog, retry_transient)
from repro.serving.sampler import SamplingConfig, filtered_probs, sample
from repro.serving.scheduler import Scheduler, SchedulerConfig

#: terminal request statuses -- a request in one of these will never
#: produce another token.  ``failed`` = the engine quarantined it after an
#: unrecoverable fault (NaN logits, corruption past the re-prefill cap);
#: ``rejected`` = admission control shed it before it ever decoded.
TERMINAL_STATUSES = ("done", "aborted", "truncated", "failed", "rejected")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    priority: int = 0                  # lower = more urgent (paged engine)
    deadline: Optional[float] = None   # absolute time (paged engine, EDF)
    retain: bool = False               # keep pages pinned after finish
                                       # (paged engine: enables fork())
    parent_rid: Optional[int] = None   # copy-on-write fork parent
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    status: str = "new"                # new|queued|running|done|aborted|
                                       # truncated|failed|rejected
    detail: Optional[str] = None       # why a request failed / was rejected
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    truncated: bool = False            # ran out of pool pages mid-generation

    @property
    def finished(self) -> bool:
        return self.status in TERMINAL_STATUSES


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    slots: int = 4                    # decode batch size
    cache_capacity: int = 256         # max context per slot (tile-aligned)
    sampling: SamplingConfig = SamplingConfig()
    seed: int = 0                     # sampling PRNG seed


class _OpTrafficMeter:
    """Accumulates per-op-kind SPU traffic over decode steps.

    Bytes come from the registered ops' own ``traffic(plan)`` descriptors
    (``repro.ops.decode_traffic_by_kind``) at each active row's real context
    length, so the serving stats attribute bandwidth between attention and
    state-update ops with the same numbers the cost models use.

    ``layout="dense"`` traffic is affine in the context length; the
    ``layout="paged"`` ops are affine in the *page count* (whole 128-token
    pages stream, appends write one slot).  Either way the descriptors are
    probed once at two operating points and each step costs O(kinds), not
    O(rows) registry walks -- no per-slot Python work in the decode loop.
    The paged engine passes pre-deduplicated units so a copy-on-write
    shared page is attributed once per step, not once per reader.
    """

    def __init__(self, cfg: ModelConfig, layout: str = "dense",
                 metrics=None):
        self.cfg = cfg
        self.layout = layout
        self.metrics = metrics        # mirror into the obs registry
        self.by_kind: Dict[str, float] = {}
        self._affine = None   # kind -> (bytes at 1 unit, bytes per +1 unit)

    def _coeffs(self) -> Dict[str, tuple]:
        if self._affine is None:
            if self.layout == "paged":
                u1, u2 = PAGE_TOKENS, 2 * PAGE_TOKENS   # 1 page, 2 pages
            else:
                u1, u2 = 1, 2                            # 1 token, 2 tokens
            t1 = OPS.decode_traffic_by_kind(self.cfg, 1, u1, self.layout)
            t2 = OPS.decode_traffic_by_kind(self.cfg, 1, u2, self.layout)
            self._affine = {k: (t1[k].total, t2[k].total - t1[k].total)
                            for k in t1}
        return self._affine

    def _units(self, length: int) -> int:
        """Traffic units of one row: tokens (dense) or pages (paged)."""
        if self.layout == "paged":
            return pages_for(max(int(length), 1))
        return max(int(length), 1)

    def account_units(self, units: Sequence[int]) -> None:
        if not units:
            return
        n, total = len(units), sum(units)
        for kind, (base, slope) in self._coeffs().items():
            add = n * base + (total - n) * slope
            self.by_kind[kind] = self.by_kind.get(kind, 0.0) + add
            if self.metrics is not None:
                self.metrics.counter("op_traffic_bytes_total",
                                     kind=kind).inc(add)

    def account_step(self, lengths) -> None:
        self.account_units([self._units(L) for L in lengths])

    def stats(self) -> Dict[str, float]:
        return {f"op_traffic_bytes/{k}": v
                for k, v in sorted(self.by_kind.items())}


def _sample_tokens(key, logits, sampling: SamplingConfig):
    """The one sampling helper both engines route through (prefill's first
    token and every decode step): split the engine key once, sample a whole
    batch of logits.  Returns (new_key, tokens (B,) on device)."""
    key, sub = jax.random.split(key)
    return key, sample(logits, sampling, sub)


def _row_insert(pool_leaf, row_leaf, slot):
    """Write one batch row into a pooled cache leaf (leading dims may include
    the n_groups stack: (G, B, ...) vs row (G, 1, ...))."""
    if pool_leaf.ndim == 0:
        return pool_leaf
    # find the batch axis: row has size 1 there, pool has size slots
    for ax in range(row_leaf.ndim):
        if row_leaf.shape[ax] == 1 and pool_leaf.shape[ax] != row_leaf.shape[ax]:
            idx = [slice(None)] * pool_leaf.ndim
            idx[ax] = slot
            return pool_leaf.at[tuple(idx)].set(
                jnp.squeeze(row_leaf, ax).astype(pool_leaf.dtype))
    # lengths-style (B,) leaves: row (1,), pool (slots,)
    return pool_leaf.at[slot].set(row_leaf.reshape(-1)[0].astype(pool_leaf.dtype))


# ===========================================================================
# Shared stepper core
# ===========================================================================


class _EngineCore:
    """Request-lifecycle machinery both engines are rebased onto.

    Subclasses implement the mechanics (``_admit``, ``_decode_step``,
    ``_abort_impl``, ``has_work``, ``pending_requests``); the core owns the
    public lifecycle: ``submit`` -> ``step``/``run`` -> terminal status,
    plus ``abort`` and the stats schema.

    Every engine carries an :class:`repro.obs.Observability` bundle:
    ``stats()`` is a schema-stable view over its metrics registry, request
    phase transitions land in its lifecycle tracker, decode steps and
    per-bank traffic stream into its trace ring buffer, and the jitted
    steppers are wrapped by its recompile watcher.
    """

    backend: str = "?"

    def __init__(self, cfg: ModelConfig, seed: int = 0,
                 obs: Optional[Observability] = None):
        self.cfg = cfg
        self.obs = obs if obs is not None else Observability()
        self.done: List[Request] = []
        self.step_count = 0
        self.step_times: List[float] = []
        #: parallel to ``step_times``: True where the step paid a fresh
        #: XLA compile (warmup or retrace), so p99 can be reported with
        #: and without compilation stalls
        self.step_compiled: List[bool] = []
        #: tokens ingested as fresh context (full-sequence prefill plus
        #: prompt tails / fork continuations streamed through decode) --
        #: copy-on-write forks skip the shared prefix, so this is the
        #: number the prefix-sharing benches compare
        self.prefill_tokens = 0
        self._key = jax.random.PRNGKey(seed)
        #: wall-clock step budget monitor (paged engine wires one up when
        #: ``step_budget_s`` is configured; None = zero cost)
        self.watchdog: Optional[StepWatchdog] = None

    # ------------- public lifecycle API -------------

    def submit(self, req: Request):
        self._validate(req)
        req.t_submit = time.perf_counter()
        req.status = "queued"
        self.obs.metrics.counter("requests_submitted_total").inc()
        self.obs.lifecycle.enqueued(req.rid, t=req.t_submit)
        self._enqueue(req)

    def step(self) -> bool:
        """One event-loop iteration: admit what fits, run one batched decode
        step if anything is active.  Returns True while work remains, so
        callers can drive the engine open-loop (`while eng.step(): ...`) and
        interleave submits/aborts between steps."""
        raise NotImplementedError

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drain: step until queue + batch are empty; returns terminal
        requests.  If ``max_steps`` is hit first, still-active/queued
        requests are surfaced at the end of the returned list (statuses
        ``running``/``queued``) instead of being silently dropped; their
        lifecycle spans are closed with an explicit ``interrupted`` marker
        so traces never contain dangling spans (a later ``run()`` reopens
        the span if work resumes)."""
        for r in self.pending_requests():
            self.obs.lifecycle.reopen(r.rid)
        stalled = 0
        while self.has_work() and self.step_count < max_steps:
            before = (self.step_count, len(self.done))
            self.step()
            # no decode ran and nothing reached a terminal status: the
            # engine is wedged (e.g. a head-of-queue request admission can
            # never satisfy).  Bounded tolerance, then shed work loudly --
            # run() must terminate, never spin.
            stalled = 0 if (self.step_count, len(self.done)) != before \
                else stalled + 1
            if stalled >= 3:
                self._break_stall()
                stalled = 0
        if self.has_work():
            pending = self.pending_requests()
            for r in pending:
                self.obs.lifecycle.interrupt(r.rid)
            return self.done + pending
        self._sanitize_teardown()
        return self.done

    def _sanitize_teardown(self) -> None:
        """Shadow-ledger leak check after a full drain (REPRO_SANITIZE=1).
        Paged engines override; the default engine has no page ledger."""

    def _break_stall(self) -> None:
        """Called by ``run()`` after consecutive no-progress steps.  The
        fixed-slot engine cannot stall (a free slot always admits, an
        occupied slot always decodes), so the default sheds every queued
        request defensively; the paged engine overrides with a targeted
        ``rejected`` drop of the unadmittable head."""
        for r in list(self.pending_requests()):
            if r.status == "queued":
                self._abort_impl(r.rid)

    def abort(self, rid: int) -> bool:
        """Cancel a request at any lifecycle point: waiting, mid-decode, or
        spilled.  Frees its slot/pages immediately; the request lands in
        ``done`` with status ``aborted`` (tokens already streamed remain in
        ``output``).  Returns False if ``rid`` is unknown or terminal."""
        return self._abort_impl(rid)

    def has_work(self) -> bool:
        raise NotImplementedError

    def pending_requests(self) -> List[Request]:
        """Requests submitted but not yet terminal (running, waiting, or
        spilled)."""
        raise NotImplementedError

    def stats(self) -> Dict[str, float]:
        """Always the full key schema -- zeros before anything finishes.

        The dict is a *view over the obs metrics registry*: counts read
        the counters the lifecycle hooks incremented, percentiles read the
        registry histograms (``ttft_s``, ``step_s`` split by compile tag,
        ``tok_latency_s``).  Step latency is additionally reported with
        compile steps excluded (``*_step_nocompile_s``) so steady-state
        latency separates from compilation stalls, and ``recompiles``
        counts every fresh XLA trace the watcher saw.
        """
        m = self.obs.metrics
        pending = self.pending_requests()
        n_active = sum(1 for r in pending if r.status == "running")
        n_queued = sum(1 for r in pending if r.status == "queued")
        m.gauge("active_requests").set(n_active)
        m.gauge("queued_requests").set(n_queued)
        out: Dict[str, float] = {
            "tokens": m.value("tokens_total"),
            "wall_s": 0.0, "tokens_per_s": 0.0,
            "prefill_tokens": m.value("prefill_tokens_total"),
            "requests_done": m.value("requests_total", status="done"),
            "requests_aborted": m.value("requests_total", status="aborted"),
            "requests_truncated": m.value("requests_total",
                                          status="truncated"),
            "requests_failed": m.value("requests_total", status="failed"),
            "requests_rejected": m.value("requests_total",
                                         status="rejected"),
            "active_requests": float(n_active),
            "queued_requests": float(n_queued),
        }
        timed = [r for r in self.done if r.t_done > 0]
        if timed:
            t0 = min(r.t_submit for r in timed)
            t1 = max(r.t_done for r in timed)
            out["wall_s"] = t1 - t0
            out["tokens_per_s"] = out["tokens"] / max(t1 - t0, 1e-9)
        ttft = m.histogram("ttft_s")
        out["mean_ttft_s"] = ttft.mean
        out["p50_ttft_s"] = ttft.percentile(50)
        out["p99_ttft_s"] = ttft.percentile(99)
        steps_all = m.family_samples("step_s")
        out["p50_step_s"] = (float(np.percentile(steps_all, 50))
                             if steps_all else 0.0)
        out["p99_step_s"] = (float(np.percentile(steps_all, 99))
                             if steps_all else 0.0)
        steady = m.histogram("step_s", compile="false")
        out["p50_step_nocompile_s"] = steady.percentile(50)
        out["p99_step_nocompile_s"] = steady.percentile(99)
        out["compile_steps"] = float(
            m.histogram("step_s", compile="true").count)
        tok = m.histogram("tok_latency_s")
        out["p50_tok_latency_s"] = tok.percentile(50)
        out["p99_tok_latency_s"] = tok.percentile(99)
        out["recompiles"] = float(self.obs.recompiles.n_events)
        # speculation accounting is schema-stable: zeros when speculation is
        # off (or on engines without it) so downstream consumers never key-miss
        proposed = m.value("spec_proposed_tokens_total")
        accepted = m.value("spec_accepted_tokens_total")
        steps = m.value("spec_verify_steps_total")
        out["proposed_tokens"] = proposed
        out["accepted_tokens"] = accepted
        out["acceptance_rate"] = accepted / proposed if proposed else 0.0
        # each verify row-step emits the accepted drafts plus one token the
        # target model produced itself, so the floor is 1.0, not 0.0
        out["accepted_tokens_per_step"] = ((accepted + steps) / steps
                                           if steps else 0.0)
        out.update(self._traffic.stats())
        return out

    # ------------- subclass hooks -------------

    def _validate(self, req: Request):
        if req.parent_rid is not None:
            raise ValueError(
                f"{type(self).__name__} does not support fork/sessions "
                "(copy-on-write prefix sharing needs the paged pool)")
        if req.retain:
            raise ValueError(
                f"{type(self).__name__} cannot retain finished requests "
                "(page refcounts need the paged pool)")

    def _enqueue(self, req: Request):
        raise NotImplementedError

    def _abort_impl(self, rid: int) -> bool:
        raise NotImplementedError

    def _finalize(self, req: Request, status: str,
                  detail: Optional[str] = None):
        req.status = status
        if detail is not None:
            req.detail = detail
        req.truncated = status == "truncated"
        req.t_done = time.perf_counter()
        self.done.append(req)
        m = self.obs.metrics
        m.counter("requests_total", status=status).inc()
        m.counter("tokens_total").inc(len(req.output))
        self.obs.lifecycle.finish(req.rid, status,
                                  n_tokens=len(req.output), t=req.t_done)

    def _count_prefill(self, n: int):
        """Fresh-context tokens ingested (prefill + streamed tails)."""
        self.prefill_tokens += int(n)
        self.obs.metrics.counter("prefill_tokens_total").inc(int(n))

    def _record_step(self, t0: float, dt: float, compiled: bool,
                     batch: int):
        """Shared per-step bookkeeping: the step-time series with its
        compile tag, the ``step_s`` histogram split by tag, and the
        ``decode_step`` X event on the engine track."""
        self.step_times.append(dt)
        self.step_compiled.append(compiled)
        if self.watchdog is not None:
            self.watchdog.observe(self.step_count, dt)
        self.obs.metrics.histogram(
            "step_s", compile="true" if compiled else "false").observe(dt)
        self.obs.tracer.complete(
            "decode_step", cat="step", ts=self.obs.tracer.ts_of(t0),
            dur=dt * 1e6, track="engine", step=self.step_count,
            batch=batch, compiled=compiled)


# ===========================================================================
# Fixed-slot engine
# ===========================================================================


class ServingEngine(_EngineCore):
    backend = "slots"

    def __init__(self, params, cfg: ModelConfig, ecfg: EngineConfig,
                 mesh_axes=None, obs: Optional[Observability] = None):
        assert not cfg.encoder_only
        super().__init__(cfg, seed=ecfg.seed, obs=obs)
        self.params = params
        self.ecfg = ecfg
        self.mesh_axes = mesh_axes
        B = ecfg.slots
        self.caches = M.init_decode_caches(cfg, B, ecfg.cache_capacity)
        # host-side mirror of per-slot lengths: the engine is the writer of
        # record, so keeping it in numpy makes the step loop sync-free --
        # it streams host->device with the decode call instead of being
        # read back device->host every step (JH101)
        self.lengths = np.zeros((B,), np.int32)
        self.cur_tokens = jnp.zeros((B,), jnp.int32)
        self.active = np.zeros((B,), bool)
        self.slot_req: List[Optional[Request]] = [None] * B
        self.queue: List[Request] = []
        self._traffic = _OpTrafficMeter(cfg, metrics=self.obs.metrics)

        # donate the cache tree: the engine drops its reference on return,
        # so XLA appends the token in place instead of copying every cache
        # leaf every step (same treatment as the paged pool's donated pools)
        self._decode = self.obs.wrap_jit(
            jax.jit(partial(M.decode_step, cfg=cfg, mesh_axes=mesh_axes),
                    donate_argnames=("caches",)),
            "engine.decode")
        self._prefill = self.obs.wrap_jit(
            jax.jit(partial(M.prefill, cfg=cfg, mesh_axes=mesh_axes)),
            "engine.prefill")

    # ------------- lifecycle -------------

    def _enqueue(self, req: Request):
        self.queue.append(req)

    def step(self) -> bool:
        self._admit()
        if self.active.any():
            self._decode_step()
        return self.has_work()

    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.active.any())

    def pending_requests(self) -> List[Request]:
        return ([r for r in self.slot_req if r is not None]
                + list(self.queue))

    def _abort_impl(self, rid: int) -> bool:
        for i, r in enumerate(self.queue):
            if r.rid == rid:
                self.queue.pop(i)
                self._finalize(r, "aborted")
                return True
        for slot, r in enumerate(self.slot_req):
            if r is not None and r.rid == rid:
                # free the slot immediately; the stale cache row is simply
                # overwritten by the next admission
                self.slot_req[slot] = None
                self.active[slot] = False
                self._finalize(r, "aborted")
                return True
        return False

    # ------------- internals -------------

    def _admit(self):
        while self.queue and not self.active.all():
            slot = int(np.flatnonzero(~self.active)[0])
            req = self.queue.pop(0)
            self._prefill_into(slot, req)

    def _prefill_into(self, slot: int, req: Request):
        t_p0 = time.perf_counter()
        self.obs.lifecycle.phase(req.rid, "prefill", t=t_p0)
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]       # (1, S)
        S = prompt.shape[1]
        self._count_prefill(S)
        batch = {"tokens": prompt, "targets": prompt}
        logits, row_caches = self._prefill(self.params, batch=batch)
        # re-capacity the row cache to the pool capacity (explicit time axis)
        row_caches = AC.recapacity(row_caches, self.ecfg.cache_capacity)
        # NB: zip leaves rather than tree.map -- QuantizedTensor aux data
        # embeds its logical shape, which differs between the B=1 prefill
        # row and the B=slots pool (the structures are otherwise parallel)
        pool_leaves, pool_def = jax.tree_util.tree_flatten(self.caches)
        row_leaves = jax.tree_util.tree_leaves(row_caches)
        assert len(pool_leaves) == len(row_leaves)
        self.caches = jax.tree_util.tree_unflatten(
            pool_def, [_row_insert(p, r, slot)
                       for p, r in zip(pool_leaves, row_leaves)])
        self._key, toks = _sample_tokens(self._key, logits, self.ecfg.sampling)
        tok = int(toks[0])
        req.t_first = time.perf_counter()
        self.obs.lifecycle.first_token(req.rid, t=req.t_first)
        self.obs.tracer.complete(
            "prefill", cat="prefill", ts=self.obs.tracer.ts_of(t_p0),
            dur=(req.t_first - t_p0) * 1e6, track="engine",
            rid=req.rid, tokens=int(S))
        req.output.append(tok)
        hit_eos = req.eos_id is not None and tok == req.eos_id
        if len(req.output) >= req.max_new_tokens or hit_eos:
            self._finalize(req, "done")
            return                      # never occupies a decode slot
        self.cur_tokens = self.cur_tokens.at[slot].set(tok)
        self.lengths[slot] = S
        self.active[slot] = True
        self.slot_req[slot] = req
        req.status = "running"
        self.obs.lifecycle.phase(req.rid, "decode")
        # sync pool cache lengths for this row
        self.caches = _set_row_lengths(self.caches, slot, S)

    def _decode_step(self):
        self.step_count += 1
        c0 = self.obs.recompiles.n_events
        t0 = time.perf_counter()
        logits, self.caches = self._decode(
            self.params, tokens=self.cur_tokens, caches=self.caches,
            lengths=jnp.asarray(self.lengths), seed=jnp.int32(self.step_count))
        self._key, toks = _sample_tokens(self._key, logits, self.ecfg.sampling)
        self.lengths = self.lengths + self.active.astype(np.int32)
        self.cur_tokens = toks
        # the sampled tokens are the step's single device->host sync; the
        # lengths ledger lives host-side (see __init__) and needs none
        toks_np = np.asarray(toks)
        lengths_np = self.lengths
        self._record_step(t0, time.perf_counter() - t0,
                          compiled=self.obs.recompiles.n_events > c0,
                          batch=int(self.active.sum()))
        self._traffic.account_step(lengths_np[self.active])
        for slot in np.flatnonzero(self.active):
            req = self.slot_req[slot]
            req.output.append(int(toks_np[slot]))
            hit_eos = req.eos_id is not None and req.output[-1] == req.eos_id
            done = len(req.output) >= req.max_new_tokens or hit_eos
            full = int(lengths_np[slot]) + 1 >= self.ecfg.cache_capacity
            if done or full:
                self.slot_req[slot] = None
                self.active[slot] = False
                # a request stopped only by slot capacity was clipped, not
                # completed -- same contract as the paged pool's truncation
                self._finalize(req, "done" if done else "truncated")


def _set_row_lengths(caches, slot: int, length: int):
    def fix(c):
        if isinstance(c, AC.KVCache):
            # lengths may be group-stacked (G, B) or flat (B,)
            if c.lengths.ndim == 2:
                nl = c.lengths.at[:, slot].set(length)
            else:
                nl = c.lengths.at[slot].set(length)
            return AC.KVCache(c.k, c.v, nl, c.fmt, c.v_width, c.time_axis)
        return c
    return jax.tree.map(fix, caches,
                        is_leaf=lambda x: isinstance(x, AC.KVCache))


# ===========================================================================
# Paged engine
# ===========================================================================

from repro.serving.memory import (PagedStatePool,  # noqa: E402,F401
                                  SpilledRequest, TieredStatePool)


@dataclasses.dataclass(frozen=True)
class PagedEngineConfig:
    max_decode_batch: int = 4         # rows in the jitted decode step
    n_pages: Optional[int] = 33       # 128-token pages (incl. 1 scratch)
    n_slabs: int = 9                  # state slabs (incl. 1 scratch)
    byte_budget: Optional[int] = None  # alternative to n_pages
    prefill_chunk: int = 128          # longest full-sequence prefill; the
                                      # prompt tail streams through decode
    # opt-in prefill length bucketing (JH103): when set, the full-sequence
    # prefill length snaps down to the largest bucket <= the prompt length
    # and the remainder streams through the decode batch, so the prefill
    # jit compiles one executable per *bucket* instead of one per distinct
    # prompt length.  Off by default: moving tokens from prefill to decode
    # changes which op consumes which stochastic-rounding draw, so mx8
    # token streams differ from the unbucketed engine (both are valid).
    prefill_buckets: Optional[Tuple[int, ...]] = None
    sampling: SamplingConfig = SamplingConfig()
    scheduler: SchedulerConfig = SchedulerConfig()
    seed: int = 0
    # --- tiered memory hierarchy (serving/memory/tiered) ---
    prefix_cache: bool = False        # radix prefix store: automatic
                                      # cross-request CoW prefix sharing
    prefix_store_pages: int = 64      # store capacity (LRU-evicted)
    host_tier_bytes: Optional[int] = None  # host tier budget (None = unmetered)
    prefetch_window: int = 2          # scheduler lookahead for async
                                      # spill-resume / prefix prefetch
    # --- resilience / fault injection (serving/faults, serving/resilience) ---
    fault_plan: Optional[str] = None  # fault spec string; the REPRO_FAULTS
                                      # env var applies when unset
    nan_guard: Optional[bool] = None  # post-step non-finite-logits guard;
                                      # None = enabled iff faults are active
                                      # (the check costs one device sync)
    max_queued: Optional[int] = None  # admission control: submits beyond
                                      # this queue depth are ``rejected``
    request_timeout_s: Optional[float] = None  # queued longer -> ``rejected``
    step_budget_s: Optional[float] = None      # watchdog wall-clock budget
    # --- speculative decoding (serving/spec) ---
    spec: Optional[str] = None        # draft source: None (off), "ngram"
                                      # (self-drafting) or "model:<arch>"
                                      # (small-model drafting)
    spec_k: int = 3                   # max drafts per row; the verify step
                                      # always compiles at spec_k+1 positions
    spec_window: int = 8              # acceptance window of the k-controller


@dataclasses.dataclass
class _Active:
    req: Request
    length: int                       # cached positions so far
    pending: List[int]                # prompt tokens not yet consumed
    cur_token: int                    # next token to feed once prompt is done
    replayed: bool = False            # corruption-recovery re-prefill: the
                                      # "prompt" includes generated tokens,
                                      # so prefix-store inserts are skipped


class PagedServingEngine(_EngineCore):
    """Continuous batching over the paged, bank-aware state/KV pool."""

    backend = "paged"

    def __init__(self, params, cfg: ModelConfig, pcfg: PagedEngineConfig,
                 mesh_axes=None, obs: Optional[Observability] = None):
        assert not cfg.encoder_only
        super().__init__(cfg, seed=pcfg.seed, obs=obs)
        self.params = params
        self.pcfg = pcfg
        self.pool = TieredStatePool(
            cfg, n_pages=None if pcfg.byte_budget is not None else pcfg.n_pages,
            n_slabs=pcfg.n_slabs, byte_budget=pcfg.byte_budget,
            mesh_axes=mesh_axes, host_tier_bytes=pcfg.host_tier_bytes,
            prefix_cache=pcfg.prefix_cache,
            prefix_store_pages=pcfg.prefix_store_pages)
        self.pool.attach_obs(self.obs)
        self.sched = Scheduler(pcfg.scheduler)
        self.sched.obs = self.obs
        self.active: Dict[int, _Active] = {}
        self.rows: List[Optional[int]] = [None] * pcfg.max_decode_batch
        self.spilled: Dict[int, Tuple[SpilledRequest, List[int], int]] = {}
        #: finished-but-pinned requests: fork parents for sessions /
        #: N-way continuations; release_retained() frees them
        self.retained: Dict[int, _Active] = {}
        # account the block-table-native ops this engine actually dispatches
        self._traffic = _OpTrafficMeter(cfg, layout="paged",
                                        metrics=self.obs.metrics)
        self.preemptions = 0
        self._occ: List[float] = []
        self._frag: List[float] = []
        self.last_traffic: Optional[np.ndarray] = None
        # --- resilience wiring (all None/empty => zero overhead) ---
        self.faults = FaultPlan.maybe(pcfg.fault_plan, seed=pcfg.seed)
        self.pool.faults = self.faults
        self.watchdog = StepWatchdog(pcfg.step_budget_s, obs=self.obs)
        self._nan_guard = (pcfg.nan_guard if pcfg.nan_guard is not None
                           else self.faults is not None)
        #: rid -> full replay token stream (prompt + generated) for the
        #: bounded re-prefill after a detected spill-blob corruption
        self._replay: Dict[int, List[int]] = {}
        self._reprefills: Dict[int, int] = {}
        #: rid -> consecutive failed admission attempts (degradation rung)
        self._admit_fails: Dict[int, int] = {}
        self._prefill = self.obs.wrap_jit(
            jax.jit(partial(M.prefill, cfg=cfg, mesh_axes=mesh_axes)),
            "engine.prefill")
        max_chunk_pages = pages_for(pcfg.prefill_chunk)
        assert max_chunk_pages <= self.pool.usable_pages, \
            "prefill_chunk does not fit the page pool"
        # --- speculative decoding (serving/spec) ---
        self.draft = None
        self.kctl = None
        if pcfg.spec is not None:
            from repro.serving.spec import (KController, ModelDraft,
                                            NGramDraft)
            assert pcfg.spec_k >= 1, "spec_k must be at least 1"
            if pcfg.spec == "ngram":
                self.draft = NGramDraft()
            elif pcfg.spec.startswith("model:"):
                from repro.configs import get_smoke_config
                dcfg = get_smoke_config(pcfg.spec.split(":", 1)[1]).with_(
                    state_quant=cfg.state_quant)
                # the draft pool is deliberately NOT obs-wrapped: its jits
                # are warmup-only per draft request and must not count
                # against the target engine's decode recompile budget
                self.draft = ModelDraft(
                    dcfg, max_requests=pcfg.max_decode_batch + 1,
                    seed=pcfg.seed)
            else:
                raise ValueError(
                    f"unknown spec draft source {pcfg.spec!r} "
                    "(expected 'ngram' or 'model:<arch>')")
            self.kctl = KController(pcfg.spec_k, window=pcfg.spec_window)
            # per-position seeds inside the verify step are spec_seed + i,
            # so advance by n per step to keep the streams non-overlapping
            self._spec_seed = 0

    # ------------- lifecycle -------------

    def _validate(self, req: Request):
        if req.parent_rid is not None and req.parent_rid not in self.retained:
            raise ValueError(
                f"fork parent {req.parent_rid} is not retained (submit the "
                "parent with retain=True and let it finish first)")

    def _enqueue(self, req: Request):
        mq = self.pcfg.max_queued
        if mq is not None and len(self.sched) >= mq:
            # overload shedding at the door: better an immediate, explicit
            # rejection than an unbounded queue nobody drains in time
            self.obs.metrics.counter("degradations_total", rung="shed").inc()
            self._finalize(req, "rejected",
                           detail=f"queue full (max_queued={mq})")
            return
        self.sched.push(req)

    def step(self) -> bool:
        if self.faults is not None:
            self.faults.set_step(self.step_count)
        if self.pcfg.request_timeout_s is not None:
            self._expire_queued()
        admitted = self._admit()
        if self.active:
            self._ensure_headroom()
        if self.active:
            # stage prefetches *before* dispatching decode: the host->device
            # copies ride JAX's async dispatch behind the decode kernels, so
            # the next admission window's data lands while this step runs
            self._issue_prefetches()
            self._decode_step()
        elif self.sched and not admitted:
            # queue non-empty but nothing fits and nothing runs: shed the
            # head loudly rather than spinning (a request whose admission
            # can *never* be satisfied would otherwise wedge the engine)
            self._drop_queued(
                self.sched.peek(), "rejected",
                detail="cannot admit with the pool idle (request does not "
                       "fit the page budget)")
        return self.has_work()

    def _expire_queued(self) -> None:
        now = time.perf_counter()
        budget = self.pcfg.request_timeout_s
        for req in self.sched.requests():
            if req.t_submit and now - req.t_submit > budget:
                self.obs.metrics.counter("request_timeouts_total").inc()
                self._drop_queued(
                    req, "rejected",
                    detail=f"queued longer than request_timeout_s={budget}")

    def _drop_queued(self, req: Request, status: str, detail: str) -> None:
        """Remove a not-yet-admitted request (queued or spilled) with full
        cleanup: scheduler entry, spill blob, staged prefetch, replay ctx."""
        rid = req.rid
        self.sched.remove(rid)
        if rid in self.spilled:
            sp, _, _ = self.spilled.pop(rid)
            self.pool.prefetch_cancel(rid)
            self.pool.drop_spilled(sp, rid)
        self._replay.pop(rid, None)
        self._admit_fails.pop(rid, None)
        self._finalize(req, status, detail=detail)

    def has_work(self) -> bool:
        return bool(self.sched) or bool(self.active)

    def pending_requests(self) -> List[Request]:
        return ([a.req for a in self.active.values()]
                + self.sched.requests())

    def _abort_impl(self, rid: int) -> bool:
        if rid in self.active:
            a = self.active.pop(rid)
            self._free_row(rid)
            self._spec_release(rid)
            self.pool.release(rid)
            self._finalize(a.req, "aborted")
            return True
        if rid in self.spilled:
            sp, _, _ = self.spilled.pop(rid)
            self.pool.prefetch_cancel(rid)
            self.pool.drop_spilled(sp, rid)
            req = self.sched.remove(rid)
            assert req is not None, "spilled request must be in the heap"
            self._finalize(req, "aborted")
            return True
        req = self.sched.remove(rid)
        if req is not None:
            self._finalize(req, "aborted")
            return True
        return False

    # ------------- retained parents / copy-on-write fork -------------

    def retained_length(self, rid: int) -> int:
        return self.retained[rid].length

    def release_retained(self, rid: int):
        """Drop a retained parent's page references (shared pages free when
        the last fork drops; must not race a never-admitted fork child).
        Preempted fork children are fine: their spill blobs already hold
        their own references on the shared pages."""
        assert all(r.parent_rid != rid or r.rid in self.spilled
                   for r in self.sched.requests()), \
            f"retained {rid} still has unadmitted fork children"
        self.retained.pop(rid)
        self.pool.release(rid)

    # ------------- admission / preemption -------------

    def _admission_need(self, req: Request) -> int:
        """Pages admission must find free for ``req`` (plus one slab)."""
        if req.rid in self._replay:
            # corruption recovery re-prefills from the replay stream; the
            # prefix store is bypassed entirely
            return pages_for(
                self._bucket_prefill_len(len(self._replay[req.rid])))
        if req.rid in self.spilled:
            if self.pool.prefetch_ready(req.rid):
                return 0            # staged: commit is O(1) bookkeeping
            return self.spilled[req.rid][0].pages_needed
        if req.parent_rid is not None:
            # CoW fork: at most the private tail-page copy
            return 1 if self.retained[req.parent_rid].length % PAGE_TOKENS \
                else 0
        nodes = self.pool.prefix_match(req.prompt)
        if nodes:
            # prefix hit: promote any demoted nodes + one page of headroom
            # for the first streamed tail token
            return sum(1 for n in nodes if not n.resident) + 1
        s0 = min(len(req.prompt), self.pcfg.prefill_chunk)
        return pages_for(s0)

    def _admit(self) -> bool:
        admitted = False
        while len(self.active) < self.pcfg.max_decode_batch and self.sched:
            head = self.sched.peek()
            need = self._admission_need(head)
            if not self.pool.can_admit(need):
                # first try reclaiming device pages from the prefix store
                # (demote LRU nodes to host) before preempting live work
                self.pool.reclaim(need)
            if not self.pool.can_admit(need):
                victim = self.sched.choose_victim(
                    [a.req for a in self.active.values()])
                if victim is not None and self.sched.should_preempt(head,
                                                                    victim):
                    self._preempt(victim.rid)
                    continue
                break
            req = self.sched.pop()
            try:
                if req.rid in self.spilled:
                    ok = self._resume(req)
                elif req.parent_rid is not None:
                    ok = self._fork_into(req)
                else:
                    ok = self._prefill_into(req)
            except BlobCorruption:
                # the spill blob failed its checksum inside pool.resume:
                # the spilled entry is still intact -- recover by bounded
                # re-prefill (the request was popped, so re-push happens
                # inside the recovery)
                self._recover_corrupt(req, in_queue=False)
                continue
            if not ok:
                # transient allocation failure survived bounded retry:
                # walk the degradation ladder (progress is guaranteed --
                # the final rung sheds the request)
                self._degrade(req, need)
                continue
            self._admit_fails.pop(req.rid, None)
            admitted = True
        return admitted

    def _retry(self, site: str, fn) -> bool:
        """Bounded retry around an allocation-style pool call (the PL206
        contract: alloc/pin sites never assert success, they retry and
        escalate).  Counts retries and recoveries per site."""
        retried = [0]

        def on_retry(_k):
            retried[0] += 1
            self.obs.metrics.counter("fault_retries_total", site=site).inc()

        ok = bool(retry_transient(fn, on_retry=on_retry))
        if ok and retried[0]:
            self.obs.metrics.counter("faults_recovered_total",
                                     site=site).inc()
        return ok

    def _degrade(self, req: Request, need: int) -> None:
        """Admission of a popped request failed after bounded retry: walk
        the degradation ladder, escalating per request across attempts --
        reclaim store pages, then preempt live work, then shed the request
        with ``rejected``.  The rung counter guarantees termination."""
        fails = self._admit_fails.get(req.rid, 0) + 1
        self._admit_fails[req.rid] = fails
        m = self.obs.metrics
        if fails == 1:
            self.pool.reclaim(need + 1)
            m.counter("degradations_total", rung="demote_store").inc()
        elif fails == 2:
            victim = self.sched.choose_victim(
                [a.req for a in self.active.values()])
            if victim is not None:
                self._preempt(victim.rid)
            m.counter("degradations_total", rung="preempt").inc()
        else:
            m.counter("degradations_total", rung="shed").inc()
            self._drop_queued(
                req, "rejected",
                detail=f"admission failed after retries (need {need} pages)")
            return
        req.status = "queued"
        self.sched.push(req, resumed=True)

    def _recover_corrupt(self, req: Request, in_queue: bool) -> None:
        """A spill blob failed its checksum: drop the poisoned bytes and
        re-prefill the request from its retained token ids (prompt plus
        every token generated so far), bounded by ``REPREFILL_CAP``.

        ``in_queue`` distinguishes the two detection points: during a
        prefetch (request still in the scheduler heap, which must not be
        touched -- tombstoned rids cannot be re-pushed) vs during admission
        (request just popped, so recovery re-pushes it)."""
        rid = req.rid
        entry = self.spilled.pop(rid, None)
        self.pool.prefetch_cancel(rid)
        if entry is not None:
            self.pool.drop_spilled(entry[0], rid)
        self.obs.metrics.counter("blob_corruptions_total").inc()
        self.obs.tracer.instant("fault.blob_corrupt_detected", cat="fault",
                                track="engine", rid=rid)
        n = self._reprefills.get(rid, 0)
        if req.parent_rid is not None or n >= REPREFILL_CAP:
            # a fork child's shared prefix belongs to its parent -- its own
            # token ids cannot rebuild that state -- and a request that
            # keeps corrupting is dropped, not retried forever
            why = ("fork child spill blob corrupted (shared prefix is not "
                   "replayable)" if req.parent_rid is not None else
                   f"spill blob corrupted {n + 1}x (re-prefill cap "
                   f"{REPREFILL_CAP} exhausted)")
            if in_queue:
                self._drop_queued(req, "failed", detail=why)
            else:
                self._replay.pop(rid, None)
                self._finalize(req, "failed", detail=why)
            return
        self._reprefills[rid] = n + 1
        # everything the model had consumed, rebuilt through a fresh
        # prefill + streamed tail: the prompt plus all generated tokens
        self._replay[rid] = list(map(int, req.prompt)) + list(req.output)
        self.obs.metrics.counter("faults_recovered_total",
                                 site="blob_corrupt").inc()
        if not in_queue:
            req.status = "queued"
            self.sched.push(req, resumed=True)

    def _assign_row(self, rid: int):
        row = self.rows.index(None)
        self.rows[row] = rid
        if self.draft is not None and rid in self.active:
            # draft-side admission is best-effort: a refusal (draft pool
            # full) just means this request decodes without drafts for now
            self.draft.admit(rid, list(map(int, self.active[rid].req.prompt)))

    def _free_row(self, rid: int):
        self.rows[self.rows.index(rid)] = None

    def _spec_release(self, rid: int) -> None:
        """Drop every speculation-side trace of a terminal request: drafted-
        but-unverified tokens die with the draft state (they were never in
        ``req.output``), draft-model pages free, acceptance history resets."""
        if self.draft is not None:
            self.draft.release(rid)
        if self.kctl is not None:
            self.kctl.forget(rid)

    def _bucket_prefill_len(self, n: int) -> int:
        """Full-sequence prefill length for an ``n``-token prompt.

        Unbucketed: ``min(n, prefill_chunk)`` -- one compiled prefill per
        distinct prompt length.  With ``prefill_buckets``, snap down to the
        largest bucket that fits (prompts shorter than every bucket keep
        their exact length); the tail streams through the decode batch via
        the existing pending mechanism."""
        s0 = min(n, self.pcfg.prefill_chunk)
        buckets = self.pcfg.prefill_buckets
        if buckets:
            fits = [b for b in buckets if 0 < b <= s0]
            if fits:
                s0 = max(fits)
        return s0

    def _prefill_into(self, req: Request) -> bool:
        replay = self._replay.get(req.rid)
        if replay is None:
            nodes = self.pool.prefix_match(req.prompt)
            if nodes:
                if self.pool.prefix_admit(req.rid, nodes):
                    self._prefix_hit_into(req, nodes)
                    return True
                # ladder rung "drop_prefix": the store hit could not be
                # admitted (promotion short) -- fall back to plain prefill
                self.obs.metrics.counter("degradations_total",
                                         rung="drop_prefix").inc()
            self.pool.note_prefix_miss()
        src = np.asarray(replay, np.int32) if replay is not None \
            else req.prompt
        t_p0 = time.perf_counter()
        self.obs.lifecycle.phase(req.rid, "prefill", t=t_p0)
        s0 = self._bucket_prefill_len(len(src))
        if not self._retry("alloc",
                           lambda: self.pool.register(req.rid, pages_for(s0))):
            return False                # replay ctx (if any) stays for retry
        self._replay.pop(req.rid, None)
        # the whole prompt is fresh context: s0 through full-sequence
        # prefill, the tail streamed through the decode batch.  With
        # prefill_buckets set, s0 comes from a fixed bucket set, so the
        # slice below feeds a bounded family of compiled shapes.
        self._count_prefill(len(src))
        prompt = jnp.asarray(src[:s0], jnp.int32)[None]  # lint: disable=JH103
        logits, row_caches = self._prefill(
            self.params, batch={"tokens": prompt, "targets": prompt})
        self.pool.insert_prefill(req.rid, row_caches)
        if replay is None and s0 % PAGE_TOKENS == 0:
            # the prefilled pages are full and immutable: remember them in
            # the prefix store for future requests sharing this prompt
            # (replay streams contain generated tokens -- never stored)
            self.pool.store_insert(req.rid, req.prompt[:s0])
        self.obs.tracer.complete(
            "prefill", cat="prefill", ts=self.obs.tracer.ts_of(t_p0),
            dur=(time.perf_counter() - t_p0) * 1e6, track="engine",
            rid=req.rid, tokens=s0, chunked=bool(len(src) > s0),
            replay=bool(replay is not None))
        a = _Active(req, length=s0, pending=list(map(int, src[s0:])),
                    cur_token=-1, replayed=replay is not None)
        if not a.pending:
            self._key, toks = _sample_tokens(self._key, logits,
                                             self.pcfg.sampling)
            tok = int(toks[0])
            if not req.t_first:
                req.t_first = time.perf_counter()
                self.obs.lifecycle.first_token(req.rid, t=req.t_first)
            req.output.append(tok)
            a.cur_token = tok
        self.active[req.rid] = a
        self._assign_row(req.rid)
        req.status = "running"
        self.obs.lifecycle.phase(req.rid, "decode")
        if req.output and (len(req.output) >= req.max_new_tokens
                           or (req.eos_id is not None
                               and req.output[-1] == req.eos_id)):
            self._finish(req.rid)       # prefill already produced the end
        return True

    def _prefix_hit_into(self, req: Request, nodes) -> None:
        """Admit a request whose prompt prefix came out of the radix store:
        the stored pages joined its block table by reference inside
        ``prefix_admit`` (no prefill compute for them), the tail node's
        recurrent-state snapshot seeded its slab, and only the *un-cached*
        prompt tail streams through the decode batch -- the cross-request
        twin of ``_fork_into``."""
        j = len(nodes)
        length = j * PAGE_TOKENS
        self.obs.lifecycle.phase(req.rid, "prefill")
        pending = list(map(int, req.prompt[length:]))
        assert pending, "prefix match must leave a prompt tail"
        # only the un-cached tail is fresh context -- that is the whole point
        self._count_prefill(len(pending))
        a = _Active(req, length=length, pending=pending, cur_token=-1)
        self.active[req.rid] = a
        self._assign_row(req.rid)
        req.status = "running"
        self.obs.lifecycle.phase(req.rid, "decode")

    def _issue_prefetches(self) -> None:
        """Scheduler-lookahead prefetch: for requests in the next admission
        window, dispatch spilled-blob copies into staging pages and promote
        demoted prefix-store nodes *now*, so the copies overlap the decode
        step dispatched right after and their eventual admission is O(1)."""
        window = self.pcfg.prefetch_window
        if window <= 0:
            return
        reserve = max(1, len(self.active))
        for req in self.sched.lookahead(window):
            if req.rid in self.spilled:
                try:
                    self.pool.prefetch_begin(req.rid,
                                             self.spilled[req.rid][0],
                                             reserve=reserve)
                except BlobCorruption:
                    # detected before the device copy was ever dispatched;
                    # the request stays in the scheduler heap and its next
                    # admission re-prefills from the replay stream
                    self._recover_corrupt(req, in_queue=True)
            elif req.parent_rid is None and req.rid not in self._replay:
                self.pool.prefetch_prefix(req.prompt)

    def _fork_into(self, req: Request) -> bool:
        """Admit a copy-on-write fork: share the retained parent's full
        prefix pages, copy only its partial tail page + slab, and stream
        the continuation tokens (the parent's final sampled token, then the
        new turn's tokens) through the decode batch -- no re-prefill of the
        shared prefix ever happens."""
        parent = self.retained.get(req.parent_rid)
        assert parent is not None, f"fork parent {req.parent_rid} released"
        if not self._retry("alloc", lambda: self.pool.fork(
                req.parent_rid, req.rid, parent.length)):
            return False
        pending = [int(parent.cur_token)] + list(map(int, req.prompt))
        self._count_prefill(len(pending))
        a = _Active(req, length=parent.length, pending=pending, cur_token=-1)
        self.active[req.rid] = a
        self._assign_row(req.rid)
        req.status = "running"
        self.obs.lifecycle.phase(req.rid, "decode")
        return True

    def _resume(self, req: Request) -> bool:
        # read without popping: a checksum failure inside ``pool.resume``
        # propagates as BlobCorruption with the spill entry intact, so the
        # recovery path can account for and drop the poisoned blob
        sp, pending, cur = self.spilled[req.rid]
        if not self._retry("alloc",
                           lambda: self.pool.resume(req.rid, sp)):
            return False
        del self.spilled[req.rid]
        self.active[req.rid] = _Active(req, sp.length, pending, cur)
        self._assign_row(req.rid)
        req.status = "running"
        self.obs.lifecycle.phase(req.rid, "decode")
        return True

    def _preempt(self, rid: int):
        """Evict by page spill: state leaves the device bit-exactly and the
        request goes back to the scheduler queue."""
        a = self.active.pop(rid)
        self._free_row(rid)
        if self.draft is not None:
            self.draft.suspend(rid)
        sp = self.pool.spill(rid, a.length)
        self.spilled[rid] = (sp, a.pending, a.cur_token)
        a.req.status = "queued"
        self.obs.lifecycle.phase(rid, "spilled")
        self.obs.metrics.counter("preemptions_total").inc()
        self.sched.push(a.req, resumed=True)
        self.preemptions += 1

    def _finish(self, rid: int, truncated: bool = False):
        a = self.active.pop(rid)
        self._free_row(rid)
        self._spec_release(rid)
        if a.req.retain and not truncated:
            # keep the pages pinned: this request is now a fork parent
            self.retained[rid] = a
        else:
            self.pool.release(rid)
        self._finalize(a.req, "truncated" if truncated else "done")

    def _ensure_headroom(self):
        """Every active request must own the page its next token writes --
        and with speculation on, every page an *accepted* draft could write:
        a generation row may commit up to ``spec_k + 1`` tokens per step,
        none of which may land on the shared scratch page."""
        for rid in list(self.active):
            a = self.active.get(rid)
            if a is None:
                continue
            span = (self.pcfg.spec_k
                    if self.draft is not None and not a.pending else 0)
            needed = (a.length + span) // PAGE_TOKENS + 1
            while needed > len(self.pool.page_table[rid]):
                short = needed - len(self.pool.page_table[rid])
                if self._retry("alloc",
                               lambda: self.pool.grow(rid, short)):
                    break
                victim = self.sched.choose_victim(
                    [b.req for b in self.active.values()], exclude=a.req)
                if victim is None:
                    self._finish(rid, truncated=True)
                    break
                self._preempt(victim.rid)

    # ------------- the decode step -------------

    def _decode_step(self):
        if self.draft is not None:
            self._spec_decode_step()
            return
        self.step_count += 1
        B = self.pcfg.max_decode_batch
        tokens = np.zeros((B,), np.int32)
        lengths = np.zeros((B,), np.int32)
        for row, rid in enumerate(self.rows):
            if rid is None:
                continue
            a = self.active[rid]
            tokens[row] = a.pending[0] if a.pending else a.cur_token
            lengths[row] = a.length
        c0 = self.obs.recompiles.n_events
        t0 = time.perf_counter()
        if self.faults is not None and self.faults.should_fire("slow_step"):
            stall_s = self.faults.param("slow_step", "ms") / 1000.0
            self.obs.metrics.counter("faults_injected_total",
                                     site="slow_step").inc()
            self.obs.tracer.instant("fault.slow_step", cat="fault",
                                    track="engine", ms=stall_s * 1e3)
            time.sleep(stall_s)     # inside the timed window: the watchdog
                                    # must see (and flag) the blown budget
        logits = self.pool.decode(self.params, self.rows, tokens, lengths,
                                  seed=self.step_count)
        if self.faults is not None:
            logits = self._inject_nan(logits)
        self._key, toks = _sample_tokens(self._key, logits,
                                         self.pcfg.sampling)
        toks_np = np.asarray(toks)
        bad_rows = self._scan_nonfinite(logits) if self._nan_guard else ()
        self._record_step(t0, time.perf_counter() - t0,
                          compiled=self.obs.recompiles.n_events > c0,
                          batch=sum(1 for r in self.rows if r is not None))
        # account at the attended length: the step appends one token at
        # `length` and attends over length+1 (matches ServingEngine, which
        # accounts after its post-step lengths increment).  Copy-on-write
        # shared pages are deduplicated across rows -- a physical page
        # streamed for several forks of one prefix is attributed once.
        seen_pages = set()
        units = []
        for row, rid in enumerate(self.rows):
            if rid is None:
                continue
            npg = pages_for(int(lengths[row]) + 1)
            fresh = [p for p in self.pool.page_table[rid][:npg]
                     if p not in seen_pages]
            seen_pages.update(fresh)
            units.append(max(len(fresh), 1))
        self._traffic.account_units(units)

        rids = [r for r in self.rows if r is not None]
        self.last_traffic = self.pool.bank_traffic(rids)
        self._occ.append(self.pool.occupancy())
        self._frag.append(self.pool.fragmentation(
            {r: self.active[r].length for r in rids}))
        self.obs.tracer.counter(
            "bank_traffic", pimsim.bank_trace_counters(self.last_traffic))
        self.obs.tracer.counter(
            "pool", {"occupancy": self._occ[-1],
                     "fragmentation": self._frag[-1]})

        for row, rid in enumerate(self.rows):
            if rid is None:
                continue
            if row in bad_rows:
                # quarantine exactly this request -- its logits are
                # non-finite and its sampled token is garbage.  Every other
                # row's token stream is untouched (sampling is row-wise).
                self._fail_active(rid, "non-finite logits after decode step")
                continue
            a = self.active[rid]
            a.length += 1
            if (a.req.parent_rid is None
                    and not a.replayed
                    and a.length % PAGE_TOKENS == 0
                    and a.length <= len(a.req.prompt)):
                # a chunk-streamed prompt just filled a page: the page is
                # immutable from here on and the slab holds the recurrent
                # state at this exact boundary -- store both
                self.pool.store_insert(rid, a.req.prompt[:a.length])
            if a.pending:
                fed = a.pending.pop(0)
                a.cur_token = fed
                if a.pending:
                    continue            # still consuming the prompt
                # that was the last prompt token: this step's logits are
                # the first-generation distribution
                tok = int(toks_np[row])
                if not a.req.t_first:   # replays already emitted tokens
                    a.req.t_first = time.perf_counter()
                    self.obs.lifecycle.first_token(rid, t=a.req.t_first)
                a.req.output.append(tok)
                a.cur_token = tok
            else:
                tok = int(toks_np[row])
                a.req.output.append(tok)
                a.cur_token = tok
            req = a.req
            hit_eos = (req.eos_id is not None and req.output
                       and req.output[-1] == req.eos_id)
            if len(req.output) >= req.max_new_tokens or hit_eos:
                self._finish(rid)

    # ------------- the speculative decode step -------------

    def _spec_decode_step(self):
        """One continuous-batching step with speculative verification.

        Every active row rides the same fused ``spec_verify`` pass at the
        fixed compiled width ``n = spec_k + 1`` (so the recompile watcher
        stays at the warmup count): generation rows carry their current
        token plus up to ``k`` drafted continuations, prompt-streaming rows
        carry one real position padded with garbage.  Afterwards the model
        state is rolled back per row to exactly the accepted prefix
        (``commit_spec``), which also unwinds the garbage positions the
        padding pushed through the recurrent state.

        Greedy rows emit the model's own argmax stream -- drafts only decide
        how many of those tokens one pass may confirm -- so greedy output is
        bit-identical to non-speculative decoding.  Sampled rows use
        rejection sampling against :func:`filtered_probs`, which preserves
        the non-speculative sampling distribution.
        """
        self.step_count += 1
        B = self.pcfg.max_decode_batch
        n = self.pcfg.spec_k + 1
        tokens = np.zeros((B, n), np.int32)
        lengths = np.zeros((B,), np.int32)
        drafts: Dict[int, List[int]] = {}
        for row, rid in enumerate(self.rows):
            if rid is None:
                continue
            a = self.active[rid]
            lengths[row] = a.length
            if a.pending:
                tokens[row, 0] = a.pending[0]   # positions 1.. are garbage
                continue
            # the budget keeps one fully-accepted step inside the request's
            # remaining token allowance, so emitted tokens never need a
            # post-hoc cut that would desync length from committed state
            budget = min(self.kctl.k_for(rid), self.pcfg.spec_k,
                         a.req.max_new_tokens - len(a.req.output) - 1)
            d = []
            if budget > 0:
                ctx = list(map(int, a.req.prompt)) + list(a.req.output)
                d = [int(t) for t in
                     self.draft.propose(rid, ctx, budget)[:budget]]
            drafts[rid] = d
            tokens[row, 0] = a.cur_token
            tokens[row, 1:1 + len(d)] = d
        c0 = self.obs.recompiles.n_events
        t0 = time.perf_counter()
        if self.faults is not None and self.faults.should_fire("slow_step"):
            stall_s = self.faults.param("slow_step", "ms") / 1000.0
            self.obs.metrics.counter("faults_injected_total",
                                     site="slow_step").inc()
            self.obs.tracer.instant("fault.slow_step", cat="fault",
                                    track="engine", ms=stall_s * 1e3)
            time.sleep(stall_s)
        # every row's block table must span the garbage positions too, or
        # an out-of-width page index would clamp onto a live physical page
        min_pages = max(pages_for(int(lengths[row]) + n)
                        for row, rid in enumerate(self.rows)
                        if rid is not None)
        seed = self._spec_seed
        self._spec_seed += n
        logits, snaps = self.pool.decode_spec(
            self.params, self.rows, tokens, lengths, seed=seed,
            min_pages=min_pages)
        if self.faults is not None:
            logits = self._inject_nan(logits)
        bad_rows = self._scan_nonfinite(logits) if self._nan_guard else ()
        greedy = self.pcfg.sampling.temperature <= 0.0
        if greedy:
            # same device op as the sampler's greedy branch, so ties break
            # identically to non-speculative decoding
            g = np.asarray(jnp.argmax(logits, axis=-1))
        else:
            probs = np.asarray(filtered_probs(logits, self.pcfg.sampling))
        sel = np.zeros((B,), np.int32)
        emits: Dict[int, List[int]] = {}
        for row, rid in enumerate(self.rows):
            if rid is None or row in bad_rows:
                continue
            a = self.active[rid]
            if a.pending:
                continue                      # single real position: sel = 0
            d = drafts.get(rid, [])
            if greedy:
                m = 0
                while m < len(d) and d[m] == int(g[row, m]):
                    m += 1
                emit = [int(g[row, j]) for j in range(m + 1)]
            else:
                rng = np.random.default_rng(
                    (self.pcfg.seed, self.step_count, row))
                emit = []
                for j, t in enumerate(d):
                    pj = probs[row, j]
                    pj = pj / pj.sum()
                    if rng.random() < pj[t]:
                        emit.append(t)        # accepted with probability p(t)
                        continue
                    # rejected: the correction comes from the residual
                    # distribution max(0, p - q) with the one-hot draft q
                    q = pj.copy()
                    q[t] = 0.0
                    s = q.sum()
                    if s <= 0.0:
                        emit.append(t)        # p was a point mass on t
                        continue
                    emit.append(int(rng.choice(len(q), p=q / s)))
                    break
                else:
                    pj = probs[row, len(d)]
                    emit.append(int(rng.choice(len(pj), p=pj / pj.sum())))
            if a.req.eos_id is not None and a.req.eos_id in emit:
                emit = emit[:emit.index(a.req.eos_id) + 1]
            sel[row] = len(emit) - 1
            emits[rid] = emit
        # roll state back to the accepted prefix *before* any host-side
        # bookkeeping -- every row (prompt rows included: their garbage
        # padding advanced recurrent state too) needs its slab restored
        self.pool.commit_spec(self.rows, snaps, sel)
        self._record_step(t0, time.perf_counter() - t0,
                          compiled=self.obs.recompiles.n_events > c0,
                          batch=sum(1 for r in self.rows if r is not None))
        # one cache stream serves the whole verify span: account the pages
        # attended at length + n once, amortized over the accepted tokens
        seen_pages = set()
        units = []
        for row, rid in enumerate(self.rows):
            if rid is None:
                continue
            npg = min(pages_for(int(lengths[row]) + n),
                      len(self.pool.page_table[rid]))
            fresh = [p for p in self.pool.page_table[rid][:npg]
                     if p not in seen_pages]
            seen_pages.update(fresh)
            units.append(max(len(fresh), 1))
        self._traffic.account_units(units)
        rids = [r for r in self.rows if r is not None]
        self.last_traffic = self.pool.bank_traffic(rids)
        self._occ.append(self.pool.occupancy())
        self._frag.append(self.pool.fragmentation(
            {r: self.active[r].length for r in rids}))
        self.obs.tracer.counter(
            "bank_traffic", pimsim.bank_trace_counters(self.last_traffic))
        self.obs.tracer.counter(
            "pool", {"occupancy": self._occ[-1],
                     "fragmentation": self._frag[-1]})
        n_proposed = n_accepted = n_steps = 0
        for row, rid in enumerate(self.rows):
            if rid is None:
                continue
            if row in bad_rows:
                self._fail_active(rid, "non-finite logits after decode step")
                continue
            a = self.active[rid]
            if a.pending:
                a.length += 1
                if (a.req.parent_rid is None
                        and not a.replayed
                        and a.length % PAGE_TOKENS == 0
                        and a.length <= len(a.req.prompt)):
                    self.pool.store_insert(rid, a.req.prompt[:a.length])
                fed = a.pending.pop(0)
                a.cur_token = fed
                if a.pending:
                    continue
                tok = (int(g[row, 0]) if greedy else int(
                    np.random.default_rng(
                        (self.pcfg.seed, self.step_count, row)
                    ).choice(probs.shape[-1],
                             p=probs[row, 0] / probs[row, 0].sum())))
                if not a.req.t_first:
                    a.req.t_first = time.perf_counter()
                    self.obs.lifecycle.first_token(rid, t=a.req.t_first)
                a.req.output.append(tok)
                a.cur_token = tok
            else:
                emit = emits[rid]
                proposed = len(drafts.get(rid, []))
                # the last emitted token is the model's own (correction or
                # bonus), so drafts surviving into the stream are len - 1,
                # capped by proposed (an eos cut can only shorten the prefix)
                accepted = min(len(emit) - 1, proposed)
                self.kctl.observe(rid, proposed, accepted)
                n_proposed += proposed
                n_accepted += accepted
                n_steps += 1
                a.length += len(emit)
                if not a.req.t_first:
                    a.req.t_first = time.perf_counter()
                    self.obs.lifecycle.first_token(rid, t=a.req.t_first)
                a.req.output.extend(emit)
                a.cur_token = emit[-1]
            req = a.req
            hit_eos = (req.eos_id is not None and req.output
                       and req.output[-1] == req.eos_id)
            if len(req.output) >= req.max_new_tokens or hit_eos:
                self._finish(rid)
        m = self.obs.metrics
        m.counter("spec_proposed_tokens_total").inc(n_proposed)
        m.counter("spec_accepted_tokens_total").inc(n_accepted)
        m.counter("spec_verify_steps_total").inc(n_steps)
        if n_steps:
            self.obs.tracer.counter(
                "spec", {"proposed": n_proposed, "accepted": n_accepted})

    # ------------- fault handling -------------

    def _inject_nan(self, logits):
        """Apply any scheduled ``nan`` faults: poison the logits row of the
        targeted request (the guard below must quarantine it)."""
        for row, rid in enumerate(self.rows):
            if rid is not None and self.faults.should_fire("nan", rid=rid):
                logits = logits.at[row].set(jnp.nan)
                self.obs.metrics.counter("faults_injected_total",
                                         site="nan").inc()
                self.obs.tracer.instant("fault.nan", cat="fault",
                                        track="engine", rid=rid, row=row)
        return logits

    def _scan_nonfinite(self, logits) -> set:
        """Rows whose logits contain NaN/Inf (one device sync; only runs
        when the guard is enabled).  Reduces over every non-batch axis so
        the (B, V) plain decode and (B, n, V) speculative verify shapes both
        collapse to one flag per row."""
        axes = tuple(range(1, logits.ndim))
        finite = np.asarray(jnp.all(jnp.isfinite(logits), axis=axes))
        return {row for row, rid in enumerate(self.rows)
                if rid is not None and not bool(finite[row])}

    def _fail_active(self, rid: int, reason: str) -> None:
        """Quarantine one active request mid-batch: free its row and pages
        immediately, close its lifecycle span as ``failed``.  The rest of
        the batch keeps decoding bit-exactly."""
        a = self.active.pop(rid)
        self._free_row(rid)
        self._spec_release(rid)
        self.pool.release(rid)
        self.obs.metrics.counter("quarantines_total").inc()
        self.obs.tracer.instant("fault.quarantine", cat="fault",
                                track="engine", rid=rid)
        self._finalize(a.req, "failed", detail=reason)

    def _break_stall(self) -> None:
        """No-progress steps in ``run()``: shed the unadmittable queue head
        with a clear reason instead of spinning forever."""
        head = self.sched.peek() if self.sched else None
        if head is None:
            super()._break_stall()
            return
        self.obs.metrics.counter("stalls_broken_total").inc()
        self._drop_queued(
            head, "rejected",
            detail="engine made no progress for 3 consecutive steps with "
                   "this request at the head of the queue")

    def _sanitize_teardown(self) -> None:
        # only assert once the spill set is empty: engine-held
        # SpilledRequest objects legitimately own shared pages mid-flight
        if not self.spilled:
            self.pool.sanitizer_check_leaks(
                what=f"drained paged engine (step {self.step_count})")
            if self.draft is not None and hasattr(
                    self.draft, "sanitizer_check_leaks"):
                self.draft.sanitizer_check_leaks(
                    what=f"drained draft pool (step {self.step_count})")

    # ------------- stats -------------

    def stats(self) -> Dict[str, float]:
        out = super().stats()
        out.update({
            "preemptions": float(self.preemptions),
            "occupancy": float(np.mean(self._occ)) if self._occ else 0.0,
            "fragmentation": (float(np.mean(self._frag))
                              if self._frag else 0.0),
            # bytes still moved by gather/scatter: spill/resume, prefill
            # insertion, and the one-page fork copy -- the decode loop
            # contributes zero
            "gather_bytes": float(self.pool.gather_bytes),
            "pages_allocated": float(self.pool.pages_allocated),
            "shared_page_hits": float(self.pool.shared_page_hits),
            # peak, not instantaneous: sharing savings survive request
            # release in end-of-run stats (the live value is also exposed)
            "shared_page_savings": float(self.pool.shared_savings_peak),
            "shared_page_savings_live": float(self.pool.shared_page_savings),
            # --- tiered memory hierarchy ---
            "prefix_hits": float(self.pool.prefix_hits),
            "prefix_hit_pages": float(self.pool.prefix_hit_pages),
            "prefix_hit_tokens": float(self.pool.prefix_hit_tokens),
            "prefix_store_pages": float(
                self.pool.store.n_pages if self.pool.store else 0),
            "prefetch_commits": float(self.pool.prefetch_commits),
            "tier_hits": self.obs.metrics.family_total("tier_hit_total"),
            "tier_misses": self.obs.metrics.family_total("tier_miss_total"),
            "promote_bytes": self.obs.metrics.family_total(
                "promote_bytes_total"),
            "demote_bytes": self.obs.metrics.family_total(
                "demote_bytes_total"),
            "host_bytes": float(self.pool.host.bytes_used),
        })
        return out

    def bank_report(self) -> Dict[str, float]:
        """Score the pool's *actual* page map with the PIM timing model."""
        from repro.core import pimsim
        m = self.last_traffic
        if m is None:
            m = self.pool.bank_traffic(list(self.active))
        rep = pimsim.placement_step_latency(m, pimsim.SystemConfig())
        rep["imbalance"] = self.pool.placement.imbalance()
        return rep
