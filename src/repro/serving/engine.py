"""Batched serving engine: the Pimba system loop (paper Fig. 7).

Continuous batching over a fixed pool of decode slots:
  * prefill runs full-sequence ("GPU phase": compute-intensive chunked form)
    and writes the resulting quantized state / KV cache into a free slot;
  * every decode step advances ALL active slots through the fused quantized
    state-update / attention path (the "PIM phase") in one jitted call;
  * finished sequences free their slot, the scheduler admits the next
    request (FCFS), and tokens stream back per request.

The cache pool is preallocated (slots x capacity) in MX8 -- the 8-bit state
is what makes slot memory ~2x smaller than the fp16 baseline (paper Fig. 1a,
15b).  Slot writes go through ``insert_cache_entry`` which overwrites one
batch row of every cache leaf.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attention_cache as AC
from repro.core import formats as F
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving.sampler import SamplingConfig, sample


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    slots: int = 4                    # decode batch size
    cache_capacity: int = 256         # max context per slot (tile-aligned)
    sampling: SamplingConfig = SamplingConfig()


def _row_insert(pool_leaf, row_leaf, slot):
    """Write one batch row into a pooled cache leaf (leading dims may include
    the n_groups stack: (G, B, ...) vs row (G, 1, ...))."""
    if pool_leaf.ndim == 0:
        return pool_leaf
    # find the batch axis: row has size 1 there, pool has size slots
    for ax in range(row_leaf.ndim):
        if row_leaf.shape[ax] == 1 and pool_leaf.shape[ax] != row_leaf.shape[ax]:
            idx = [slice(None)] * pool_leaf.ndim
            idx[ax] = slot
            return pool_leaf.at[tuple(idx)].set(
                jnp.squeeze(row_leaf, ax).astype(pool_leaf.dtype))
    # lengths-style (B,) leaves: row (1,), pool (slots,)
    return pool_leaf.at[slot].set(row_leaf.reshape(-1)[0].astype(pool_leaf.dtype))


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, ecfg: EngineConfig,
                 mesh_axes=None):
        assert not cfg.encoder_only
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.mesh_axes = mesh_axes
        B = ecfg.slots
        self.caches = M.init_decode_caches(cfg, B, ecfg.cache_capacity)
        self.lengths = jnp.zeros((B,), jnp.int32)
        self.cur_tokens = jnp.zeros((B,), jnp.int32)
        self.active = np.zeros((B,), bool)
        self.slot_req: List[Optional[Request]] = [None] * B
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self.step_count = 0
        self._key = jax.random.PRNGKey(0)

        self._decode = jax.jit(partial(M.decode_step, cfg=cfg,
                                       mesh_axes=mesh_axes),
                               static_argnames=())
        self._prefill = jax.jit(partial(M.prefill, cfg=cfg,
                                        mesh_axes=mesh_axes))

    # ------------- public API -------------

    def submit(self, req: Request):
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Run until queue + slots drain; returns finished requests."""
        while (self.queue or self.active.any()) and self.step_count < max_steps:
            self._admit()
            if self.active.any():
                self._decode_step()
        return self.done

    def stats(self) -> Dict[str, float]:
        toks = sum(len(r.output) for r in self.done)
        if not self.done:
            return {"tokens": 0}
        t0 = min(r.t_submit for r in self.done)
        t1 = max(r.t_done for r in self.done)
        return {"tokens": toks, "wall_s": t1 - t0,
                "tokens_per_s": toks / max(t1 - t0, 1e-9),
                "mean_ttft_s": float(np.mean(
                    [r.t_first - r.t_submit for r in self.done]))}

    # ------------- internals -------------

    def _admit(self):
        while self.queue and not self.active.all():
            slot = int(np.flatnonzero(~self.active)[0])
            req = self.queue.pop(0)
            self._prefill_into(slot, req)

    def _prefill_into(self, slot: int, req: Request):
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]       # (1, S)
        S = prompt.shape[1]
        batch = {"tokens": prompt, "targets": prompt}
        logits, row_caches = self._prefill(self.params, batch=batch)
        # re-capacity the row cache to the pool capacity
        row_caches = _recapacity(row_caches, self.ecfg.cache_capacity)
        # NB: zip leaves rather than tree.map -- QuantizedTensor aux data
        # embeds its logical shape, which differs between the B=1 prefill
        # row and the B=slots pool (the structures are otherwise parallel)
        pool_leaves, pool_def = jax.tree_util.tree_flatten(self.caches)
        row_leaves = jax.tree_util.tree_leaves(row_caches)
        assert len(pool_leaves) == len(row_leaves)
        self.caches = jax.tree_util.tree_unflatten(
            pool_def, [_row_insert(p, r, slot)
                       for p, r in zip(pool_leaves, row_leaves)])
        tok = int(jnp.argmax(logits[0]))
        req.t_first = time.perf_counter()
        req.output.append(tok)
        self.cur_tokens = self.cur_tokens.at[slot].set(tok)
        self.lengths = self.lengths.at[slot].set(S)
        self.active[slot] = True
        self.slot_req[slot] = req
        # sync pool cache lengths for this row
        self.caches = _set_row_lengths(self.caches, slot, S)

    def _decode_step(self):
        self.step_count += 1
        logits, self.caches = self._decode(
            self.params, tokens=self.cur_tokens, caches=self.caches,
            lengths=self.lengths, seed=jnp.int32(self.step_count))
        self._key, sub = jax.random.split(self._key)
        toks = sample(logits, self.ecfg.sampling, sub)
        self.lengths = self.lengths + jnp.asarray(self.active, jnp.int32)
        self.cur_tokens = toks
        toks_np = np.asarray(toks)
        for slot in np.flatnonzero(self.active):
            req = self.slot_req[slot]
            req.output.append(int(toks_np[slot]))
            hit_eos = req.eos_id is not None and req.output[-1] == req.eos_id
            full = int(self.lengths[slot]) + 1 >= self.ecfg.cache_capacity
            if len(req.output) >= req.max_new_tokens or hit_eos or full:
                req.t_done = time.perf_counter()
                self.done.append(req)
                self.slot_req[slot] = None
                self.active[slot] = False


def _recapacity(caches, capacity: int):
    """Pad/trim every KV-cache time axis to the pool capacity."""
    def fix(c):
        if not isinstance(c, AC.KVCache):
            return c
        def pad_t(leaf):
            # time axis is axis 1 of (B, T, ...) or axis 2 when group-stacked
            ax = 1 if leaf.ndim < 4 or leaf.shape[1] % 128 == 0 else 2
            # locate the tile-aligned time axis (first dim divisible by 128
            # after batch); robust for both stacked and unstacked leaves
            for a in range(1, leaf.ndim - 1):
                if leaf.shape[a] % 128 == 0 and leaf.shape[a] >= 128:
                    ax = a
                    break
            T = leaf.shape[ax]
            if T == capacity:
                return leaf
            if T > capacity:
                idx = [slice(None)] * leaf.ndim
                idx[ax] = slice(0, capacity)
                return leaf[tuple(idx)]
            pad = [(0, 0)] * leaf.ndim
            pad[ax] = (0, capacity - T)
            return jnp.pad(leaf, pad)
        if isinstance(c.k, F.QuantizedTensor):
            def fix_qt(qt):
                payload = {f: pad_t(v) for f, v in qt.payload.items()}
                ref = payload.get("mantissa", payload.get("q", payload.get("x")))
                return F.QuantizedTensor(qt.fmt, ref.shape, payload)
            nk = fix_qt(c.k)
            nv = None if c.v is None else fix_qt(c.v)
        else:
            nk = pad_t(c.k)
            nv = None if c.v is None else pad_t(c.v)
        return AC.KVCache(nk, nv, c.lengths, c.fmt, c.v_width)
    return jax.tree.map(fix, caches,
                        is_leaf=lambda x: isinstance(x, AC.KVCache))


def _set_row_lengths(caches, slot: int, length: int):
    def fix(c):
        if isinstance(c, AC.KVCache):
            # lengths may be group-stacked (G, B) or flat (B,)
            if c.lengths.ndim == 2:
                nl = c.lengths.at[:, slot].set(length)
            else:
                nl = c.lengths.at[slot].set(length)
            return AC.KVCache(c.k, c.v, nl, c.fmt, c.v_width)
        return c
    return jax.tree.map(fix, caches,
                        is_leaf=lambda x: isinstance(x, AC.KVCache))
