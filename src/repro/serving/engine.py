"""Batched serving engines: the Pimba system loop (paper Fig. 7).

Two engines share the request/stats machinery:

``ServingEngine`` -- the original fixed-slot pool: continuous batching over
``slots x cache_capacity`` preallocated caches.  One long request dictates
everyone's memory footprint and admission is FCFS.

``PagedServingEngine`` -- the paged pool (``serving/memory``): state/KV
memory is block/page granular with a block table per request, so short and
long prompts coexist in the same byte budget, admission follows a
priority/deadline scheduler (``serving/scheduler``), prefill is chunked
(the tail of a long prompt streams through the shared decode step instead
of blocking the batch), and the pool preempts by page eviction -- victim
pages spill to host bit-exactly and resume re-pins them.

The cache pool is MX8 by default -- the 8-bit state is what makes slot
memory ~2x smaller than the fp16 baseline (paper Fig. 1a, 15b).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import ops as OPS
from repro.core import attention_cache as AC
from repro.core import formats as F
from repro.core.paged import PAGE_TOKENS, pages_for
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving.sampler import SamplingConfig, sample
from repro.serving.scheduler import Scheduler, SchedulerConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    priority: int = 0                  # lower = more urgent (paged engine)
    deadline: Optional[float] = None   # absolute time (paged engine, EDF)
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    truncated: bool = False            # ran out of pool pages mid-generation


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    slots: int = 4                    # decode batch size
    cache_capacity: int = 256         # max context per slot (tile-aligned)
    sampling: SamplingConfig = SamplingConfig()


class _OpTrafficMeter:
    """Accumulates per-op-kind SPU traffic over decode steps.

    Bytes come from the registered ops' own ``traffic(plan)`` descriptors
    (``repro.ops.decode_traffic_by_kind``) at each active row's real context
    length, so the serving stats attribute bandwidth between attention and
    state-update ops with the same numbers the cost models use.

    ``layout="dense"`` traffic is affine in the context length; the
    ``layout="paged"`` ops are affine in the *page count* (whole 128-token
    pages stream, appends write one slot).  Either way the descriptors are
    probed once at two operating points and each step costs O(kinds), not
    O(rows) registry walks -- no per-slot Python work in the decode loop.
    """

    def __init__(self, cfg: ModelConfig, layout: str = "dense"):
        self.cfg = cfg
        self.layout = layout
        self.by_kind: Dict[str, float] = {}
        self._affine = None   # kind -> (bytes at 1 unit, bytes per +1 unit)

    def _coeffs(self) -> Dict[str, tuple]:
        if self._affine is None:
            if self.layout == "paged":
                u1, u2 = PAGE_TOKENS, 2 * PAGE_TOKENS   # 1 page, 2 pages
            else:
                u1, u2 = 1, 2                            # 1 token, 2 tokens
            t1 = OPS.decode_traffic_by_kind(self.cfg, 1, u1, self.layout)
            t2 = OPS.decode_traffic_by_kind(self.cfg, 1, u2, self.layout)
            self._affine = {k: (t1[k].total, t2[k].total - t1[k].total)
                            for k in t1}
        return self._affine

    def _units(self, length: int) -> int:
        """Traffic units of one row: tokens (dense) or pages (paged)."""
        if self.layout == "paged":
            return pages_for(max(int(length), 1))
        return max(int(length), 1)

    def account_step(self, lengths) -> None:
        units = [self._units(L) for L in lengths]
        if not units:
            return
        n, total = len(units), sum(units)
        for kind, (base, slope) in self._coeffs().items():
            self.by_kind[kind] = (self.by_kind.get(kind, 0.0)
                                  + n * base + (total - n) * slope)

    def stats(self) -> Dict[str, float]:
        return {f"op_traffic_bytes/{k}": v
                for k, v in sorted(self.by_kind.items())}


def _sample_tokens(key, logits, sampling: SamplingConfig):
    """The one sampling helper both engines route through (prefill's first
    token and every decode step): split the engine key once, sample a whole
    batch of logits.  Returns (new_key, tokens (B,) on device)."""
    key, sub = jax.random.split(key)
    return key, sample(logits, sampling, sub)


def _percentile_stats(done: List[Request],
                      step_times: List[float]) -> Dict[str, float]:
    """TTFT and per-token latency percentiles shared by both engines."""
    out: Dict[str, float] = {}
    ttfts = [r.t_first - r.t_submit for r in done if r.t_first > 0]
    if ttfts:
        out["p50_ttft_s"] = float(np.percentile(ttfts, 50))
        out["p99_ttft_s"] = float(np.percentile(ttfts, 99))
        out["mean_ttft_s"] = float(np.mean(ttfts))
    if step_times:
        out["p50_step_s"] = float(np.percentile(step_times, 50))
        out["p99_step_s"] = float(np.percentile(step_times, 99))
    per_tok = [(r.t_done - r.t_first) / max(len(r.output) - 1, 1)
               for r in done if r.t_done > 0 and r.t_first > 0
               and len(r.output) > 1]
    if per_tok:
        out["p50_tok_latency_s"] = float(np.percentile(per_tok, 50))
        out["p99_tok_latency_s"] = float(np.percentile(per_tok, 99))
    return out


def _row_insert(pool_leaf, row_leaf, slot):
    """Write one batch row into a pooled cache leaf (leading dims may include
    the n_groups stack: (G, B, ...) vs row (G, 1, ...))."""
    if pool_leaf.ndim == 0:
        return pool_leaf
    # find the batch axis: row has size 1 there, pool has size slots
    for ax in range(row_leaf.ndim):
        if row_leaf.shape[ax] == 1 and pool_leaf.shape[ax] != row_leaf.shape[ax]:
            idx = [slice(None)] * pool_leaf.ndim
            idx[ax] = slot
            return pool_leaf.at[tuple(idx)].set(
                jnp.squeeze(row_leaf, ax).astype(pool_leaf.dtype))
    # lengths-style (B,) leaves: row (1,), pool (slots,)
    return pool_leaf.at[slot].set(row_leaf.reshape(-1)[0].astype(pool_leaf.dtype))


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, ecfg: EngineConfig,
                 mesh_axes=None):
        assert not cfg.encoder_only
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.mesh_axes = mesh_axes
        B = ecfg.slots
        self.caches = M.init_decode_caches(cfg, B, ecfg.cache_capacity)
        self.lengths = jnp.zeros((B,), jnp.int32)
        self.cur_tokens = jnp.zeros((B,), jnp.int32)
        self.active = np.zeros((B,), bool)
        self.slot_req: List[Optional[Request]] = [None] * B
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self.step_count = 0
        self.step_times: List[float] = []
        self._traffic = _OpTrafficMeter(cfg)
        self._key = jax.random.PRNGKey(0)

        # donate the cache tree: the engine drops its reference on return,
        # so XLA appends the token in place instead of copying every cache
        # leaf every step (same treatment as the paged pool's donated pools)
        self._decode = jax.jit(partial(M.decode_step, cfg=cfg,
                                       mesh_axes=mesh_axes),
                               donate_argnames=("caches",))
        self._prefill = jax.jit(partial(M.prefill, cfg=cfg,
                                        mesh_axes=mesh_axes))

    # ------------- public API -------------

    def submit(self, req: Request):
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Run until queue + slots drain; returns finished requests."""
        while (self.queue or self.active.any()) and self.step_count < max_steps:
            self._admit()
            if self.active.any():
                self._decode_step()
        return self.done

    def stats(self) -> Dict[str, float]:
        toks = sum(len(r.output) for r in self.done)
        if not self.done:
            return {"tokens": 0}
        t0 = min(r.t_submit for r in self.done)
        t1 = max(r.t_done for r in self.done)
        out = {"tokens": toks, "wall_s": t1 - t0,
               "tokens_per_s": toks / max(t1 - t0, 1e-9)}
        out.update(_percentile_stats(self.done, self.step_times))
        out.update(self._traffic.stats())
        return out

    # ------------- internals -------------

    def _admit(self):
        while self.queue and not self.active.all():
            slot = int(np.flatnonzero(~self.active)[0])
            req = self.queue.pop(0)
            self._prefill_into(slot, req)

    def _prefill_into(self, slot: int, req: Request):
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]       # (1, S)
        S = prompt.shape[1]
        batch = {"tokens": prompt, "targets": prompt}
        logits, row_caches = self._prefill(self.params, batch=batch)
        # re-capacity the row cache to the pool capacity (explicit time axis)
        row_caches = AC.recapacity(row_caches, self.ecfg.cache_capacity)
        # NB: zip leaves rather than tree.map -- QuantizedTensor aux data
        # embeds its logical shape, which differs between the B=1 prefill
        # row and the B=slots pool (the structures are otherwise parallel)
        pool_leaves, pool_def = jax.tree_util.tree_flatten(self.caches)
        row_leaves = jax.tree_util.tree_leaves(row_caches)
        assert len(pool_leaves) == len(row_leaves)
        self.caches = jax.tree_util.tree_unflatten(
            pool_def, [_row_insert(p, r, slot)
                       for p, r in zip(pool_leaves, row_leaves)])
        self._key, toks = _sample_tokens(self._key, logits, self.ecfg.sampling)
        tok = int(toks[0])
        req.t_first = time.perf_counter()
        req.output.append(tok)
        hit_eos = req.eos_id is not None and tok == req.eos_id
        if len(req.output) >= req.max_new_tokens or hit_eos:
            req.t_done = time.perf_counter()
            self.done.append(req)
            return                      # never occupies a decode slot
        self.cur_tokens = self.cur_tokens.at[slot].set(tok)
        self.lengths = self.lengths.at[slot].set(S)
        self.active[slot] = True
        self.slot_req[slot] = req
        # sync pool cache lengths for this row
        self.caches = _set_row_lengths(self.caches, slot, S)

    def _decode_step(self):
        self.step_count += 1
        t0 = time.perf_counter()
        logits, self.caches = self._decode(
            self.params, tokens=self.cur_tokens, caches=self.caches,
            lengths=self.lengths, seed=jnp.int32(self.step_count))
        self._key, toks = _sample_tokens(self._key, logits, self.ecfg.sampling)
        self.lengths = self.lengths + jnp.asarray(self.active, jnp.int32)
        self.cur_tokens = toks
        toks_np = np.asarray(toks)
        # one host sync for the whole step, not one per slot
        lengths_np = np.asarray(self.lengths)
        self.step_times.append(time.perf_counter() - t0)
        self._traffic.account_step(lengths_np[self.active])
        for slot in np.flatnonzero(self.active):
            req = self.slot_req[slot]
            req.output.append(int(toks_np[slot]))
            hit_eos = req.eos_id is not None and req.output[-1] == req.eos_id
            full = int(lengths_np[slot]) + 1 >= self.ecfg.cache_capacity
            if len(req.output) >= req.max_new_tokens or hit_eos or full:
                req.t_done = time.perf_counter()
                self.done.append(req)
                self.slot_req[slot] = None
                self.active[slot] = False


def _set_row_lengths(caches, slot: int, length: int):
    def fix(c):
        if isinstance(c, AC.KVCache):
            # lengths may be group-stacked (G, B) or flat (B,)
            if c.lengths.ndim == 2:
                nl = c.lengths.at[:, slot].set(length)
            else:
                nl = c.lengths.at[slot].set(length)
            return AC.KVCache(c.k, c.v, nl, c.fmt, c.v_width, c.time_axis)
        return c
    return jax.tree.map(fix, caches,
                        is_leaf=lambda x: isinstance(x, AC.KVCache))


# ===========================================================================
# Paged engine
# ===========================================================================

from repro.serving.memory import (PagedStatePool,  # noqa: E402
                                  SpilledRequest)


@dataclasses.dataclass(frozen=True)
class PagedEngineConfig:
    max_decode_batch: int = 4         # rows in the jitted decode step
    n_pages: Optional[int] = 33       # 128-token pages (incl. 1 scratch)
    n_slabs: int = 9                  # state slabs (incl. 1 scratch)
    byte_budget: Optional[int] = None  # alternative to n_pages
    prefill_chunk: int = 128          # longest full-sequence prefill; the
                                      # prompt tail streams through decode
    sampling: SamplingConfig = SamplingConfig()
    scheduler: SchedulerConfig = SchedulerConfig()
    seed: int = 0


@dataclasses.dataclass
class _Active:
    req: Request
    length: int                       # cached positions so far
    pending: List[int]                # prompt tokens not yet consumed
    cur_token: int                    # next token to feed once prompt is done


class PagedServingEngine:
    """Continuous batching over the paged, bank-aware state/KV pool."""

    def __init__(self, params, cfg: ModelConfig, pcfg: PagedEngineConfig,
                 mesh_axes=None):
        assert not cfg.encoder_only
        self.params = params
        self.cfg = cfg
        self.pcfg = pcfg
        self.pool = PagedStatePool(
            cfg, n_pages=None if pcfg.byte_budget is not None else pcfg.n_pages,
            n_slabs=pcfg.n_slabs, byte_budget=pcfg.byte_budget,
            mesh_axes=mesh_axes)
        self.sched = Scheduler(pcfg.scheduler)
        self.active: Dict[int, _Active] = {}
        self.rows: List[Optional[int]] = [None] * pcfg.max_decode_batch
        self.spilled: Dict[int, Tuple[SpilledRequest, List[int], int]] = {}
        self.done: List[Request] = []
        self.step_count = 0
        self.step_times: List[float] = []
        # account the block-table-native ops this engine actually dispatches
        self._traffic = _OpTrafficMeter(cfg, layout="paged")
        self.preemptions = 0
        self._occ: List[float] = []
        self._frag: List[float] = []
        self.last_traffic: Optional[np.ndarray] = None
        self._key = jax.random.PRNGKey(pcfg.seed)
        self._prefill = jax.jit(partial(M.prefill, cfg=cfg,
                                        mesh_axes=mesh_axes))
        max_chunk_pages = pages_for(pcfg.prefill_chunk)
        assert max_chunk_pages <= self.pool.usable_pages, \
            "prefill_chunk does not fit the page pool"

    # ------------- public API -------------

    def submit(self, req: Request):
        req.t_submit = time.perf_counter()
        self.sched.push(req)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        while (self.sched or self.active) and self.step_count < max_steps:
            admitted = self._admit()
            if self.active:
                self._ensure_headroom()
            if self.active:
                self._step()
            elif not admitted:
                # queue non-empty but nothing fits and nothing runs:
                # fail the head loudly rather than spinning
                req = self.sched.pop()
                req.truncated = True
                req.t_done = time.perf_counter()
                self.done.append(req)
                self.spilled.pop(req.rid, None)
        return self.done

    def stats(self) -> Dict[str, float]:
        toks = sum(len(r.output) for r in self.done)
        if not self.done:
            return {"tokens": 0}
        t0 = min(r.t_submit for r in self.done)
        t1 = max(r.t_done for r in self.done)
        out = {"tokens": toks, "wall_s": t1 - t0,
               "tokens_per_s": toks / max(t1 - t0, 1e-9),
               "preemptions": float(self.preemptions),
               "occupancy": float(np.mean(self._occ)) if self._occ else 0.0,
               "fragmentation": (float(np.mean(self._frag))
                                 if self._frag else 0.0),
               # bytes still moved by gather/scatter: spill/resume and
               # prefill insertion only -- the decode loop contributes zero
               "gather_bytes": float(self.pool.gather_bytes)}
        out.update(_percentile_stats(self.done, self.step_times))
        out.update(self._traffic.stats())
        return out

    def bank_report(self) -> Dict[str, float]:
        """Score the pool's *actual* page map with the PIM timing model."""
        from repro.core import pimsim
        m = self.last_traffic
        if m is None:
            m = self.pool.bank_traffic(list(self.active))
        rep = pimsim.placement_step_latency(m, pimsim.SystemConfig())
        rep["imbalance"] = self.pool.placement.imbalance()
        return rep

    # ------------- admission / preemption -------------

    def _admit(self) -> bool:
        admitted = False
        while len(self.active) < self.pcfg.max_decode_batch and self.sched:
            head = self.sched.peek()
            if head.rid in self.spilled:
                need = self.spilled[head.rid][0].n_pages
            else:
                s0 = min(len(head.prompt), self.pcfg.prefill_chunk)
                need = pages_for(s0)
            if not self.pool.can_admit(need):
                victim = self.sched.choose_victim(
                    [a.req for a in self.active.values()])
                if victim is not None and self.sched.should_preempt(head,
                                                                    victim):
                    self._preempt(victim.rid)
                    continue
                break
            req = self.sched.pop()
            if req.rid in self.spilled:
                self._resume(req)
            else:
                self._prefill_into(req)
            admitted = True
        return admitted

    def _assign_row(self, rid: int):
        row = self.rows.index(None)
        self.rows[row] = rid

    def _free_row(self, rid: int):
        self.rows[self.rows.index(rid)] = None

    def _prefill_into(self, req: Request):
        s0 = min(len(req.prompt), self.pcfg.prefill_chunk)
        ok = self.pool.register(req.rid, pages_for(s0))
        assert ok, "admission checked capacity"
        prompt = jnp.asarray(req.prompt[:s0], jnp.int32)[None]
        logits, row_caches = self._prefill(
            self.params, batch={"tokens": prompt, "targets": prompt})
        self.pool.insert_prefill(req.rid, row_caches)
        a = _Active(req, length=s0, pending=list(map(int, req.prompt[s0:])),
                    cur_token=-1)
        if not a.pending:
            self._key, toks = _sample_tokens(self._key, logits,
                                             self.pcfg.sampling)
            tok = int(toks[0])
            req.t_first = time.perf_counter()
            req.output.append(tok)
            a.cur_token = tok
        self.active[req.rid] = a
        self._assign_row(req.rid)
        if req.output and (len(req.output) >= req.max_new_tokens
                           or (req.eos_id is not None
                               and req.output[-1] == req.eos_id)):
            self._finish(req.rid)       # prefill already produced the end

    def _resume(self, req: Request):
        sp, pending, cur = self.spilled.pop(req.rid)
        ok = self.pool.resume(req.rid, sp)
        assert ok, "admission checked capacity"
        self.active[req.rid] = _Active(req, sp.length, pending, cur)
        self._assign_row(req.rid)

    def _preempt(self, rid: int):
        """Evict by page spill: state leaves the device bit-exactly and the
        request goes back to the scheduler queue."""
        a = self.active.pop(rid)
        self._free_row(rid)
        sp = self.pool.spill(rid, a.length)
        self.spilled[rid] = (sp, a.pending, a.cur_token)
        self.sched.push(a.req, resumed=True)
        self.preemptions += 1

    def _finish(self, rid: int, truncated: bool = False):
        a = self.active.pop(rid)
        self._free_row(rid)
        self.pool.release(rid)
        a.req.truncated = truncated
        a.req.t_done = time.perf_counter()
        self.done.append(a.req)

    def _ensure_headroom(self):
        """Every active request must own the page its next token writes."""
        for rid in list(self.active):
            a = self.active.get(rid)
            if a is None:
                continue
            needed = a.length // PAGE_TOKENS + 1
            while needed > len(self.pool.page_table[rid]):
                if self.pool.grow(rid, needed - len(self.pool.page_table[rid])):
                    break
                victim = self.sched.choose_victim(
                    [b.req for b in self.active.values()], exclude=a.req)
                if victim is None:
                    self._finish(rid, truncated=True)
                    break
                self._preempt(victim.rid)

    # ------------- the decode step -------------

    def _step(self):
        self.step_count += 1
        B = self.pcfg.max_decode_batch
        tokens = np.zeros((B,), np.int32)
        lengths = np.zeros((B,), np.int32)
        for row, rid in enumerate(self.rows):
            if rid is None:
                continue
            a = self.active[rid]
            tokens[row] = a.pending[0] if a.pending else a.cur_token
            lengths[row] = a.length
        t0 = time.perf_counter()
        logits = self.pool.decode(self.params, self.rows, tokens, lengths,
                                  seed=self.step_count)
        self._key, toks = _sample_tokens(self._key, logits,
                                         self.pcfg.sampling)
        toks_np = np.asarray(toks)
        self.step_times.append(time.perf_counter() - t0)
        # account at the attended length: the step appends one token at
        # `length` and attends over length+1 (matches ServingEngine, which
        # accounts after its post-step lengths increment)
        self._traffic.account_step(
            [lengths[row] + 1 for row, rid in enumerate(self.rows)
             if rid is not None])

        rids = [r for r in self.rows if r is not None]
        self.last_traffic = self.pool.bank_traffic(rids)
        self._occ.append(self.pool.occupancy())
        self._frag.append(self.pool.fragmentation(
            {r: self.active[r].length for r in rids}))

        for row, rid in enumerate(self.rows):
            if rid is None:
                continue
            a = self.active[rid]
            a.length += 1
            if a.pending:
                fed = a.pending.pop(0)
                a.cur_token = fed
                if a.pending:
                    continue            # still consuming the prompt
                # that was the last prompt token: this step's logits are
                # the first-generation distribution
                tok = int(toks_np[row])
                a.req.t_first = time.perf_counter()
                a.req.output.append(tok)
                a.cur_token = tok
            else:
                tok = int(toks_np[row])
                a.req.output.append(tok)
                a.cur_token = tok
            req = a.req
            hit_eos = (req.eos_id is not None and req.output
                       and req.output[-1] == req.eos_id)
            if len(req.output) >= req.max_new_tokens or hit_eos:
                self._finish(rid)
