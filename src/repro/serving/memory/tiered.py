"""Two-tier (device HBM <-> pinned host DRAM) paged pool with async
prefetch and a persistent cross-request prefix cache.

``TieredStatePool`` extends :class:`~repro.serving.memory.pool.PagedStatePool`
with the memory hierarchy the ROADMAP calls for:

  * **host tier** -- preemption spills still move a victim's private pages to
    host bit-exactly (the base class), but the bytes are now *accounted*
    against a host-tier budget (:class:`HostTier`) and come back through an
    **async prefetch** path: ``prefetch_begin`` dispatches the device copy
    (JAX's async dispatch returns immediately) into freshly allocated staging
    pages while decode keeps stepping, and ``prefetch_commit`` later installs
    the staged pages into the block table -- an O(1) bookkeeping operation,
    no synchronous gather in the step loop.  The staging pages *are* the
    final pages (dispatch-then-commit double buffering, no bounce copy).
  * **prefix store** -- a :class:`~.prefix_store.PrefixStore` radix tree keyed
    by token ids remembers every *full* 128-token prompt page a request
    prefills (plus, for recurrent/hybrid models, a host snapshot of the
    recurrent state at each page boundary).  A later request whose prompt
    shares that prefix adopts the stored pages with a refcount bump -- the
    same copy-on-write sharing a ``Session.fork`` buys, but automatic and
    across requests.  Stored pages outlive their creating request under
    ``prefix_store_pages`` capacity with LRU + refcount-aware eviction, and
    can themselves be demoted to the host tier and promoted back on a hit
    (a *cold* hit), staying bit-exact either way.

Nothing here adds decode-shape retraces: prefetch reuses the same
``insert_blob`` jit signatures as synchronous resume, and page-table installs
never change block-table bucketing rules.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.memory.layout import PAGE_TOKENS
from repro.serving.memory.pool import PagedStatePool, SpilledRequest
from repro.serving.memory.prefix_store import PrefixStore, StoredPage
from repro.serving.resilience import (BlobCorruption, corrupt_blob, crc_blob,
                                      retry_transient, verify_blob)


class HostTier:
    """Byte ledger for the pinned-host tier.

    Two classes of payload:

      * **pinned** spill blobs (``pin``/``unpin`` keyed by rid) -- a preempted
        request's bits must survive no matter what, so pins may overshoot the
        budget (the alternative is dropping live state);
      * **cached** prefix-store payloads (``cache_add``/``cache_drop``) --
        best-effort, admitted only while ``room_for`` says the budget holds.

    ``byte_budget=None`` means unmetered (the pre-tiered behaviour).
    """

    def __init__(self, byte_budget: Optional[int] = None):
        self.byte_budget = byte_budget
        self._pinned: Dict[int, float] = {}
        self.cached_bytes = 0.0

    @property
    def pinned_bytes(self) -> float:
        return sum(self._pinned.values())

    @property
    def bytes_used(self) -> float:
        return self.pinned_bytes + self.cached_bytes

    def room_for(self, nbytes: float) -> bool:
        if self.byte_budget is None:
            return True
        return self.bytes_used + nbytes <= self.byte_budget

    def pin(self, rid: int, nbytes: float) -> None:
        self._pinned[rid] = self._pinned.get(rid, 0.0) + nbytes

    def unpin(self, rid: int) -> float:
        return self._pinned.pop(rid, 0.0)

    def cache_add(self, nbytes: float) -> None:
        self.cached_bytes += nbytes

    def cache_drop(self, nbytes: float) -> None:
        self.cached_bytes = max(0.0, self.cached_bytes - nbytes)


@dataclasses.dataclass
class _Staged:
    """An in-flight prefetch: device copy dispatched, not yet committed."""
    pages: List[int]
    slab: int
    sp: SpilledRequest
    ts0: float          # tracer timestamp at dispatch


def _blob_nbytes(blob) -> float:
    return float(sum(np.asarray(x).nbytes for x in blob))


class TieredStatePool(PagedStatePool):
    """Paged pool with a host tier, async spill-resume prefetch, and an
    automatic cross-request prefix cache.  Drop-in for ``PagedStatePool``."""

    def __init__(self, cfg, *args, host_tier_bytes: Optional[int] = None,
                 prefix_cache: bool = False, prefix_store_pages: int = 64,
                 **kw):
        super().__init__(cfg, *args, **kw)
        self.host = HostTier(host_tier_bytes)
        self.store: Optional[PrefixStore] = (
            PrefixStore(prefix_store_pages) if prefix_cache else None)
        self._staged: Dict[int, _Staged] = {}
        #: cross-request prefix-cache hit ledger
        self.prefix_hits = 0
        self.prefix_hit_pages = 0
        self.prefix_hit_tokens = 0
        #: prefetch lifecycle ledger: every begin must end in exactly one
        #: commit or cancel (checked by the sanitizer at teardown)
        self.prefetch_begun = 0
        self.prefetch_commits = 0
        self.prefetch_cancels = 0
        # tier movement jits: bare page stacks and slab rows (the units of
        # store demotion / promotion and state-snapshot capture).  Extracts
        # never donate -- callers keep using the pools; inserts donate like
        # every other pool-chain op.
        self._extract_pages = jax.jit(self.paging.extract_pages)
        self._insert_pages = jax.jit(self.paging.insert_pages,
                                     donate_argnums=(0,))
        self._extract_slab = jax.jit(self.paging.extract_slab)
        self._insert_slab = jax.jit(self.paging.insert_slab,
                                    donate_argnums=(0,))
        self._has_slabs = any(s.kind == "slab" for s in self.paging.specs)

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------

    def attach_obs(self, obs) -> None:
        super().attach_obs(obs)
        self._extract_pages = obs.wrap_jit(self._extract_pages,
                                           "pool.tier_extract")
        self._insert_pages = obs.wrap_jit(self._insert_pages,
                                          "pool.tier_insert")
        self._extract_slab = obs.wrap_jit(self._extract_slab,
                                          "pool.slab_extract")
        self._insert_slab = obs.wrap_jit(self._insert_slab,
                                         "pool.slab_insert")

    def _tier_metric(self, name: str, v: float = 1.0, **labels) -> None:
        if self._obs is not None:
            self._obs.metrics.counter(name, **labels).inc(v)

    def _tier_instant(self, name: str, **args) -> None:
        if self._obs is not None:
            self._obs.tracer.instant(name, cat="tier", track="pool", **args)

    def _sync_host_gauge(self) -> None:
        if self._obs is not None:
            self._obs.metrics.gauge("host_tier_bytes").set(
                self.host.bytes_used)

    # ------------------------------------------------------------------
    # spill / resume with host-tier accounting
    # ------------------------------------------------------------------

    def spill(self, rid: int, length: int) -> SpilledRequest:
        sp = super().spill(rid, length)
        if self._inject("blob_corrupt", rid=rid, what="spill"):
            # flip one byte *after* the CRC was recorded: resume/prefetch
            # must detect the mismatch, not decode the garbage
            corrupt_blob(sp.blob)
        nbytes = _blob_nbytes(sp.blob)
        self._pin_with_retry(rid, nbytes)
        self._tier_metric("demote_bytes_total", nbytes, kind="spill")
        self._tier_instant("tier.demote", rid=rid, bytes=nbytes, kind="spill")
        self._sync_host_gauge()
        return sp

    def _pin_with_retry(self, rid: int, nbytes: float) -> None:
        """Pin a spill blob in the host ledger with bounded retry against
        injected transient pin failures, then *force-pin*: a preempted
        request's bits are live state and may never be dropped, so the
        terminal rung here is overshoot-and-degrade, not failure."""
        retried = [0]

        def attempt():
            if self._inject("host_pin", rid=rid, what="spill"):
                return False
            self.host.pin(rid, nbytes)
            return True

        def on_retry(_k):
            retried[0] += 1
            self._tier_metric("fault_retries_total", site="host_pin")

        if retry_transient(attempt, on_retry=on_retry):
            if retried[0]:
                self._tier_metric("faults_recovered_total", site="host_pin")
            return
        # retries exhausted: pin anyway (HostTier pins may overshoot the
        # budget by contract) and record the degradation
        self.host.pin(rid, nbytes)
        self._tier_metric("degradations_total", rung="force_pin")
        if self._obs is not None:
            self._obs.tracer.instant("fault.host_pin_forced", cat="fault",
                                     track="pool", rid=rid)

    def resume(self, rid: int, sp: SpilledRequest) -> bool:
        """Synchronous resume -- the fallback when no prefetch was staged.
        A staged prefetch commits instead (O(1), no gather here)."""
        if rid in self._staged:
            if self._inject("prefetch_commit", rid=rid, what="commit"):
                # injected commit failure: return the staging pages and
                # fall back to the synchronous path below -- the request
                # still resumes, one gather later than planned
                self.prefetch_cancel(rid)
                self._tier_metric("faults_recovered_total",
                                  site="prefetch_commit")
            else:
                return self.prefetch_commit(rid)
        if not super().resume(rid, sp):
            return False
        nbytes = self.host.unpin(rid)
        self._tier_metric("tier_miss_total", kind="resume")
        self._tier_metric("promote_bytes_total", nbytes, kind="resume")
        self._tier_instant("tier.promote", rid=rid, bytes=nbytes,
                           kind="resume")
        self._sync_host_gauge()
        return True

    def drop_spilled(self, sp: SpilledRequest, rid: Optional[int] = None):
        super().drop_spilled(sp, rid)
        if rid is not None:
            self.host.unpin(rid)
            self._sync_host_gauge()

    # ------------------------------------------------------------------
    # async prefetch (dispatch-then-commit)
    # ------------------------------------------------------------------

    def prefetch_begin(self, rid: int, sp: SpilledRequest,
                       reserve: int = 1) -> bool:
        """Dispatch the device copy for a spilled request's blob into fresh
        staging pages, without installing them.  Returns False (no-op) when
        pages/slabs are too tight -- ``reserve`` pages are left free so
        staging never starves decode growth."""
        if rid in self._staged or rid in self.page_table:
            return rid in self._staged
        need = sp.pages_needed
        if self.free_pages < need + reserve or self.free_slabs < 2:
            return False
        # verify *before* dispatch: a corrupt blob must never start a
        # device copy (the engine converts this into a re-prefill)
        verify_blob(sp.blob, sp.crc, "spill blob", rid=rid)
        pages = self.placement.alloc(need)
        if pages is None:
            return False
        self.pages_allocated += need
        self.prefetch_begun += 1
        slab = self._free_slabs.pop()
        ts0 = (self._obs.tracer.now_us() if self._obs is not None else 0.0)
        # async dispatch: XLA begins the host->device copy immediately and
        # returns; the step loop keeps dispatching decode kernels behind it
        self.pools = self._insert_blob(self.pools, sp.blob,
                                       jnp.asarray(pages, jnp.int32),
                                       jnp.int32(slab))
        self._staged[rid] = _Staged(pages, slab, sp, ts0)
        self._tier_instant("prefetch.dispatch", rid=rid, pages=need)
        return True

    def prefetch_ready(self, rid: int) -> bool:
        return rid in self._staged

    def prefetch_commit(self, rid: int) -> bool:
        """Install a staged prefetch: build the block table from still-
        resident shared pages + the staged private pages.  O(1) bookkeeping;
        the data moved while decode was running."""
        st = self._staged.pop(rid, None)
        if st is None:
            return False
        assert rid not in self.page_table
        sp = st.sp
        table = [0] * sp.n_pages
        for pos, pid in sp.shared:
            table[pos] = pid
        for pos, pid in zip(sp.private_idx, st.pages):
            table[pos] = pid
        self.page_table[rid] = table
        self.slab_of[rid] = st.slab
        nbytes = self.host.unpin(rid)
        self._account_gather(self.request_nbytes(sp.pages_needed))
        self.prefetch_commits += 1
        self._tier_metric("tier_hit_total", kind="prefetch")
        self._tier_metric("promote_bytes_total", nbytes, kind="prefetch")
        self._sync_host_gauge()
        if self._obs is not None:
            ts1 = self._obs.tracer.now_us()
            self._obs.tracer.async_span("prefetch", rid, cat="prefetch",
                                        ts0=st.ts0, ts1=ts1, track="pool",
                                        rid=rid, pages=sp.pages_needed)
        return True

    def prefetch_cancel(self, rid: int) -> None:
        """Abandon a staged prefetch (request aborted / truncated): return
        the staging pages and slab; the host blob stays pinned."""
        st = self._staged.pop(rid, None)
        if st is None:
            return
        self.placement.unref(st.pages)
        self._free_slabs.append(st.slab)
        self.prefetch_cancels += 1
        if self._obs is not None:
            ts1 = self._obs.tracer.now_us()
            self._obs.tracer.async_span("prefetch", rid, cat="prefetch",
                                        ts0=st.ts0, ts1=ts1, track="pool",
                                        rid=rid, canceled=True)

    # ------------------------------------------------------------------
    # prefix store: match / admit / insert / tiering
    # ------------------------------------------------------------------

    def prefix_match(self, prompt: Sequence[int]) -> Optional[List[StoredPage]]:
        """Longest usable stored prefix for ``prompt``, or None.

        Pure lookup -- no metrics (the engine may probe repeatedly while a
        request waits in the queue); hit/miss is counted at admission.  The
        match is capped so at least one prompt token remains un-cached (the
        engine needs a tail to feed through prefill/decode), and trimmed to
        the deepest node carrying a recurrent-state snapshot (without the
        state at the boundary, a hit would not be bit-exact for SSM/hybrid
        models)."""
        if self.store is None or len(prompt) <= PAGE_TOKENS:
            return None
        max_pages = (len(prompt) - 1) // PAGE_TOKENS
        path = self.store.match(self.store.chunks(prompt, max_pages))
        while path and path[-1].state is None:
            path.pop()
        return path or None

    def prefix_admit(self, rid: int, nodes: List[StoredPage]) -> bool:
        """Admit ``rid`` with its first ``len(nodes)`` pages adopted from the
        store (refcount bumps, no prefill).  Demoted nodes are promoted
        first; the tail node's state snapshot is written into the fresh
        slab.  Returns False (nothing changed) if capacity is short."""
        assert self.store is not None and nodes
        assert rid not in self.page_table
        cold = [n for n in nodes if not n.resident]
        if not self.can_admit(len(cold)):
            return False
        for n in cold:
            if not self.promote_node(n):
                return False
        warm = len(nodes) - len(cold)
        pages = [n.device_page for n in nodes]
        self.placement.ref(pages)
        self.shared_page_hits += len(pages)
        self.page_table[rid] = list(pages)
        slab = self._free_slabs.pop()
        self.slab_of[rid] = slab
        tail = nodes[-1]
        if self._has_slabs:
            self.pools = self._insert_slab(self.pools, tail.state,
                                           jnp.int32(slab))
            self._account_gather(self.slab_nbytes)
        self.store.touch(nodes)
        self.prefix_hits += 1
        self.prefix_hit_pages += len(nodes)
        self.prefix_hit_tokens += len(nodes) * PAGE_TOKENS
        self._tier_metric("tier_hit_total", kind="prefix")
        self._tier_instant("tier.prefix_hit", rid=rid, pages=len(nodes),
                           warm=warm, cold=len(cold))
        return True

    def note_prefix_miss(self) -> None:
        if self.store is not None:
            self._tier_metric("tier_miss_total", kind="prefix")

    def snapshot_slab(self, rid: int) -> List[np.ndarray]:
        """Host copy of ``rid``'s recurrent-state slab row (may be [])."""
        if not self._has_slabs:
            return []
        blob = self._extract_slab(self.pools, jnp.int32(self.slab_of[rid]))
        return [np.asarray(x) for x in blob]

    def store_insert(self, rid: int, tokens: Sequence[int]) -> int:
        """Record ``rid``'s pages for the exact-page-boundary prefix
        ``tokens`` (``len(tokens) % PAGE_TOKENS == 0``) in the store.  The
        store takes one placement ref per newly created node, and the tail
        node captures the request's recurrent state at this boundary.
        Returns the number of new nodes."""
        if self.store is None or len(tokens) == 0:
            return 0
        assert len(tokens) % PAGE_TOKENS == 0
        chunks = self.store.chunks(tokens)
        path, created = self.store.extend(chunks)
        table = self.page_table[rid]
        for node in created:
            node.device_page = table[node.depth - 1]
            self.placement.ref([node.device_page])
        tail = path[-1]
        if tail.state is None:
            state = self.snapshot_slab(rid)
            tail.state = state
            nbytes = _blob_nbytes(state)
            self.host.cache_add(nbytes)
            self._account_gather(self.slab_nbytes)
            self._sync_host_gauge()
        if created:
            self._tier_instant("tier.store_insert", rid=rid,
                               pages=len(created), depth=len(path))
        self._enforce_store_capacity()
        return len(created)

    # ------------------------------------------------------------------
    # store tiering: demote / promote / evict
    # ------------------------------------------------------------------

    def _locked(self, node: StoredPage) -> bool:
        """A node whose device page other owners still reference (live
        requests, spill blobs) must not be demoted or evicted."""
        return (node.resident
                and self.placement.refcount(node.device_page) > 1)

    def demote_node(self, node: StoredPage) -> bool:
        """Move one resident store node's page payload to the host tier and
        free its device page.  Refuses locked nodes; falls back to eviction
        when the host budget has no room (a cache entry is best-effort)."""
        if not node.resident or self._locked(node):
            return False
        nbytes = self.page_nbytes
        if not self.host.room_for(nbytes):
            if node.is_leaf:
                self.evict_node(node)
            return False
        blob = self._extract_pages(
            self.pools, jnp.asarray([node.device_page], jnp.int32))
        node.host_blob = [np.asarray(x) for x in blob]
        node.host_crc = crc_blob(node.host_blob)
        if self._inject("blob_corrupt", what="store_demote"):
            corrupt_blob(node.host_blob)
        self.placement.unref([node.device_page])
        node.device_page = None
        self.host.cache_add(nbytes)
        self._account_gather(nbytes)
        self._tier_metric("demote_bytes_total", float(nbytes), kind="store")
        self._tier_instant("tier.demote", node=node.node_id,
                           bytes=float(nbytes), kind="store")
        self._sync_host_gauge()
        return True

    def promote_node(self, node: StoredPage) -> bool:
        """Bring a demoted store node back to the device (a cold hit).

        The host payload is checksum-verified first: a corrupt cache entry
        is converted into a *miss* (the node -- and, for interior nodes,
        its whole subtree -- is evicted) rather than a poisoned hit."""
        if node.resident:
            return True
        assert node.host_blob is not None
        try:
            verify_blob(node.host_blob, node.host_crc, "store blob")
        except BlobCorruption:
            self._evict_subtree(node)
            self._tier_metric("faults_recovered_total", site="store_promote")
            self._tier_instant("tier.store_corrupt", node=node.node_id)
            return False
        if self._inject("alloc", what="promote"):
            return False
        got = self.placement.alloc(1)
        if got is None:
            return False
        self.pages_allocated += 1
        self.pools = self._insert_pages(self.pools, node.host_blob,
                                        jnp.asarray(got, jnp.int32))
        node.device_page = got[0]
        node.host_blob = None
        node.host_crc = None
        nbytes = self.page_nbytes
        self.host.cache_drop(nbytes)
        self._account_gather(nbytes)
        self._tier_metric("promote_bytes_total", float(nbytes), kind="store")
        self._tier_instant("tier.promote", node=node.node_id,
                           bytes=float(nbytes), kind="store")
        self._sync_host_gauge()
        return True

    def evict_node(self, node: StoredPage) -> None:
        """Drop a leaf store node entirely (device ref and/or host bytes)."""
        assert not self._locked(node)
        self.store.remove(node)
        if node.resident:
            self.placement.unref([node.device_page])
            node.device_page = None
        if node.host_blob is not None:
            self.host.cache_drop(self.page_nbytes)
            node.host_blob = None
            node.host_crc = None
        if node.state is not None:
            self.host.cache_drop(_blob_nbytes(node.state))
            node.state = None
        self._tier_instant("tier.evict", node=node.node_id)
        self._sync_host_gauge()

    def _evict_subtree(self, node: StoredPage) -> int:
        """Evict ``node`` and every descendant (``PrefixStore.remove`` is
        leaf-only, so the subtree is peeled deepest-first).  Used when an
        *interior* node's host payload fails its checksum: its cached path
        is unusable below the corruption point.  Locked descendants stop
        the peel -- their payloads back live requests -- in which case the
        corrupt node simply stays unpromotable: every later promote attempt
        re-detects the mismatch and reports a miss.  Returns nodes evicted."""
        evicted = 0
        while True:
            sub = [node]
            i = 0
            while i < len(sub):
                sub.extend(sub[i].children.values())
                i += 1
            peel = [n for n in sub if n.is_leaf and not self._locked(n)]
            if not peel:
                return evicted
            for n in peel:
                self.evict_node(n)
                evicted += 1
            if node not in self.store.nodes():
                return evicted

    def sanitizer_owned_pages(self) -> set:
        """Base owners plus staged prefetch pages and resident prefix-store
        nodes (the store holds one placement ref per resident node)."""
        owned = super().sanitizer_owned_pages()
        for st in self._staged.values():
            owned.update(st.pages)
        if self.store is not None:
            owned.update(self.store.resident_pages())
        return owned

    def sanitizer_check_leaks(self, what: str = "engine teardown") -> None:
        """Tiered teardown additionally requires the prefetch ledger to be
        settled: a staged prefetch whose request already retired would hold
        its staging pages (and slab) forever -- exactly the leak an abort
        racing an in-flight prefetch used to cause."""
        shadow = getattr(self.placement, "_shadow", None)
        if shadow is not None and self._staged:
            from repro.analysis.lint.runtime import SanitizerError
            raise SanitizerError(
                "PL255", f"{len(self._staged)} staged prefetch(es) never "
                f"committed or canceled at {what} "
                f"(rids {sorted(self._staged)})")
        super().sanitizer_check_leaks(what)

    def _enforce_store_capacity(self) -> None:
        over = self.store.over_capacity()
        while over > 0:
            cands = self.store.evict_candidates(locked=self._locked)
            if not cands:
                break
            self.evict_node(cands[0])
            over -= 1

    def reclaim(self, n_pages: int) -> int:
        """Free device pages by demoting (or evicting) LRU store nodes until
        ``n_pages`` are available.  Returns pages actually reclaimed."""
        if self.store is None:
            return 0
        got = 0
        for node in self.store.lru_nodes():
            if self.free_pages >= n_pages:
                break
            if not node.resident or self._locked(node):
                continue
            if self.demote_node(node):
                got += 1
            elif node.is_leaf:
                # demote refused for host-budget reasons and evicted inside
                got += 1
        return got

    def demote_all(self) -> int:
        """Demote every unlocked resident store node to the host tier
        (cold-store hook for tests / checkpoint-style drains)."""
        if self.store is None:
            return 0
        n = 0
        for node in self.store.nodes():
            if node.resident and not self._locked(node):
                if self.demote_node(node):
                    n += 1
        return n

    def prefetch_prefix(self, prompt: Sequence[int]) -> int:
        """Scheduler lookahead hook: promote demoted store nodes matching a
        queued prompt ahead of its admission, so the hit is warm by the time
        the request admits.  Returns nodes promoted."""
        nodes = self.prefix_match(prompt)
        if not nodes:
            return 0
        n = 0
        for node in nodes:
            if not node.resident and self.free_pages > 1:
                if self.promote_node(node):
                    n += 1
        if n:
            self._tier_instant("prefetch.prefix", pages=n)
        return n
