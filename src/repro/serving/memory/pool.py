"""Paged, bank-aware state/KV memory pool.

One ``PagedStatePool`` owns the physical decode-cache storage of a serving
engine:

  * **KV pages** -- every attention/MLA cache leaf is stored as
    ``(n_pages, ..., 128, ...)`` arrays; a physical page id addresses one
    128-token, MX-tile-aligned chunk across *all* KV leaves at once.
  * **state slabs** -- every fixed-size recurrent leaf (SSM state, conv
    tails, sLSTM carries) is ``(n_slabs, ...)``; one slab id per request.

A request owns a block table (list of page ids) plus one slab id.  Slot
reuse is copy-free: finishing or growing a request only moves integer ids
between free lists -- no cache-tree rewrite, which is what retires the old
``_recapacity`` per-prefill tree surgery from the serving hot path.

Placement is bank-aware (see :mod:`.placement`): page ids map to
(pseudo-channel, bank-pair) coordinates and allocation balances live load
across bank pairs, producing a real page map that
:func:`repro.core.pimsim.placement_step_latency` can score.

Preemption spills a victim's pages+slab to host memory bit-exactly; resume
re-pins them to fresh physical ids (identical logits, different placement).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import ops as OPS
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.core.paged import pages_for  # noqa: F401  (canonical home moved)
from repro.serving.memory.layout import PAGE_TOKENS, CachePaging
from repro.serving.memory.placement import BankAwarePlacement, BankTopology
from repro.serving.resilience import crc_blob, verify_blob


def bucket_pages(npg: int) -> int:
    """Round a page count up to a power of two to bound jit retraces."""
    return 1 << max(0, (npg - 1).bit_length())


@dataclasses.dataclass
class SpilledRequest:
    """Host-side copy of an evicted request's state (bit-exact).

    Copy-on-write aware: only *privately owned* pages are extracted into
    ``blob``.  Pages shared with other resident requests never leave the
    device -- the spilled request keeps its reference on them (recorded in
    ``shared`` as (block-table position, physical id)), so they cannot be
    freed or overwritten while it waits, and resume reuses the ids verbatim.
    A shared page therefore spills zero extra times.
    """
    blob: List[np.ndarray]
    n_pages: int                        # total block-table length
    length: int
    private_idx: List[int] = dataclasses.field(default_factory=list)
    shared: List[tuple] = dataclasses.field(default_factory=list)
    #: CRC32 of ``blob`` at extraction; resume/prefetch verify it before
    #: the bits re-enter the device (None = unchecked legacy blob)
    crc: Optional[int] = None

    @property
    def pages_needed(self) -> int:
        """Fresh pages a resume must allocate (private pages only)."""
        return len(self.private_idx)


class PagedStatePool:
    """Block/page-granular pool backing both KV caches and SSM states.

    Page id 0 and slab id 0 are reserved scratch targets for inactive decode
    rows; usable capacity is ``n_pages - 1`` pages / ``n_slabs - 1`` slabs.
    """

    def __init__(self, cfg: ModelConfig, n_pages: Optional[int] = None,
                 n_slabs: int = 9, byte_budget: Optional[int] = None,
                 topology: Optional[BankTopology] = None, mesh_axes=None,
                 decode_mode: str = "paged"):
        assert decode_mode in ("paged", "gather")
        self.cfg = cfg
        self.mesh_axes = mesh_axes
        self.decode_mode = decode_mode
        template = M.init_decode_caches(cfg, 1, PAGE_TOKENS)
        t_b2 = M.abstract_decode_caches(cfg, 2, PAGE_TOKENS)
        t_t2 = M.abstract_decode_caches(cfg, 1, 2 * PAGE_TOKENS)
        self.paging = CachePaging(template, t_b2, t_t2)

        if byte_budget is not None:
            assert n_pages is None, "give n_pages or byte_budget, not both"
            state_bytes = (n_slabs - 1) * self.paging.slab_nbytes
            per_page = max(self.paging.page_nbytes, 1)
            n_pages = 1 + max(1, (byte_budget - state_bytes) // per_page)
        assert n_pages is not None and n_pages >= 2 and n_slabs >= 2
        self.n_pages = int(n_pages)
        self.n_slabs = int(n_slabs)

        self.pools = self.paging.make_pools(self.n_pages, self.n_slabs)
        if topology is None:
            # size the coordinate space to the pool, so the conflict score
            # compares against a *reachable* ideal spread
            pch, pairs = 16, 8
            while pch * pairs > max(self.n_pages - 1, 1) and pch * pairs > 1:
                if pairs >= pch:
                    pairs = max(1, pairs // 2)
                else:
                    pch = max(1, pch // 2)
            topology = BankTopology(pch, pairs)
        self.placement = BankAwarePlacement(self.n_pages, topology)
        self._free_slabs: List[int] = list(range(1, self.n_slabs))
        self.page_table: Dict[int, List[int]] = {}     # rid -> page ids
        self.slab_of: Dict[int, int] = {}              # rid -> slab id

        # steady-state decode: block-table-native paged ops over donated
        # pools -- XLA updates page slots and slab rows in place instead of
        # copying every pool every token
        self._decode = jax.jit(self._decode_impl, donate_argnums=(1,))
        # speculative verify: n positions per row in one pass, returning
        # per-position state snapshots; commit_spec rolls rejected drafts
        # back by rewriting slab rows from the selected snapshot
        self._decode_spec = jax.jit(self._decode_spec_impl,
                                    donate_argnums=(1,))
        self._commit_spec = jax.jit(self._commit_spec_impl,
                                    donate_argnums=(0,))
        # dense-gather reference path (parity tests; never donates, so
        # callers may hold pool snapshots around a reference step)
        self._decode_gather = jax.jit(self._decode_gather_impl)  # lint: disable=JH104
        self._insert = jax.jit(self.paging.insert_request,
                               donate_argnums=(0,))
        self._extract = jax.jit(self.paging.extract_request)
        self._insert_blob = jax.jit(self.paging.insert_blob,
                                    donate_argnums=(0,))
        self._fork_copy = jax.jit(self.paging.fork_copy, donate_argnums=(0,))
        self._copy_slab = jax.jit(self.paging.copy_slab, donate_argnums=(0,))

        # block-table-native op plans (layout="paged"): per-page stream
        # bytes and per-request slab bytes for the PIM bank model come from
        # the registered ops' own traffic descriptors, not local formulas
        entries = OPS.decode_op_plans(cfg, 1, PAGE_TOKENS, layout="paged")
        self._page_stream_bytes = sum(
            e.traffic.state_read for e in entries
            if e.kind in ("attn_decode", "mla_decode"))
        self._slab_rw_bytes = sum(
            e.traffic.state_total for e in entries
            if e.kind == "state_update")
        #: host-side ledger of bytes still moved by gather/scatter -- which
        #: after the block-table-native rewire is only preemption
        #: spill/resume, prefill insertion, and the one-page fork copy --
        #: never the decode loop
        self.gather_bytes = 0.0
        #: cumulative pages handed out by the allocator (register / grow /
        #: resume / the fork tail copy); copy-on-write shares are *not*
        #: counted here -- the gap versus an unshared run is the savings
        self.pages_allocated = 0
        #: cumulative extra references taken by fork() -- each one is a page
        #: a prefix-sharing-free pool would have had to allocate and fill
        self.shared_page_hits = 0
        #: optional repro.obs.Observability (see ``attach_obs``)
        self._obs = None
        #: optional repro.serving.faults.FaultPlan -- when installed (the
        #: engine wires ``ServeConfig.fault_plan`` / ``REPRO_FAULTS``
        #: through), allocation sites consult it for injected transient
        #: failures.  One ``is None`` test per site when disabled.
        self.faults = None

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------

    def attach_obs(self, obs) -> None:
        """Attach an engine's :class:`repro.obs.Observability` bundle: the
        jitted pool steppers get recompile watchers, the placement mirrors
        page alloc/free/ref into the metrics registry, and page movement
        (register / grow / fork / spill / resume / release) emits instants
        on the pool track."""
        self._obs = obs
        self._decode = obs.wrap_jit(self._decode, "pool.decode")
        self._decode_spec = obs.wrap_jit(self._decode_spec,
                                         "pool.decode_spec")
        self._commit_spec = obs.wrap_jit(self._commit_spec,
                                         "pool.commit_spec")
        self._decode_gather = obs.wrap_jit(self._decode_gather,
                                           "pool.decode_gather")
        self._insert = obs.wrap_jit(self._insert, "pool.prefill_insert")
        self._insert_blob = obs.wrap_jit(self._insert_blob,
                                         "pool.resume_insert")
        self.placement.metrics = obs.metrics

    def _instant(self, name: str, **args) -> None:
        if self._obs is not None:
            self._obs.tracer.instant(name, cat="pool", track="pool", **args)

    def _inject(self, site: str, rid: Optional[int] = None,
                what: str = "") -> bool:
        """One fault-plan consult: True means the caller must fail now.
        Fires are mirrored into ``faults_injected_total{site=}`` and a
        ``cat="fault"`` trace instant."""
        if self.faults is None or not self.faults.should_fire(site, rid=rid):
            return False
        if self._obs is not None:
            self._obs.metrics.counter("faults_injected_total",
                                      site=site).inc()
            self._obs.tracer.instant(f"fault.{site}", cat="fault",
                                     track="pool", rid=rid, what=what)
        return True

    def _account_gather(self, nbytes: float) -> None:
        """Bytes moved by gather/scatter (spill/resume/prefill-insert/fork
        copies): the host ledger plus the metrics counter."""
        self.gather_bytes += nbytes
        if self._obs is not None:
            self._obs.metrics.counter("gather_bytes_total").inc(nbytes)

    # ------------------------------------------------------------------
    # allocation
    # ------------------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return self.placement.n_free

    @property
    def free_slabs(self) -> int:
        return len(self._free_slabs)

    @property
    def usable_pages(self) -> int:
        return self.placement.n_usable

    def can_admit(self, n_pages: int, n_slabs: int = 1) -> bool:
        return self.free_pages >= n_pages and self.free_slabs >= n_slabs

    def register(self, rid: int, n_pages: int) -> bool:
        """Claim a slab + ``n_pages`` pages for a new / resuming request."""
        assert rid not in self.page_table
        if not self.can_admit(n_pages):
            return False
        if self._inject("alloc", rid=rid, what="register"):
            return False                # injected transient shortage
        pages = self.placement.alloc(n_pages)
        if pages is None:
            return False
        self.page_table[rid] = pages
        self.slab_of[rid] = self._free_slabs.pop()
        self.pages_allocated += n_pages
        self._instant("pool.register", rid=rid, pages=n_pages)
        return True

    def grow(self, rid: int, n_new: int) -> bool:
        """Extend a request's block table -- copy-free, just new page ids."""
        if self._inject("alloc", rid=rid, what="grow"):
            return False                # injected transient shortage
        pages = self.placement.alloc(n_new)
        if pages is None:
            return False
        self.page_table[rid].extend(pages)
        self.pages_allocated += n_new
        self._instant("pool.grow", rid=rid, pages=n_new)
        return True

    def release(self, rid: int):
        """Drop a request's references: pages return to the free list only
        when the last owner drops them (copy-on-write forks keep shared
        prefix pages alive); the slab is always exclusive and frees now."""
        pages = self.page_table.pop(rid)
        self.placement.unref(pages)
        self._free_slabs.append(self.slab_of.pop(rid))
        self._instant("pool.release", rid=rid, pages=len(pages))

    def fork(self, parent_rid: int, child_rid: int, length: int) -> bool:
        """Copy-on-write fork: the child shares the parent's full (append-
        immutable) prefix pages by reference and gets a private copy of only
        the partially filled tail page plus the parent's slab row (recurrent
        state at ``length``).  Costs at most 1 page + 1 slab regardless of
        prefix length -- re-prefill is skipped entirely.

        ``length`` is the parent's cached context length.  The parent may
        keep running (or stay retained): its own tail stays private to it,
        and full pages are never written by either side (decode appends only
        at positions >= length).
        """
        assert child_rid not in self.page_table
        parent_pages = self.page_table[parent_rid]
        n_full, tail = divmod(length, PAGE_TOKENS)
        assert len(parent_pages) >= n_full + (1 if tail else 0), \
            (parent_rid, length, len(parent_pages))
        need = 1 if tail else 0
        if not self.can_admit(need):
            return False
        new_pages: List[int] = []
        if tail:
            got = self.placement.alloc(1)
            if got is None:
                return False
            new_pages = got
            self.pages_allocated += 1
        shared = list(parent_pages[:n_full])
        self.placement.ref(shared)
        self.shared_page_hits += len(shared)
        self.page_table[child_rid] = shared + new_pages
        slab = self._free_slabs.pop()
        self.slab_of[child_rid] = slab
        src_slab = jnp.int32(self.slab_of[parent_rid])
        if tail:
            self.pools = self._fork_copy(
                self.pools, jnp.int32(parent_pages[n_full]),
                jnp.int32(new_pages[0]), src_slab, jnp.int32(slab))
            self._account_gather(self.page_nbytes + self.slab_nbytes)
        else:
            self.pools = self._copy_slab(self.pools, src_slab,
                                         jnp.int32(slab))
            self._account_gather(self.slab_nbytes)
        self._instant("pool.fork", parent=parent_rid, child=child_rid,
                      shared_pages=len(shared), copied_pages=len(new_pages))
        if self._obs is not None:
            self._obs.metrics.counter("forks_total").inc()
            self._obs.metrics.counter(
                "shared_page_refs_total").inc(len(shared))
        return True

    # ------------------------------------------------------------------
    # data movement
    # ------------------------------------------------------------------

    def request_nbytes(self, n_pages: int) -> float:
        """Physical bytes one request's pages + slab occupy (spill size)."""
        return n_pages * self.page_nbytes + self.slab_nbytes

    def insert_prefill(self, rid: int, row_caches):
        """Pin a prefilled B=1 cache row (T must equal npg*PAGE_TOKENS)."""
        pages = jnp.asarray(self.page_table[rid], jnp.int32)
        slab = jnp.int32(self.slab_of[rid])
        self.pools = self._insert(self.pools, row_caches, pages, slab)
        self._account_gather(self.request_nbytes(len(self.page_table[rid])))

    def spill(self, rid: int, length: int) -> SpilledRequest:
        """Evict: copy the request's *private* pages + slab to host
        bit-exactly and free those device ids.  Pages shared with other
        requests (copy-on-write prefixes, refcount > 1) are not extracted:
        the spilled request keeps its reference, so the bits stay resident
        for the co-owners and the page cannot be reallocated underneath the
        waiting blob -- a shared page never spills twice."""
        pages = self.page_table[rid]
        private_idx = [i for i, p in enumerate(pages)
                       if self.placement.refcount(p) == 1]
        shared = [(i, p) for i, p in enumerate(pages)
                  if self.placement.refcount(p) > 1]
        priv = [pages[i] for i in private_idx]
        blob = self._extract(self.pools, jnp.asarray(priv, jnp.int32),
                             jnp.int32(self.slab_of[rid]))
        host = [np.asarray(x) for x in blob]
        # free only the private pages (refcount 1 -> 0) + the slab; shared
        # refs travel with the SpilledRequest
        self.page_table.pop(rid)
        self.placement.unref(priv)
        self._free_slabs.append(self.slab_of.pop(rid))
        self._account_gather(self.request_nbytes(len(priv)))
        self._instant("pool.spill", rid=rid, private_pages=len(priv),
                      shared_pages=len(shared))
        # checksum the host copy at the tier boundary: resume/prefetch
        # verify it, so a corrupted blob is detected instead of silently
        # poisoning decode
        return SpilledRequest(host, len(pages), length,
                              private_idx=private_idx, shared=shared,
                              crc=crc_blob(host))

    def resume(self, rid: int, sp: SpilledRequest) -> bool:
        """Re-pin a spilled request: private pages land on fresh physical
        ids, shared prefix pages are still resident and rejoin the block
        table verbatim (same bits, possibly a different bank placement for
        the private part)."""
        assert rid not in self.page_table
        if not self.can_admit(sp.pages_needed):
            return False
        # the blob is about to re-enter the device: a corrupted byte must
        # stop here (BlobCorruption), not surface as garbage logits
        verify_blob(sp.blob, sp.crc, "spill blob", rid=rid)
        if self._inject("alloc", rid=rid, what="resume"):
            return False                # injected transient shortage
        fresh = self.placement.alloc(sp.pages_needed)
        if fresh is None:
            return False
        self.pages_allocated += sp.pages_needed
        table = [0] * sp.n_pages
        for pos, pid in sp.shared:
            table[pos] = pid
        for pos, pid in zip(sp.private_idx, fresh):
            table[pos] = pid
        self.page_table[rid] = table
        slab = self._free_slabs.pop()
        self.slab_of[rid] = slab
        self.pools = self._insert_blob(self.pools, sp.blob,
                                       jnp.asarray(fresh, jnp.int32),
                                       jnp.int32(slab))
        self._account_gather(self.request_nbytes(sp.pages_needed))
        self._instant("pool.resume", rid=rid, pages=sp.pages_needed,
                      shared_pages=len(sp.shared))
        return True

    def drop_spilled(self, sp: SpilledRequest, rid: Optional[int] = None):
        """Abort a spilled request: release the references its blob holds on
        still-resident shared pages (the last owner to drop frees them).
        ``rid`` lets tiered subclasses release per-request host accounting."""
        self.placement.unref([pid for _, pid in sp.shared])
        sp.shared = []

    # ------------------------------------------------------------------
    # the decode step
    # ------------------------------------------------------------------

    def _decode_impl(self, params, pools, bt, slabs, lengths, tokens, seed):
        """Block-table-native step: the layout="paged" SPU ops read pages
        and slab rows straight from the (donated) pools -- no gathered
        dense cache tree exists in the steady-state loop."""
        views = self.paging.paged_view(pools, bt, slabs, lengths)
        logits, new_views = M.paged_decode_step(
            params, cfg=self.cfg, tokens=tokens, caches=views,
            lengths=lengths, seed=seed, mesh_axes=self.mesh_axes)
        pools = self.paging.commit(pools, new_views, slabs)
        return logits, pools

    def _decode_spec_impl(self, params, pools, bt, slabs, lengths, tokens,
                          seed):
        """Speculative verify step: tokens (B, n) run through the paged
        caches in one pass; the per-position state snapshots ride back so
        ``commit_spec`` can roll rejected positions back bit-exactly."""
        views = self.paging.paged_view(pools, bt, slabs, lengths)
        logits, new_views, snaps = M.paged_spec_decode_step(
            params, cfg=self.cfg, tokens=tokens, caches=views,
            lengths=lengths, seed=seed, mesh_axes=self.mesh_axes)
        pools = self.paging.commit(pools, new_views, slabs)
        return logits, pools, snaps

    def _commit_spec_impl(self, pools, snaps, slabs, sel):
        return self.paging.commit_select(pools, snaps, slabs, sel)

    def _decode_gather_impl(self, params, pools, bt, slabs, lengths, tokens,
                            seed):
        """Dense-gather reference step (the pre-paged-kernel data path):
        materialize the context, run the dense ops, scatter one token back.
        Kept for bit-exact parity testing against the paged ops."""
        caches = self.paging.gather(pools, bt, slabs, lengths)
        logits, new_caches = M.decode_step(
            params, cfg=self.cfg, tokens=tokens, caches=caches,
            lengths=lengths, seed=seed, mesh_axes=self.mesh_axes)
        pools = self.paging.scatter_step(pools, new_caches, bt, slabs, lengths)
        return logits, pools

    def block_table(self, rids: Sequence[Optional[int]],
                    min_pages: int = 1) -> np.ndarray:
        """Dense (B, npg_bucket) block table; absent rows use scratch ids.

        ``min_pages`` floors the (pre-bucketing) width: the speculative
        verify step appends n rows per request, so its table must span
        ``pages_for(length + n)`` even when a garbage-padded row does not
        own that many pages yet -- those appends land on the scratch page,
        like idle rows' writes, and are never read back.
        """
        npg = max([len(self.page_table[r]) for r in rids if r is not None],
                  default=1)
        npg = bucket_pages(max(npg, min_pages))
        # rows dim is the fixed decode-batch width and the page dim is
        # power-of-2 bucketed, so the trace set is bounded by design
        bt = np.zeros((len(rids), npg), np.int32)  # lint: disable=JH103
        shadow = getattr(self.placement, "_shadow", None)
        if shadow is not None:   # PL254: every addressed page must be live
            shadow.check_live(
                {pid for r in rids if r is not None
                 for pid in self.page_table[r]},
                what=f"block table for rids {[r for r in rids if r is not None]}")
        for i, r in enumerate(rids):
            if r is not None:
                pages = self.page_table[r]
                bt[i, :len(pages)] = pages
        return bt

    def decode(self, params, rids: Sequence[Optional[int]],
               tokens: np.ndarray, lengths: np.ndarray, seed: int):
        """Run one batched decode step over ``rids`` (None = idle row) and
        commit the pools.  Returns logits (B, V) on device.

        ``decode_mode="paged"`` (default) runs the block-table-native ops in
        place over the donated pools; ``"gather"`` runs the dense-gather
        reference path (parity testing; old pool buffers stay valid).
        """
        bt = jnp.asarray(self.block_table(rids))
        slabs = jnp.asarray([self.slab_of.get(r, 0) if r is not None else 0
                             for r in rids], jnp.int32)
        step = self._decode if self.decode_mode == "paged" \
            else self._decode_gather
        logits, self.pools = step(
            params, self.pools, bt, slabs,
            jnp.asarray(lengths, jnp.int32), jnp.asarray(tokens, jnp.int32),
            jnp.int32(seed))
        return logits

    def decode_spec(self, params, rids: Sequence[Optional[int]],
                    tokens: np.ndarray, lengths: np.ndarray, seed: int,
                    min_pages: int = 1):
        """Run one speculative verify step: tokens (B, n) per row, logits
        (B, n, V) back, plus the snapshot tree for ``commit_spec``.

        Position i of every row runs with the seeds of the sequential
        decode step ``seed + i``, so its logits row is bit-identical to
        decoding that token in a normal step.  ``min_pages`` must span
        ``pages_for(length + n)`` over the batch (see :meth:`block_table`).
        """
        assert self.decode_mode == "paged", \
            "speculative decode requires the block-table-native path"
        bt = jnp.asarray(self.block_table(rids, min_pages=min_pages))
        slabs = jnp.asarray([self.slab_of.get(r, 0) if r is not None else 0
                             for r in rids], jnp.int32)
        logits, self.pools, snaps = self._decode_spec(
            params, self.pools, bt, slabs,
            jnp.asarray(lengths, jnp.int32), jnp.asarray(tokens, jnp.int32),
            jnp.int32(seed))
        return logits, snaps

    def commit_spec(self, rids: Sequence[Optional[int]], snaps,
                    sel: np.ndarray) -> None:
        """Roll recurrent state back to each row's last accepted position
        (``sel`` (B,), an index into the verify step's n positions).  KV
        needs no rollback -- the engine's host lengths mask rejected rows
        and later appends overwrite them."""
        slabs = jnp.asarray([self.slab_of.get(r, 0) if r is not None else 0
                             for r in rids], jnp.int32)
        self.pools = self._commit_spec(self.pools, snaps, slabs,
                                       jnp.asarray(sel, jnp.int32))

    # ------------------------------------------------------------------
    # accounting
    # ------------------------------------------------------------------

    @property
    def page_nbytes(self) -> int:
        return self.paging.page_nbytes

    @property
    def slab_nbytes(self) -> int:
        return self.paging.slab_nbytes

    def bytes_total(self) -> int:
        """Usable pool bytes (scratch page/slab excluded)."""
        return (self.usable_pages * self.page_nbytes
                + (self.n_slabs - 1) * self.slab_nbytes)

    def occupancy(self) -> float:
        """Fraction of usable pages currently pinned."""
        used = self.usable_pages - self.free_pages
        return used / max(self.usable_pages, 1)

    # ------------------------------------------------------------------
    # shadow-ledger sanitizer (REPRO_SANITIZE=1)
    # ------------------------------------------------------------------

    def sanitizer_owned_pages(self) -> set:
        """Every page some owner can still account for: resident request
        block tables here; tiered pools add staged prefetches and resident
        prefix-store nodes.  Spilled requests' shared pages are owned by
        the engine-held SpilledRequest, so teardown checks only run once
        the engine has fully drained."""
        return {pid for pages in self.page_table.values() for pid in pages}

    def sanitizer_check_leaks(self, what: str = "engine teardown") -> None:
        """``PL255``: raise if the shadow ledger sees live pages no owner
        accounts for.  No-op unless ``REPRO_SANITIZE=1`` attached a ledger."""
        shadow = getattr(self.placement, "_shadow", None)
        if shadow is not None:
            shadow.assert_no_leaks(self.sanitizer_owned_pages(), what=what)

    @property
    def shared_page_savings(self) -> int:
        """Physical pages currently saved by copy-on-write sharing: extra
        references beyond one owner per live page."""
        return self.placement.n_shared_extra

    @property
    def shared_savings_peak(self) -> int:
        """High-water mark of :attr:`shared_page_savings` -- survives
        request release, so end-of-run stats still show what sharing saved."""
        return self.placement.shared_extra_peak

    def fragmentation(self, lengths: Dict[int, int]) -> float:
        """1 - used_tokens / allocated_token_capacity over resident requests
        (internal fragmentation of the last partially-filled pages)."""
        alloc_tokens = sum(len(p) for p in self.page_table.values()) \
            * PAGE_TOKENS
        used_tokens = sum(lengths.get(r, 0) for r in self.page_table)
        if alloc_tokens == 0:
            return 0.0
        return 1.0 - used_tokens / alloc_tokens

    def bank_traffic(self, rids: Sequence[int]) -> np.ndarray:
        """Column bursts per (pseudo-channel, bank-pair) for one decode step
        over ``rids``: every resident page is streamed once (the paged
        attention ops read whole 128-token pages in place), every slab row
        is read+written by the paged state-update op.

        Bytes come from the ``layout="paged"`` ops' own ``traffic(plan)``
        descriptors (page-granular reads, one-slot writes) -- the same
        numbers the serving stats account -- so
        :func:`repro.core.pimsim.placement_step_latency` scores exactly the
        traffic the dispatched ops move.
        """
        burst = 32.0
        page_lists = [self.page_table[r] for r in rids if r in self.page_table]
        m = self.placement.traffic_map(page_lists,
                                       self._page_stream_bytes / burst)
        topo = self.placement.topo
        for r in rids:
            s = self.slab_of.get(r)
            if s is not None:
                m[topo.coord(s)] += self._slab_rw_bytes / burst
        return m
