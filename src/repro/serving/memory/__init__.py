"""Paged, bank-aware state/KV memory pool for the serving engine."""
from repro.serving.memory.layout import PAGE_TOKENS, CachePaging, LeafSpec
from repro.serving.memory.placement import BankAwarePlacement, BankTopology
from repro.serving.memory.pool import (PagedStatePool, SpilledRequest,
                                       bucket_pages, pages_for)
from repro.serving.memory.prefix_store import PrefixStore, StoredPage
from repro.serving.memory.tiered import HostTier, TieredStatePool

__all__ = [
    "PAGE_TOKENS", "CachePaging", "LeafSpec",
    "BankAwarePlacement", "BankTopology",
    "PagedStatePool", "SpilledRequest", "bucket_pages", "pages_for",
    "PrefixStore", "StoredPage", "HostTier", "TieredStatePool",
]
