"""Persistent radix-tree prefix index over full 128-token pages.

The serving engine's copy-on-write fork path (PR 5) only helps callers who
*explicitly* fork a :class:`~repro.serving.api.Session`.  Real serving traffic
shares prompts implicitly -- every request carries the same system prompt --
and the pool forgets those pages the moment the request that prefilled them
retires.  This module is the index that makes the sharing automatic: a radix
tree keyed by token ids, one node per *full* page (``PAGE_TOKENS`` tokens), so
a new request whose prompt extends a previously-served prefix can adopt the
stored pages with a refcount bump instead of re-prefilling them.

Design points:

  * Nodes only ever represent *immutable full pages*.  A partially-filled
    tail page is never inserted -- it is still being written by its request.
  * The tree is pure Python / numpy; it never touches jax.  The pool
    (:class:`~repro.serving.memory.tiered.TieredStatePool`) owns the device /
    host payloads and tells the store which node holds which page.
  * A node can be *resident* (``device_page`` set: the pool holds one
    placement reference on its behalf) or *demoted* (``host_blob`` set: the
    page payload lives in the host tier).  Both count against
    ``capacity_pages``.
  * Eviction is LRU over *leaf* nodes only -- evicting an interior node would
    orphan its descendants' token paths.  The pool additionally passes a
    ``locked`` predicate so pages still referenced by live requests or spill
    blobs are never evicted (refcount-aware eviction).
  * For recurrent / hybrid architectures bit-exactness needs more than KV
    pages: each node may also carry a host-side snapshot of the recurrent
    state *at the end of its page* (``state``), captured when the request
    that created the node crossed that page boundary.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

Chunk = Tuple[int, ...]


@dataclasses.dataclass(eq=False)
class StoredPage:
    """One radix-tree node == one immutable full page of a stored prefix."""
    chunk: Chunk                       # the PAGE_TOKENS token ids of this page
    depth: int                         # 1-based: prefix length = depth * PAGE_TOKENS
    parent: Optional["StoredPage"]
    node_id: int
    children: Dict[Chunk, "StoredPage"] = dataclasses.field(default_factory=dict)
    #: physical device page id when resident (store holds one placement ref)
    device_page: Optional[int] = None
    #: host-tier payload (list of numpy leaves) when demoted
    host_blob: Optional[object] = None
    #: CRC32 of ``host_blob`` recorded at demotion; promote verifies it,
    #: evicting the node on mismatch (a corrupt cache entry is a miss,
    #: never a poisoned hit)
    host_crc: Optional[int] = None
    #: host snapshot of the recurrent state at the *end* of this page; an
    #: empty list is valid (attention-only models have no slab leaves)
    state: Optional[object] = None
    last_used: int = 0

    @property
    def resident(self) -> bool:
        return self.device_page is not None

    @property
    def is_leaf(self) -> bool:
        return not self.children


class PrefixStore:
    """Radix tree of stored prefix pages with LRU, leaf-only eviction.

    The store tracks *which* prefixes are cached and in what tier; it never
    owns device memory directly.  ``capacity_pages`` bounds the total node
    count (resident + demoted) -- the pool calls :meth:`evict_candidates`
    and :meth:`remove` to enforce it, skipping locked nodes.
    """

    def __init__(self, capacity_pages: int, page_tokens: int = 128):
        if capacity_pages < 1:
            raise ValueError("capacity_pages must be >= 1")
        self.capacity_pages = capacity_pages
        self.page_tokens = page_tokens
        self._root: Dict[Chunk, StoredPage] = {}
        self._nodes: List[StoredPage] = []
        self._clock = itertools.count(1)
        self._ids = itertools.count(0)
        # counters (read by the pool / stats)
        self.inserts = 0
        self.evictions = 0

    # ------------- token helpers -------------

    def chunks(self, tokens: Sequence[int],
               max_pages: Optional[int] = None) -> List[Chunk]:
        """Split ``tokens`` into full-page chunks (partial tail dropped)."""
        n = len(tokens) // self.page_tokens
        if max_pages is not None:
            n = min(n, max_pages)
        return [tuple(int(t) for t in
                      tokens[i * self.page_tokens:(i + 1) * self.page_tokens])
                for i in range(n)]

    # ------------- lookup / insert -------------

    def match(self, chunks: Sequence[Chunk]) -> List[StoredPage]:
        """Longest stored path matching ``chunks`` front-to-back.

        Touches every matched node's LRU clock (a hit is a use)."""
        path: List[StoredPage] = []
        level = self._root
        for ch in chunks:
            node = level.get(ch)
            if node is None:
                break
            path.append(node)
            level = node.children
        self.touch(path)
        return path

    def extend(self, chunks: Sequence[Chunk]
               ) -> Tuple[List[StoredPage], List[StoredPage]]:
        """Walk/create the path for ``chunks``; returns (path, created)."""
        path: List[StoredPage] = []
        created: List[StoredPage] = []
        level = self._root
        parent: Optional[StoredPage] = None
        for depth, ch in enumerate(chunks, start=1):
            node = level.get(ch)
            if node is None:
                node = StoredPage(chunk=ch, depth=depth, parent=parent,
                                  node_id=next(self._ids))
                level[ch] = node
                self._nodes.append(node)
                created.append(node)
                self.inserts += 1
            path.append(node)
            parent = node
            level = node.children
        self.touch(path)
        return path, created

    def touch(self, nodes: Sequence[StoredPage]):
        tick = next(self._clock)
        for n in nodes:
            n.last_used = tick

    # ------------- eviction -------------

    @property
    def n_pages(self) -> int:
        return len(self._nodes)

    def over_capacity(self) -> int:
        return max(0, self.n_pages - self.capacity_pages)

    def lru_nodes(self) -> List[StoredPage]:
        return sorted(self._nodes, key=lambda n: n.last_used)

    def evict_candidates(
            self, locked: Optional[Callable[[StoredPage], bool]] = None
    ) -> List[StoredPage]:
        """Evictable leaves, LRU-first.  ``locked(node)`` True exempts it."""
        out = [n for n in self.lru_nodes() if n.is_leaf]
        if locked is not None:
            out = [n for n in out if not locked(n)]
        return out

    def remove(self, node: StoredPage):
        """Detach a *leaf* node from the tree.  Caller frees its payloads."""
        assert node.is_leaf, "only leaf nodes are evictable"
        level = self._root if node.parent is None else node.parent.children
        assert level.get(node.chunk) is node
        del level[node.chunk]
        self._nodes.remove(node)
        self.evictions += 1

    # ------------- introspection (tests / stats) -------------

    def nodes(self) -> List[StoredPage]:
        return list(self._nodes)

    def resident_pages(self) -> List[int]:
        return [n.device_page for n in self._nodes if n.resident]
