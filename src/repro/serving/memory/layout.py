"""Cache-tree paging adapter: maps the model's decode-cache pytree onto
page / slab pools and back.

The decode caches of a model are an arbitrary pytree of

  * ``KVCache`` nodes -- quantized (or plain) K/V streams with a **time
    axis** that grows with the context.  These are paged: the time axis is
    cut into 128-token, MX-tile-aligned pages and each page lives at a
    physical page id shared by every KV leaf (page id ``p`` indexes slice
    ``[p]`` of every KV pool array).
  * fixed-size recurrent-state leaves (``QuantizedTensor`` payloads or plain
    arrays: SSM states, conv tails, sLSTM carries).  These are slab
    allocated: one slab id per request indexes one row of every slab pool.

Axes are discovered **exactly**, not guessed: the layout is probed by
building abstract cache skeletons at (B=1,T=128), (B=2,T=128) and
(B=1,T=256) and diffing shapes -- the axis that moves with B is the batch
axis, the one that moves with T is the time axis.  Group-stacked leaves
((G, B, T, ...) from scan-over-layers) fall out of the same probe.

All gather/scatter functions are pure jnp and run inside jit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attention_cache as AC
from repro.core import formats as F
from repro.core import paged as PG
from repro.core.paged import PAGE_TOKENS  # noqa: F401  (canonical home moved)
from repro.ops.base import fmt_of_state


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """One pooled array leaf of the cache tree."""
    kind: str              # "page" | "slab"
    batch_axis: int        # in leaf coordinates (stacked layout)
    time_axis: int         # leaf coordinates; -1 for slabs
    shape: Tuple[int, ...]  # template leaf shape at B=1, T=PAGE_TOKENS
    dtype: Any

    @property
    def content_shape(self) -> Tuple[int, ...]:
        """Leaf shape with the batch axis removed (one page / one slab)."""
        s = list(self.shape)
        s.pop(self.batch_axis)
        return tuple(s)

    @property
    def content_time_axis(self) -> int:
        """Time axis position inside ``content_shape`` (pages only)."""
        assert self.kind == "page"
        return self.time_axis - (1 if self.batch_axis < self.time_axis else 0)

    @property
    def content_nbytes(self) -> int:
        return int(np.prod(self.content_shape)) * jnp.dtype(self.dtype).itemsize


def _is_array(x) -> bool:
    return isinstance(x, (jnp.ndarray, np.ndarray, jax.ShapeDtypeStruct))


def _diff_axis(a, b) -> int:
    """The single axis where shapes differ, or -1 if identical."""
    assert len(a.shape) == len(b.shape), (a.shape, b.shape)
    axes = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y]
    assert len(axes) <= 1, (a.shape, b.shape)
    return axes[0] if axes else -1


class CachePaging:
    """Flattens a model's cache tree into LeafSpecs and moves data between
    pooled storage and dense per-step cache pytrees."""

    def __init__(self, template, t_b2, t_t2):
        """``template`` is a *real* cache tree at (B=1, T=PAGE_TOKENS);
        ``t_b2``/``t_t2`` are abstract skeletons at (B=2, T) and (B, 2T)."""
        self.template = template
        self.specs: List[LeafSpec] = []
        self._build_specs(template, t_b2, t_t2, in_kv=False)

    # ------------------------------------------------------------------
    # traversal -- the one canonical order every operation below follows
    # ------------------------------------------------------------------

    def _build_specs(self, t, b2, t2, in_kv: bool):
        if t is None:
            return
        if isinstance(t, AC.KVCache):
            self._build_specs(t.k, b2.k, t2.k, in_kv=True)
            self._build_specs(t.v, b2.v, t2.v, in_kv=True)
            # lengths is reconstructed from the request lengths vector,
            # not pooled -- no spec.
            return
        if isinstance(t, F.QuantizedTensor):
            for f in sorted(t.payload):
                self._build_specs(t.payload[f], b2.payload[f], t2.payload[f],
                                  in_kv=in_kv)
            return
        if isinstance(t, dict):
            for k in sorted(t):
                self._build_specs(t[k], b2[k], t2[k], in_kv=in_kv)
            return
        if isinstance(t, (tuple, list)):
            for a, b, c in zip(t, b2, t2):
                self._build_specs(a, b, c, in_kv=in_kv)
            return
        assert _is_array(t), type(t)
        b_ax = _diff_axis(t, b2)
        t_ax = _diff_axis(t, t2)
        assert b_ax >= 0, f"cache leaf {t.shape} does not scale with batch"
        if in_kv:
            assert t_ax >= 0 and t_ax != b_ax, \
                f"KV leaf {t.shape} has no time axis"
            self.specs.append(LeafSpec("page", b_ax, t_ax,
                                       tuple(t.shape), t.dtype))
        else:
            assert t_ax == -1, f"state leaf {t.shape} scales with T"
            self.specs.append(LeafSpec("slab", b_ax, -1,
                                       tuple(t.shape), t.dtype))

    # ------------------------------------------------------------------
    # pools
    # ------------------------------------------------------------------

    def make_pools(self, n_pages: int, n_slabs: int) -> List[jnp.ndarray]:
        """One pool array per spec: (n_pages, *content) / (n_slabs, *content).

        Slab pools replicate the template's *initial* state content (e.g.
        sLSTM's ``m = -1e30`` carry), so a freshly pinned slab is a valid
        zero-context state even before prefill overwrites it.
        """
        pools = []
        it = iter(self._iter_template_leaves(self.template))
        for spec in self.specs:
            leaf = next(it)
            if spec.kind == "page":
                pools.append(jnp.zeros((n_pages,) + spec.content_shape,
                                       spec.dtype))
            else:
                content = jnp.squeeze(jnp.asarray(leaf), axis=spec.batch_axis)
                pools.append(jnp.broadcast_to(
                    content[None], (n_slabs,) + spec.content_shape
                ).astype(spec.dtype))
        return pools

    def _iter_template_leaves(self, t):
        """Array leaves in spec order (KVCache lengths skipped)."""
        if t is None:
            return
        if isinstance(t, AC.KVCache):
            yield from self._iter_template_leaves(t.k)
            yield from self._iter_template_leaves(t.v)
            return
        if isinstance(t, F.QuantizedTensor):
            for f in sorted(t.payload):
                yield from self._iter_template_leaves(t.payload[f])
            return
        if isinstance(t, dict):
            for k in sorted(t):
                yield from self._iter_template_leaves(t[k])
            return
        if isinstance(t, (tuple, list)):
            for a in t:
                yield from self._iter_template_leaves(a)
            return
        yield t

    @property
    def page_nbytes(self) -> int:
        """Device bytes one page occupies across every KV pool."""
        return sum(s.content_nbytes for s in self.specs if s.kind == "page")

    @property
    def slab_nbytes(self) -> int:
        return sum(s.content_nbytes for s in self.specs if s.kind == "slab")

    # ------------------------------------------------------------------
    # per-leaf moves (all jnp, jit-safe)
    # ------------------------------------------------------------------

    @staticmethod
    def _gather_page_leaf(pool, bt, spec: LeafSpec):
        """pool (P, *content), bt (B, npg) -> dense leaf (.., B, T, ..)."""
        ct = spec.content_time_axis
        g = pool[bt]                                   # (B, npg, *content)
        g = jnp.moveaxis(g, 1, 1 + ct)                 # (B, c.., npg, 128, ..)
        shape = (g.shape[:1 + ct]
                 + (g.shape[1 + ct] * g.shape[2 + ct],)
                 + g.shape[3 + ct:])
        g = g.reshape(shape)
        return jnp.moveaxis(g, 0, spec.batch_axis)

    @staticmethod
    def _gather_slab_leaf(pool, slabs, spec: LeafSpec):
        return jnp.moveaxis(pool[slabs], 0, spec.batch_axis)

    @staticmethod
    def _scatter_token_leaf(pool, dense, bt, pos, spec: LeafSpec):
        """Write back the single token row each request appended at ``pos``."""
        ct = spec.content_time_axis
        B = pos.shape[0]
        phys = bt[jnp.arange(B), pos // PAGE_TOKENS]
        off = pos % PAGE_TOKENS
        d = jnp.moveaxis(dense, (spec.batch_axis, spec.time_axis), (0, 1))
        vals = d[jnp.arange(B), pos]                   # (B, *rest)
        pm = jnp.moveaxis(pool, 1 + ct, 1)             # (P, 128, *rest)
        pm = pm.at[phys, off].set(vals)
        return jnp.moveaxis(pm, 1, 1 + ct)

    @staticmethod
    def _scatter_slab_leaf(pool, dense, slabs, spec: LeafSpec):
        vals = jnp.moveaxis(dense, spec.batch_axis, 0)
        return pool.at[slabs].set(vals)

    @staticmethod
    def _row_to_pages(row, spec: LeafSpec):
        """Row leaf (B=1 dense, T=npg*128) -> page stack (npg, 128, *rest)."""
        d = jnp.moveaxis(row, (spec.batch_axis, spec.time_axis), (0, 1))[0]
        npg = d.shape[0] // PAGE_TOKENS
        return d.reshape((npg, PAGE_TOKENS) + d.shape[1:])

    @staticmethod
    def _insert_pages_leaf(pool, pages_vals, page_ids, spec: LeafSpec):
        ct = spec.content_time_axis
        pm = jnp.moveaxis(pool, 1 + ct, 1)             # (P, 128, *rest)
        pm = pm.at[page_ids].set(pages_vals)
        return jnp.moveaxis(pm, 1, 1 + ct)

    @staticmethod
    def _extract_pages_leaf(pool, page_ids, spec: LeafSpec):
        ct = spec.content_time_axis
        pm = jnp.moveaxis(pool, 1 + ct, 1)
        return pm[page_ids]                            # (npg, 128, *rest)

    # ------------------------------------------------------------------
    # tree-level operations
    # ------------------------------------------------------------------

    def gather(self, pools: Sequence[jnp.ndarray], bt: jnp.ndarray,
               slabs: jnp.ndarray, lengths: jnp.ndarray):
        """Materialize the dense cache pytree for one decode step.

        bt (B, npg) physical page ids; slabs (B,); lengths (B,).
        Returns a cache tree structurally identical to the model's, with
        QuantizedTensor aux shapes patched to the gathered (B, T) so the
        MX kernels see the right logical geometry.
        """
        B = int(bt.shape[0])
        T = int(bt.shape[1]) * PAGE_TOKENS
        dense = []
        for pool, spec in zip(pools, self.specs):
            if spec.kind == "page":
                dense.append(self._gather_page_leaf(pool, bt, spec))
            else:
                dense.append(self._gather_slab_leaf(pool, slabs, spec))
        it = iter(dense)
        return self._rebuild(self.template, it, B, T, lengths)

    def _rebuild(self, t, it, B, T, lengths, in_kv=False, kv_time_axis=1):
        if t is None:
            return None
        if isinstance(t, AC.KVCache):
            k = self._rebuild(t.k, it, B, T, lengths, True, t.time_axis)
            v = self._rebuild(t.v, it, B, T, lengths, True, t.time_axis)
            ln = jnp.broadcast_to(
                lengths.astype(t.lengths.dtype),
                t.lengths.shape[:-1] + (B,))
            return AC.KVCache(k, v, ln, t.fmt, t.v_width, t.time_axis)
        if isinstance(t, F.QuantizedTensor):
            payload = {f: next(it) for f in sorted(t.payload)}
            shape = list(t.shape)
            shape[0] = B
            if in_kv:
                shape[kv_time_axis] = T
            return F.QuantizedTensor(t.fmt, tuple(shape), payload)
        if isinstance(t, dict):
            return {k: self._rebuild(t[k], it, B, T, lengths, in_kv,
                                     kv_time_axis)
                    for k in sorted(t)}
        if isinstance(t, tuple):
            return tuple(self._rebuild(a, it, B, T, lengths, in_kv,
                                       kv_time_axis) for a in t)
        if isinstance(t, list):
            return [self._rebuild(a, it, B, T, lengths, in_kv, kv_time_axis)
                    for a in t]
        return next(it)

    def _iter_cache_leaves(self, t):
        """Array leaves of a *dense cache tree* in spec order."""
        yield from self._iter_template_leaves(t)

    def scatter_step(self, pools: Sequence[jnp.ndarray], new_caches,
                     bt: jnp.ndarray, slabs: jnp.ndarray,
                     lengths: jnp.ndarray) -> List[jnp.ndarray]:
        """Commit one decode step: the appended KV token row goes to its
        page, recurrent slabs are rewritten in place."""
        out = []
        it = self._iter_cache_leaves(new_caches)
        for pool, spec in zip(pools, self.specs):
            dense = next(it)
            if spec.kind == "page":
                out.append(self._scatter_token_leaf(pool, dense, bt,
                                                    lengths, spec))
            else:
                out.append(self._scatter_slab_leaf(pool, dense, slabs, spec))
        return out

    def insert_request(self, pools: Sequence[jnp.ndarray], row_caches,
                       page_ids: jnp.ndarray, slab: jnp.ndarray
                       ) -> List[jnp.ndarray]:
        """Pin a prefilled B=1 cache row into freshly allocated pages+slab."""
        out = []
        it = self._iter_cache_leaves(row_caches)
        for pool, spec in zip(pools, self.specs):
            row = next(it)
            if spec.kind == "page":
                vals = self._row_to_pages(row, spec)
                out.append(self._insert_pages_leaf(pool, vals, page_ids, spec))
            else:
                vals = jnp.moveaxis(row, spec.batch_axis, 0)[0]
                out.append(pool.at[slab].set(vals))
        return out

    def extract_request(self, pools: Sequence[jnp.ndarray],
                        page_ids: jnp.ndarray, slab: jnp.ndarray
                        ) -> List[jnp.ndarray]:
        """Pull one request's pages+slab out of the pools (for host spill)."""
        out = []
        for pool, spec in zip(pools, self.specs):
            if spec.kind == "page":
                out.append(self._extract_pages_leaf(pool, page_ids, spec))
            else:
                out.append(pool[slab])
        return out

    def fork_copy(self, pools: Sequence[jnp.ndarray], src_page: jnp.ndarray,
                  dst_page: jnp.ndarray, src_slab: jnp.ndarray,
                  dst_slab: jnp.ndarray) -> List[jnp.ndarray]:
        """Copy-on-write fork: duplicate one physical page (the parent's
        partially filled tail -- the only page a forked child may later
        write inside) and the parent's slab row (recurrent state is mutated
        every step, so it is never shareable).  Full prefix pages are shared
        by reference, not touched here."""
        out = []
        for pool, spec in zip(pools, self.specs):
            if spec.kind == "page":
                out.append(pool.at[dst_page].set(pool[src_page]))
            else:
                out.append(pool.at[dst_slab].set(pool[src_slab]))
        return out

    def copy_slab(self, pools: Sequence[jnp.ndarray], src_slab: jnp.ndarray,
                  dst_slab: jnp.ndarray) -> List[jnp.ndarray]:
        """Fork at an exact page boundary: only the slab row is copied (the
        child's first append opens a fresh page of its own)."""
        out = []
        for pool, spec in zip(pools, self.specs):
            if spec.kind == "slab":
                out.append(pool.at[dst_slab].set(pool[src_slab]))
            else:
                out.append(pool)
        return out

    def insert_blob(self, pools: Sequence[jnp.ndarray], blob,
                    page_ids: jnp.ndarray, slab: jnp.ndarray
                    ) -> List[jnp.ndarray]:
        """Re-pin a spilled request (inverse of extract_request); the new
        physical page ids may differ from the ones it was evicted from."""
        out = []
        for pool, spec, vals in zip(pools, self.specs, blob):
            if spec.kind == "page":
                out.append(self._insert_pages_leaf(pool, jnp.asarray(vals),
                                                   page_ids, spec))
            else:
                out.append(pool.at[slab].set(jnp.asarray(vals)))
        return out

    def extract_pages(self, pools: Sequence[jnp.ndarray],
                      page_ids: jnp.ndarray) -> List[jnp.ndarray]:
        """Pull bare pages out of the page pools (no slab row) -- the unit of
        host-tier demotion for prefix-store nodes.  Returns one
        (npg, 128, *rest) array per *page* spec, in spec order."""
        out = []
        for pool, spec in zip(pools, self.specs):
            if spec.kind == "page":
                out.append(self._extract_pages_leaf(pool, page_ids, spec))
        return out

    def insert_pages(self, pools: Sequence[jnp.ndarray], blob,
                     page_ids: jnp.ndarray) -> List[jnp.ndarray]:
        """Re-pin bare pages (inverse of :meth:`extract_pages`); slab pools
        pass through untouched."""
        out = []
        it = iter(blob)
        for pool, spec in zip(pools, self.specs):
            if spec.kind == "page":
                out.append(self._insert_pages_leaf(pool, jnp.asarray(next(it)),
                                                   page_ids, spec))
            else:
                out.append(pool)
        return out

    def extract_slab(self, pools: Sequence[jnp.ndarray],
                     slab: jnp.ndarray) -> List[jnp.ndarray]:
        """Pull one slab row per *slab* spec (a recurrent-state snapshot)."""
        out = []
        for pool, spec in zip(pools, self.specs):
            if spec.kind == "slab":
                out.append(pool[slab])
        return out

    def insert_slab(self, pools: Sequence[jnp.ndarray], blob,
                    slab: jnp.ndarray) -> List[jnp.ndarray]:
        """Write a snapshot back into one slab row (inverse of
        :meth:`extract_slab`); page pools pass through untouched."""
        out = []
        it = iter(blob)
        for pool, spec in zip(pools, self.specs):
            if spec.kind == "slab":
                out.append(pool.at[slab].set(jnp.asarray(next(it))))
            else:
                out.append(pool)
        return out

    # ------------------------------------------------------------------
    # block-table-native views (the steady-state decode path)
    # ------------------------------------------------------------------
    #
    # paged_view / commit replace gather / scatter_step in the decode loop:
    # KV pools become PagedKVCache views (zero-copy -- the group-axis
    # normalization is a reshape) that the layout="paged" SPU ops walk via
    # the block table; recurrent "S" leaves become PagedState slab views the
    # paged state_update op updates in place; only the small residual slab
    # leaves (conv tails, sLSTM carries) are gathered/scattered as B rows --
    # which is the minimal traffic, since every step rewrites them anyway.

    @staticmethod
    def _norm_groups(pool: jnp.ndarray, n_lead: int):
        """(n, *lead, *rest) -> ((n, G, *rest), lead): fold the group-stack
        axes into one.  A reshape, never a copy."""
        lead = pool.shape[1:1 + n_lead]
        g = 1
        for d in lead:
            g *= d
        return pool.reshape((pool.shape[0], g) + pool.shape[1 + n_lead:]), lead

    def _view_stream(self, t, take):
        """Template KV/state stream -> pool-backed stream + lead shape."""
        if t is None:
            return None, ()
        if isinstance(t, F.QuantizedTensor):
            payload, lead = {}, ()
            for f in sorted(t.payload):
                pool, spec = take()
                n_lead = (spec.content_time_axis if spec.kind == "page"
                          else len(spec.content_shape) - 3)
                payload[f], lead = self._norm_groups(pool, n_lead)
            return F.QuantizedTensor(t.fmt, tuple(payload["mantissa"].shape),
                                     payload), lead
        pool, spec = take()
        n_lead = (spec.content_time_axis if spec.kind == "page"
                  else len(spec.content_shape) - 3)
        return self._norm_groups(pool, n_lead)

    def paged_view(self, pools: Sequence[jnp.ndarray], bt: jnp.ndarray,
                   slabs: jnp.ndarray, lengths: jnp.ndarray):
        """Build the paged cache-view tree for one decode step (zero-copy
        for KV pages and recurrent states; B-row gathers for residual slab
        leaves).  Structure matches the model's cache tree."""
        it = iter(zip(pools, self.specs))
        take = lambda: next(it)
        group0 = jnp.int32(0)

        def walk(t):
            if t is None:
                return None
            if isinstance(t, AC.KVCache):
                k, lead = self._view_stream(t.k, take)
                v, _ = self._view_stream(t.v, take)
                return PG.PagedKVCache(k, v, bt, lengths, group0,
                                       t.fmt, t.v_width, tuple(lead))
            if isinstance(t, dict):
                out = {}
                for key in sorted(t):
                    if key == "S":
                        s, lead = self._view_stream(t[key], take)
                        fmt = (t[key].fmt
                               if isinstance(t[key], F.QuantizedTensor)
                               else fmt_of_state(t[key]))
                        out[key] = PG.PagedState(s, slabs, group0, fmt,
                                                 tuple(lead))
                    else:
                        out[key] = walk(t[key])
                return out
            if isinstance(t, (tuple, list)):
                return tuple(walk(a) for a in t)
            # residual slab leaf: must be a plain array -- a quantized leaf
            # outside a KVCache / "S" slot would expand to several specs and
            # silently misalign the pool iterator, so fail loudly instead
            assert _is_array(t), \
                f"paged_view: unsupported residual cache leaf {type(t)}"
            pool, spec = take()
            return self._gather_slab_leaf(pool, slabs, spec)

        return walk(self.template)

    def _commit_stream(self, stream, take):
        """Updated pool-backed stream -> pool arrays in spec order."""
        out = []
        if stream is None:
            return out
        arrays = ([stream.payload[f] for f in sorted(stream.payload)]
                  if isinstance(stream, F.QuantizedTensor) else [stream])
        for arr in arrays:
            _, spec = take()
            out.append(arr.reshape((arr.shape[0],) + spec.content_shape))
        return out

    def commit(self, pools: Sequence[jnp.ndarray], new_caches,
               slabs: jnp.ndarray) -> List[jnp.ndarray]:
        """Commit one paged decode step: unwrap the (already updated) KV and
        state pools from the view containers and scatter the residual slab
        rows back.  The inverse traversal of :meth:`paged_view`."""
        it = iter(zip(pools, self.specs))
        take = lambda: next(it)
        out: List[jnp.ndarray] = []

        def walk(t, c):
            if t is None:
                return
            if isinstance(t, AC.KVCache):
                out.extend(self._commit_stream(c.k, take))
                out.extend(self._commit_stream(c.v, take))
                return
            if isinstance(t, dict):
                for key in sorted(t):
                    if key == "S":
                        out.extend(self._commit_stream(c[key].pool, take))
                    else:
                        walk(t[key], c[key])
                return
            if isinstance(t, (tuple, list)):
                for a, b in zip(t, c):
                    walk(a, b)
                return
            pool, spec = take()
            out.append(self._scatter_slab_leaf(pool, c, slabs, spec))

        walk(self.template, new_caches)
        return out

    def commit_select(self, pools: Sequence[jnp.ndarray], snaps,
                      slabs: jnp.ndarray, sel: jnp.ndarray
                      ) -> List[jnp.ndarray]:
        """Roll every slab row back to one selected speculative position.

        ``snaps`` is the snapshot tree a ``paged_spec_decode_step`` returns:
        it mirrors the cache tree, with every recurrent-state leaf stacked
        position-major to ``(n, B, *row)`` (``None`` under attention
        elements -- KV rollback is a host-side length reset, so page pools
        pass through untouched).  ``sel`` (B,) picks, per request, the last
        accepted position; row b of every slab pool is rewritten with
        ``snap[sel[b], b]``.  Requests that accepted every position rewrite
        their final state verbatim, so running this after :meth:`commit`
        is idempotent for them.
        """
        it = iter(zip(pools, self.specs))
        take = lambda: next(it)
        B = int(slabs.shape[0])
        bidx = jnp.arange(B)
        out: List[jnp.ndarray] = []

        def skip(t):
            for _ in self._iter_template_leaves(t):
                pool, _ = take()
                out.append(pool)

        def put(snap_leaf):
            pool, spec = take()
            assert spec.kind == "slab", \
                "snapshot leaf aligned with a page spec"
            vals = snap_leaf[sel, bidx]            # (B, *row)
            out.append(pool.at[slabs].set(
                vals.reshape((B,) + spec.content_shape)))

        def walk(t, s):
            if t is None:
                return
            if s is None or isinstance(t, AC.KVCache):
                skip(t)
                return
            if isinstance(t, F.QuantizedTensor):
                for f in sorted(t.payload):
                    put(s[f])
                return
            if isinstance(t, dict):
                for key in sorted(t):
                    walk(t[key], s[key])
                return
            if isinstance(t, (tuple, list)):
                for a, b in zip(t, s):
                    walk(a, b)
                return
            put(s)

        walk(self.template, snaps)
        return out
