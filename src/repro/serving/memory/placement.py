"""Bank-aware page placement for the paged state/KV pool.

Pimba puts one SPU per two DRAM banks and interleaves accesses between the
bank pair (paper Fig. 8), so *where* a page lands -- which pseudo-channel and
which bank pair -- decides whether a decode step's traffic pipelines cleanly
or serializes on a hot bank pair.  The placement policy here mirrors that
argument in software:

  * every physical page id has a static (pseudo-channel, bank-pair)
    coordinate, striped channel-first so consecutive ids land on different
    pseudo-channels (the widest parallelism axis);
  * allocation is load-aware: among coordinates that still have free pages,
    pick the one with the least *live* allocated pages, so the concurrent
    traffic of a decode batch spreads across SPUs instead of piling onto one
    bank pair.

The resulting page map is what :mod:`repro.core.pimsim` scores with
``placement_step_latency`` -- real allocations instead of idealized uniform
traffic.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class BankTopology:
    """The coordinate space pages are placed into.

    Defaults are one HBM device's worth of Pimba SPUs: 16 pseudo-channels,
    16 banks each => 8 bank pairs per pseudo-channel (paper Table 1).
    """
    pseudo_channels: int = 16
    bank_pairs: int = 8

    @property
    def n_coords(self) -> int:
        return self.pseudo_channels * self.bank_pairs

    def coord(self, page_id: int) -> Tuple[int, int]:
        """Static page id -> (pseudo-channel, bank-pair), channel-striped."""
        return (page_id % self.pseudo_channels,
                (page_id // self.pseudo_channels) % self.bank_pairs)


class BankAwarePlacement:
    """Free-page bookkeeping with load-balanced, bank-aware allocation.

    Page id 0 is reserved as the scratch page that inactive decode rows write
    into; it is never handed out.
    """

    def __init__(self, n_pages: int, topo: Optional[BankTopology] = None,
                 reserved: Sequence[int] = (0,)):
        self.topo = topo or BankTopology()
        self.n_pages = n_pages
        self.reserved = frozenset(reserved)
        self._free: Dict[Tuple[int, int], Deque[int]] = {}
        for pid in range(n_pages):
            if pid in self.reserved:
                continue
            self._free.setdefault(self.topo.coord(pid), deque()).append(pid)
        # live allocated-page count per coordinate (the balance target)
        self._live = np.zeros(
            (self.topo.pseudo_channels, self.topo.bank_pairs), np.int64)
        self._n_free = n_pages - len(self.reserved)
        # copy-on-write sharing: physical page id -> reference count.  A page
        # leaves the free list with one reference; forked requests take extra
        # references on a parent's immutable full pages; the page returns to
        # the free list only when the last owner drops it.
        self._refs: Dict[int, int] = {}
        self._extra_peak = 0
        #: optional repro.obs MetricsRegistry -- when attached (via
        #: ``PagedStatePool.attach_obs``) alloc/free/ref mirror into
        #: ``pages_alloc_total`` / ``pages_freed_total`` /
        #: ``page_refs_total`` counters and the ``pages_live`` gauge
        self.metrics = None
        #: shadow-ledger sanitizer (``REPRO_SANITIZE=1``): an independent
        #: refcount mirror that raises SanitizerError on double-free,
        #: ref-on-free, free-with-sharers, double-alloc, use-after-evict,
        #: and teardown leaks.  Lazy import: runtime.py is stdlib-only and
        #: must not be paid for when the sanitizer is off.
        self._shadow = None
        import os as _os
        if _os.environ.get("REPRO_SANITIZE", "").strip() not in \
                ("", "0", "false"):
            from repro.analysis.lint import runtime as _rt
            _rt.attach(self)

    # ------------- allocation -------------

    @property
    def n_free(self) -> int:
        return self._n_free

    @property
    def n_usable(self) -> int:
        return self.n_pages - len(self.reserved)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Allocate ``n`` pages from the least-loaded bank pairs, or None."""
        if n > self._n_free:
            return None
        out: List[int] = []
        for _ in range(n):
            best = min((c for c, dq in self._free.items() if dq),
                       key=lambda c: (int(self._live[c]), c))
            out.append(self._free[best].popleft())
            self._live[best] += 1
        self._n_free -= n
        for pid in out:
            self._refs[pid] = 1
        if self._shadow is not None:
            self._shadow.on_alloc(out)
        if self.metrics is not None:
            self.metrics.counter("pages_alloc_total").inc(n)
            self.metrics.gauge("pages_live").set(self.n_usable - self._n_free)
        return out

    def ref(self, pages: Sequence[int]):
        """Take one extra (copy-on-write) reference on each page."""
        if self._shadow is not None:
            self._shadow.on_ref(pages)
        for pid in pages:
            assert self._refs.get(pid, 0) >= 1, f"ref on free page {pid}"
            self._refs[pid] += 1
        self._extra_peak = max(self._extra_peak, self.n_shared_extra)
        if self.metrics is not None:
            self.metrics.counter("page_refs_total").inc(len(pages))

    def refcount(self, pid: int) -> int:
        return self._refs.get(pid, 0)

    def unref(self, pages: Sequence[int]) -> List[int]:
        """Drop one reference per page; pages whose count hits zero return to
        the free list.  Returns the page ids actually freed."""
        if self._shadow is not None:
            self._shadow.pre_unref(pages)
        freed: List[int] = []
        for pid in pages:
            n = self._refs[pid] - 1
            if n > 0:
                self._refs[pid] = n
                continue
            del self._refs[pid]
            c = self.topo.coord(pid)
            self._free[c].append(pid)
            self._live[c] -= 1
            freed.append(pid)
        self._n_free += len(freed)
        if self._shadow is not None:
            self._shadow.on_unref(pages, freed)
        if self.metrics is not None and freed:
            self.metrics.counter("pages_freed_total").inc(len(freed))
            self.metrics.gauge("pages_live").set(self.n_usable - self._n_free)
        return freed

    # back-compat alias: pre-refcount callers freed unconditionally; with
    # single-owner pages (refcount 1) unref is exactly the old free
    free = unref

    @property
    def n_shared_extra(self) -> int:
        """Extra references beyond one owner per live page -- the number of
        physical pages copy-on-write sharing is currently saving."""
        return sum(self._refs.values()) - len(self._refs)

    @property
    def shared_extra_peak(self) -> int:
        """High-water mark of :attr:`n_shared_extra` over the pool's life."""
        return self._extra_peak

    # ------------- accounting -------------

    def live_map(self) -> np.ndarray:
        """(pseudo_channels, bank_pairs) live allocated-page counts."""
        return self._live.copy()

    def traffic_map(self, page_lists: Sequence[Sequence[int]],
                    bursts_per_page: float) -> np.ndarray:
        """Column bursts per (pch, bank-pair) for one decode step.

        ``page_lists`` is one list of physical page ids per active request --
        a decode step streams every resident page of every active request
        (KV attention reads the whole context).
        """
        m = np.zeros((self.topo.pseudo_channels, self.topo.bank_pairs))
        for pages in page_lists:
            for pid in pages:
                m[self.topo.coord(pid)] += bursts_per_page
        return m

    def imbalance(self) -> float:
        """max/mean live load across bank pairs (1.0 == perfectly even)."""
        mean = self._live.mean()
        if mean == 0:
            return 1.0
        return float(self._live.max() / mean)
