"""repro.ops -- the unified SPU operator subsystem.

One registry-dispatched decode-op interface for attention and state updates
(paper §4: both are the same memory-bound op class, served by one SPU).
See ``repro/ops/base.py`` for the plan/execute/traffic contract and
``repro/ops/registry.py`` for dispatch and capability negotiation.

Typical call sites::

    from repro import ops as OPS

    # state-update families (Mamba-2 / GLA / RetNet / HGRN2 / mLSTM)
    Sn, y = OPS.state_update_step(S, d, k, v, q, cfg.state_quant, seed=seed)

    # attention decode (GQA and MLA, paged and contiguous caches)
    out, cache = OPS.attention_decode_step(cache, k_new, v_new, q,
                                           cfg.state_quant, seed=seed)

    # cost models / benchmarks: the ops' own byte counts
    for entry in OPS.decode_op_plans(cfg, batch, seq_len):
        entry.traffic.state_read  # etc.
"""
# NOTE: import order matters -- base and registry first (no repro deps
# beyond core.formats), then the op implementations (which register
# themselves on import; dense before paged, the paged ops delegate to the
# dense kernels on gathered rows), then the model-level traffic bridge.
from repro.ops.base import (LAYOUTS, OpPlan, SpuDeprecationWarning, SpuOp,
                            StateQuantConfig, TrafficBytes, fmt_bits,
                            fmt_of_state)
from repro.ops.registry import (BACKEND_PREFERENCE, OP_KINDS, backends_for,
                                execute, get_op, plan, register, registered,
                                resolve_backend, supports, traffic)
from repro.ops.state_update import (StateLike, init_state,
                                    plan_state_update,
                                    plan_state_update_dims, state_nbytes,
                                    state_update_float, state_update_step)
from repro.ops.attention import (attention_decode_step, attn_decode,
                                 attn_kind_of, kv_append,
                                 plan_attn_decode_dims)
import repro.ops.paged_ops  # noqa: F401  (registers the paged-layout ops)
from repro.ops.spec_verify import (attention_spec_step, spec_attend)
from repro.core.paged import PagedKVCache, PagedState
from repro.ops.model_traffic import (OpTrafficEntry, decode_op_plans,
                                     decode_traffic_by_kind)

__all__ = [
    "LAYOUTS", "OpPlan", "SpuDeprecationWarning", "SpuOp", "StateQuantConfig",
    "TrafficBytes", "fmt_bits", "fmt_of_state",
    "BACKEND_PREFERENCE", "OP_KINDS", "backends_for", "execute", "get_op",
    "plan", "register", "registered", "resolve_backend", "supports",
    "traffic",
    "StateLike", "init_state", "plan_state_update", "plan_state_update_dims",
    "state_nbytes", "state_update_float", "state_update_step",
    "attention_decode_step", "attn_decode", "attn_kind_of", "kv_append",
    "plan_attn_decode_dims",
    "attention_spec_step", "spec_attend",
    "PagedKVCache", "PagedState",
    "OpTrafficEntry", "decode_op_plans", "decode_traffic_by_kind",
]
