"""The generalized state-update operator (paper Eq. 2) as registered SpuOps.

    S_t = d_t ⊙ S_{t-1} + k_t v_tᵀ ;   y_t = S_tᵀ q_t

Storage layout for the resident state is ``(B, H, dv, dk)`` (Sᵀ) with MX
groups along dk; see ``kernels/mx_state_update.py`` for why.  Two backends:

* ``pallas`` -- the fused kernel (``interpret=True`` on CPU; compiled
  natively on real TPUs).  MX8 only.
* ``jnp``    -- mathematically identical pure-jnp path for every storage
  format (bitwise identical packed state for MX8).  This is what the
  multi-pod dry-run lowers: interpret-mode pallas would trace its grid as an
  unrolled Python loop and distort cost analysis.

The plan/execute/traffic split (see ``repro.ops.base``) keeps the cost
models honest: ``traffic(plan)`` is *the* byte count for an invocation --
``core/pimsim.py`` and ``analysis/roofline.py`` consume it directly.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple, Union

import jax.numpy as jnp

from repro.core import formats as F
from repro.kernels import ref as _ref
from repro.kernels.mx_state_update import mx_state_update as _su_pallas
from repro.ops import registry
from repro.ops.base import (OPERAND_BYTES, OUTPUT_BYTES, OpPlan, SpuOp,
                            StateQuantConfig, TrafficBytes, fmt_bits,
                            fmt_of_state)

StateLike = Union[F.QuantizedTensor, jnp.ndarray]

_FLOAT_DTYPES = {"fp32": jnp.float32, "bf16": jnp.bfloat16, "fp16": jnp.float16}


# ---------------------------------------------------------------------------
# state containers
# ---------------------------------------------------------------------------

def init_state(B: int, H: int, dk: int, dv: int,
               cfg: StateQuantConfig) -> StateLike:
    """Zero-initialized recurrent state, stored layout (B, H, dv, dk)."""
    zeros = jnp.zeros((B, H, dv, dk), jnp.float32)
    if not cfg.quantized:
        return zeros.astype(_FLOAT_DTYPES[cfg.fmt])
    return F.quantize(zeros, cfg.fmt)


def state_nbytes(B: int, H: int, dk: int, dv: int, cfg: StateQuantConfig) -> float:
    """Logical storage bytes of one layer's state (bandwidth accounting)."""
    p = plan_state_update_dims(B, H, dk, dv, cfg)
    return registry.traffic(p).state_read


# ---------------------------------------------------------------------------
# op implementations
# ---------------------------------------------------------------------------

class _StateUpdateBase(SpuOp):
    kind = "state_update"

    def traffic(self, plan: OpPlan) -> TrafficBytes:
        B, H = plan.dim("B"), plan.dim("H")
        dk, dv = plan.dim("dk"), plan.dim("dv")
        state = B * H * dk * dv * plan.bits_per_val / 8.0
        # d/k/q are (B,H,dk), v is (B,H,dv); y is (B,H,dv) f32
        operands = B * H * (3 * dk + dv) * OPERAND_BYTES
        out = B * H * dv * OUTPUT_BYTES
        return TrafficBytes(state_read=state, state_write=state,
                            operand_read=operands, output_write=out)


@registry.register
class StateUpdatePallas(_StateUpdateBase):
    """Fused MX8 state update (quant + decay + outer + GEMV in one kernel)."""
    backend = "pallas"
    formats = ("mx8",)

    def execute(self, state, inputs: Dict[str, Any],
                plan: OpPlan) -> Tuple[StateLike, jnp.ndarray]:
        return _su_pallas(state, inputs["d"], inputs["k"], inputs["v"],
                          inputs["q"],
                          jnp.asarray(inputs.get("seed", 0), jnp.int32),
                          rounding=plan.rounding, interpret=True)


@registry.register
class StateUpdateJnp(_StateUpdateBase):
    """Pure-jnp reference semantics for every storage format."""
    backend = "jnp"
    formats = ("mx8", "int8", "fp8_e4m3", "fp8_e5m2", "fp32", "bf16", "fp16")

    def execute(self, state, inputs: Dict[str, Any],
                plan: OpPlan) -> Tuple[StateLike, jnp.ndarray]:
        d, k, v, q = inputs["d"], inputs["k"], inputs["v"], inputs["q"]
        seed = inputs.get("seed", 0)
        if not isinstance(state, F.QuantizedTensor):
            return state_update_float(state, d, k, v, q, dtype=state.dtype)
        if state.fmt == "mx8":
            return _ref.quantized_state_update_stored_ref(
                state, d, k, v, q, rounding=plan.rounding, seed=seed)
        # int8 / fp8 paths: dequant -> update -> requant reference semantics
        B, H, dv, dk = state.shape
        St = F.dequantize(state)
        d_ = jnp.broadcast_to(d.astype(jnp.float32), (B, H, dk))[:, :, None, :]
        Sn = St * d_ + (v.astype(jnp.float32)[..., :, None]
                        * k.astype(jnp.float32)[..., None, :])
        bits = (F.sr_bits(Sn.shape, seed)
                if plan.rounding == "stochastic" else None)
        qSn = F.quantize(Sn, state.fmt, plan.rounding, bits)
        y = jnp.einsum("bhvk,bhk->bhv", F.dequantize(qSn), q.astype(jnp.float32))
        return qSn, y


def state_update_float(S: jnp.ndarray, d, k, v, q,
                       dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Unquantized baseline (the paper's "GPU" fp16 configuration).

    State layout (B, H, dv, dk) to match the quantized path.
    """
    St = S.astype(jnp.float32)
    d_ = jnp.broadcast_to(d.astype(jnp.float32), St.shape[:2] + St.shape[-1:])
    Sn = St * d_[:, :, None, :] + (v.astype(jnp.float32)[..., :, None]
                                   * k.astype(jnp.float32)[..., None, :])
    y = jnp.einsum("bhvk,bhk->bhv", Sn, q.astype(jnp.float32))
    return Sn.astype(dtype), y


# ---------------------------------------------------------------------------
# call-site entry points
# ---------------------------------------------------------------------------

def plan_state_update_dims(B: int, H: int, dk: int, dv: int,
                           cfg: StateQuantConfig, *, layout: str = "dense",
                           strict: bool = False) -> OpPlan:
    """Plan one Eq. 2 invocation from explicit dims (cost-model entry)."""
    return registry.plan("state_update", dict(B=B, H=H, dk=dk, dv=dv),
                         cfg, cfg.backend, layout=layout, strict=strict)


def plan_state_update(state, cfg: StateQuantConfig) -> OpPlan:
    """Plan from a live state container; format and layout come from the
    container (a ``PagedState`` slab view dispatches the paged op, which
    updates the owned slab rows in place)."""
    from repro.core.paged import PagedState
    if isinstance(state, PagedState):
        B, H, dv, dk = state.shape
        quant = StateQuantConfig(fmt=state.fmt, rounding=cfg.rounding,
                                 backend=cfg.backend)
        return plan_state_update_dims(B, H, dk, dv, quant, layout="paged")
    B, H, dv, dk = state.shape
    quant = StateQuantConfig(fmt=fmt_of_state(state), rounding=cfg.rounding,
                             backend=cfg.backend)
    return plan_state_update_dims(B, H, dk, dv, quant)


def state_update_step(state: StateLike, d: jnp.ndarray, k: jnp.ndarray,
                      v: jnp.ndarray, q: jnp.ndarray, cfg: StateQuantConfig,
                      seed=0) -> Tuple[StateLike, jnp.ndarray]:
    """One decode step of Eq. 2: plan + dispatch through the registry.

    d: (B,H,dk) or (B,H,1); k,q: (B,H,dk); v: (B,H,dv)  ->  y: (B,H,dv) f32.
    """
    p = plan_state_update(state, cfg)
    return registry.execute(state, {"d": d, "k": k, "v": v, "q": q,
                                    "seed": seed}, p)
